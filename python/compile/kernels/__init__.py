"""L1: Pallas kernels for the paper's compute hot-spot (the convolutions)."""

from .conv2d import conv2d, conv2d_fwd, conv2d_wgrad, conv2d_xgrad
from .pool import maxpool2
from .ref import conv2d_ref, lrn_ref, maxpool2_ref

__all__ = [
    "conv2d",
    "conv2d_fwd",
    "conv2d_wgrad",
    "conv2d_xgrad",
    "maxpool2",
    "conv2d_ref",
    "lrn_ref",
    "maxpool2_ref",
]
