"""Pallas 2x2/stride-2 max-pooling kernel (forward).

Used on the inference/eval path (no gradient needed there); the training
graph pools via the differentiable reshape-max in ``model.py`` so autodiff
stays in plain jnp.  Checked against ``ref.maxpool2_ref`` by pytest.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["maxpool2"]


def _maxpool2_kernel(x_ref, o_ref):
    x = x_ref[0]  # [C, H, W]
    c, h, w = x.shape
    blocks = x.reshape(c, h // 2, 2, w // 2, 2)
    o_ref[...] = blocks.max(axis=(2, 4))[None]


def maxpool2(x: jax.Array) -> jax.Array:
    """Non-overlapping 2x2 max pool with stride 2 over NCHW input."""
    bsz, c, h, w = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2 requires even spatial dims, got {h}x{w}")
    return pl.pallas_call(
        _maxpool2_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h // 2, w // 2), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, c, h // 2, w // 2), jnp.float32),
        interpret=True,
    )(x)
