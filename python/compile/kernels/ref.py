"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest asserts kernel == ref (and jax.grad(kernel) == jax.grad(ref)) over
hypothesis-swept shapes; nothing in this module is ever exported to HLO.
"""

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["conv2d_ref", "maxpool2_ref", "lrn_ref"]


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Valid stride-1 cross-correlation via lax.conv_general_dilated."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def maxpool2_ref(x: jax.Array) -> jax.Array:
    """2x2 max pooling, stride 2 (paper's 'pooling layer, with stride 2')."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def lrn_ref(
    x: jax.Array, *, n: int = 5, k: float = 2.0, alpha: float = 1e-4, beta: float = 0.75
) -> jax.Array:
    """AlexNet-style local response normalization across channels.

    The paper's architecture interleaves a 'normalization layer' after each
    convolution; LRN is the standard choice for that slot in 2017-era CNNs.
    """
    sq = x * x
    half = n // 2
    # Sum sq over a window of `n` adjacent channels, zero-padded.
    padded = jnp.pad(sq, ((0, 0), (half, n - 1 - half), (0, 0), (0, 0)))
    window = sum(padded[:, i : i + x.shape[1]] for i in range(n))
    return x / jnp.power(k + alpha * window, beta)
