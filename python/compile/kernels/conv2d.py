"""Pallas 2-D convolution (valid, stride 1, NCHW) — the paper's hot spot.

The paper (Marques et al., 2017) distributes exactly this operation: the
convolutional layers account for 60-90% of CNN training time, forward AND
backward, so both directions are implemented as Pallas kernels here:

  * ``conv2d_fwd``   y[b,k]  = sum_c  x[b,c]  * w[k,c]          (valid corr.)
  * ``conv2d_wgrad`` gw[k,c] = sum_b  x[b,c]  * gy[b,k]         (valid corr.)
  * ``conv2d_xgrad`` gx[b,c] = sum_k  pad(gy)[b,k] * flip(w)[c,k] (full corr.)

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's CUDA
mapping assigns one threadblock per output tile.  On a systolic-array target
the win comes from phrasing the whole operation as ONE MXU-shaped GEMM: the
kernel builds the im2col matrix ``[C*KH*KW, BT*OH*OW]`` in VMEM and issues a
single ``[K, C*KH*KW] @ [C*KH*KW, N]`` contraction.  Contracting over
C*KH*KW (75 for the paper's 5x5 RGB layer) instead of per-offset C keeps the
systolic array fed even for shallow layers — the per-offset formulation ran
~10x slower on layer 1 (C=3) because a 3-deep inner dimension cannot fill
the pipeline (§Perf in EXPERIMENTS.md records the before/after).

BlockSpec tiles the batch so the input block plus its im2col expansion fit
the VMEM budget; ``interpret=True`` everywhere because the CPU PJRT plugin
cannot execute Mosaic custom-calls — interpret mode lowers to portable HLO
which both pytest and the rust runtime execute bit-identically.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d", "conv2d_fwd", "conv2d_wgrad", "conv2d_xgrad", "batch_tile"]

# Per-tile scratch budget.  A real TPU build would set this to ~12 MiB (VMEM
# minus headroom), giving smaller batch tiles; under CPU interpret mode the
# "VMEM" is just an XLA buffer and grid steps cost interpreter overhead, so
# the budget is raised to keep CIFAR-scale batches in one grid step.  The
# TPU sizing arithmetic is documented in DESIGN.md §Hardware-Adaptation.
VMEM_BUDGET_BYTES = 64 * (1 << 20)


def _im2col(x: jax.Array, oh: int, ow: int, kh: int, kw: int) -> jax.Array:
    """[BT,C,H,W] -> [KH*KW*C, BT*OH*OW] patch matrix ((ki,kj)-major rows)."""
    bt, c, _, _ = x.shape
    n = bt * oh * ow
    cols = []
    for ki in range(kh):
        for kj in range(kw):
            cols.append(
                x[:, :, ki : ki + oh, kj : kj + ow].transpose(1, 0, 2, 3).reshape(c, n)
            )
    return jnp.concatenate(cols, axis=0)


def _w_as_gemm(w: jax.Array) -> jax.Array:
    """[K,C,KH,KW] -> [K, KH*KW*C], row order matching :func:`_im2col`."""
    k = w.shape[0]
    return w.transpose(0, 2, 3, 1).reshape(k, -1)


# Channel depth below which the per-offset contraction cannot fill the
# vector/systolic pipeline and the full-im2col GEMM wins despite its 25x
# patch-matrix traffic (conv layer 1 on RGB: C=3).
SHALLOW_C = 8


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int):
    """One batch tile: o[BT,K,OH,OW] = conv(x, w) + b.

    Two execution strategies (chosen statically at trace time):
    * deep input (C >= SHALLOW_C): accumulate one `[K,C] @ [C,N]` GEMM per
      filter offset — no patch-matrix materialization, so the cost of the
      kernel-sharded executables actually scales with K (the property the
      paper's Eq. 1 partitioning relies on);
    * shallow input: single `[K, KH*KW*C] @ [KH*KW*C, N]` GEMM over the
      materialized im2col matrix, because a C=3 inner dimension starves the
      pipeline (measured 10x slowdown — EXPERIMENTS.md §Perf).
    """
    bt, c, _, _ = x_ref.shape
    _, k, oh, ow = o_ref.shape
    n = bt * oh * ow
    x = x_ref[...]
    if c >= SHALLOW_C:
        acc = jnp.zeros((k, n), jnp.float32)
        for ki in range(kh):
            for kj in range(kw):
                patch = (
                    x[:, :, ki : ki + oh, kj : kj + ow].transpose(1, 0, 2, 3).reshape(c, n)
                )
                acc = acc + w_ref[:, :, ki, kj] @ patch
    else:
        colmat = _im2col(x, oh, ow, kh, kw)  # [KH*KW*C, N]
        acc = _w_as_gemm(w_ref[...]) @ colmat
    out = (acc + b_ref[...][:, None]).reshape(k, bt, oh, ow)
    o_ref[...] = out.transpose(1, 0, 2, 3)


def batch_tile(bsz: int, c: int, h: int, w: int, kh: int, kw: int) -> int:
    """Largest batch tile whose input block + im2col expansion fits the
    scratch budget (and divides the batch so every grid step is full)."""
    per_image = (1 + kh * kw) * c * h * w * 4
    tile = max(1, VMEM_BUDGET_BYTES // max(per_image, 1))
    tile = min(tile, bsz)
    while bsz % tile:
        tile -= 1
    return tile


def conv2d_fwd(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Valid stride-1 convolution (cross-correlation), NCHW/OIHW -> NCHW."""
    bsz, c, h, wdt = x.shape
    k, wc, kh, kw = w.shape
    if wc != c:
        raise ValueError(f"channel mismatch: x has {c}, w has {wc}")
    if b.shape != (k,):
        raise ValueError(f"bias must be [{k}], got {b.shape}")
    oh, ow = h - kh + 1, wdt - kw + 1
    if oh <= 0 or ow <= 0:
        raise ValueError(f"kernel {kh}x{kw} larger than input {h}x{wdt}")
    bt = batch_tile(bsz, c, h, wdt, kh, kw)
    return pl.pallas_call(
        partial(_fwd_kernel, kh=kh, kw=kw),
        grid=(bsz // bt,),
        in_specs=[
            pl.BlockSpec((bt, c, h, wdt), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, c, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, k, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k, oh, ow), jnp.float32),
        interpret=True,
    )(x, w, b)


def _wgrad_kernel(x_ref, gy_ref, gw_ref, gb_ref, *, kh: int, kw: int):
    """gw[K,C,ki,kj] = sum_{b,oh,ow} x[b,c,oh+ki,ow+kj] * gy[b,k,oh,ow].

    Same im2col matrix as the forward pass, transposed GEMM:
    ``[K, N] @ [N, KH*KW*C]``.
    """
    bsz, c, _, _ = x_ref.shape
    _, k, oh, ow = gy_ref.shape
    n = bsz * oh * ow
    gy = gy_ref[...]
    gy_mat = gy.transpose(1, 0, 2, 3).reshape(k, n)  # [K, N]
    x = x_ref[...]
    # Per-offset [K,N] @ [N,C] contractions: N is always large, so the
    # pipeline stays fed without materializing the im2col matrix, and the
    # GEMM cost scales with the shard's K.
    for ki in range(kh):
        for kj in range(kw):
            patch = x[:, :, ki : ki + oh, kj : kj + ow].transpose(1, 0, 2, 3).reshape(c, n)
            gw_ref[:, :, ki, kj] = gy_mat @ patch.T
    gb_ref[...] = gy.sum(axis=(0, 2, 3))


def conv2d_wgrad(x: jax.Array, gy: jax.Array, kh: int, kw: int):
    """Gradients w.r.t. the kernels and bias of :func:`conv2d_fwd`."""
    bsz, c, h, wdt = x.shape
    gb, k, oh, ow = gy.shape
    if gb != bsz:
        raise ValueError(f"batch mismatch: x has {bsz}, gy has {gb}")
    if (oh, ow) != (h - kh + 1, wdt - kw + 1):
        raise ValueError(f"gy spatial {oh}x{ow} inconsistent with {h}x{wdt} conv {kh}x{kw}")
    return pl.pallas_call(
        partial(_wgrad_kernel, kh=kh, kw=kw),
        out_shape=(
            jax.ShapeDtypeStruct((k, c, kh, kw), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ),
        interpret=True,
    )(x, gy)


def conv2d_xgrad(w: jax.Array, gy: jax.Array) -> jax.Array:
    """Gradient w.r.t. the input: full correlation of gy with flipped kernels.

    Expressed as the *same* Pallas forward kernel with the roles of the
    channel axes swapped — gx = conv_fwd(pad(gy), flip(w)^T) — so the one
    kernel body covers both propagation directions.
    """
    k, c, kh, kw = w.shape
    gyp = jnp.pad(gy, ((0, 0), (0, 0), (kh - 1, kh - 1), (kw - 1, kw - 1)))
    # [C, K, KH, KW], spatially flipped.
    wt = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)
    return conv2d_fwd(gyp, wt, jnp.zeros((c,), jnp.float32))


@jax.custom_vjp
def conv2d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable valid conv2d; every direction runs the Pallas kernels."""
    return conv2d_fwd(x, w, b)


def _conv2d_vjp_fwd(x, w, b):
    return conv2d_fwd(x, w, b), (x, w)


def _conv2d_vjp_bwd(res, gy):
    x, w = res
    _, _, kh, kw = w.shape
    gw, gb = conv2d_wgrad(x, gy, kh, kw)
    gx = conv2d_xgrad(w, gy)
    return gx, gw, gb


conv2d.defvjp(_conv2d_vjp_fwd, _conv2d_vjp_bwd)
