"""AOT pipeline: lower every model segment to HLO *text* + write manifest.json.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts [--arch k1:k2]
                                       [--batch N] [--img N]

The manifest records, for every executable, its file, argument names/shapes/
dtypes and output names/shapes/dtypes; the rust runtime is driven entirely by
the manifest and never hard-codes a shape.
"""

import argparse
import hashlib
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = "f32"
I32 = "i32"
_DTYPES = {F32: jnp.float32, I32: jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the rust
    side unwraps with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Emitter:
    """Lowers named segments and accumulates manifest entries."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(
        self,
        name: str,
        fn: Callable,
        args: Sequence[tuple],  # (arg_name, shape, dtype)
        outs: Sequence[tuple],  # (out_name, shape, dtype)
        flops: int = 0,
    ) -> None:
        specs = [jax.ShapeDtypeStruct(tuple(s), _DTYPES[d]) for _, s, d in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries[name] = {
            "file": fname,
            "args": [[n, list(s), d] for n, s, d in args],
            "outs": [[n, list(s), d] for n, s, d in outs],
            # Nominal FLOPs of one execution — drives the virtual-time
            # device emulation (devices::Throttle::Virtual) and §Perf
            # roofline estimates.
            "flops": int(flops),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name:28s} {len(text):>9d} chars")


# Probe workload (fixed across experiments — see model.probe_config).
PROBE_BATCH, PROBE_CH, PROBE_IMG, PROBE_K = (
    M.PROBE_BATCH,
    M.PROBE_CH,
    M.PROBE_IMG,
    M.PROBE_K,
)


def conv_fwd_flops(batch: int, kb: int, cin: int, hout: int) -> int:
    """2 * B * K * OH^2 * C * KH * KW (one multiply-add per tap)."""
    return 2 * batch * kb * hout * hout * cin * M.KH * M.KW


def build_all(cfg: M.ArchConfig, out_dir: str, legacy_config: bool = False) -> dict:
    em = Emitter(out_dir)
    B, C0, IMG = cfg.batch, cfg.in_ch, cfg.img
    c1o, p1o, c2o, p2o = cfg.c1_out, cfg.p1_out, cfg.c2_out, cfg.p2_out

    # --- conv shard executables (the distributed hot path) ----------------
    layer_specs = [
        ("conv1", C0, IMG, c1o, cfg.buckets1),
        ("conv2", cfg.k1, p1o, c2o, cfg.buckets2),
    ]
    for lname, cin, hin, hout, buckets in layer_specs:
        for kb in buckets:
            x_s = ("x", (B, cin, hin, hin), F32)
            w_s = ("w", (kb, cin, M.KH, M.KW), F32)
            b_s = ("b", (kb,), F32)
            y_s = ("y", (B, kb, hout, hout), F32)
            gy_s = ("gy", (B, kb, hout, hout), F32)
            fwd_fl = conv_fwd_flops(B, kb, cin, hout)
            em.emit(f"{lname}_fwd_b{kb}", M.conv_fwd_seg, [x_s, w_s, b_s], [y_s],
                    flops=fwd_fl)
            em.emit(
                f"{lname}_bwd_b{kb}",
                M.conv_bwd_seg,
                [x_s, w_s, gy_s],
                [
                    ("gx", (B, cin, hin, hin), F32),
                    ("gw", (kb, cin, M.KH, M.KW), F32),
                    ("gb", (kb,), F32),
                ],
                # wgrad + xgrad are each another conv of the same volume.
                flops=2 * fwd_fl,
            )

    # --- master-resident segments ------------------------------------------
    for lname, k, hout, pout in [("mid1", cfg.k1, c1o, p1o), ("mid2", cfg.k2, c2o, p2o)]:
        y_s = ("y", (B, k, hout, hout), F32)
        p_s = ("p", (B, k, pout, pout), F32)
        # LRN+pool: ~20 flops per activation (window sum, powers, division).
        mid_fl = 20 * B * k * hout * hout
        em.emit(f"{lname}_fwd", M.mid_fwd_seg, [y_s], [p_s], flops=mid_fl)
        em.emit(
            f"{lname}_bwd",
            M.mid_bwd_seg,
            [y_s, ("gp", (B, k, pout, pout), F32)],
            [("gy", (B, k, hout, hout), F32)],
            flops=2 * mid_fl,
        )

    p2_s = ("p2", (B, cfg.k2, p2o, p2o), F32)
    wf_s = ("wf", (cfg.fc_in, cfg.num_classes), F32)
    bf_s = ("bf", (cfg.num_classes,), F32)
    lab_s = ("labels", (B,), I32)
    head_fl = 2 * B * cfg.fc_in * cfg.num_classes
    em.emit(
        "head_grad",
        M.head_grad_seg,
        [p2_s, wf_s, bf_s, lab_s],
        [
            ("loss", (), F32),
            ("gp2", (B, cfg.k2, p2o, p2o), F32),
            ("gwf", (cfg.fc_in, cfg.num_classes), F32),
            ("gbf", (cfg.num_classes,), F32),
        ],
        flops=3 * head_fl,
    )
    em.emit("head_eval", M.head_eval_seg, [p2_s, wf_s, bf_s],
            [("logits", (B, cfg.num_classes), F32)], flops=head_fl)

    # --- fused full-network executables (baselines) -------------------------
    pshapes = M.param_shapes(cfg)
    param_args = [(n, pshapes[n], F32) for n in M.PARAM_NAMES]
    grad_outs = [("loss", (), F32)] + [
        (f"g{n}", pshapes[n], F32) for n in M.PARAM_NAMES
    ]
    def full_fwd_flops(bb):
        return (
            conv_fwd_flops(bb, cfg.k1, C0, c1o)
            + conv_fwd_flops(bb, cfg.k2, cfg.k1, c2o)
            + 20 * bb * (cfg.k1 * c1o * c1o + cfg.k2 * c2o * c2o)
            + 2 * bb * cfg.fc_in * cfg.num_classes
        )

    for bb in cfg.batch_buckets:
        em.emit(
            f"grad_full_b{bb}",
            M.grad_full_seg,
            [("x", (bb, C0, IMG, IMG), F32), ("labels", (bb,), I32)] + param_args,
            grad_outs,
            flops=3 * full_fwd_flops(bb),
        )
    em.emit(
        "eval_full",
        M.eval_full_seg,
        [("x", (B, C0, IMG, IMG), F32)] + param_args,
        [("logits", (B, cfg.num_classes), F32)],
        flops=full_fwd_flops(B),
    )

    # --- calibration probe (paper §4.1.1) -----------------------------------
    em.emit(
        "probe",
        M.probe_seg,
        [
            ("x", (PROBE_BATCH, PROBE_CH, PROBE_IMG, PROBE_IMG), F32),
            ("w", (PROBE_K, PROBE_CH, M.KH, M.KW), F32),
            ("b", (PROBE_K,), F32),
        ],
        [("y", (PROBE_BATCH, PROBE_K, PROBE_IMG - M.KH + 1, PROBE_IMG - M.KW + 1), F32)],
        flops=conv_fwd_flops(PROBE_BATCH, PROBE_K, PROBE_CH, PROBE_IMG - M.KH + 1),
    )

    # The manifest's config block: the layer-graph schema by default (what
    # rust's ArchSpec::from_json parses natively and re-derives geometry
    # from); --legacy-config keeps the pre-graph k1/k2 form, which rust
    # loads by conversion.
    config = M.legacy_config(cfg) if legacy_config else M.graph_config(cfg)
    manifest = {
        "version": 1,
        "config": config,
        "executables": em.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--arch", default="32:64", help="k1:k2 kernel counts")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument(
        "--legacy-config",
        action="store_true",
        help="emit the pre-graph k1/k2 manifest config schema",
    )
    args = ap.parse_args()
    cfg = M.ArchConfig.parse(args.arch, batch=args.batch, img=args.img)
    print(f"AOT: arch {cfg.k1}:{cfg.k2} batch={cfg.batch} img={cfg.img} -> {args.out}")
    manifest = build_all(cfg, args.out, legacy_config=args.legacy_config)
    n = len(manifest["executables"])
    print(f"wrote {n} executables + manifest.json")


if __name__ == "__main__":
    main()
