"""L2: the paper's CNN as JAX segment functions calling the Pallas kernels.

Architecture (paper §5.2, CIFAR-10):

    conv 5x5 (C1) -> LRN -> maxpool /2 -> conv 5x5 (C2) -> LRN -> maxpool /2
    -> fully connected -> softmax loss

The network is cut into the exact segments the distributed runtime needs
(DESIGN.md §3): the conv layers — the part the paper distributes — are their
own fwd/bwd executables parameterised by the *kernel-shard* size, while the
LRN+pool "mid" blocks and the FC+softmax "head" stay on the master.  Every
segment is a pure function exported to HLO text by ``aot.py``; composing the
segments must reproduce ``grad_full`` exactly, which pytest asserts.
"""

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels import conv2d, maxpool2

KH = KW = 5  # paper: 5x5 kernels in both conv layers
POOL = 2  # paper: pooling stride 2


# --------------------------------------------------------------------------
# Architecture description
# --------------------------------------------------------------------------


def bucket_ladder(k: int, steps: int = 8) -> List[int]:
    """Shard-size buckets for a conv layer with `k` kernels.

    HLO executables have static shapes but Eq. 1 assigns data-dependent shard
    sizes, so the partitioner rounds every shard up to the nearest bucket and
    zero-pads.  Eighths of `k`, rounded up to a multiple of 4, bound padding
    waste by ~12.5% worst-case.
    """
    raw = sorted({-(-k * i // steps) for i in range(1, steps + 1)})
    buckets = sorted({min(k, -(-r // 4) * 4) for r in raw})
    assert buckets[-1] == k
    return buckets


@dataclass
class ArchConfig:
    """Shapes of one experiment architecture (paper notation 'k1:k2')."""

    k1: int = 16
    k2: int = 32
    batch: int = 64
    img: int = 32
    in_ch: int = 3
    num_classes: int = 10

    # Derived spatial sizes (valid conv, /2 pool), e.g. 32->28->14->10->5.
    @property
    def c1_out(self) -> int:
        return self.img - KH + 1

    @property
    def p1_out(self) -> int:
        return self.c1_out // POOL

    @property
    def c2_out(self) -> int:
        return self.p1_out - KH + 1

    @property
    def p2_out(self) -> int:
        return self.c2_out // POOL

    @property
    def fc_in(self) -> int:
        return self.k2 * self.p2_out * self.p2_out

    buckets1: List[int] = field(default_factory=list)
    buckets2: List[int] = field(default_factory=list)
    batch_buckets: List[int] = field(default_factory=list)

    def __post_init__(self):
        if self.c1_out % POOL or self.c2_out % POOL:
            raise ValueError(f"architecture {self.k1}:{self.k2} img={self.img} "
                             "does not pool evenly")
        if not self.buckets1:
            self.buckets1 = bucket_ladder(self.k1)
        if not self.buckets2:
            self.buckets2 = bucket_ladder(self.k2)
        if not self.batch_buckets:
            bb = {self.batch}
            b = self.batch
            while b % 2 == 0 and b > max(2, self.batch // 8):
                b //= 2
                bb.add(b)
            self.batch_buckets = sorted(bb)

    @classmethod
    def parse(cls, spec: str, batch: int = 64, img: int = 32) -> "ArchConfig":
        """Parse the paper's 'k1:k2' notation, e.g. '500:1500'."""
        k1, k2 = (int(p) for p in spec.split(":"))
        return cls(k1=k1, k2=k2, batch=batch, img=img)


# --------------------------------------------------------------------------
# Manifest `config` block emission
# --------------------------------------------------------------------------

# Probe workload is fixed across every experiment so performance ratios are
# comparable between devices (paper §4.1.1 runs the same N-d convolution on
# every node).
PROBE_BATCH, PROBE_CH, PROBE_IMG, PROBE_K = 16, 3, 32, 32


def probe_config() -> Dict:
    """The calibration-probe block shared by both manifest schemas."""
    return {
        "batch": PROBE_BATCH,
        "in_ch": PROBE_CH,
        "img": PROBE_IMG,
        "k": PROBE_K,
        "kh": KH,
        "kw": KW,
        # FLOPs of one probe execution (2*MACs), used to convert the
        # measured probe time into a GFLOPS performance value.
        "flops": 2 * PROBE_BATCH * PROBE_K * PROBE_CH
        * (PROBE_IMG - KH + 1) ** 2 * KH * KW,
    }


def layer_graph(cfg: ArchConfig) -> List[Dict]:
    """The two-conv paper network as an ordered layer-graph op list — the
    schema the rust side's ``ArchSpec::from_json`` parses natively."""
    return [
        {"op": "conv", "k": cfg.k1, "kh": KH, "kw": KW},
        {"op": "lrn"},
        {"op": "maxpool2"},
        {"op": "conv", "k": cfg.k2, "kh": KH, "kw": KW},
        {"op": "lrn"},
        {"op": "maxpool2"},
        {"op": "fc", "out": cfg.num_classes},
        {"op": "softmax_xent"},
    ]


def graph_config(cfg: ArchConfig) -> Dict:
    """Manifest ``config`` block in the layer-graph schema (PR 4's IR).

    Derived geometry (spatial chain, param shapes, fc_in) is *not* emitted:
    the rust side re-derives it by shape inference, so the two pipelines
    cannot silently disagree.  The bucket ladders and the probe are emitted
    as overrides because they are contract, not derivation.
    """
    return {
        "layers": layer_graph(cfg),
        "batch": cfg.batch,
        "img": cfg.img,
        "in_ch": cfg.in_ch,
        "batch_buckets": cfg.batch_buckets,
        "buckets": [cfg.buckets1, cfg.buckets2],
        "probe": probe_config(),
    }


def legacy_config(cfg: ArchConfig) -> Dict:
    """The pre-graph ``k1``/``k2`` schema with spelled-out derived geometry
    (kept behind ``aot.py --legacy-config``; rust still loads it by
    conversion and cross-checks every pinned value)."""
    pshapes = param_shapes(cfg)
    probe = probe_config()
    probe.pop("kh"), probe.pop("kw")  # the legacy probe had no kernel geometry
    return {
        "k1": cfg.k1,
        "k2": cfg.k2,
        "batch": cfg.batch,
        "img": cfg.img,
        "in_ch": cfg.in_ch,
        "num_classes": cfg.num_classes,
        "kh": KH,
        "kw": KW,
        "c1_out": cfg.c1_out,
        "p1_out": cfg.p1_out,
        "c2_out": cfg.c2_out,
        "p2_out": cfg.p2_out,
        "fc_in": cfg.fc_in,
        "buckets1": cfg.buckets1,
        "buckets2": cfg.buckets2,
        "batch_buckets": cfg.batch_buckets,
        "param_shapes": {n: list(pshapes[n]) for n in PARAM_NAMES},
        "param_order": list(PARAM_NAMES),
        "probe": probe,
    }


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def lrn(x: jax.Array, n: int = 5, k: float = 2.0, alpha: float = 1e-4,
        beta: float = 0.75) -> jax.Array:
    """Differentiable LRN (same math as kernels.ref.lrn_ref)."""
    sq = x * x
    half = n // 2
    padded = jnp.pad(sq, ((0, 0), (half, n - 1 - half), (0, 0), (0, 0)))
    window = sum(padded[:, i : i + x.shape[1]] for i in range(n))
    return x / jnp.power(k + alpha * window, beta)


def pool2(x: jax.Array) -> jax.Array:
    """Differentiable 2x2/stride-2 max pool (reshape-max; jax handles vjp)."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def mid_segment(y: jax.Array) -> jax.Array:
    """The master-resident block between a conv layer and the next: LRN+pool."""
    return pool2(lrn(y))


def head_logits(p2: jax.Array, wf: jax.Array, bf: jax.Array) -> jax.Array:
    return p2.reshape(p2.shape[0], -1) @ wf + bf


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def head_loss(p2, wf, bf, labels):
    return softmax_xent(head_logits(p2, wf, bf), labels)


# --------------------------------------------------------------------------
# Full network (params as a flat tuple so HLO arg order is self-evident)
# --------------------------------------------------------------------------

PARAM_NAMES = ("w1", "b1", "w2", "b2", "wf", "bf")


def param_shapes(cfg: ArchConfig) -> Dict[str, tuple]:
    return {
        "w1": (cfg.k1, cfg.in_ch, KH, KW),
        "b1": (cfg.k1,),
        "w2": (cfg.k2, cfg.k1, KH, KW),
        "b2": (cfg.k2,),
        "wf": (cfg.fc_in, cfg.num_classes),
        "bf": (cfg.num_classes,),
    }


def forward(params, x):
    """Full forward pass: logits."""
    w1, b1, w2, b2, wf, bf = params
    p1 = mid_segment(conv2d(x, w1, b1))
    p2 = mid_segment(conv2d(p1, w2, b2))
    return head_logits(p2, wf, bf)


def loss_full(params, x, labels):
    return softmax_xent(forward(params, x), labels)


# --------------------------------------------------------------------------
# Exported segment functions.  Flat-arg signatures only (HLO interchange).
# --------------------------------------------------------------------------


def conv_fwd_seg(x, w, b):
    """Worker executable: conv a kernel shard. -> (y,)"""
    return (conv2d(x, w, b),)


def conv_bwd_seg(x, w, gy):
    """Worker executable: shard backward. -> (gx_partial, gw, gb).

    gx is *partial* — the master sums the gx of every shard (conv is linear
    in the kernels, so sharding the K axis shards gx additively).
    """
    _, vjp = jax.vjp(lambda xx, ww, bb: conv2d(xx, ww, bb), x, w,
                     jnp.zeros((w.shape[0],), jnp.float32))
    gx, gw, gb = vjp(gy)
    return gx, gw, gb


def mid_fwd_seg(y):
    """Master executable: LRN + pool. -> (p,)"""
    return (mid_segment(y),)


def mid_bwd_seg(y, gp):
    """Master executable: vjp of LRN + pool (recompute-in-bwd). -> (gy,)"""
    _, vjp = jax.vjp(mid_segment, y)
    (gy,) = vjp(gp)
    return (gy,)


def head_grad_seg(p2, wf, bf, labels):
    """Master executable: loss + grads wrt (p2, wf, bf). -> (loss, gp2, gwf, gbf)"""
    loss, grads = jax.value_and_grad(head_loss, argnums=(0, 1, 2))(p2, wf, bf, labels)
    return (loss,) + grads


def head_eval_seg(p2, wf, bf):
    """Master executable: logits for accuracy eval (uses the Pallas pool on
    the way in, so the eval path exercises maxpool2 end-to-end)."""
    return (head_logits(p2, wf, bf),)


def grad_full_seg(x, labels, w1, b1, w2, b2, wf, bf):
    """Single-device / data-parallel executable: full fused fwd+bwd.
    -> (loss, gw1, gb1, gw2, gb2, gwf, gbf)
    """
    params = (w1, b1, w2, b2, wf, bf)
    loss, grads = jax.value_and_grad(loss_full)(params, x, labels)
    return (loss,) + tuple(grads)


def eval_full_seg(x, w1, b1, w2, b2, wf, bf):
    """Inference executable: logits for the full network.

    The eval path routes pooling through the Pallas ``maxpool2`` kernel
    (training uses the differentiable jnp pool).
    """
    p1 = maxpool2(lrn(conv2d(x, w1, b1)))
    p2 = maxpool2(lrn(conv2d(p1, w2, b2)))
    return (head_logits(p2, wf, bf),)


def probe_seg(x, w, b):
    """Calibration probe (paper §4.1.1): the 'quick test' every device runs
    so the master can compute Eq. 1 performance ratios. -> (y,)"""
    return (conv2d(x, w, b),)
