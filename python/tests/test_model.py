"""L2 correctness: the exported segments must compose into the full model.

The distributed runtime (rust) chains conv/mid/head segment executables;
these tests prove, in pure JAX, that the *same functions* the AOT pipeline
exports compose to the fused `grad_full` — i.e. the distributed step is
mathematically identical to single-device training.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def make_cfg(k1=4, k2=6, batch=2):
    return M.ArchConfig(k1=k1, k2=k2, batch=batch)


def make_inputs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    shapes = M.param_shapes(cfg)
    params = tuple(
        jnp.asarray(rng.standard_normal(shapes[n]) * 0.1, jnp.float32) for n in M.PARAM_NAMES
    )
    x = jnp.asarray(
        rng.standard_normal((cfg.batch, cfg.in_ch, cfg.img, cfg.img)), jnp.float32
    )
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, cfg.batch), jnp.int32)
    return params, x, labels


def test_spatial_chain():
    cfg = make_cfg()
    assert (cfg.c1_out, cfg.p1_out, cfg.c2_out, cfg.p2_out) == (28, 14, 10, 5)
    assert cfg.fc_in == cfg.k2 * 25


def test_bucket_ladder_properties():
    for k in [5, 16, 32, 50, 500, 1500]:
        ladder = M.bucket_ladder(k)
        assert ladder[-1] == k
        assert ladder == sorted(set(ladder))
        # Any shard size 1..k fits in a bucket with <= max(4/k, ~18%) waste.
        for n in range(1, k + 1):
            b = min(x for x in ladder if x >= n)
            assert b - n <= max(4, -(-k // 8)), (k, n, b)


def test_arch_parse():
    cfg = M.ArchConfig.parse("500:1500", batch=1024)
    assert (cfg.k1, cfg.k2, cfg.batch) == (500, 1500, 1024)
    with pytest.raises(ValueError):
        M.ArchConfig(k1=4, k2=4, img=31)  # does not pool evenly


def test_segment_forward_composition_equals_full():
    cfg = make_cfg()
    params, x, labels = make_inputs(cfg)
    w1, b1, w2, b2, wf, bf = params
    # Chain the exported segments exactly as the rust master does.
    (y1,) = M.conv_fwd_seg(x, w1, b1)
    (p1,) = M.mid_fwd_seg(y1)
    (y2,) = M.conv_fwd_seg(p1, w2, b2)
    (p2,) = M.mid_fwd_seg(y2)
    (logits,) = M.head_eval_seg(p2, wf, bf)
    want = M.forward(params, x)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_segment_backward_composition_equals_grad_full():
    """Full distributed backward chain == fused jax.grad."""
    cfg = make_cfg()
    params, x, labels = make_inputs(cfg, seed=1)
    w1, b1, w2, b2, wf, bf = params

    # Forward chain with residuals.
    (y1,) = M.conv_fwd_seg(x, w1, b1)
    (p1,) = M.mid_fwd_seg(y1)
    (y2,) = M.conv_fwd_seg(p1, w2, b2)
    (p2,) = M.mid_fwd_seg(y2)
    # Head grad.
    loss, gp2, gwf, gbf = M.head_grad_seg(p2, wf, bf, labels)
    # Backward chain.
    (gy2,) = M.mid_bwd_seg(y2, gp2)
    gp1, gw2, gb2 = M.conv_bwd_seg(p1, w2, gy2)
    (gy1,) = M.mid_bwd_seg(y1, gp1)
    _, gw1, gb1 = M.conv_bwd_seg(x, w1, gy1)

    ref = M.grad_full_seg(x, labels, *params)
    ref_loss, rgw1, rgb1, rgw2, rgb2, rgwf, rgbf = ref
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    for got, want, name in [
        (gw1, rgw1, "gw1"),
        (gb1, rgb1, "gb1"),
        (gw2, rgw2, "gw2"),
        (gb2, rgb2, "gb2"),
        (gwf, rgwf, "gwf"),
        (gbf, rgbf, "gbf"),
    ]:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-5, err_msg=name
        )


@settings(max_examples=5, deadline=None, print_blob=True)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_sharded_conv_bwd_sums_to_full(k1, k2, seed):
    """Kernel-sharded backward: gx partials sum to the full gx, and gw/gb
    shards concatenate to the full gradients — the linearity the rust master
    relies on when gathering (dist_conv_bwd)."""
    cfg = make_cfg(k1=k1, k2=k2)
    params, x, _ = make_inputs(cfg, seed=seed)
    w1, b1, *_ = params
    rng = np.random.default_rng(seed + 1)
    gy = jnp.asarray(
        rng.standard_normal((cfg.batch, k1, cfg.c1_out, cfg.c1_out)), jnp.float32
    )
    full_gx, full_gw, full_gb = M.conv_bwd_seg(x, w1, gy)
    cut = max(1, k1 // 2)
    gx_a, gw_a, gb_a = M.conv_bwd_seg(x, w1[:cut], gy[:, :cut])
    gx_b, gw_b, gb_b = M.conv_bwd_seg(x, w1[cut:], gy[:, cut:])
    # Tolerances are scaled to the gradient magnitudes: gw accumulates
    # B*OH*OW float32 products, so absolute error grows with the reduction.
    def close(got, want, name):
        got, want = np.asarray(got), np.asarray(want)
        scale = max(np.abs(want).max(), 1.0)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * scale, err_msg=name)

    close(gx_a + gx_b, full_gx, "gx")
    close(np.concatenate([gw_a, gw_b]), full_gw, "gw")
    close(np.concatenate([gb_a, gb_b]), full_gb, "gb")


def test_loss_decreases_under_sgd():
    """A few fused-gradient steps must reduce the loss on a fixed batch —
    the python-side sanity check behind the e2e rust example."""
    cfg = make_cfg(k1=4, k2=6, batch=8)
    params, x, labels = make_inputs(cfg, seed=2)
    params = list(params)
    first = None
    lr = 0.1
    for _ in range(20):
        out = M.grad_full_seg(x, labels, *params)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        params = [p - lr * g for p, g in zip(params, grads)]
    assert float(loss) < first * 0.8, (first, float(loss))


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]], jnp.float32)
    labels = jnp.asarray([0, 2], jnp.int32)
    got = float(M.softmax_xent(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1.0 + np.exp(-1.0))
    want = -0.5 * (np.log(p0) + np.log(1.0 / 3.0))
    assert abs(got - want) < 1e-5


def test_eval_full_matches_forward():
    """The Pallas-pooling eval path must agree with the training forward."""
    cfg = make_cfg()
    params, x, _ = make_inputs(cfg, seed=3)
    (logits,) = M.eval_full_seg(x, *params)
    want = M.forward(params, x)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-4, atol=1e-5)
