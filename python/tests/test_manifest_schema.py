"""Manifest config emission: the graph schema (default) and the legacy
k1/k2 schema (behind ``aot.py --legacy-config``).

The cross-language contract is the checked-in fixture
``rust/tests/fixtures/py_graph_config.json``: this suite asserts the python
emitter reproduces it exactly, and the rust suite
(``rust/tests/layer_graph.rs::python_emitted_graph_config_loads_via_manifest``)
asserts the same bytes load through ``Manifest::from_json`` /
``ArchSpec::from_json`` and derive the identical architecture.  If either
side drifts, exactly one of the two suites fails and names the fixture.
"""

import json
import os

from compile import model as M

FIXTURE = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures",
    "py_graph_config.json",
)


def test_graph_config_matches_rust_fixture():
    with open(FIXTURE) as f:
        want = json.load(f)
    got = M.graph_config(M.ArchConfig())
    assert got == want, "regenerate the fixture if the schema changed deliberately"
    # And the emitted document is valid JSON end to end.
    assert json.loads(json.dumps(got)) == want


def test_graph_config_structure():
    cfg = M.ArchConfig.parse("500:1500", batch=1024)
    doc = M.graph_config(cfg)
    ops = [l["op"] for l in doc["layers"]]
    assert ops == ["conv", "lrn", "maxpool2", "conv", "lrn", "maxpool2",
                   "fc", "softmax_xent"]
    convs = [l for l in doc["layers"] if l["op"] == "conv"]
    assert [c["k"] for c in convs] == [500, 1500]
    assert all(c["kh"] == M.KH and c["kw"] == M.KW for c in convs)
    assert doc["batch"] == 1024 and doc["img"] == 32 and doc["in_ch"] == 3
    # Bucket ladders are emitted per conv layer and end at k.
    assert doc["buckets"][0][-1] == 500
    assert doc["buckets"][1][-1] == 1500
    assert doc["batch_buckets"][-1] == 1024
    # No derived geometry leaks into the graph schema (rust re-derives it).
    for stale in ("c1_out", "p1_out", "c2_out", "p2_out", "fc_in",
                  "param_shapes", "param_order", "k1", "k2"):
        assert stale not in doc


def test_legacy_config_keeps_old_schema():
    cfg = M.ArchConfig()
    doc = M.legacy_config(cfg)
    # The exact key set the pre-graph rust loader cross-checks.
    assert set(doc) == {
        "k1", "k2", "batch", "img", "in_ch", "num_classes", "kh", "kw",
        "c1_out", "p1_out", "c2_out", "p2_out", "fc_in", "buckets1",
        "buckets2", "batch_buckets", "param_shapes", "param_order", "probe",
    }
    assert (doc["k1"], doc["k2"]) == (16, 32)
    assert (doc["c1_out"], doc["p1_out"], doc["c2_out"], doc["p2_out"]) == (28, 14, 10, 5)
    assert doc["param_shapes"]["w2"] == [32, 16, 5, 5]
    # The legacy probe block carries no kernel geometry (rust defaults it to
    # the first conv's kernel).
    assert "kh" not in doc["probe"] and "kw" not in doc["probe"]
    # Both schemas agree on the shared probe numbers.
    g = M.graph_config(cfg)["probe"]
    assert doc["probe"]["flops"] == g["flops"] == 60211200
    assert doc["probe"]["batch"] == g["batch"]


def test_probe_config_flops_formula():
    p = M.probe_config()
    oh = M.PROBE_IMG - M.KH + 1
    assert p["flops"] == 2 * M.PROBE_BATCH * M.PROBE_K * M.PROBE_CH * oh * oh * M.KH * M.KW
