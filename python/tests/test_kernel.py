"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and seeds; every property is checked in both
forward and backward (vjp) directions — the paper distributes the
convolutions of *training*, so the gradients are as load-bearing as the
forward pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


shape_strategy = st.tuples(
    st.integers(1, 4),  # batch
    st.integers(1, 5),  # in channels
    st.integers(1, 6),  # out channels (kernels)
    st.sampled_from([(1, 6), (3, 8), (5, 9), (2, 5)]),  # (kernel hw, img hw)
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_conv2d_fwd_matches_ref(dims, seed):
    b, c, k, (khw, hw) = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, b, c, hw, hw)
    w = rand(rng, k, c, khw, khw)
    bias = rand(rng, k)
    got = K.conv2d(x, w, bias)
    want = K.conv2d_ref(x, w, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(shape_strategy, st.integers(0, 2**31 - 1))
def test_conv2d_grads_match_ref(dims, seed):
    b, c, k, (khw, hw) = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, b, c, hw, hw)
    w = rand(rng, k, c, khw, khw)
    bias = rand(rng, k)

    def loss(fn):
        return lambda x, w, b: jnp.sum(jnp.tanh(fn(x, w, b)))

    got = jax.grad(loss(K.conv2d), argnums=(0, 1, 2))(x, w, bias)
    want = jax.grad(loss(K.conv2d_ref), argnums=(0, 1, 2))(x, w, bias)
    for g, r, name in zip(got, want, "xwb"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=1e-3, atol=1e-4, err_msg=f"grad {name}"
        )


def test_conv2d_kernel_axis_is_linear():
    """The property the whole paper rests on: convolving a kernel *shard*
    yields exactly the corresponding slice of the full feature map."""
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 3, 10, 10)
    w = rand(rng, 8, 3, 5, 5)
    b = rand(rng, 8)
    full = K.conv2d(x, w, b)
    lo, hi = 2, 7
    shard = K.conv2d(x, w[lo:hi], b[lo:hi])
    np.testing.assert_allclose(
        np.asarray(full[:, lo:hi]), np.asarray(shard), rtol=1e-5, atol=1e-5
    )


def test_conv2d_zero_padded_kernels_extend_without_disturbing():
    """Bucket rounding: zero-padding the kernel axis must leave real outputs
    bit-identical and produce all-zero padding maps (bias also padded)."""
    rng = np.random.default_rng(1)
    x = rand(rng, 2, 3, 8, 8)
    w = rand(rng, 5, 3, 3, 3)
    b = rand(rng, 5)
    wp = jnp.concatenate([w, jnp.zeros((3, 3, 3, 3), jnp.float32)])
    bp = jnp.concatenate([b, jnp.zeros((3,), jnp.float32)])
    got = K.conv2d(x, wp, bp)
    np.testing.assert_array_equal(np.asarray(got[:, :5]), np.asarray(K.conv2d(x, w, b)))
    assert np.all(np.asarray(got[:, 5:]) == 0.0)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 3),
    st.integers(1, 4),
    st.sampled_from([2, 4, 6, 8]),
    st.integers(0, 2**31 - 1),
)
def test_maxpool2_matches_ref(b, c, hw, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, c, hw, hw)
    np.testing.assert_array_equal(
        np.asarray(K.maxpool2(x)), np.asarray(K.maxpool2_ref(x))
    )


def test_maxpool2_rejects_odd_spatial():
    with pytest.raises(ValueError):
        K.maxpool2(jnp.zeros((1, 1, 5, 5), jnp.float32))


def test_conv2d_rejects_bad_shapes():
    x = jnp.zeros((1, 3, 8, 8), jnp.float32)
    with pytest.raises(ValueError):
        K.conv2d_fwd(x, jnp.zeros((4, 2, 5, 5), jnp.float32), jnp.zeros(4))  # chan mismatch
    with pytest.raises(ValueError):
        K.conv2d_fwd(x, jnp.zeros((4, 3, 5, 5), jnp.float32), jnp.zeros(3))  # bias mismatch
    with pytest.raises(ValueError):
        K.conv2d_fwd(x, jnp.zeros((4, 3, 9, 9), jnp.float32), jnp.zeros(4))  # kernel > img


def test_lrn_ref_properties():
    """LRN must be sign-preserving and shrink magnitudes."""
    rng = np.random.default_rng(3)
    x = rand(rng, 2, 8, 4, 4, scale=2.0)
    y = K.lrn_ref(x)
    assert np.all(np.sign(np.asarray(y)) == np.sign(np.asarray(x)))
    assert np.all(np.abs(np.asarray(y)) <= np.abs(np.asarray(x)) + 1e-6)


def test_conv2d_wgrad_direct():
    """conv2d_wgrad standalone (it is its own executable path in bwd)."""
    rng = np.random.default_rng(4)
    x = rand(rng, 3, 2, 9, 9)
    w = rand(rng, 4, 2, 5, 5)
    gy = rand(rng, 3, 4, 5, 5)
    gw, gb = K.conv2d_wgrad(x, gy, 5, 5)

    # Against autodiff of the reference.
    def f(w):
        return jnp.vdot(K.conv2d_ref(x, w, jnp.zeros(4)), gy)

    gw_ref = jax.grad(f)(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gy.sum(axis=(0, 2, 3))), rtol=1e-5)


def test_conv2d_xgrad_direct():
    rng = np.random.default_rng(5)
    x = rand(rng, 2, 3, 9, 9)
    w = rand(rng, 4, 3, 5, 5)
    gy = rand(rng, 2, 4, 5, 5)
    gx = K.conv2d_xgrad(w, gy)

    def f(x):
        return jnp.vdot(K.conv2d_ref(x, w, jnp.zeros(4)), gy)

    gx_ref = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-4, atol=1e-4)
