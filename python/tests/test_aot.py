"""AOT pipeline: manifest generation + HLO text sanity for a tiny arch."""

import json
import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_tiny")
    cfg = M.ArchConfig(k1=2, k2=3, batch=2)
    manifest = aot.build_all(cfg, str(out))
    return cfg, manifest, out


def test_manifest_lists_every_file(tiny_build):
    _, manifest, out = tiny_build
    assert manifest["version"] == 1
    for name, spec in manifest["executables"].items():
        path = os.path.join(out, spec["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        # xla_extension 0.5.1 gate: text interchange, ids reassigned by the
        # parser — the file must never be a serialized proto.
        assert "\x00" not in text


def test_manifest_shapes_are_consistent(tiny_build):
    cfg, manifest, _ = tiny_build
    ex = manifest["executables"]
    # One fwd+bwd pair per bucket per conv layer.
    for layer, buckets in [(1, cfg.buckets1), (2, cfg.buckets2)]:
        for kb in buckets:
            fwd = ex[f"conv{layer}_fwd_b{kb}"]
            assert fwd["args"][1][1][0] == kb  # w leading dim = bucket
            bwd = ex[f"conv{layer}_bwd_b{kb}"]
            assert bwd["outs"][1][1][0] == kb  # gw leading dim = bucket
            # bwd gx must match fwd x.
            assert bwd["outs"][0][1] == fwd["args"][0][1]
    # grad_full outputs match param shapes.  The default (graph) config
    # schema carries no param_shapes — rust re-derives them — so check the
    # executables against the ArchConfig derivation directly.
    pshapes = M.param_shapes(cfg)
    gf = ex[f"grad_full_b{cfg.batch}"]
    for out_spec, pname in zip(gf["outs"][1:], M.PARAM_NAMES):
        assert out_spec[1] == list(pshapes[pname]), pname


def test_manifest_config_schemas(tiny_build, tmp_path):
    cfg, manifest, _ = tiny_build
    # Default emission is the layer-graph schema (no spelled-out geometry).
    config = manifest["config"]
    assert config == M.graph_config(cfg)
    assert "layers" in config and "param_shapes" not in config
    # --legacy-config emits the pre-graph k1/k2 schema over the *same*
    # executable set (exercise the real flag path end to end).
    legacy = aot.build_all(cfg, str(tmp_path), legacy_config=True)
    assert legacy["config"] == M.legacy_config(cfg)
    assert legacy["config"]["k1"] == cfg.k1
    assert "param_shapes" in legacy["config"] and "layers" not in legacy["config"]
    assert set(legacy["executables"]) == set(manifest["executables"])


def test_probe_flops_formula(tiny_build):
    _, manifest, _ = tiny_build
    p = manifest["config"]["probe"]
    expect = 2 * p["batch"] * p["k"] * p["in_ch"] * (p["img"] - 5 + 1) ** 2 * 25
    assert p["flops"] == expect


def test_manifest_is_valid_json_on_disk(tiny_build):
    _, _, out = tiny_build
    with open(os.path.join(out, "manifest.json")) as f:
        doc = json.load(f)
    assert "executables" in doc and "config" in doc


def test_hlo_text_has_expected_entry_signature(tiny_build):
    cfg, manifest, out = tiny_build
    spec = manifest["executables"]["head_eval"]
    text = open(os.path.join(out, spec["file"])).read()
    # Entry computation mentions the fc dims.
    assert f"{cfg.fc_in},{cfg.num_classes}" in text.replace(" ", "")
