//! `cargo bench --bench paper_figures` — regenerates every *figure* of the
//! paper's evaluation (Figures 5-13) and times the generators.  Output rows
//! are the reproduction record that EXPERIMENTS.md quotes.

use convdist::sim::figures;
use convdist::util::bench::Bencher;

fn main() {
    let b = Bencher::quick();
    for id in ["fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13"] {
        let fig = figures::generate(id).expect("known id");
        println!("\n{}", fig.render());
        b.run(&format!("generate::{id}"), || {
            std::hint::black_box(figures::generate(id).unwrap())
        });
    }
}
