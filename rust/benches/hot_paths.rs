//! `cargo bench --bench hot_paths` — microbenchmarks of the L3 hot path,
//! the §Perf evidence base: wire protocol encode/decode, tensor
//! slice/concat/pad (shard assembly), Eq. 1 partitioning, executable
//! dispatch, and the full distributed step.
//!
//! Runs against the default native CPU backend — no artifacts needed.
//! (With `--features pjrt` + `CONVDIST_BACKEND=pjrt` the same benches time
//! the PJRT path instead, given `make artifacts`.)

use convdist::config::TrainerConfig;
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::proto::{read_frame, write_frame, Message, WireTensor};
use convdist::runtime::{bucket_ladder, Runtime};
use convdist::sched::{
    partition_layer, AdaptiveConfig, AdaptivePolicy, FleetTelemetry, LayerPlan,
};
use convdist::session::SessionBuilder;
use convdist::tensor::{Pcg32, Tensor, Value};
use convdist::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();
    let mut rng = Pcg32::seed(7);

    // --- proto: the per-batch ConvWork frame (inputs + kernels + bias) -----
    let inputs = Tensor::randn(&[64, 32, 14, 14], &mut rng);
    let kernels = Tensor::randn(&[32, 32, 5, 5], &mut rng);
    let bias = Tensor::randn(&[32], &mut rng);
    let msg = Message::ConvWork {
        seq: 1,
        layer: 2,
        dir: 0,
        bucket: 32,
        inputs: WireTensor::from(&inputs),
        kernels: WireTensor::from(&kernels),
        extra: Some(WireTensor::from(&bias)),
    };
    let mut encoded = Vec::new();
    write_frame(&mut encoded, &msg)?;
    println!("ConvWork frame: {:.2} MiB", encoded.len() as f64 / (1 << 20) as f64);
    b.run("proto::encode ConvWork (1.6 MiB)", || {
        let mut buf = Vec::with_capacity(encoded.len());
        write_frame(&mut buf, &msg).unwrap();
        buf
    });
    b.run("proto::decode ConvWork (1.6 MiB)", || {
        read_frame(&mut std::io::Cursor::new(&encoded)).unwrap()
    });

    // --- tensor ops on the gather path --------------------------------------
    let maps = Tensor::randn(&[64, 64, 10, 10], &mut rng);
    b.run("tensor::slice_axis1 (64ch -> 21ch)", || maps.slice_axis1(21, 42).unwrap());
    let parts: Vec<Tensor> = vec![
        maps.slice_axis1(0, 21).unwrap(),
        maps.slice_axis1(21, 42).unwrap(),
        maps.slice_axis1(42, 64).unwrap(),
    ];
    b.run("tensor::concat_axis1 (3 shards)", || Tensor::concat_axis1(&parts).unwrap());
    let w = Tensor::randn(&[21, 32, 5, 5], &mut rng);
    b.run("tensor::pad_axis0 (21 -> 24 kernels)", || w.pad_axis0(24).unwrap());

    // --- linalg: the blocked GEMM engine on a conv-shaped product ----------
    // conv1 of the paper's 500-kernel layer per image: 500 x 75 x 784.
    println!(
        "linalg: isa {}  blocks {:?}",
        convdist::linalg::isa().label(),
        convdist::linalg::blocks()
    );
    let (gm, gk, gn) = (500usize, 75usize, 784usize);
    let ga = Tensor::randn(&[gm, gk], &mut rng);
    let gb = Tensor::randn(&[gk, gn], &mut rng);
    let mut gout = vec![0f32; gm * gn];
    let flops = convdist::linalg::gemm_flops(gm, gk, gn);
    // Serial, like the conv hot path runs it inside the batch-parallel pool.
    let serial_pool = rayon::ThreadPoolBuilder::new().num_threads(1).build()?;
    let r = b.run("linalg::gemm conv1-shape (500x75x784, serial)", || {
        serial_pool.install(|| {
            gout.fill(0.0);
            convdist::linalg::gemm(ga.data(), gb.data(), gm, gk, gn, &mut gout);
        })
    });
    println!("  engine best: {:.2} GFLOP/s", flops / 1e9 / r.min.as_secs_f64());
    let r = b.run("linalg::reference::gemm conv1-shape (naive)", || {
        gout.fill(0.0);
        convdist::linalg::reference::gemm(ga.data(), gb.data(), gm, gk, gn, &mut gout);
    });
    println!("  naive best:  {:.2} GFLOP/s", flops / 1e9 / r.min.as_secs_f64());

    // --- Eq. 1 partitioning --------------------------------------------------
    let times: Vec<f64> = (0..16).map(|i| 0.01 * (1.0 + (i % 5) as f64)).collect();
    let buckets: Vec<usize> = (1..=32).map(|i| i * 48).collect();
    b.run("sched::partition_layer (1500 kernels, 16 devices)", || {
        partition_layer(1500, &times, &buckets).unwrap()
    });

    // --- adaptive scheduler: telemetry feed + re-partition decision ----------
    // The per-step overhead adaptation adds to the master's loop: one
    // telemetry record per gathered shard, then a policy consult that
    // builds candidate Eq. 1 tables for both layers and prices them.
    let mut telem = FleetTelemetry::new(16, 0.4);
    for d in 0..16 {
        telem.record(d, 0.01 * (1.0 + (d % 5) as f64), 1e9);
    }
    b.run("sched::telemetry record (1 shard observation)", || {
        telem.record(3, 0.021, 1e9);
        telem.rate(3)
    });
    let (b1500, b500) = (bucket_ladder(1500), bucket_ladder(500));
    let t1500 = partition_layer(1500, &times, &b1500).unwrap();
    let t500 = partition_layer(500, &times, &b500).unwrap();
    let active: Vec<usize> = (0..16).collect();
    let rates = telem.rates_for(&active, 1).unwrap();
    let mut policy = AdaptivePolicy::new(AdaptiveConfig { warmup_steps: 0, ..Default::default() });
    let mut step = 0u64;
    b.run("sched::policy decide + candidate re-partition (2 layers, 16 devices)", || {
        let plans = [
            LayerPlan { k: 1500, buckets: &b1500, current: &t1500, flops_per_kernel: 5.1e6 },
            LayerPlan { k: 500, buckets: &b500, current: &t500, flops_per_kernel: 7.5e6 },
        ];
        step += 1;
        policy.decide(step, &plans, &active, &rates).unwrap()
    });

    // --- PJRT dispatch + full distributed step ------------------------------
    let artifacts = convdist::artifacts_dir();
    let rt = Runtime::open(&artifacts)?;
    let arch = rt.arch().clone();
    let (c2_in, c2_hw) = arch.conv_input(2);
    let (kh2, kw2) = arch.conv_kernel(2);
    let k2 = arch.kernels(2);
    let x = Tensor::randn(&[arch.batch, c2_in, c2_hw, c2_hw], &mut rng);
    let wk = Tensor::randn(&[k2, c2_in, kh2, kw2], &mut rng);
    let bk = Tensor::zeros(&[k2]);
    let exec = format!("conv2_fwd_b{k2}");
    let args = [Value::F32(x), Value::F32(wk), Value::F32(bk)];
    rt.execute(&exec, &args)?; // compile outside the timing loop
    b.run(&format!("runtime::execute {exec}"), || rt.execute(&exec, &args).unwrap());

    let cfg = TrainerConfig { steps: 1, calib_rounds: 1, ..Default::default() };
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 9);
    let batch = ds.batch(arch.batch, 0)?;
    let mut dist = SessionBuilder::new()
        .artifacts(artifacts)
        .trainer(cfg)
        .workers(&[Throttle::none(); 2])
        .build()?;
    dist.step(&batch)?; // warm caches
    let slow = Bencher { budget: std::time::Duration::from_secs(6), max_iters: 12, warmup: 1 };
    slow.run("cluster::step end-to-end (3 devices)", || dist.step(&batch).unwrap());
    let r = dist.step(&batch)?;
    println!("  step breakdown: {}", r.breakdown);
    dist.shutdown()?;
    Ok(())
}
