//! `cargo bench --bench paper_tables` — regenerates every *table* of the
//! paper's evaluation (Tables 1, 4, 5 + the Amdahl/§5.3.1 anchors) and
//! times the generators.  Output rows are the reproduction record that
//! EXPERIMENTS.md quotes.

use convdist::sim::figures;
use convdist::util::bench::Bencher;

fn main() {
    let b = Bencher::quick();
    for id in ["table1", "table4", "table5", "amdahl"] {
        let fig = figures::generate(id).expect("known id");
        println!("\n{}", fig.render());
        b.run(&format!("generate::{id}"), || {
            std::hint::black_box(figures::generate(id).unwrap())
        });
    }
}
