//! The default backend: pure-rust CPU execution of every executable in the
//! contract, composed from the [`crate::kernels`] primitives.  Needs no
//! artifacts, no Python, no network — `Runtime::open` on a clean checkout
//! lands here.
//!
//! Per-shard conv executables are shape-driven (all dims are read from the
//! already manifest-validated arguments), but the mid segments and the
//! fused full-network executables depend on the architecture *graph*: the
//! backend holds the [`ArchSpec`] and interprets its [`MidOp`] lists and
//! conv chain directly, so any graph the IR can express runs here with no
//! per-architecture code.

use anyhow::{anyhow, bail, Result};

use super::exec::ExecKind;
use super::graph::MidOp;
use super::{ArchSpec, Backend, PreparedExec};
use crate::kernels as k;
use crate::linalg;
use crate::runtime::ExecutableSpec;
use crate::tensor::{ITensor, Tensor, Value};

pub struct NativeBackend {
    /// Shared with every prepared executable — `prepare` is a pointer bump,
    /// not a deep copy of the graph per executable.
    arch: std::sync::Arc<ArchSpec>,
}

impl NativeBackend {
    pub fn new(arch: ArchSpec) -> Self {
        Self { arch: std::sync::Arc::new(arch) }
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".into()
    }

    fn prepare(&self, name: &str, _spec: &ExecutableSpec) -> Result<Box<dyn PreparedExec>> {
        let kind = ExecKind::parse(name)
            .ok_or_else(|| anyhow!("no native implementation for executable {name:?}"))?;
        Ok(Box::new(NativeExec { kind, arch: self.arch.clone() }))
    }
}

struct NativeExec {
    kind: ExecKind,
    arch: std::sync::Arc<ArchSpec>,
}

/// Borrow a 4-d f32 argument and its dims.
fn t4(v: &Value) -> Result<(&Tensor, usize, usize, usize, usize)> {
    let t = v.as_f32()?;
    let s = t.shape();
    anyhow::ensure!(s.len() == 4, "expected rank-4 tensor, got {s:?}");
    Ok((t, s[0], s[1], s[2], s[3]))
}

fn labels_of(v: &Value) -> Result<&ITensor> {
    match v {
        Value::I32(t) => Ok(t),
        Value::F32(_) => bail!("expected i32 labels tensor"),
    }
}

/// One conv-layer forward: `(x, w, bias) -> y` as a tensor.
fn conv_fwd(x: &Tensor, w: &Tensor, bias: &Tensor) -> Result<Tensor> {
    let (b, c, h, wd) = {
        let s = x.shape();
        (s[0], s[1], s[2], s[3])
    };
    let (kk, kh, kw) = {
        let s = w.shape();
        (s[0], s[2], s[3])
    };
    let y = k::conv2d_fwd(x.data(), w.data(), bias.data(), b, c, h, wd, kk, kh, kw);
    Tensor::new(vec![b, kk, h - kh + 1, wd - kw + 1], y)
}

/// Mid-segment forward: apply `ops` to the conv output `y`.  The first op
/// reads straight from `y`'s buffer (no seed copy); only the intermediates
/// between ops are materialized.
fn mid_fwd(ops: &[MidOp], y: &Tensor) -> Result<Tensor> {
    let s = y.shape();
    let (b, c) = (s[0], s[1]);
    let (mut h, mut w) = (s[2], s[3]);
    let mut cur: Option<Vec<f32>> = None; // None = still reading from y
    for op in ops {
        let src: &[f32] = cur.as_deref().unwrap_or_else(|| y.data());
        let next = match op {
            MidOp::Lrn => k::lrn_fwd(src, b, c, h, w),
            MidOp::Relu => k::relu_fwd(src),
            MidOp::MaxPool2 => {
                let p = k::maxpool2_fwd(src, b, c, h, w);
                h /= 2;
                w /= 2;
                p
            }
        };
        cur = Some(next);
    }
    match cur {
        Some(v) => Tensor::new(vec![b, c, h, w], v),
        // Empty segment: identity (the output copy is the executable's
        // contract — it must own its result).
        None => Ok(y.clone()),
    }
}

/// Mid-segment vjp: `gp -> gy`, recomputing the forward chain from the conv
/// output `y` (recompute-in-bwd — the pooled outputs are never stored).
/// The first op's input *is* `y`, so no copy of it is stored either.
fn mid_bwd(ops: &[MidOp], y: &Tensor, gp: &Tensor) -> Result<Tensor> {
    let s = y.shape();
    let (b, c) = (s[0], s[1]);
    // Forward recompute, keeping each op's input and extent (`None` = `y`).
    // Backward only needs each op's *input*, so the final op's output is
    // never computed.
    let (mut h, mut w) = (s[2], s[3]);
    let mut stages: Vec<(Option<Vec<f32>>, usize, usize)> = Vec::with_capacity(ops.len());
    let mut cur: Option<Vec<f32>> = None;
    for (idx, op) in ops.iter().enumerate() {
        let next = if idx + 1 == ops.len() {
            None
        } else {
            let src: &[f32] = cur.as_deref().unwrap_or_else(|| y.data());
            Some(match op {
                MidOp::Lrn => k::lrn_fwd(src, b, c, h, w),
                MidOp::Relu => k::relu_fwd(src),
                MidOp::MaxPool2 => k::maxpool2_fwd(src, b, c, h, w),
            })
        };
        stages.push((cur.take(), h, w));
        if matches!(op, MidOp::MaxPool2) {
            h /= 2;
            w /= 2;
        }
        cur = next;
    }
    // Backward through the stored inputs.
    let mut g = gp.data().to_vec();
    for (op, (input, hin, win)) in ops.iter().zip(&stages).rev() {
        let src: &[f32] = input.as_deref().unwrap_or_else(|| y.data());
        g = match op {
            MidOp::Lrn => k::lrn_bwd(src, &g, b, c, *hin, *win),
            MidOp::Relu => k::relu_bwd(src, &g),
            MidOp::MaxPool2 => k::maxpool2_bwd(src, &g, b, c, *hin, *win),
        };
    }
    Tensor::new(y.shape().to_vec(), g)
}

/// FC head gradients: `(p_flat, wf, bf, labels) -> (loss, gp, gwf, gbf)`.
fn head_grad(
    p: &[f32],
    wf: &Tensor,
    bf: &Tensor,
    labels: &[i32],
    b: usize,
) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (fin, ncls) = (wf.shape()[0], wf.shape()[1]);
    let logits = k::fc_logits(p, wf.data(), bf.data(), b, fin, ncls);
    let (loss, gl) = k::softmax_xent_grad(&logits, labels, b, ncls);
    let mut gp = vec![0f32; b * fin];
    linalg::gemm_abt(&gl, wf.data(), b, ncls, fin, &mut gp);
    let mut gwf = vec![0f32; fin * ncls];
    linalg::gemm_atb(p, &gl, b, fin, ncls, &mut gwf);
    let mut gbf = vec![0f32; ncls];
    for row in gl.chunks(ncls) {
        for (g, &v) in gbf.iter_mut().zip(row) {
            *g += v;
        }
    }
    (loss, gp, gwf, gbf)
}

impl NativeExec {
    /// Full-network forward over the graph: returns the per-conv inputs,
    /// per-conv outputs and the final mid output (the FC input).
    /// `params[2l]`/`params[2l+1]` are conv `l+1`'s weight/bias.
    fn forward_chain(
        &self,
        x: &Tensor,
        params: &[&Tensor],
    ) -> Result<(Vec<Tensor>, Vec<Tensor>, Tensor)> {
        let n = self.arch.num_convs();
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        let mut cur = x.clone();
        for l in 1..=n {
            let (w, b) = (params[2 * (l - 1)], params[2 * (l - 1) + 1]);
            let y = conv_fwd(&cur, w, b)?;
            let p = mid_fwd(self.arch.mid_ops(l), &y)?;
            xs.push(std::mem::replace(&mut cur, p));
            ys.push(y);
        }
        Ok((xs, ys, cur))
    }
}

impl PreparedExec for NativeExec {
    fn run(&self, args: &[Value]) -> Result<Vec<Value>> {
        match &self.kind {
            ExecKind::Probe | ExecKind::ConvFwd { .. } | ExecKind::ConvFwdAt { .. } => {
                let y = conv_fwd(args[0].as_f32()?, args[1].as_f32()?, args[2].as_f32()?)?;
                Ok(vec![Value::F32(y)])
            }
            ExecKind::ConvBwd { .. } => {
                let (x, b, c, h, wd) = t4(&args[0])?;
                let (w, kk, _, kh, kw) = t4(&args[1])?;
                let gy = args[2].as_f32()?;
                let (gx, gw, gb) =
                    k::conv2d_bwd(x.data(), w.data(), gy.data(), b, c, h, wd, kk, kh, kw);
                Ok(vec![
                    Value::F32(Tensor::new(vec![b, c, h, wd], gx)?),
                    Value::F32(Tensor::new(vec![kk, c, kh, kw], gw)?),
                    Value::F32(Tensor::new(vec![kk], gb)?),
                ])
            }
            ExecKind::MidFwd { layer } | ExecKind::MidFwdAt { layer, .. } => {
                let p = mid_fwd(self.arch.mid_ops(*layer), args[0].as_f32()?)?;
                Ok(vec![Value::F32(p)])
            }
            ExecKind::MidBwd { layer } => {
                let gy = mid_bwd(self.arch.mid_ops(*layer), args[0].as_f32()?, args[1].as_f32()?)?;
                Ok(vec![Value::F32(gy)])
            }
            ExecKind::HeadGrad => {
                let (p, b, kc, ph, pw) = t4(&args[0])?;
                let wf = args[1].as_f32()?;
                let bf = args[2].as_f32()?;
                let labels = labels_of(&args[3])?;
                let (loss, gp, gwf, gbf) = head_grad(p.data(), wf, bf, labels.data(), b);
                Ok(vec![
                    Value::F32(Tensor::scalar(loss)),
                    Value::F32(Tensor::new(vec![b, kc, ph, pw], gp)?),
                    Value::F32(Tensor::new(wf.shape().to_vec(), gwf)?),
                    Value::F32(Tensor::new(bf.shape().to_vec(), gbf)?),
                ])
            }
            ExecKind::HeadLogits { .. } => {
                let (p, b, _, _, _) = t4(&args[0])?;
                let wf = args[1].as_f32()?;
                let bf = args[2].as_f32()?;
                let (fin, ncls) = (wf.shape()[0], wf.shape()[1]);
                let logits = k::fc_logits(p.data(), wf.data(), bf.data(), b, fin, ncls);
                Ok(vec![Value::F32(Tensor::new(vec![b, ncls], logits)?)])
            }
            ExecKind::EvalFull => {
                let x = args[0].as_f32()?;
                let params: Vec<&Tensor> =
                    args[1..].iter().map(|v| v.as_f32()).collect::<Result<_>>()?;
                let n = self.arch.num_convs();
                let (_xs, _ys, p) = self.forward_chain(x, &params[..2 * n])?;
                let (wf, bf) = (params[2 * n], params[2 * n + 1]);
                let b = x.shape()[0];
                let (fin, ncls) = (wf.shape()[0], wf.shape()[1]);
                let logits = k::fc_logits(p.data(), wf.data(), bf.data(), b, fin, ncls);
                Ok(vec![Value::F32(Tensor::new(vec![b, ncls], logits)?)])
            }
            ExecKind::GradFull { .. } => {
                let x = args[0].as_f32()?;
                let labels = labels_of(&args[1])?;
                let params: Vec<&Tensor> =
                    args[2..].iter().map(|v| v.as_f32()).collect::<Result<_>>()?;
                let n = self.arch.num_convs();
                let b = x.shape()[0];

                // ---- forward, keeping what backward needs ----------------
                let (xs, ys, p) = self.forward_chain(x, &params[..2 * n])?;

                // ---- head ------------------------------------------------
                let (wf, bf) = (params[2 * n], params[2 * n + 1]);
                let (loss, gp, gwf, gbf) = head_grad(p.data(), wf, bf, labels.data(), b);
                let mut gp = Tensor::new(p.shape().to_vec(), gp)?;

                // ---- backward through each mid + conv, deepest first -----
                let mut conv_grads: Vec<(Tensor, Tensor)> = Vec::with_capacity(n);
                for l in (1..=n).rev() {
                    let gy = mid_bwd(self.arch.mid_ops(l), &ys[l - 1], &gp)?;
                    let xin = &xs[l - 1];
                    let w = params[2 * (l - 1)];
                    let (c, h) = (xin.shape()[1], xin.shape()[2]);
                    let (kk, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
                    // The input-layer gx is discarded (no layer below), but
                    // the kernel computes it anyway — same cost structure as
                    // the paper's convn.
                    let (gx, gw, gb) = k::conv2d_bwd(
                        xin.data(),
                        w.data(),
                        gy.data(),
                        b,
                        c,
                        h,
                        h,
                        kk,
                        kh,
                        kw,
                    );
                    conv_grads.push((
                        Tensor::new(w.shape().to_vec(), gw)?,
                        Tensor::new(vec![kk], gb)?,
                    ));
                    gp = Tensor::new(xin.shape().to_vec(), gx)?;
                }

                // Outputs in param order: loss, conv grads shallow-to-deep,
                // then the FC pair.
                let mut outs = vec![Value::F32(Tensor::scalar(loss))];
                for (gw, gb) in conv_grads.into_iter().rev() {
                    outs.push(Value::F32(gw));
                    outs.push(Value::F32(gb));
                }
                outs.push(Value::F32(Tensor::new(wf.shape().to_vec(), gwf)?));
                outs.push(Value::F32(Tensor::new(bf.shape().to_vec(), gbf)?));
                Ok(outs)
            }
        }
    }
}
