//! The default backend: pure-rust CPU execution of every executable in the
//! contract, composed from the [`crate::kernels`] primitives.  Needs no
//! artifacts, no Python, no network — `Runtime::open` on a clean checkout
//! lands here.
//!
//! All shapes are read from the (already manifest-validated) arguments, so a
//! prepared executable is just its parsed [`ExecKind`]; "compilation" is
//! name parsing.

use anyhow::{anyhow, bail, Result};

use super::exec::ExecKind;
use super::{Backend, PreparedExec};
use crate::kernels as k;
use crate::linalg;
use crate::runtime::ExecutableSpec;
use crate::tensor::{ITensor, Tensor, Value};

pub struct NativeBackend;

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".into()
    }

    fn prepare(&self, name: &str, _spec: &ExecutableSpec) -> Result<Box<dyn PreparedExec>> {
        let kind = ExecKind::parse(name)
            .ok_or_else(|| anyhow!("no native implementation for executable {name:?}"))?;
        Ok(Box::new(NativeExec { kind }))
    }
}

struct NativeExec {
    kind: ExecKind,
}

/// Borrow a 4-d f32 argument and its dims.
fn t4(v: &Value) -> Result<(&Tensor, usize, usize, usize, usize)> {
    let t = v.as_f32()?;
    let s = t.shape();
    anyhow::ensure!(s.len() == 4, "expected rank-4 tensor, got {s:?}");
    Ok((t, s[0], s[1], s[2], s[3]))
}

fn labels_of(v: &Value) -> Result<&ITensor> {
    match v {
        Value::I32(t) => Ok(t),
        Value::F32(_) => bail!("expected i32 labels tensor"),
    }
}

/// One conv-layer forward: `(y, bias, w) -> y` as raw data + dims.
fn conv_fwd(x: &Tensor, w: &Tensor, bias: &Tensor) -> Result<Tensor> {
    let (b, c, h, wd) = {
        let s = x.shape();
        (s[0], s[1], s[2], s[3])
    };
    let (kk, kh, kw) = {
        let s = w.shape();
        (s[0], s[2], s[3])
    };
    let y = k::conv2d_fwd(x.data(), w.data(), bias.data(), b, c, h, wd, kk, kh, kw);
    Tensor::new(vec![b, kk, h - kh + 1, wd - kw + 1], y)
}

/// `mid` forward pieces: returns (lrn(y), pool(lrn(y))) so backward can
/// reuse the LRN output for pooling argmax recomputation.
fn mid_fwd_parts(y: &Tensor) -> (Vec<f32>, Vec<f32>, [usize; 4]) {
    let s = y.shape();
    let (b, c, h, w) = (s[0], s[1], s[2], s[3]);
    let z = k::lrn_fwd(y.data(), b, c, h, w);
    let p = k::maxpool2_fwd(&z, b, c, h, w);
    (z, p, [b, c, h, w])
}

/// vjp of the mid block: `gp -> gy` (recomputes the LRN output for pooling
/// argmax; the pooled output itself is not needed, so no pool forward).
fn mid_bwd(y: &Tensor, gp: &Tensor) -> Vec<f32> {
    let s = y.shape();
    let (b, c, h, w) = (s[0], s[1], s[2], s[3]);
    let z = k::lrn_fwd(y.data(), b, c, h, w);
    let gz = k::maxpool2_bwd(&z, gp.data(), b, c, h, w);
    k::lrn_bwd(y.data(), &gz, b, c, h, w)
}

/// FC head gradients: `(p2_flat, wf, bf, labels) -> (loss, gp2, gwf, gbf)`.
fn head_grad(
    p2: &[f32],
    wf: &Tensor,
    bf: &Tensor,
    labels: &[i32],
    b: usize,
) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (fin, ncls) = (wf.shape()[0], wf.shape()[1]);
    let logits = k::fc_logits(p2, wf.data(), bf.data(), b, fin, ncls);
    let (loss, gl) = k::softmax_xent_grad(&logits, labels, b, ncls);
    let mut gp2 = vec![0f32; b * fin];
    linalg::gemm_abt(&gl, wf.data(), b, ncls, fin, &mut gp2);
    let mut gwf = vec![0f32; fin * ncls];
    linalg::gemm_atb(p2, &gl, b, fin, ncls, &mut gwf);
    let mut gbf = vec![0f32; ncls];
    for row in gl.chunks(ncls) {
        for (g, &v) in gbf.iter_mut().zip(row) {
            *g += v;
        }
    }
    (loss, gp2, gwf, gbf)
}

impl PreparedExec for NativeExec {
    fn run(&self, args: &[Value]) -> Result<Vec<Value>> {
        match &self.kind {
            ExecKind::Probe | ExecKind::ConvFwd { .. } => {
                let y = conv_fwd(args[0].as_f32()?, args[1].as_f32()?, args[2].as_f32()?)?;
                Ok(vec![Value::F32(y)])
            }
            ExecKind::ConvBwd { .. } => {
                let (x, b, c, h, wd) = t4(&args[0])?;
                let (w, kk, _, kh, kw) = t4(&args[1])?;
                let gy = args[2].as_f32()?;
                let (gx, gw, gb) =
                    k::conv2d_bwd(x.data(), w.data(), gy.data(), b, c, h, wd, kk, kh, kw);
                Ok(vec![
                    Value::F32(Tensor::new(vec![b, c, h, wd], gx)?),
                    Value::F32(Tensor::new(vec![kk, c, kh, kw], gw)?),
                    Value::F32(Tensor::new(vec![kk], gb)?),
                ])
            }
            ExecKind::MidFwd { .. } => {
                let y = args[0].as_f32()?;
                let (_z, p, [b, c, h, w]) = mid_fwd_parts(y);
                Ok(vec![Value::F32(Tensor::new(vec![b, c, h / 2, w / 2], p)?)])
            }
            ExecKind::MidBwd { .. } => {
                let y = args[0].as_f32()?;
                let gy = mid_bwd(y, args[1].as_f32()?);
                Ok(vec![Value::F32(Tensor::new(y.shape().to_vec(), gy)?)])
            }
            ExecKind::HeadGrad => {
                let (p2, b, kc, ph, pw) = t4(&args[0])?;
                let wf = args[1].as_f32()?;
                let bf = args[2].as_f32()?;
                let labels = labels_of(&args[3])?;
                let (loss, gp2, gwf, gbf) = head_grad(p2.data(), wf, bf, labels.data(), b);
                Ok(vec![
                    Value::F32(Tensor::scalar(loss)),
                    Value::F32(Tensor::new(vec![b, kc, ph, pw], gp2)?),
                    Value::F32(Tensor::new(wf.shape().to_vec(), gwf)?),
                    Value::F32(Tensor::new(bf.shape().to_vec(), gbf)?),
                ])
            }
            ExecKind::EvalFull => {
                let x = args[0].as_f32()?;
                let (w1, b1, w2, b2) =
                    (args[1].as_f32()?, args[2].as_f32()?, args[3].as_f32()?, args[4].as_f32()?);
                let (wf, bf) = (args[5].as_f32()?, args[6].as_f32()?);
                let y1 = conv_fwd(x, w1, b1)?;
                let (_z1, p1, [b, k1, h1, _]) = mid_fwd_parts(&y1);
                let p1 = Tensor::new(vec![b, k1, h1 / 2, h1 / 2], p1)?;
                let y2 = conv_fwd(&p1, w2, b2)?;
                let (_z2, p2, _) = mid_fwd_parts(&y2);
                let (fin, ncls) = (wf.shape()[0], wf.shape()[1]);
                let logits = k::fc_logits(&p2, wf.data(), bf.data(), b, fin, ncls);
                Ok(vec![Value::F32(Tensor::new(vec![b, ncls], logits)?)])
            }
            ExecKind::GradFull { .. } => {
                let x = args[0].as_f32()?;
                let labels = labels_of(&args[1])?;
                let (w1, b1, w2, b2) =
                    (args[2].as_f32()?, args[3].as_f32()?, args[4].as_f32()?, args[5].as_f32()?);
                let (wf, bf) = (args[6].as_f32()?, args[7].as_f32()?);
                let b = x.shape()[0];

                // ---- forward, keeping what backward needs --------------------
                let y1 = conv_fwd(x, w1, b1)?;
                let (z1, p1v, [_, k1, h1, _]) = mid_fwd_parts(&y1);
                let p1 = Tensor::new(vec![b, k1, h1 / 2, h1 / 2], p1v)?;
                let y2 = conv_fwd(&p1, w2, b2)?;
                let (z2, p2v, [_, k2, h2, _]) = mid_fwd_parts(&y2);

                // ---- head ----------------------------------------------------
                let (loss, gp2, gwf, gbf) = head_grad(&p2v, wf, bf, labels.data(), b);

                // ---- backward through mid2 + conv2 ---------------------------
                let gz2 = k::maxpool2_bwd(&z2, &gp2, b, k2, h2, h2);
                let gy2 = k::lrn_bwd(y2.data(), &gz2, b, k2, h2, h2);
                let (c2in, h2in) = (p1.shape()[1], p1.shape()[2]);
                let (kh, kw) = (w2.shape()[2], w2.shape()[3]);
                let (gp1, gw2, gb2) = k::conv2d_bwd(
                    p1.data(),
                    w2.data(),
                    &gy2,
                    b,
                    c2in,
                    h2in,
                    h2in,
                    k2,
                    kh,
                    kw,
                );

                // ---- backward through mid1 + conv1 ---------------------------
                let gz1 = k::maxpool2_bwd(&z1, &gp1, b, k1, h1, h1);
                let gy1 = k::lrn_bwd(y1.data(), &gz1, b, k1, h1, h1);
                let (c1in, h1in) = (x.shape()[1], x.shape()[2]);
                let (kh1, kw1) = (w1.shape()[2], w1.shape()[3]);
                let (_gx, gw1, gb1) = k::conv2d_bwd(
                    x.data(),
                    w1.data(),
                    &gy1,
                    b,
                    c1in,
                    h1in,
                    h1in,
                    k1,
                    kh1,
                    kw1,
                );

                Ok(vec![
                    Value::F32(Tensor::scalar(loss)),
                    Value::F32(Tensor::new(w1.shape().to_vec(), gw1)?),
                    Value::F32(Tensor::new(vec![k1], gb1)?),
                    Value::F32(Tensor::new(w2.shape().to_vec(), gw2)?),
                    Value::F32(Tensor::new(vec![k2], gb2)?),
                    Value::F32(Tensor::new(wf.shape().to_vec(), gwf)?),
                    Value::F32(Tensor::new(bf.shape().to_vec(), gbf)?),
                ])
            }
        }
    }
}
