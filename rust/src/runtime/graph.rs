//! The layer-graph architecture IR — the typed description every other
//! subsystem derives its shapes, names and work estimates from.
//!
//! The paper's instance is one fixed network (`conv -> lrn -> pool -> conv
//! -> lrn -> pool -> fc`), but its method distributes *every* conv layer, so
//! the architecture contract is a graph, not a pair of kernel counts:
//! [`ArchSpec`] holds an ordered [`LayerSpec`] list plus input geometry, and
//! shape inference ([`ArchSpec::build`]) walks it once to derive
//!
//! * per-conv geometry ([`ConvInfo`]): input channels/extent, output extent,
//!   the master-resident *mid* segment (LRN / pool / ReLU ops between this
//!   conv and the next distributable layer) and its output extent;
//! * parameter names, shapes and order (`conv{N}.w`, `conv{N}.b`, …,
//!   `fc.w`, `fc.b`);
//! * the per-conv shard-bucket ladders and the batch-bucket ladder;
//! * the calibration probe geometry.
//!
//! `runtime::exec` turns the graph into the executable set
//! (`conv{N}_{fwd,bwd}_b{K}`, `mid{N}_{fwd,bwd}`, `head_grad`, `eval_full`,
//! `grad_full_b{B}`), `runtime::native` interprets it, and
//! `cluster::master` loops over `1..=num_convs()` — a 3-, 4- or N-conv
//! network trains with zero new code (DESIGN.md §8).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// One layer of the architecture graph, in network order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// Distributable convolution: `k` kernels of `kh x kw` (valid padding,
    /// stride 1).  The runtime's activations are square, so `kh == kw`.
    Conv { k: usize, kh: usize, kw: usize },
    /// AlexNet-style cross-channel local response normalization.
    Lrn,
    /// 2x2 / stride-2 max pooling (requires an even extent).
    MaxPool2,
    /// Elementwise rectifier.
    Relu,
    /// Fully connected head over the flattened activations.
    Fc { out: usize },
    /// Mean softmax cross-entropy loss; must terminate the graph.
    SoftmaxXent,
}

/// A master-resident element op inside a conv layer's mid segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MidOp {
    Lrn,
    MaxPool2,
    Relu,
}

/// Derived geometry of one conv layer and its trailing mid segment.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvInfo {
    /// Kernel count (the distributed K axis).
    pub k: usize,
    pub kh: usize,
    pub kw: usize,
    /// Input channels and (square) input extent.
    pub in_ch: usize,
    pub in_hw: usize,
    /// Conv output extent (`in_hw - kh + 1`).
    pub out_hw: usize,
    /// Extent after the mid segment (pooling halves it; LRN/ReLU keep it).
    pub mid_out_hw: usize,
    /// The ops between this conv and the next conv (or the FC head), in
    /// network order.  May be empty — the mid executable is then identity.
    pub mid_ops: Vec<MidOp>,
    /// Compiled shard buckets for this layer's K axis.
    pub buckets: Vec<usize>,
}

/// Calibration-probe geometry (paper §4.1.1): a fixed small convolution
/// every device times to produce its performance value.
#[derive(Clone, Debug)]
pub struct ProbeSpec {
    pub batch: usize,
    pub in_ch: usize,
    pub img: usize,
    pub k: usize,
    pub kh: usize,
    pub kw: usize,
    /// FLOPs of one probe execution; measured time -> GFLOPS value.
    pub flops: u64,
}

impl ProbeSpec {
    /// Parse from manifest JSON; `kh`/`kw` default to the first conv's
    /// kernel when absent (the legacy schema had no per-probe kernel size).
    pub(crate) fn from_json(v: &Json, default_kh: usize, default_kw: usize) -> Result<Self> {
        Ok(Self {
            batch: v.get("batch")?.as_usize()?,
            in_ch: v.get("in_ch")?.as_usize()?,
            img: v.get("img")?.as_usize()?,
            k: v.get("k")?.as_usize()?,
            kh: v.opt("kh").map(|x| x.as_usize()).transpose()?.unwrap_or(default_kh),
            kw: v.opt("kw").map(|x| x.as_usize()).transpose()?.unwrap_or(default_kw),
            flops: v.get("flops")?.as_u64()?,
        })
    }
}

/// The compiled architecture: the layer graph plus everything shape
/// inference derives from it.  The derived fields are data, not methods,
/// so a manifest can pin them and the runtime can validate against them.
#[derive(Clone, Debug)]
pub struct ArchSpec {
    /// The network, in order.  Invariants (enforced by [`ArchSpec::build`]):
    /// starts with a `Conv`, ends with `Fc` + `SoftmaxXent`, convs are
    /// separated only by mid ops.
    pub layers: Vec<LayerSpec>,
    pub batch: usize,
    /// Square input extent (CIFAR-10: 32).
    pub img: usize,
    pub in_ch: usize,
    /// FC output width == class count (derived from `Fc`).
    pub num_classes: usize,
    /// Batch buckets for the fused `grad_full` executables.
    pub batch_buckets: Vec<usize>,
    pub probe: ProbeSpec,
    /// Derived per-conv geometry, in conv order (index 0 = conv1).
    pub convs: Vec<ConvInfo>,
    /// Flattened FC input width (`last_k * last_mid_out^2`).
    pub fc_in: usize,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub param_order: Vec<String>,
}

impl ArchSpec {
    /// Canonical FC parameter names.
    pub const FC_W: &'static str = "fc.w";
    pub const FC_B: &'static str = "fc.b";

    /// Canonical weight name of conv layer `l` (1-based).
    pub fn conv_weight(layer: usize) -> String {
        format!("conv{layer}.w")
    }

    /// Canonical bias name of conv layer `l` (1-based).
    pub fn conv_bias(layer: usize) -> String {
        format!("conv{layer}.b")
    }

    /// Shape inference: walk `layers` over a `batch x in_ch x img x img`
    /// input, validating the graph and deriving every downstream contract
    /// (conv geometry, mid segments, param names/shapes/order, bucket
    /// ladders, probe).
    pub fn build(
        batch: usize,
        img: usize,
        in_ch: usize,
        layers: Vec<LayerSpec>,
    ) -> Result<ArchSpec> {
        ensure!(batch > 0 && img > 0 && in_ch > 0, "degenerate input geometry");
        let mut convs: Vec<ConvInfo> = Vec::new();
        let mut c = in_ch;
        let mut hw = img;
        let mut fc: Option<(usize, usize)> = None;
        let mut saw_loss = false;
        for (i, l) in layers.iter().enumerate() {
            ensure!(
                fc.is_none() || matches!(l, LayerSpec::SoftmaxXent),
                "layer {i}: only SoftmaxXent may follow Fc"
            );
            match *l {
                LayerSpec::Conv { k, kh, kw } => {
                    ensure!(k > 0 && kh > 0 && kw > 0, "layer {i}: degenerate conv");
                    ensure!(
                        kh == kw,
                        "layer {i}: non-square {kh}x{kw} kernel (activations are square)"
                    );
                    ensure!(
                        hw >= kh,
                        "layer {i}: {kh}x{kw} conv does not fit a {hw}x{hw} input"
                    );
                    let out = hw - kh + 1;
                    convs.push(ConvInfo {
                        k,
                        kh,
                        kw,
                        in_ch: c,
                        in_hw: hw,
                        out_hw: out,
                        mid_out_hw: out,
                        mid_ops: Vec::new(),
                        buckets: bucket_ladder(k),
                    });
                    c = k;
                    hw = out;
                }
                LayerSpec::Lrn | LayerSpec::Relu => {
                    let Some(last) = convs.last_mut() else {
                        bail!("layer {i}: {l:?} before the first conv");
                    };
                    last.mid_ops.push(if matches!(l, LayerSpec::Lrn) {
                        MidOp::Lrn
                    } else {
                        MidOp::Relu
                    });
                }
                LayerSpec::MaxPool2 => {
                    let Some(last) = convs.last_mut() else {
                        bail!("layer {i}: MaxPool2 before the first conv");
                    };
                    ensure!(hw % 2 == 0, "layer {i}: maxpool2 needs an even extent, got {hw}");
                    hw /= 2;
                    last.mid_ops.push(MidOp::MaxPool2);
                    last.mid_out_hw = hw;
                }
                LayerSpec::Fc { out } => {
                    ensure!(!convs.is_empty(), "graph needs at least one conv before Fc");
                    ensure!(out > 0, "layer {i}: zero-width Fc");
                    fc = Some((c * hw * hw, out));
                }
                LayerSpec::SoftmaxXent => {
                    ensure!(fc.is_some(), "layer {i}: SoftmaxXent must follow Fc");
                    ensure!(!saw_loss, "layer {i}: duplicate SoftmaxXent");
                    saw_loss = true;
                }
            }
        }
        let Some((fc_in, num_classes)) = fc else {
            bail!("graph has no Fc head");
        };
        ensure!(saw_loss, "graph must end in SoftmaxXent");

        let mut param_shapes = BTreeMap::new();
        let mut param_order = Vec::new();
        for (li, cv) in convs.iter().enumerate() {
            let (wn, bn) = (Self::conv_weight(li + 1), Self::conv_bias(li + 1));
            param_shapes.insert(wn.clone(), vec![cv.k, cv.in_ch, cv.kh, cv.kw]);
            param_shapes.insert(bn.clone(), vec![cv.k]);
            param_order.push(wn);
            param_order.push(bn);
        }
        param_shapes.insert(Self::FC_W.to_string(), vec![fc_in, num_classes]);
        param_shapes.insert(Self::FC_B.to_string(), vec![num_classes]);
        param_order.push(Self::FC_W.to_string());
        param_order.push(Self::FC_B.to_string());

        // Batch buckets: halve down to batch/8 (model.py's ladder), so the
        // data-parallel baseline finds a grad_full for every replica split.
        let mut batch_buckets = vec![batch];
        let mut bb = batch;
        while bb % 2 == 0 && bb > std::cmp::max(2, batch / 8) {
            bb /= 2;
            batch_buckets.push(bb);
        }
        batch_buckets.sort_unstable();

        // Probe sized so one round is ~milliseconds: big enough to time,
        // small enough that calibration never dominates a test run.  The
        // probe convolves with the first conv layer's kernel geometry.
        let (pkh, pkw) = (convs[0].kh, convs[0].kw);
        let probe_img = 24usize.max(pkh);
        let (po_h, po_w) = (probe_img - pkh + 1, probe_img - pkw + 1);
        let probe = ProbeSpec {
            batch: 8,
            in_ch: 3,
            img: probe_img,
            k: 8,
            kh: pkh,
            kw: pkw,
            flops: 2 * (8 * po_h * po_w * 3 * pkh * pkw * 8) as u64,
        };

        Ok(ArchSpec {
            layers,
            batch,
            img,
            in_ch,
            num_classes,
            batch_buckets,
            probe,
            convs,
            fc_in,
            param_shapes,
            param_order,
        })
    }

    /// Build a full spec from the paper's `k1:k2 @ batch` notation with the
    /// fixed CIFAR-10 geometry (32x32x3, 5x5 kernels, /2 pools, 10 classes)
    /// — the same derivation as `python/compile/model.py::ArchConfig`.
    pub fn from_geometry(k1: usize, k2: usize, batch: usize) -> ArchSpec {
        Self::build(
            batch,
            32,
            3,
            vec![
                LayerSpec::Conv { k: k1, kh: 5, kw: 5 },
                LayerSpec::Lrn,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: k2, kh: 5, kw: 5 },
                LayerSpec::Lrn,
                LayerSpec::MaxPool2,
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent,
            ],
        )
        .expect("paper geometry is a valid graph")
    }

    /// The architecture the native backend synthesizes when no
    /// `manifest.json` is present: the `python/compile` default (16:32 @ 64,
    /// CIFAR-10 geometry), including its bucket ladders.
    pub fn native_default() -> ArchSpec {
        ArchSpec::from_geometry(16, 32, 64)
    }

    /// A deliberately small architecture (4:8 @ batch 2) for unit and
    /// integration tests — steps complete in milliseconds on one core.
    pub fn tiny() -> ArchSpec {
        ArchSpec::from_geometry(4, 8, 2)
    }

    /// A 3-conv CIFAR network the old two-conv API could not express:
    /// `32@5x5 -> lrn -> pool -> 48@3x3 -> relu -> pool -> 64@3x3 -> relu
    /// -> pool -> fc(10)` (spatial chain 32 -> 28 -> 14 -> 12 -> 6 -> 4 ->
    /// 2, so `fc_in = 64*2*2 = 256`).
    pub fn deep_cifar() -> ArchSpec {
        Self::build(
            64,
            32,
            3,
            vec![
                LayerSpec::Conv { k: 32, kh: 5, kw: 5 },
                LayerSpec::Lrn,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: 48, kh: 3, kw: 3 },
                LayerSpec::Relu,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: 64, kh: 3, kw: 3 },
                LayerSpec::Relu,
                LayerSpec::MaxPool2,
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent,
            ],
        )
        .expect("deep_cifar is a valid graph")
    }

    /// The test-scale counterpart of [`ArchSpec::deep_cifar`]: three conv
    /// layers (4:6:8) at batch 2, with a bare-pool mid segment on conv3 to
    /// exercise the non-LRN path.
    pub fn tiny_deep() -> ArchSpec {
        Self::build(
            2,
            32,
            3,
            vec![
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::Lrn,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: 6, kh: 3, kw: 3 },
                LayerSpec::Relu,
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: 8, kh: 3, kw: 3 },
                LayerSpec::MaxPool2,
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent,
            ],
        )
        .expect("tiny_deep is a valid graph")
    }

    /// The same network at a different batch size: shape inference re-runs
    /// (batch ladders depend on the batch), then the per-conv bucket-ladder
    /// overrides and the probe carry over — batch does not change kernel
    /// geometry, so a manifest-pinned ladder stays valid.  Replica fleets
    /// use this to compile each fleet at its slice of the global batch
    /// ([`crate::session::SessionBuilder::replicas`]); the resulting spec
    /// shares [`ArchSpec::label`] with the original, so checkpoints move
    /// freely between batch variants of one architecture.
    pub fn with_batch(&self, batch: usize) -> Result<ArchSpec> {
        let mut arch = Self::build(batch, self.img, self.in_ch, self.layers.clone())?;
        for (cv, orig) in arch.convs.iter_mut().zip(&self.convs) {
            cv.buckets = orig.buckets.clone();
        }
        arch.probe = self.probe.clone();
        Ok(arch)
    }

    /// Named presets selectable from the CLI's `--arch` (and the e2e
    /// example's `[arch]` argument).
    pub fn preset(name: &str) -> Option<ArchSpec> {
        match name {
            "default" | "paper" => Some(Self::native_default()),
            "tiny" => Some(Self::tiny()),
            "deep_cifar" => Some(Self::deep_cifar()),
            "tiny_deep" => Some(Self::tiny_deep()),
            _ => None,
        }
    }

    /// Number of (distributable) conv layers.
    pub fn num_convs(&self) -> usize {
        self.convs.len()
    }

    /// Geometry of conv layer `l` (1-based, matching the executable names).
    pub fn conv(&self, layer: usize) -> &ConvInfo {
        assert!(
            (1..=self.convs.len()).contains(&layer),
            "conv layer {layer} out of range 1..={}",
            self.convs.len()
        );
        &self.convs[layer - 1]
    }

    /// Kernel count of conv layer `l` (1-based, matching the paper's C1/C2).
    pub fn kernels(&self, layer: usize) -> usize {
        self.conv(layer).k
    }

    pub fn buckets(&self, layer: usize) -> &[usize] {
        &self.conv(layer).buckets
    }

    /// Input (channels, extent) of conv layer `l`.
    pub fn conv_input(&self, layer: usize) -> (usize, usize) {
        let cv = self.conv(layer);
        (cv.in_ch, cv.in_hw)
    }

    /// Output extent of conv layer `l`.
    pub fn conv_output(&self, layer: usize) -> usize {
        self.conv(layer).out_hw
    }

    /// Kernel (kh, kw) of conv layer `l`.
    pub fn conv_kernel(&self, layer: usize) -> (usize, usize) {
        let cv = self.conv(layer);
        (cv.kh, cv.kw)
    }

    /// Mid-segment ops of conv layer `l` (between it and the next conv/FC).
    pub fn mid_ops(&self, layer: usize) -> &[MidOp] {
        &self.conv(layer).mid_ops
    }

    /// Extent after conv layer `l`'s mid segment.
    pub fn mid_output(&self, layer: usize) -> usize {
        self.conv(layer).mid_out_hw
    }

    /// `k1:k2:...:kN` — the paper's notation, extended to N convs.
    pub fn label(&self) -> String {
        self.convs.iter().map(|c| c.k.to_string()).collect::<Vec<_>>().join(":")
    }

    /// Forward FLOPs of `k` kernels of conv layer `layer` at batch `batch`
    /// (one multiply-add = 2 FLOPs per tap per output pixel).  The single
    /// source of conv FLOP arithmetic — executable specs, telemetry layer
    /// weights and the comp-share pricing all derive from it, so a future
    /// stride/padding variant changes the accounting in exactly one place.
    pub fn conv_layer_flops(&self, layer: usize, k: usize, batch: usize) -> f64 {
        let cv = self.conv(layer);
        2.0 * batch as f64
            * (cv.out_hw * cv.out_hw) as f64
            * cv.in_ch as f64
            * (cv.kh * cv.kw) as f64
            * k as f64
    }

    /// Forward conv FLOPs of the whole network at batch size `batch`.
    pub fn conv_flops_fwd_at(&self, batch: usize) -> f64 {
        (1..=self.num_convs())
            .map(|l| self.conv_layer_flops(l, self.kernels(l), batch))
            .sum()
    }

    // -- JSON (manifest `config` block) -------------------------------------

    /// Parse either manifest-config schema: the layer-graph form (a
    /// `"layers"` array) or the legacy two-conv `k1`/`k2` form, which is
    /// converted into the equivalent graph (same executables, same shapes —
    /// only the parameter names move to the canonical `convN.w` scheme).
    pub(crate) fn from_json(v: &Json) -> Result<Self> {
        if v.opt("layers").is_some() {
            Self::from_json_graph(v)
        } else {
            Self::from_json_legacy(v)
        }
    }

    /// Parse a standalone architecture document (either schema) — the
    /// session API's graph-file arch source and the inline `arch` object of
    /// an experiment config both load through this.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("parsing architecture JSON")?)
    }

    fn from_json_graph(v: &Json) -> Result<Self> {
        let mut layers = Vec::new();
        for (i, lv) in v.get("layers")?.as_arr()?.iter().enumerate() {
            let op = lv.get("op")?.as_str()?;
            layers.push(match op {
                "conv" => LayerSpec::Conv {
                    k: lv.get("k")?.as_usize()?,
                    kh: lv.get("kh")?.as_usize()?,
                    kw: lv.get("kw")?.as_usize()?,
                },
                "lrn" => LayerSpec::Lrn,
                "maxpool2" => LayerSpec::MaxPool2,
                "relu" => LayerSpec::Relu,
                "fc" => LayerSpec::Fc { out: lv.get("out")?.as_usize()? },
                "softmax_xent" => LayerSpec::SoftmaxXent,
                other => bail!("layer {i}: unknown op {other:?}"),
            });
        }
        let mut arch = Self::build(
            v.get("batch")?.as_usize()?,
            v.get("img")?.as_usize()?,
            v.get("in_ch")?.as_usize()?,
            layers,
        )?;
        if let Some(bb) = v.opt("batch_buckets") {
            arch.batch_buckets = bb.as_usize_vec()?;
        }
        if let Some(bk) = v.opt("buckets") {
            let lists = bk.as_arr()?;
            ensure!(
                lists.len() == arch.convs.len(),
                "buckets has {} ladders for {} conv layers",
                lists.len(),
                arch.convs.len()
            );
            for (cv, lv) in arch.convs.iter_mut().zip(lists) {
                let ladder = lv.as_usize_vec()?;
                ensure!(
                    ladder.last() == Some(&cv.k),
                    "bucket ladder {ladder:?} must end at k={}",
                    cv.k
                );
                cv.buckets = ladder;
            }
        }
        if let Some(p) = v.opt("probe") {
            arch.probe = ProbeSpec::from_json(p, arch.convs[0].kh, arch.convs[0].kw)?;
        }
        Ok(arch)
    }

    /// The pre-graph schema: explicit `k1`/`k2` fields plus spelled-out
    /// derived geometry.  Converted to the equivalent two-conv graph; every
    /// derived quantity the file pins is cross-checked against inference so
    /// a stale or inconsistent manifest fails loudly instead of silently
    /// training a different network.
    fn from_json_legacy(v: &Json) -> Result<Self> {
        let (k1, k2) = (v.get("k1")?.as_usize()?, v.get("k2")?.as_usize()?);
        let num_classes = v.get("num_classes")?.as_usize()?;
        let (kh, kw) = (v.get("kh")?.as_usize()?, v.get("kw")?.as_usize()?);
        let layers = vec![
            LayerSpec::Conv { k: k1, kh, kw },
            LayerSpec::Lrn,
            LayerSpec::MaxPool2,
            LayerSpec::Conv { k: k2, kh, kw },
            LayerSpec::Lrn,
            LayerSpec::MaxPool2,
            LayerSpec::Fc { out: num_classes },
            LayerSpec::SoftmaxXent,
        ];
        let mut arch = Self::build(
            v.get("batch")?.as_usize()?,
            v.get("img")?.as_usize()?,
            v.get("in_ch")?.as_usize()?,
            layers,
        )?;
        for (key, got) in [
            ("c1_out", arch.convs[0].out_hw),
            ("p1_out", arch.convs[0].mid_out_hw),
            ("c2_out", arch.convs[1].out_hw),
            ("p2_out", arch.convs[1].mid_out_hw),
            ("fc_in", arch.fc_in),
        ] {
            let want = v.get(key)?.as_usize()?;
            ensure!(got == want, "legacy manifest says {key}={want} but the graph derives {got}");
        }
        arch.convs[0].buckets = v.get("buckets1")?.as_usize_vec()?;
        arch.convs[1].buckets = v.get("buckets2")?.as_usize_vec()?;
        arch.batch_buckets = v.get("batch_buckets")?.as_usize_vec()?;
        arch.probe = ProbeSpec::from_json(v.get("probe")?, kh, kw)?;
        if let Some(shapes) = v.opt("param_shapes") {
            for (old, new) in [
                ("w1", Self::conv_weight(1)),
                ("b1", Self::conv_bias(1)),
                ("w2", Self::conv_weight(2)),
                ("b2", Self::conv_bias(2)),
                ("wf", Self::FC_W.to_string()),
                ("bf", Self::FC_B.to_string()),
            ] {
                if let Some(s) = shapes.opt(old) {
                    let got = s.as_usize_vec()?;
                    ensure!(
                        got == arch.param_shapes[&new],
                        "legacy param {old} shape {got:?} != derived {new} {:?}",
                        arch.param_shapes[&new]
                    );
                }
            }
        }
        Ok(arch)
    }

    /// Serialize as the layer-graph manifest-config schema (the inverse of
    /// [`ArchSpec::from_json`] on the graph form; derived fields are
    /// recomputed on parse, overrides carry the ladders and probe).
    pub fn to_json(&self) -> String {
        let layers: Vec<String> = self
            .layers
            .iter()
            .map(|l| match *l {
                LayerSpec::Conv { k, kh, kw } => {
                    format!("{{\"op\": \"conv\", \"k\": {k}, \"kh\": {kh}, \"kw\": {kw}}}")
                }
                LayerSpec::Lrn => "{\"op\": \"lrn\"}".to_string(),
                LayerSpec::MaxPool2 => "{\"op\": \"maxpool2\"}".to_string(),
                LayerSpec::Relu => "{\"op\": \"relu\"}".to_string(),
                LayerSpec::Fc { out } => format!("{{\"op\": \"fc\", \"out\": {out}}}"),
                LayerSpec::SoftmaxXent => "{\"op\": \"softmax_xent\"}".to_string(),
            })
            .collect();
        let buckets: Vec<String> =
            self.convs.iter().map(|c| json_usize_arr(&c.buckets)).collect();
        let p = &self.probe;
        format!(
            "{{\"layers\": [{}], \"batch\": {}, \"img\": {}, \"in_ch\": {}, \
             \"batch_buckets\": {}, \"buckets\": [{}], \
             \"probe\": {{\"batch\": {}, \"in_ch\": {}, \"img\": {}, \"k\": {}, \
             \"kh\": {}, \"kw\": {}, \"flops\": {}}}}}",
            layers.join(", "),
            self.batch,
            self.img,
            self.in_ch,
            json_usize_arr(&self.batch_buckets),
            buckets.join(", "),
            p.batch,
            p.in_ch,
            p.img,
            p.k,
            p.kh,
            p.kw,
            p.flops
        )
    }
}

/// `[1, 2, 3]` — JSON array of usizes.
pub(crate) fn json_usize_arr(v: &[usize]) -> String {
    let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Shard-size buckets for a conv layer with `k` kernels: eighths of `k`,
/// rounded up to a multiple of 4 — bounds bucket-padding waste by ~12.5 %
/// worst-case (DESIGN.md §3; mirrors `model.py::bucket_ladder`).
pub fn bucket_ladder(k: usize) -> Vec<usize> {
    let steps = 8usize;
    let mut buckets: Vec<usize> = (1..=steps)
        .map(|i| (k * i + steps - 1) / steps) // ceil(k*i/8)
        .map(|r| std::cmp::min(k, (r + 3) / 4 * 4))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    debug_assert_eq!(*buckets.last().unwrap(), k);
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_geometry_matches_hand_computed_tiny() {
        let a = ArchSpec::tiny();
        assert_eq!(a.num_convs(), 2);
        assert_eq!((a.kernels(1), a.kernels(2), a.batch), (4, 8, 2));
        assert_eq!(
            (a.conv_output(1), a.mid_output(1), a.conv_output(2), a.mid_output(2)),
            (28, 14, 10, 5)
        );
        assert_eq!(a.conv_input(2), (4, 14));
        assert_eq!(a.fc_in, 200);
        assert_eq!(a.buckets(1), &[4]);
        assert_eq!(a.buckets(2), &[4, 8]);
        assert_eq!(a.batch_buckets, vec![2]);
        assert_eq!(a.param_shapes["conv2.w"], vec![8, 4, 5, 5]);
        assert_eq!(a.param_shapes[ArchSpec::FC_W], vec![200, 10]);
        assert_eq!(
            a.param_order,
            vec!["conv1.w", "conv1.b", "conv2.w", "conv2.b", "fc.w", "fc.b"]
        );
        assert_eq!(a.mid_ops(1), &[MidOp::Lrn, MidOp::MaxPool2]);
        assert_eq!(a.label(), "4:8");
    }

    #[test]
    fn native_default_matches_python_archconfig() {
        let a = ArchSpec::native_default();
        assert_eq!((a.kernels(1), a.kernels(2), a.batch), (16, 32, 64));
        assert_eq!(a.fc_in, 32 * 5 * 5);
        assert_eq!(a.buckets(1), &[4, 8, 12, 16]);
        assert_eq!(a.buckets(2), &[4, 8, 12, 16, 20, 24, 28, 32]);
        assert_eq!(a.batch_buckets, vec![8, 16, 32, 64]);
        assert!(a.probe.flops > 0);
        assert_eq!((a.probe.kh, a.probe.kw), (5, 5));
    }

    #[test]
    fn deep_cifar_expresses_three_convs() {
        let a = ArchSpec::deep_cifar();
        assert_eq!(a.num_convs(), 3);
        assert_eq!((a.kernels(1), a.kernels(2), a.kernels(3)), (32, 48, 64));
        // Spatial chain 32 -> 28 -> 14 -> 12 -> 6 -> 4 -> 2.
        assert_eq!((a.conv_output(1), a.mid_output(1)), (28, 14));
        assert_eq!((a.conv_output(2), a.mid_output(2)), (12, 6));
        assert_eq!((a.conv_output(3), a.mid_output(3)), (4, 2));
        assert_eq!(a.fc_in, 64 * 2 * 2);
        assert_eq!(a.conv_kernel(2), (3, 3));
        assert_eq!(a.mid_ops(2), &[MidOp::Relu, MidOp::MaxPool2]);
        assert_eq!(a.label(), "32:48:64");
        assert_eq!(a.param_order.len(), 3 * 2 + 2);
    }

    #[test]
    fn tiny_deep_has_bare_pool_mid() {
        let a = ArchSpec::tiny_deep();
        assert_eq!(a.num_convs(), 3);
        assert_eq!(a.mid_ops(3), &[MidOp::MaxPool2]);
        assert_eq!(a.fc_in, 8 * 2 * 2);
        assert_eq!(a.batch, 2);
    }

    #[test]
    fn build_rejects_malformed_graphs() {
        // No conv at all.
        assert!(ArchSpec::build(
            2,
            32,
            3,
            vec![LayerSpec::Fc { out: 10 }, LayerSpec::SoftmaxXent]
        )
        .is_err());
        // Mid op before the first conv.
        assert!(ArchSpec::build(
            2,
            32,
            3,
            vec![
                LayerSpec::Lrn,
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent
            ]
        )
        .is_err());
        // Missing loss.
        assert!(ArchSpec::build(
            2,
            32,
            3,
            vec![LayerSpec::Conv { k: 4, kh: 5, kw: 5 }, LayerSpec::Fc { out: 10 }]
        )
        .is_err());
        // Conv after Fc.
        assert!(ArchSpec::build(
            2,
            32,
            3,
            vec![
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::Fc { out: 10 },
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::SoftmaxXent
            ]
        )
        .is_err());
        // Odd extent into a pool: 32 - 5 + 1 = 28 pools fine, but 28/2 = 14,
        // 14 - 4 + 1 = 11 is odd.
        assert!(ArchSpec::build(
            2,
            32,
            3,
            vec![
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::MaxPool2,
                LayerSpec::Conv { k: 4, kh: 4, kw: 4 },
                LayerSpec::MaxPool2,
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent
            ]
        )
        .is_err());
        // Conv bigger than its input.
        assert!(ArchSpec::build(
            2,
            4,
            3,
            vec![
                LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
                LayerSpec::Fc { out: 10 },
                LayerSpec::SoftmaxXent
            ]
        )
        .is_err());
    }

    #[test]
    fn bucket_ladder_covers_and_caps() {
        for k in [4usize, 16, 32, 50, 500, 1500] {
            let l = bucket_ladder(k);
            assert_eq!(*l.last().unwrap(), k, "ladder for {k} must end at {k}");
            assert!(l.windows(2).all(|w| w[0] < w[1]), "sorted/deduped for {k}");
            assert!(l.iter().all(|&b| b <= k));
        }
    }

    #[test]
    fn with_batch_rebuilds_ladder_and_keeps_kernel_geometry() {
        let a = ArchSpec::from_geometry(16, 32, 64);
        let half = a.with_batch(32).unwrap();
        assert_eq!(half.batch, 32);
        assert_eq!(half.batch_buckets, vec![4, 8, 16, 32]);
        assert_eq!(half.convs, a.convs, "kernel geometry and ladders must carry over");
        assert_eq!(half.label(), a.label(), "label excludes batch");
        assert_eq!(half.param_shapes, a.param_shapes);
        assert_eq!(half.probe.flops, a.probe.flops);
        assert!(a.with_batch(0).is_err());
    }

    #[test]
    fn graph_json_roundtrips() {
        for arch in [ArchSpec::tiny(), ArchSpec::native_default(), ArchSpec::deep_cifar()] {
            let doc = arch.to_json();
            let v = Json::parse(&doc).unwrap();
            let back = ArchSpec::from_json(&v).unwrap();
            assert_eq!(back.layers, arch.layers);
            assert_eq!(back.batch, arch.batch);
            assert_eq!(back.convs, arch.convs);
            assert_eq!(back.fc_in, arch.fc_in);
            assert_eq!(back.param_shapes, arch.param_shapes);
            assert_eq!(back.param_order, arch.param_order);
            assert_eq!(back.batch_buckets, arch.batch_buckets);
            assert_eq!(back.probe.flops, arch.probe.flops);
        }
    }

    #[test]
    fn conv_flops_scale_with_batch_and_depth() {
        let a = ArchSpec::tiny();
        assert!(a.conv_flops_fwd_at(4) > a.conv_flops_fwd_at(2));
        // Hand count, conv1 of tiny: 2*B*28^2*3*25*4.
        let l1 = 2.0 * 2.0 * 784.0 * 3.0 * 25.0 * 4.0;
        let l2 = 2.0 * 2.0 * 100.0 * 4.0 * 25.0 * 8.0;
        assert!((a.conv_flops_fwd_at(2) - (l1 + l2)).abs() < 1.0);
    }
}
