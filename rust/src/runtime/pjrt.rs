//! The original AOT-HLO / PJRT execution path, behind the off-by-default
//! `pjrt` cargo feature.
//!
//! Flow (when linked against a real PJRT client): Python lowers the JAX
//! segments to HLO text (`python/compile/aot.py`), `manifest.json` records
//! every executable's signature, and the backend compiles
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute` lazily
//! per name (the runtime's per-name once cell already serializes that).
//!
//! The external `xla` crate is **not vendored** in this offline tree, so
//! this build is a stub: it still exercises the manifest/artifact plumbing
//! (paths, existence checks, signatures) and fails at `prepare` time with an
//! actionable error instead of failing the whole build.  Swapping the body
//! of [`PjrtBackend::prepare`] for the real compile call is the only change
//! needed once an `xla`/PJRT dependency is available (DESIGN.md §4).

use std::path::PathBuf;

use anyhow::{bail, ensure, Result};

use super::{Backend, ExecutableSpec, PreparedExec};

pub struct PjrtBackend {
    dir: PathBuf,
}

impl PjrtBackend {
    pub fn new(dir: PathBuf) -> Self {
        Self { dir }
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        "pjrt-cpu (offline stub)".into()
    }

    fn prepare(&self, name: &str, spec: &ExecutableSpec) -> Result<Box<dyn PreparedExec>> {
        let path = self.dir.join(&spec.file);
        ensure!(
            path.exists(),
            "HLO artifact {} for {name} is missing — run `make artifacts` (python/compile/aot.py)",
            path.display()
        );
        bail!(
            "pjrt backend: this build carries the offline stub; compiling {} requires linking \
             the external `xla`/PJRT crate (see DESIGN.md §4). Use the default native backend \
             (unset CONVDIST_BACKEND) to run without artifacts.",
            path.display()
        )
    }
}
