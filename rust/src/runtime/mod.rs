//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! client from the rust hot path.  Python never runs at request time.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily on first use and cached; the manifest
//! drives all shape/dtype validation.

mod manifest;

pub use manifest::{ArchSpec, ArgSpec, ConvDir, ExecutableSpec, Manifest, ProbeSpec};

#[cfg(test)]
pub(crate) use manifest::tests::tiny_arch;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::tensor::{ITensor, Tensor, Value};

/// Converts the `xla` crate's error type (which is not `Sync`) into eyre.
fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// A compiled-executable handle plus its manifest signature.
struct CachedExec {
    exe: xla::PjRtLoadedExecutable,
    spec: ExecutableSpec,
}

/// Cumulative execution statistics, per executable (feeds §Perf and the
/// Comm/Conv/Comp breakdowns of Figures 6/8).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
}

/// The L3-side runtime: one PJRT CPU client + a lazy executable cache.
///
/// `Runtime` is shared behind `Arc`: compilation and stats are mutex-guarded,
/// execution itself is reentrant.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<CachedExec>>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Arc<Self>> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr).context("creating PJRT CPU client")?;
        Ok(Arc::new(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.manifest.config
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named executable.
    fn get(&self, name: &str) -> Result<Arc<CachedExec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        // Compile outside the lock: first-touch compiles of different
        // executables can proceed in parallel across worker threads.
        let spec = self.manifest.spec(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(xerr)
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(xerr)
            .with_context(|| format!("compiling {name}"))?;
        let cached = Arc::new(CachedExec { exe, spec });
        self.cache
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| cached.clone());
        Ok(cached)
    }

    /// Pre-compile a set of executables (used at cluster start-up so the
    /// first training batch is not billed the compile time).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Execute `name` with `args`, validating the call against the manifest.
    /// Returns the output tensors in manifest order.
    pub fn execute(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let cached = self.get(name)?;
        let spec = &cached.spec;
        ensure!(
            args.len() == spec.args.len(),
            "{name}: expected {} args, got {}",
            spec.args.len(),
            args.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (v, a) in args.iter().zip(&spec.args) {
            ensure!(
                v.shape() == a.shape(),
                "{name}: arg {:?} shape {:?} != manifest {:?}",
                a.name(),
                v.shape(),
                a.shape()
            );
            ensure!(
                v.dtype() == a.dtype(),
                "{name}: arg {:?} dtype {} != manifest {}",
                a.name(),
                v.dtype(),
                a.dtype()
            );
            literals.push(to_literal(v)?);
        }

        let t0 = Instant::now();
        let bufs = cached.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        // return_tuple=True in aot.py: one output buffer holding a tuple.
        let tuple = bufs[0][0].to_literal_sync().map_err(xerr)?;
        let elapsed = t0.elapsed();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.total += elapsed;
        }

        let parts = tuple.to_tuple().map_err(xerr)?;
        ensure!(
            parts.len() == spec.outs.len(),
            "{name}: executable returned {} outputs, manifest says {}",
            parts.len(),
            spec.outs.len()
        );
        parts
            .into_iter()
            .zip(&spec.outs)
            .map(|(lit, o)| from_literal(&lit, o))
            .collect()
    }

    /// Execute and also report the wall-clock compute time (the Throttle
    /// emulation and the calibration probe need the raw duration).
    pub fn execute_timed(&self, name: &str, args: &[Value]) -> Result<(Vec<Value>, Duration)> {
        let t0 = Instant::now();
        let outs = self.execute(name, args)?;
        Ok((outs, t0.elapsed()))
    }

    /// Nominal FLOPs of one execution of `name` (0 if unknown).
    pub fn flops(&self, name: &str) -> u64 {
        self.manifest.spec(name).map(|s| s.flops).unwrap_or(0)
    }

    /// Snapshot of per-executable cumulative stats, slowest first.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total.cmp(&a.1.total));
        v
    }
}

fn to_literal(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    match v {
        Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims).map_err(xerr),
        Value::I32(t) => xla::Literal::vec1(t.data()).reshape(&dims).map_err(xerr),
    }
}

fn from_literal(lit: &xla::Literal, spec: &ArgSpec) -> Result<Value> {
    let shape = spec.shape().to_vec();
    match spec.dtype() {
        "f32" => Ok(Value::F32(Tensor::new(shape, lit.to_vec::<f32>().map_err(xerr)?)?)),
        "i32" => Ok(Value::I32(ITensor::new(shape, lit.to_vec::<i32>().map_err(xerr)?)?)),
        d => Err(anyhow!("unsupported dtype {d} in manifest")),
    }
}
