//! Execution runtime: a manifest-validated executable cache over a pluggable
//! [`Backend`].
//!
//! Two backends implement the same executable contract (see DESIGN.md §4):
//!
//! * [`native::NativeBackend`] (default) — pure-rust CPU kernels
//!   ([`crate::kernels`]): im2col + blocked-GEMM convolutions, max-pool, LRN,
//!   FC and softmax-cross-entropy, rayon-parallel over the batch axis.
//!   Needs no artifacts: when `manifest.json` is absent the manifest is
//!   synthesized from [`ArchSpec::native_default`].
//! * `pjrt` (cargo feature `pjrt`, off by default) — the original AOT-HLO
//!   path: Python lowers the JAX segments to HLO text (`python/compile/`)
//!   and an external PJRT client executes them.  The `xla` crate is not
//!   vendored offline, so the in-tree build is a stub that fails at
//!   preparation time with an actionable error (DESIGN.md §4).
//!
//! Executables are prepared lazily on first use under a **per-name once
//! cell**: two threads first-touching the same name block on that name only
//! (one prepares, both get the same handle), while different names prepare
//! in parallel.  A failed preparation is not cached — the next caller
//! retries.

mod exec;
mod graph;
mod manifest;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use exec::{native_manifest, spec_for, ExecKind};
pub use graph::{bucket_ladder, ArchSpec, ConvInfo, LayerSpec, MidOp, ProbeSpec};
pub use manifest::{ArgSpec, ConvDir, ExecutableSpec, Manifest};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

#[cfg(test)]
pub(crate) use manifest::tests::tiny_arch;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::tensor::Value;

/// An execution engine: turns a manifest entry into something runnable.
pub trait Backend: Send + Sync {
    /// Human-readable platform tag (shown by the CLI at start-up).
    fn platform(&self) -> String;
    /// Prepare (parse/compile) one executable.  Called at most once per name
    /// per [`Runtime`] — the runtime serializes first-touch per name.
    fn prepare(&self, name: &str, spec: &ExecutableSpec) -> Result<Box<dyn PreparedExec>>;
}

/// A compiled/parsed executable, ready to run.  `run` must be reentrant.
pub trait PreparedExec: Send + Sync {
    fn run(&self, args: &[Value]) -> Result<Vec<Value>>;
}

/// Cumulative execution statistics, per executable (feeds §Perf and the
/// Comm/Conv/Comp breakdowns of Figures 6/8).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
}

/// Per-name once cell: the `Option` is filled exactly once, under the
/// per-name mutex, so concurrent first-touches of one executable prepare it
/// a single time (the duplicate-compile race the old cache had).
#[derive(Default)]
struct ExecCell {
    slot: Mutex<Option<Arc<Prepared>>>,
}

struct Prepared {
    exe: Box<dyn PreparedExec>,
    spec: ExecutableSpec,
}

/// The L3-side runtime: one backend + a lazy executable cache.
///
/// `Runtime` is shared behind `Arc`: preparation and stats are mutex-guarded,
/// execution itself is reentrant.
pub struct Runtime {
    backend: Box<dyn Backend>,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<ExecCell>>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl Runtime {
    /// Open an artifact directory.  If it contains a `manifest.json` the
    /// manifest drives validation (and the PJRT backend, when selected);
    /// otherwise a manifest is synthesized from
    /// [`ArchSpec::native_default`] — a clean offline checkout needs no
    /// artifacts at all.  For a *different* synthesized architecture use
    /// [`Runtime::for_arch`] with an [`ArchSpec::preset`] (the CLI's
    /// `--arch` resolves through that path — deliberately an explicit
    /// argument, not ambient env state, so tests and parallel runs cannot
    /// be silently re-architected).
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref();
        let manifest = if dir.join("manifest.json").exists() {
            Manifest::load(dir)?
        } else {
            // An *explicitly requested* artifact dir with no manifest is a
            // user error (typo'd path, artifacts not built) — silently
            // training the synthesized default arch instead would be a trap.
            if let Ok(p) = std::env::var("CONVDIST_ARTIFACTS") {
                ensure!(
                    std::path::Path::new(&p) != dir,
                    "CONVDIST_ARTIFACTS={p} is set but contains no manifest.json — \
                     generate artifacts there first, or unset it to use the \
                     synthesized native-default architecture"
                );
            }
            exec::native_manifest(ArchSpec::native_default(), dir)
        };
        let backend = Self::select_backend(&manifest)?;
        Ok(Self::with_backend(backend, manifest))
    }

    /// A runtime over the native backend for an explicit architecture — no
    /// directory involved.  Tests and benches use this with
    /// [`ArchSpec::tiny`] / [`ArchSpec::tiny_deep`].
    pub fn for_arch(arch: ArchSpec) -> Arc<Self> {
        let manifest = exec::native_manifest(arch, std::path::Path::new("."));
        let backend = Box::new(NativeBackend::new(manifest.config.clone()));
        Self::with_backend(backend, manifest)
    }

    /// Assemble a runtime from an explicit backend + manifest.
    pub fn with_backend(backend: Box<dyn Backend>, manifest: Manifest) -> Arc<Self> {
        Arc::new(Self {
            backend,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Native by default; `CONVDIST_BACKEND=pjrt` selects the PJRT path
    /// (requires building with `--features pjrt`).
    fn select_backend(manifest: &Manifest) -> Result<Box<dyn Backend>> {
        if std::env::var("CONVDIST_BACKEND").as_deref() == Ok("pjrt") {
            #[cfg(feature = "pjrt")]
            {
                return Ok(Box::new(pjrt::PjrtBackend::new(manifest.dir.clone())));
            }
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!("CONVDIST_BACKEND=pjrt requires building with --features pjrt");
        }
        Ok(Box::new(NativeBackend::new(manifest.config.clone())))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.manifest.config
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Prepare (or fetch from cache) the named executable.
    fn get(&self, name: &str) -> Result<Arc<Prepared>> {
        let cell = {
            let mut cache = self.cache.lock().unwrap();
            cache.entry(name.to_string()).or_default().clone()
        };
        // Per-name lock: first-touches of *different* executables proceed in
        // parallel; first-touches of the same one prepare exactly once.
        let mut slot = cell.slot.lock().unwrap();
        if let Some(p) = slot.as_ref() {
            return Ok(p.clone());
        }
        let spec = self.manifest.spec(name)?.clone();
        let exe = self
            .backend
            .prepare(name, &spec)
            .with_context(|| format!("preparing executable {name}"))?;
        let prepared = Arc::new(Prepared { exe, spec });
        *slot = Some(prepared.clone());
        Ok(prepared)
    }

    /// Pre-prepare a set of executables (used at cluster start-up so the
    /// first training batch is not billed the compile time).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.get(n)?;
        }
        Ok(())
    }

    /// Execute `name` with `args`, validating the call against the manifest.
    /// Returns the output tensors in manifest order.
    pub fn execute(&self, name: &str, args: &[Value]) -> Result<Vec<Value>> {
        let prepared = self.get(name)?;
        let spec = &prepared.spec;
        ensure!(
            args.len() == spec.args.len(),
            "{name}: expected {} args, got {}",
            spec.args.len(),
            args.len()
        );
        for (v, a) in args.iter().zip(&spec.args) {
            ensure!(
                v.shape() == a.shape(),
                "{name}: arg {:?} shape {:?} != manifest {:?}",
                a.name(),
                v.shape(),
                a.shape()
            );
            ensure!(
                v.dtype() == a.dtype(),
                "{name}: arg {:?} dtype {} != manifest {}",
                a.name(),
                v.dtype(),
                a.dtype()
            );
        }

        let t0 = Instant::now();
        let outs = prepared.exe.run(args)?;
        let elapsed = t0.elapsed();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.total += elapsed;
        }

        ensure!(
            outs.len() == spec.outs.len(),
            "{name}: backend returned {} outputs, manifest says {}",
            outs.len(),
            spec.outs.len()
        );
        for (v, o) in outs.iter().zip(&spec.outs) {
            ensure!(
                v.shape() == o.shape() && v.dtype() == o.dtype(),
                "{name}: output {:?} is {:?}/{} but manifest says {:?}/{}",
                o.name(),
                v.shape(),
                v.dtype(),
                o.shape(),
                o.dtype()
            );
        }
        Ok(outs)
    }

    /// Execute and also report the wall-clock compute time (the Throttle
    /// emulation and the calibration probe need the raw duration).
    pub fn execute_timed(&self, name: &str, args: &[Value]) -> Result<(Vec<Value>, Duration)> {
        let t0 = Instant::now();
        let outs = self.execute(name, args)?;
        Ok((outs, t0.elapsed()))
    }

    /// Nominal FLOPs of one execution of `name` (0 if unknown).
    pub fn flops(&self, name: &str) -> u64 {
        self.manifest.spec(name).map(|s| s.flops).unwrap_or(0)
    }

    /// Snapshot of per-executable cumulative stats, slowest first.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total.cmp(&a.1.total));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ITensor, Pcg32, Tensor};
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Backend that counts prepare() calls — proves the once-cell semantics.
    struct CountingBackend {
        prepares: Arc<AtomicUsize>,
    }

    struct Nop;
    impl PreparedExec for Nop {
        fn run(&self, _args: &[Value]) -> Result<Vec<Value>> {
            Ok(vec![])
        }
    }

    impl Backend for CountingBackend {
        fn platform(&self) -> String {
            "counting".into()
        }
        fn prepare(&self, _name: &str, _spec: &ExecutableSpec) -> Result<Box<dyn PreparedExec>> {
            self.prepares.fetch_add(1, Ordering::SeqCst);
            // Make the race window wide enough to actually collide.
            std::thread::sleep(Duration::from_millis(20));
            Ok(Box::new(Nop))
        }
    }

    #[test]
    fn concurrent_first_touch_prepares_exactly_once() {
        let prepares = Arc::new(AtomicUsize::new(0));
        let manifest = native_manifest(tiny_arch(), std::path::Path::new("."));
        let rt = Runtime::with_backend(
            Box::new(CountingBackend { prepares: prepares.clone() }),
            manifest,
        );
        std::thread::scope(|s| {
            for _ in 0..8 {
                let rt = rt.clone();
                s.spawn(move || rt.warmup(&["probe"]).unwrap());
            }
        });
        assert_eq!(prepares.load(Ordering::SeqCst), 1, "probe must compile exactly once");
        // A different name prepares separately.
        rt.warmup(&["mid1_fwd"]).unwrap();
        assert_eq!(prepares.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn native_probe_and_validation() {
        let rt = Runtime::for_arch(tiny_arch());
        let p = rt.arch().probe.clone();
        let mut rng = Pcg32::seed(1);
        let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
        let w = Tensor::randn(&[p.k, p.in_ch, p.kh, p.kw], &mut rng);
        let b = Tensor::zeros(&[p.k]);
        let outs = rt
            .execute("probe", &[x.clone().into(), w.clone().into(), b.clone().into()])
            .unwrap();
        let po = p.img - p.kh + 1;
        assert_eq!(outs[0].shape(), &[p.batch, p.k, po, po]);
        // Shape mismatch is rejected before the backend runs.
        let bad = Tensor::zeros(&[1, 1, 2, 2]);
        assert!(rt.execute("probe", &[bad.into(), w.into(), b.into()]).is_err());
        // Unknown names are rejected via the manifest.
        assert!(rt.execute("conv9_fwd_b4", &[]).is_err());
        assert!(rt.flops("probe") > 0);
        assert_eq!(rt.platform(), "native-cpu");
    }

    #[test]
    fn native_head_grad_runs_end_to_end() {
        let rt = Runtime::for_arch(tiny_arch());
        let a = rt.arch().clone();
        let mut rng = Pcg32::seed(2);
        let p2 = Tensor::randn(&[a.batch, a.kernels(2), a.mid_output(2), a.mid_output(2)], &mut rng);
        let wf = Tensor::randn(&[a.fc_in, a.num_classes], &mut rng);
        let bf = Tensor::zeros(&[a.num_classes]);
        let labels = ITensor::new(vec![a.batch], vec![0; a.batch]).unwrap();
        let outs = rt
            .execute(
                "head_grad",
                &[p2.into(), wf.into(), bf.into(), labels.into()],
            )
            .unwrap();
        assert_eq!(outs.len(), 4);
        let loss = outs[0].as_f32().unwrap().item().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }
}
