//! Executable naming contract + spec synthesis for the native backend.
//!
//! The PJRT path learns each executable's signature from `manifest.json`;
//! the native backend *derives* the same signatures from the [`ArchSpec`]
//! layer graph, so a clean checkout needs no artifacts at all.  Both paths
//! meet at [`ExecutableSpec`]: `Runtime::execute` validates every call
//! against it regardless of which backend serves it.
//!
//! Names are generated per conv layer of the graph — `conv{L}_fwd_b{K}` /
//! `conv{L}_bwd_b{K}` for every bucket of layer `L`, `mid{L}_fwd` /
//! `mid{L}_bwd` for its master-resident mid segment — plus the generic
//! head (`head_grad`), `eval_full`, `probe` and the fused
//! `grad_full_b{B}` family.  A 3- or N-conv graph therefore enumerates to
//! a larger executable set with zero new code.

use std::collections::BTreeMap;
use std::path::Path;

use super::graph::MidOp;
use super::manifest::{ArgSpec, ExecutableSpec, Manifest};
use super::ArchSpec;

/// Every executable name the trainers dispatch, parsed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecKind {
    /// Calibration probe (paper §4.1.1).
    Probe,
    /// `conv{layer}_fwd_b{bucket}`: one conv layer's kernel-shard forward.
    ConvFwd { layer: usize, bucket: usize },
    /// `conv{layer}_bwd_b{bucket}`: shard backward -> (gx, gw, gb).
    ConvBwd { layer: usize, bucket: usize },
    /// `mid{layer}_fwd`: the master-resident mid segment after conv `layer`.
    MidFwd { layer: usize },
    /// `mid{layer}_bwd`: vjp of the mid segment (recompute-in-bwd).
    MidBwd { layer: usize },
    /// `head_grad`: FC + softmax loss and grads wrt (p, fc.w, fc.b).
    HeadGrad,
    /// `eval_full`: full-network logits for accuracy evaluation.
    EvalFull,
    /// `grad_full_b{batch}`: fused full-network fwd+bwd (baselines).
    GradFull { batch: usize },
    /// `conv{layer}_fwd_b{bucket}_n{batch}`: forward kernel shard at an
    /// explicit batch — the serving path, where the dynamic batcher picks a
    /// rung off `batch_buckets` instead of the training batch.
    ConvFwdAt { layer: usize, bucket: usize, batch: usize },
    /// `mid{layer}_fwd_n{batch}`: mid segment forward at an explicit batch.
    MidFwdAt { layer: usize, batch: usize },
    /// `head_logits_n{batch}`: FC head logits only (no loss/grads) — the
    /// forward-only tail of an inference session.
    HeadLogits { batch: usize },
}

impl ExecKind {
    /// Parse an executable name; `None` if it is not part of the contract.
    /// Layer indices are only syntax here — whether `conv7_fwd_b4` exists
    /// for a given architecture is the manifest's call, not the parser's.
    pub fn parse(name: &str) -> Option<ExecKind> {
        match name {
            "probe" => return Some(ExecKind::Probe),
            "head_grad" => return Some(ExecKind::HeadGrad),
            "eval_full" => return Some(ExecKind::EvalFull),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("grad_full_b") {
            return rest.parse().ok().map(|batch| ExecKind::GradFull { batch });
        }
        if let Some(rest) = name.strip_prefix("head_logits_n") {
            return rest.parse().ok().map(|batch| ExecKind::HeadLogits { batch });
        }
        if let Some(rest) = name.strip_prefix("conv") {
            let (layer, rest) = rest.split_once('_')?;
            let layer: usize = layer.parse().ok()?;
            if layer == 0 {
                return None;
            }
            if let Some(b) = rest.strip_prefix("fwd_b") {
                if let Some((bucket, batch)) = b.split_once("_n") {
                    let bucket = bucket.parse().ok()?;
                    let batch = batch.parse().ok()?;
                    return Some(ExecKind::ConvFwdAt { layer, bucket, batch });
                }
                return b.parse().ok().map(|bucket| ExecKind::ConvFwd { layer, bucket });
            }
            if let Some(b) = rest.strip_prefix("bwd_b") {
                return b.parse().ok().map(|bucket| ExecKind::ConvBwd { layer, bucket });
            }
            return None;
        }
        if let Some(rest) = name.strip_prefix("mid") {
            let (layer, dir) = rest.split_once('_')?;
            let layer: usize = layer.parse().ok()?;
            if layer == 0 {
                return None;
            }
            if let Some(b) = dir.strip_prefix("fwd_n") {
                return b.parse().ok().map(|batch| ExecKind::MidFwdAt { layer, batch });
            }
            return match dir {
                "fwd" => Some(ExecKind::MidFwd { layer }),
                "bwd" => Some(ExecKind::MidBwd { layer }),
                _ => None,
            };
        }
        None
    }

    /// Canonical name (inverse of [`ExecKind::parse`]).
    pub fn name(&self) -> String {
        match self {
            ExecKind::Probe => "probe".into(),
            ExecKind::ConvFwd { layer, bucket } => format!("conv{layer}_fwd_b{bucket}"),
            ExecKind::ConvBwd { layer, bucket } => format!("conv{layer}_bwd_b{bucket}"),
            ExecKind::MidFwd { layer } => format!("mid{layer}_fwd"),
            ExecKind::MidBwd { layer } => format!("mid{layer}_bwd"),
            ExecKind::HeadGrad => "head_grad".into(),
            ExecKind::EvalFull => "eval_full".into(),
            ExecKind::GradFull { batch } => format!("grad_full_b{batch}"),
            ExecKind::ConvFwdAt { layer, bucket, batch } => {
                format!("conv{layer}_fwd_b{bucket}_n{batch}")
            }
            ExecKind::MidFwdAt { layer, batch } => format!("mid{layer}_fwd_n{batch}"),
            ExecKind::HeadLogits { batch } => format!("head_logits_n{batch}"),
        }
    }
}

fn f(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec(name.to_string(), shape, "f32".into())
}

fn i(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec(name.to_string(), shape, "i32".into())
}

/// FLOPs of one forward conv over `k` kernels of layer `layer` at batch `b`
/// — [`ArchSpec::conv_layer_flops`], truncated to the spec's u64 (exact:
/// conv FLOP counts sit far below 2^53).
fn conv_fwd_flops(arch: &ArchSpec, layer: usize, k: usize, b: usize) -> u64 {
    arch.conv_layer_flops(layer, k, b) as u64
}

/// Rough FLOP estimate of one mid-segment forward at batch `b`: each op is
/// priced per input element (LRN's window-of-5 + powf dominates).
fn mid_fwd_flops(arch: &ArchSpec, layer: usize, b: usize) -> u64 {
    let k = arch.kernels(layer);
    let mut hw = arch.conv_output(layer);
    let mut flops = 0u64;
    for op in arch.mid_ops(layer) {
        let elems = (b * k * hw * hw) as u64;
        match op {
            MidOp::Lrn => flops += 20 * elems,
            MidOp::MaxPool2 => {
                flops += 4 * elems;
                hw /= 2;
            }
            MidOp::Relu => flops += elems,
        }
    }
    flops
}

/// Forward conv FLOPs of the whole network at batch `b`.
fn net_conv_flops(arch: &ArchSpec, b: usize) -> u64 {
    arch.conv_flops_fwd_at(b) as u64
}

fn param_args(arch: &ArchSpec) -> Vec<ArgSpec> {
    arch.param_order
        .iter()
        .map(|n| f(n, arch.param_shapes[n].clone()))
        .collect()
}

/// Synthesize the manifest signature of `kind` from the architecture.
pub fn spec_for(arch: &ArchSpec, kind: &ExecKind) -> ExecutableSpec {
    let (b, ncls) = (arch.batch, arch.num_classes);
    let (args, outs, flops) = match kind {
        ExecKind::Probe => {
            let p = &arch.probe;
            let (po_h, po_w) = (p.img - p.kh + 1, p.img - p.kw + 1);
            (
                vec![
                    f("x", vec![p.batch, p.in_ch, p.img, p.img]),
                    f("w", vec![p.k, p.in_ch, p.kh, p.kw]),
                    f("b", vec![p.k]),
                ],
                vec![f("y", vec![p.batch, p.k, po_h, po_w])],
                p.flops,
            )
        }
        ExecKind::ConvFwd { layer, bucket } => {
            let (c, h) = arch.conv_input(*layer);
            let o = arch.conv_output(*layer);
            let (kh, kw) = arch.conv_kernel(*layer);
            (
                vec![
                    f("x", vec![b, c, h, h]),
                    f("w", vec![*bucket, c, kh, kw]),
                    f("b", vec![*bucket]),
                ],
                vec![f("y", vec![b, *bucket, o, o])],
                conv_fwd_flops(arch, *layer, *bucket, b),
            )
        }
        ExecKind::ConvBwd { layer, bucket } => {
            let (c, h) = arch.conv_input(*layer);
            let o = arch.conv_output(*layer);
            let (kh, kw) = arch.conv_kernel(*layer);
            (
                vec![
                    f("x", vec![b, c, h, h]),
                    f("w", vec![*bucket, c, kh, kw]),
                    f("gy", vec![b, *bucket, o, o]),
                ],
                vec![
                    f("gx", vec![b, c, h, h]),
                    f("gw", vec![*bucket, c, kh, kw]),
                    f("gb", vec![*bucket]),
                ],
                // Input-grad + kernel-grad are each one more conv-sized
                // contraction (the paper's 3x training factor, minus fwd).
                2 * conv_fwd_flops(arch, *layer, *bucket, b),
            )
        }
        ExecKind::MidFwd { layer } => {
            let k = arch.kernels(*layer);
            let c = arch.conv_output(*layer);
            let p = arch.mid_output(*layer);
            (
                vec![f("y", vec![b, k, c, c])],
                vec![f("p", vec![b, k, p, p])],
                mid_fwd_flops(arch, *layer, b),
            )
        }
        ExecKind::MidBwd { layer } => {
            let k = arch.kernels(*layer);
            let c = arch.conv_output(*layer);
            let p = arch.mid_output(*layer);
            (
                vec![f("y", vec![b, k, c, c]), f("gp", vec![b, k, p, p])],
                vec![f("gy", vec![b, k, c, c])],
                2 * mid_fwd_flops(arch, *layer, b),
            )
        }
        ExecKind::HeadGrad => {
            let n = arch.num_convs();
            let pn = vec![b, arch.kernels(n), arch.mid_output(n), arch.mid_output(n)];
            (
                vec![
                    f("p", pn.clone()),
                    f("wf", vec![arch.fc_in, ncls]),
                    f("bf", vec![ncls]),
                    i("labels", vec![b]),
                ],
                vec![
                    f("loss", vec![]),
                    f("gp", pn),
                    f("gwf", vec![arch.fc_in, ncls]),
                    f("gbf", vec![ncls]),
                ],
                6 * (b * arch.fc_in * ncls) as u64,
            )
        }
        ExecKind::EvalFull => {
            let mut args = vec![f("x", vec![b, arch.in_ch, arch.img, arch.img])];
            args.extend(param_args(arch));
            (args, vec![f("logits", vec![b, ncls])], net_conv_flops(arch, b))
        }
        ExecKind::GradFull { batch } => {
            let n = *batch;
            let mut args = vec![
                f("x", vec![n, arch.in_ch, arch.img, arch.img]),
                i("labels", vec![n]),
            ];
            args.extend(param_args(arch));
            let mut outs = vec![f("loss", vec![])];
            outs.extend(
                arch.param_order
                    .iter()
                    .map(|p| f(&format!("g{p}"), arch.param_shapes[p].clone())),
            );
            (args, outs, 3 * net_conv_flops(arch, n))
        }
        ExecKind::ConvFwdAt { layer, bucket, batch } => {
            let n = *batch;
            let (c, h) = arch.conv_input(*layer);
            let o = arch.conv_output(*layer);
            let (kh, kw) = arch.conv_kernel(*layer);
            (
                vec![
                    f("x", vec![n, c, h, h]),
                    f("w", vec![*bucket, c, kh, kw]),
                    f("b", vec![*bucket]),
                ],
                vec![f("y", vec![n, *bucket, o, o])],
                conv_fwd_flops(arch, *layer, *bucket, n),
            )
        }
        ExecKind::MidFwdAt { layer, batch } => {
            let n = *batch;
            let k = arch.kernels(*layer);
            let c = arch.conv_output(*layer);
            let p = arch.mid_output(*layer);
            (
                vec![f("y", vec![n, k, c, c])],
                vec![f("p", vec![n, k, p, p])],
                mid_fwd_flops(arch, *layer, n),
            )
        }
        ExecKind::HeadLogits { batch } => {
            let n = *batch;
            let nc = arch.num_convs();
            let pn = vec![n, arch.kernels(nc), arch.mid_output(nc), arch.mid_output(nc)];
            (
                vec![f("p", pn), f("wf", vec![arch.fc_in, ncls]), f("bf", vec![ncls])],
                vec![f("logits", vec![n, ncls])],
                2 * (n * arch.fc_in * ncls) as u64,
            )
        }
    };
    ExecutableSpec { file: format!("<native:{}>", kind.name()), args, outs, flops, sha256: String::new() }
}

/// Enumerate every executable an [`ArchSpec`] supports and build a manifest
/// for it — what `Runtime::open` uses when no `manifest.json` is present.
pub fn native_manifest(config: ArchSpec, dir: &Path) -> Manifest {
    let mut kinds = vec![ExecKind::Probe, ExecKind::HeadGrad, ExecKind::EvalFull];
    for layer in 1..=config.num_convs() {
        for &bucket in config.buckets(layer) {
            kinds.push(ExecKind::ConvFwd { layer, bucket });
            kinds.push(ExecKind::ConvBwd { layer, bucket });
        }
        kinds.push(ExecKind::MidFwd { layer });
        kinds.push(ExecKind::MidBwd { layer });
    }
    for &bb in &config.batch_buckets {
        kinds.push(ExecKind::GradFull { batch: bb });
        // Forward-only serving family: every batch rung gets its own conv
        // shard / mid / head executables so the dynamic batcher can pick a
        // padded shape without touching the training-batch contract.
        kinds.push(ExecKind::HeadLogits { batch: bb });
        for layer in 1..=config.num_convs() {
            for &bucket in config.buckets(layer) {
                kinds.push(ExecKind::ConvFwdAt { layer, bucket, batch: bb });
            }
            kinds.push(ExecKind::MidFwdAt { layer, batch: bb });
        }
    }
    let mut executables = BTreeMap::new();
    for kind in kinds {
        executables.insert(kind.name(), spec_for(&config, &kind));
    }
    Manifest { version: 1, config, executables, dir: dir.to_path_buf() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn parse_roundtrips_every_kind() {
        let kinds = [
            ExecKind::Probe,
            ExecKind::ConvFwd { layer: 1, bucket: 8 },
            ExecKind::ConvBwd { layer: 2, bucket: 12 },
            ExecKind::ConvFwd { layer: 3, bucket: 4 },
            ExecKind::MidFwd { layer: 1 },
            ExecKind::MidBwd { layer: 7 },
            ExecKind::HeadGrad,
            ExecKind::EvalFull,
            ExecKind::GradFull { batch: 64 },
            ExecKind::ConvFwdAt { layer: 1, bucket: 8, batch: 4 },
            ExecKind::ConvFwdAt { layer: 3, bucket: 12, batch: 16 },
            ExecKind::MidFwdAt { layer: 2, batch: 4 },
            ExecKind::HeadLogits { batch: 8 },
        ];
        for k in kinds {
            assert_eq!(ExecKind::parse(&k.name()), Some(k.clone()), "{}", k.name());
        }
        assert_eq!(ExecKind::parse("conv0_fwd_b4"), None);
        assert_eq!(ExecKind::parse("conv1_sideways_b4"), None);
        assert_eq!(ExecKind::parse("mid0_fwd"), None);
        assert_eq!(ExecKind::parse("nonsense"), None);
        assert_eq!(ExecKind::parse("conv1_fwd_b4_n"), None);
        assert_eq!(ExecKind::parse("conv1_bwd_b4_n2"), None);
        assert_eq!(ExecKind::parse("mid1_fwd_nx"), None);
        assert_eq!(ExecKind::parse("head_logits_n"), None);
    }

    #[test]
    fn native_manifest_enumerates_all_buckets() {
        let arch = ArchSpec::tiny();
        let m = native_manifest(arch, Path::new("."));
        assert!(m.spec("probe").is_ok());
        assert!(m.spec("conv1_fwd_b4").is_ok());
        assert!(m.spec("conv2_bwd_b8").is_ok());
        assert!(m.spec("mid2_bwd").is_ok());
        assert!(m.spec("grad_full_b2").is_ok());
        assert!(m.spec("conv1_fwd_b99").is_err(), "unlisted bucket must not appear");
        assert!(m.spec("conv3_fwd_b4").is_err(), "a 2-conv arch has no layer 3");
        // Shapes agree with the arch geometry.
        let s = m.spec("conv2_fwd_b8").unwrap();
        assert_eq!(s.args[0].shape(), &[2, 4, 14, 14]);
        assert_eq!(s.outs[0].shape(), &[2, 8, 10, 10]);
        assert!(s.flops > 0);
        let h = m.spec("head_grad").unwrap();
        assert_eq!(h.args[3].dtype(), "i32");
        assert_eq!(h.outs[0].shape(), &[] as &[usize]);
    }

    #[test]
    fn three_conv_arch_enumerates_layer3_executables() {
        let arch = ArchSpec::tiny_deep();
        let m = native_manifest(arch, Path::new("."));
        assert!(m.spec("conv3_fwd_b8").is_ok());
        assert!(m.spec("conv3_bwd_b4").is_ok());
        assert!(m.spec("mid3_fwd").is_ok());
        assert!(m.spec("conv4_fwd_b4").is_err());
        // conv3 of tiny_deep reads the 6-channel 6x6 mid2 output.
        let s = m.spec("conv3_fwd_b8").unwrap();
        assert_eq!(s.args[0].shape(), &[2, 6, 6, 6]);
        assert_eq!(s.outs[0].shape(), &[2, 8, 4, 4]);
        // head reads the pooled conv3 output.
        let h = m.spec("head_grad").unwrap();
        assert_eq!(h.args[0].shape(), &[2, 8, 2, 2]);
        // grad_full signature follows the 3-conv param order.
        let g = m.spec("grad_full_b2").unwrap();
        assert_eq!(g.args.len(), 2 + 3 * 2 + 2);
        assert_eq!(g.outs.len(), 1 + 3 * 2 + 2);
        assert_eq!(g.outs[1].name(), "gconv1.w");
    }

    #[test]
    fn legacy_config_resolves_to_the_identical_executable_set() {
        // The acceptance gate of the layer-IR refactor: an old k1/k2
        // manifest, converted, must enumerate exactly the executables the
        // pre-refactor code produced for the same architecture.
        let v = Json::parse(super::super::manifest::tests::LEGACY_TINY_CONFIG).unwrap();
        let config = ArchSpec::from_json(&v).unwrap();
        let m = native_manifest(config, Path::new("."));
        let got: Vec<&str> = m.executables.keys().map(|s| s.as_str()).collect();
        let want = [
            "conv1_bwd_b4",
            "conv1_fwd_b4",
            "conv1_fwd_b4_n2",
            "conv2_bwd_b4",
            "conv2_bwd_b8",
            "conv2_fwd_b4",
            "conv2_fwd_b4_n2",
            "conv2_fwd_b8",
            "conv2_fwd_b8_n2",
            "eval_full",
            "grad_full_b2",
            "head_grad",
            "head_logits_n2",
            "mid1_bwd",
            "mid1_fwd",
            "mid1_fwd_n2",
            "mid2_bwd",
            "mid2_fwd",
            "mid2_fwd_n2",
            "probe",
        ];
        assert_eq!(got, want);
    }

    #[test]
    fn serve_forward_specs_parameterize_the_batch() {
        // A wider ladder than tiny's [2]: mutate the preset so the serving
        // family enumerates more than one rung.
        let mut arch = ArchSpec::tiny();
        arch.batch = 4;
        arch.batch_buckets = vec![2, 4];
        let m = native_manifest(arch, Path::new("."));
        let s = m.spec("conv1_fwd_b4_n2").unwrap();
        assert_eq!(s.args[0].shape()[0], 2, "batch comes from the rung, not the arch");
        let full = m.spec("conv1_fwd_b4_n4").unwrap();
        assert_eq!(full.args[0].shape()[0], 4);
        let h = m.spec("head_logits_n2").unwrap();
        assert_eq!(h.outs[0].shape(), &[2, 10]);
        assert_eq!(h.args.len(), 3, "no labels: forward-only head");
        assert!(m.spec("mid2_fwd_n2").is_ok());
        assert!(m.spec("head_logits_n3").is_err(), "off-ladder batch must not appear");
    }
}
