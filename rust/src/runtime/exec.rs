//! Executable naming contract + spec synthesis for the native backend.
//!
//! The PJRT path learns each executable's signature from `manifest.json`;
//! the native backend *derives* the same signatures from the [`ArchSpec`]
//! geometry, so a clean checkout needs no artifacts at all.  Both paths meet
//! at [`ExecutableSpec`]: `Runtime::execute` validates every call against it
//! regardless of which backend serves it.

use std::collections::BTreeMap;
use std::path::Path;

use super::manifest::{ArchSpec, ArgSpec, ExecutableSpec, Manifest};

/// Every executable name the trainers dispatch, parsed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecKind {
    /// Calibration probe (paper §4.1.1).
    Probe,
    /// `conv{layer}_fwd_b{bucket}`: one conv layer's kernel-shard forward.
    ConvFwd { layer: usize, bucket: usize },
    /// `conv{layer}_bwd_b{bucket}`: shard backward -> (gx, gw, gb).
    ConvBwd { layer: usize, bucket: usize },
    /// `mid{layer}_fwd`: the master-resident LRN + pool block.
    MidFwd { layer: usize },
    /// `mid{layer}_bwd`: vjp of the mid block (recompute-in-bwd).
    MidBwd { layer: usize },
    /// `head_grad`: FC + softmax loss and grads wrt (p2, wf, bf).
    HeadGrad,
    /// `eval_full`: full-network logits for accuracy evaluation.
    EvalFull,
    /// `grad_full_b{batch}`: fused full-network fwd+bwd (baselines).
    GradFull { batch: usize },
}

impl ExecKind {
    /// Parse an executable name; `None` if it is not part of the contract.
    pub fn parse(name: &str) -> Option<ExecKind> {
        match name {
            "probe" => return Some(ExecKind::Probe),
            "head_grad" => return Some(ExecKind::HeadGrad),
            "eval_full" => return Some(ExecKind::EvalFull),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("grad_full_b") {
            return rest.parse().ok().map(|batch| ExecKind::GradFull { batch });
        }
        if let Some(rest) = name.strip_prefix("conv") {
            let (layer, rest) = rest.split_once('_')?;
            let layer: usize = layer.parse().ok()?;
            if !(1..=2).contains(&layer) {
                return None;
            }
            if let Some(b) = rest.strip_prefix("fwd_b") {
                return b.parse().ok().map(|bucket| ExecKind::ConvFwd { layer, bucket });
            }
            if let Some(b) = rest.strip_prefix("bwd_b") {
                return b.parse().ok().map(|bucket| ExecKind::ConvBwd { layer, bucket });
            }
            return None;
        }
        if let Some(rest) = name.strip_prefix("mid") {
            let (layer, dir) = rest.split_once('_')?;
            let layer: usize = layer.parse().ok()?;
            if !(1..=2).contains(&layer) {
                return None;
            }
            return match dir {
                "fwd" => Some(ExecKind::MidFwd { layer }),
                "bwd" => Some(ExecKind::MidBwd { layer }),
                _ => None,
            };
        }
        None
    }

    /// Canonical name (inverse of [`ExecKind::parse`]).
    pub fn name(&self) -> String {
        match self {
            ExecKind::Probe => "probe".into(),
            ExecKind::ConvFwd { layer, bucket } => format!("conv{layer}_fwd_b{bucket}"),
            ExecKind::ConvBwd { layer, bucket } => format!("conv{layer}_bwd_b{bucket}"),
            ExecKind::MidFwd { layer } => format!("mid{layer}_fwd"),
            ExecKind::MidBwd { layer } => format!("mid{layer}_bwd"),
            ExecKind::HeadGrad => "head_grad".into(),
            ExecKind::EvalFull => "eval_full".into(),
            ExecKind::GradFull { batch } => format!("grad_full_b{batch}"),
        }
    }
}

fn f(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec(name.to_string(), shape, "f32".into())
}

fn i(name: &str, shape: Vec<usize>) -> ArgSpec {
    ArgSpec(name.to_string(), shape, "i32".into())
}

/// FLOPs of one forward conv over `k` kernels of layer `layer` at batch `b`
/// (one multiply-add = 2 FLOPs per tap per output pixel).
fn conv_fwd_flops(arch: &ArchSpec, layer: usize, k: usize, b: usize) -> u64 {
    let (c, _) = arch.conv_input(layer);
    let o = arch.conv_output(layer);
    2 * (b * o * o * c * arch.kh * arch.kw * k) as u64
}

/// Pool-output height of conv layer `layer`.
fn pool_out(arch: &ArchSpec, layer: usize) -> usize {
    match layer {
        1 => arch.p1_out,
        2 => arch.p2_out,
        _ => panic!("conv layer {layer} out of range"),
    }
}

fn param_args(arch: &ArchSpec) -> Vec<ArgSpec> {
    arch.param_order
        .iter()
        .map(|n| f(n, arch.param_shapes[n].clone()))
        .collect()
}

/// Synthesize the manifest signature of `kind` from the architecture.
pub fn spec_for(arch: &ArchSpec, kind: &ExecKind) -> ExecutableSpec {
    let (kh, kw, b, ncls) = (arch.kh, arch.kw, arch.batch, arch.num_classes);
    let (args, outs, flops) = match kind {
        ExecKind::Probe => {
            let p = &arch.probe;
            let po = p.img - kh + 1;
            (
                vec![
                    f("x", vec![p.batch, p.in_ch, p.img, p.img]),
                    f("w", vec![p.k, p.in_ch, kh, kw]),
                    f("b", vec![p.k]),
                ],
                vec![f("y", vec![p.batch, p.k, po, po])],
                p.flops,
            )
        }
        ExecKind::ConvFwd { layer, bucket } => {
            let (c, h) = arch.conv_input(*layer);
            let o = arch.conv_output(*layer);
            (
                vec![
                    f("x", vec![b, c, h, h]),
                    f("w", vec![*bucket, c, kh, kw]),
                    f("b", vec![*bucket]),
                ],
                vec![f("y", vec![b, *bucket, o, o])],
                conv_fwd_flops(arch, *layer, *bucket, b),
            )
        }
        ExecKind::ConvBwd { layer, bucket } => {
            let (c, h) = arch.conv_input(*layer);
            let o = arch.conv_output(*layer);
            (
                vec![
                    f("x", vec![b, c, h, h]),
                    f("w", vec![*bucket, c, kh, kw]),
                    f("gy", vec![b, *bucket, o, o]),
                ],
                vec![
                    f("gx", vec![b, c, h, h]),
                    f("gw", vec![*bucket, c, kh, kw]),
                    f("gb", vec![*bucket]),
                ],
                // Input-grad + kernel-grad are each one more conv-sized
                // contraction (the paper's 3x training factor, minus fwd).
                2 * conv_fwd_flops(arch, *layer, *bucket, b),
            )
        }
        ExecKind::MidFwd { layer } => {
            let k = arch.kernels(*layer);
            let c = arch.conv_output(*layer);
            let p = pool_out(arch, *layer);
            (
                vec![f("y", vec![b, k, c, c])],
                vec![f("p", vec![b, k, p, p])],
                // LRN (window of 5 + powf) dominates; ~20 FLOPs/element.
                (b * k * c * c * 20) as u64,
            )
        }
        ExecKind::MidBwd { layer } => {
            let k = arch.kernels(*layer);
            let c = arch.conv_output(*layer);
            let p = pool_out(arch, *layer);
            (
                vec![f("y", vec![b, k, c, c]), f("gp", vec![b, k, p, p])],
                vec![f("gy", vec![b, k, c, c])],
                (b * k * c * c * 40) as u64,
            )
        }
        ExecKind::HeadGrad => {
            let p2 = vec![b, arch.k2, arch.p2_out, arch.p2_out];
            (
                vec![
                    f("p2", p2.clone()),
                    f("wf", vec![arch.fc_in, ncls]),
                    f("bf", vec![ncls]),
                    i("labels", vec![b]),
                ],
                vec![
                    f("loss", vec![]),
                    f("gp2", p2),
                    f("gwf", vec![arch.fc_in, ncls]),
                    f("gbf", vec![ncls]),
                ],
                6 * (b * arch.fc_in * ncls) as u64,
            )
        }
        ExecKind::EvalFull => {
            let mut args = vec![f("x", vec![b, arch.in_ch, arch.img, arch.img])];
            args.extend(param_args(arch));
            (
                args,
                vec![f("logits", vec![b, ncls])],
                conv_fwd_flops(arch, 1, arch.k1, b) + conv_fwd_flops(arch, 2, arch.k2, b),
            )
        }
        ExecKind::GradFull { batch } => {
            let n = *batch;
            let mut args = vec![
                f("x", vec![n, arch.in_ch, arch.img, arch.img]),
                i("labels", vec![n]),
            ];
            args.extend(param_args(arch));
            let mut outs = vec![f("loss", vec![])];
            outs.extend(
                arch.param_order
                    .iter()
                    .map(|p| f(&format!("g{p}"), arch.param_shapes[p].clone())),
            );
            (
                args,
                outs,
                3 * (conv_fwd_flops(arch, 1, arch.k1, n) + conv_fwd_flops(arch, 2, arch.k2, n)),
            )
        }
    };
    ExecutableSpec { file: format!("<native:{}>", kind.name()), args, outs, flops, sha256: String::new() }
}

/// Enumerate every executable an [`ArchSpec`] supports and build a manifest
/// for it — what `Runtime::open` uses when no `manifest.json` is present.
pub fn native_manifest(config: ArchSpec, dir: &Path) -> Manifest {
    let mut kinds = vec![ExecKind::Probe, ExecKind::HeadGrad, ExecKind::EvalFull];
    for layer in 1..=2usize {
        for &bucket in config.buckets(layer) {
            kinds.push(ExecKind::ConvFwd { layer, bucket });
            kinds.push(ExecKind::ConvBwd { layer, bucket });
        }
        kinds.push(ExecKind::MidFwd { layer });
        kinds.push(ExecKind::MidBwd { layer });
    }
    for &bb in &config.batch_buckets {
        kinds.push(ExecKind::GradFull { batch: bb });
    }
    let mut executables = BTreeMap::new();
    for kind in kinds {
        executables.insert(kind.name(), spec_for(&config, &kind));
    }
    Manifest { version: 1, config, executables, dir: dir.to_path_buf() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        let kinds = [
            ExecKind::Probe,
            ExecKind::ConvFwd { layer: 1, bucket: 8 },
            ExecKind::ConvBwd { layer: 2, bucket: 12 },
            ExecKind::MidFwd { layer: 1 },
            ExecKind::MidBwd { layer: 2 },
            ExecKind::HeadGrad,
            ExecKind::EvalFull,
            ExecKind::GradFull { batch: 64 },
        ];
        for k in kinds {
            assert_eq!(ExecKind::parse(&k.name()), Some(k.clone()), "{}", k.name());
        }
        assert_eq!(ExecKind::parse("conv3_fwd_b4"), None);
        assert_eq!(ExecKind::parse("conv1_sideways_b4"), None);
        assert_eq!(ExecKind::parse("mid9_fwd"), None);
        assert_eq!(ExecKind::parse("nonsense"), None);
    }

    #[test]
    fn native_manifest_enumerates_all_buckets() {
        let arch = ArchSpec::tiny();
        let m = native_manifest(arch, Path::new("."));
        assert!(m.spec("probe").is_ok());
        assert!(m.spec("conv1_fwd_b4").is_ok());
        assert!(m.spec("conv2_bwd_b8").is_ok());
        assert!(m.spec("mid2_bwd").is_ok());
        assert!(m.spec("grad_full_b2").is_ok());
        assert!(m.spec("conv1_fwd_b99").is_err(), "unlisted bucket must not appear");
        // Shapes agree with the arch geometry.
        let s = m.spec("conv2_fwd_b8").unwrap();
        assert_eq!(s.args[0].shape(), &[2, 4, 14, 14]);
        assert_eq!(s.outs[0].shape(), &[2, 8, 10, 10]);
        assert!(s.flops > 0);
        let h = m.spec("head_grad").unwrap();
        assert_eq!(h.args[3].dtype(), "i32");
        assert_eq!(h.outs[0].shape(), &[] as &[usize]);
    }
}
