//! `artifacts/manifest.json` — the contract between the python AOT pipeline
//! and the rust runtime.  The runtime never hard-codes a shape: every
//! executable's argument/output signature comes from here, and every call is
//! validated against it before touching a backend.  Parsed with the in-tree
//! [`crate::util::json`] parser (offline build — no serde).
//!
//! The `config` block is an [`ArchSpec`] in either schema: the layer-graph
//! form (a `"layers"` array — see `runtime::graph`) or the legacy two-conv
//! `k1`/`k2` form, which loads by conversion into the equivalent graph and
//! resolves to the identical executable set.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use super::graph::{json_usize_arr, ArchSpec};
use crate::util::json::Json;

/// `(name, shape, dtype)` triple, serialized as a JSON array.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec(pub String, pub Vec<usize>, pub String);

impl ArgSpec {
    pub fn name(&self) -> &str {
        &self.0
    }

    pub fn shape(&self) -> &[usize] {
        &self.1
    }

    pub fn dtype(&self) -> &str {
        &self.2
    }

    pub fn elements(&self) -> usize {
        self.1.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let a = v.as_arr()?;
        ensure!(a.len() == 3, "arg spec must be [name, shape, dtype]");
        Ok(ArgSpec(a[0].as_str()?.to_string(), a[1].as_usize_vec()?, a[2].as_str()?.to_string()))
    }

    fn to_json(&self) -> String {
        format!("[\"{}\", {}, \"{}\"]", esc(&self.0), json_usize_arr(&self.1), esc(&self.2))
    }
}

#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
    /// Nominal FLOPs of one execution (virtual-time emulation + §Perf).
    pub flops: u64,
    pub sha256: String,
}

impl ExecutableSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            args: v.get("args")?.as_arr()?.iter().map(ArgSpec::from_json).collect::<Result<_>>()?,
            outs: v.get("outs")?.as_arr()?.iter().map(ArgSpec::from_json).collect::<Result<_>>()?,
            flops: v.opt("flops").map(|f| f.as_u64()).transpose()?.unwrap_or(0),
            sha256: v.opt("sha256").and_then(|s| s.as_str().ok()).unwrap_or("").to_string(),
        })
    }

    fn to_json(&self) -> String {
        let args: Vec<String> = self.args.iter().map(ArgSpec::to_json).collect();
        let outs: Vec<String> = self.outs.iter().map(ArgSpec::to_json).collect();
        format!(
            "{{\"file\": \"{}\", \"args\": [{}], \"outs\": [{}], \"flops\": {}, \"sha256\": \"{}\"}}",
            esc(&self.file),
            args.join(", "),
            outs.join(", "),
            self.flops,
            esc(&self.sha256)
        )
    }

    /// Synthetic (native-backend) entries have no artifact file on disk.
    pub fn is_synthetic(&self) -> bool {
        self.file.starts_with("<native:")
    }
}

/// Minimal JSON string escape (manifest names never need more).
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u32,
    pub config: ArchSpec,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::from_json_str(&raw, dir)
    }

    pub fn from_json_str(raw: &str, dir: &Path) -> Result<Self> {
        let v = Json::parse(raw).context("parsing manifest.json")?;
        let version = v.get("version")?.as_usize()? as u32;
        ensure!(version == 1, "unsupported manifest version {version}");
        let config = ArchSpec::from_json(v.get("config")?).context("parsing manifest config")?;
        let mut executables = BTreeMap::new();
        for (name, spec) in v.get("executables")?.as_obj()? {
            let spec = ExecutableSpec::from_json(spec)
                .with_context(|| format!("executable {name:?}"))?;
            ensure!(
                spec.is_synthetic() || dir.join(&spec.file).exists(),
                "manifest lists {name} but {} is missing",
                spec.file
            );
            executables.insert(name.clone(), spec);
        }
        Ok(Manifest { version, config, executables, dir: dir.to_path_buf() })
    }

    /// Serialize (graph config schema) — the inverse of
    /// [`Manifest::from_json_str`] up to derived-field recomputation.
    pub fn to_json_string(&self) -> String {
        let execs: Vec<String> = self
            .executables
            .iter()
            .map(|(name, s)| format!("\"{}\": {}", esc(name), s.to_json()))
            .collect();
        format!(
            "{{\"version\": {}, \"config\": {}, \"executables\": {{{}}}}}",
            self.version,
            self.config.to_json(),
            execs.join(", ")
        )
    }

    pub fn spec(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable named {name:?} in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.spec(name)?.file))
    }

    /// Name of the conv fwd/bwd executable for `layer` at shard bucket `kb`.
    pub fn conv_exec(layer: usize, dir: ConvDir, kb: usize) -> String {
        let d = match dir {
            ConvDir::Fwd => "fwd",
            ConvDir::Bwd => "bwd",
        };
        format!("conv{layer}_{d}_b{kb}")
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvDir {
    Fwd,
    Bwd,
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A small ArchSpec used by unit tests across the crate.
    pub fn tiny_arch() -> ArchSpec {
        ArchSpec::tiny()
    }

    /// The legacy (pre-graph) manifest config for the tiny arch, verbatim
    /// from an old `artifacts/manifest.json`.
    pub const LEGACY_TINY_CONFIG: &str = r#"{
       "k1": 4, "k2": 8, "batch": 2, "img": 32, "in_ch": 3,
       "num_classes": 10, "kh": 5, "kw": 5,
       "c1_out": 28, "p1_out": 14, "c2_out": 10, "p2_out": 5,
       "fc_in": 200, "buckets1": [4], "buckets2": [4, 8],
       "batch_buckets": [2],
       "param_shapes": {"w1": [4,3,5,5], "b1": [4], "w2": [8,4,5,5],
                        "b2": [8], "wf": [200,10], "bf": [10]},
       "param_order": ["w1","b1","w2","b2","wf","bf"],
       "probe": {"batch": 1, "in_ch": 1, "img": 8, "k": 1, "flops": 100}
     }"#;

    #[test]
    fn parses_minimal_legacy_manifest() {
        let doc = format!(
            "{{\"version\": 1, \"config\": {LEGACY_TINY_CONFIG}, \"executables\": {{}}}}"
        );
        let m = Manifest::from_json_str(&doc, Path::new("/tmp")).unwrap();
        assert_eq!(m.config.kernels(1), 4);
        assert_eq!(m.config.buckets(2), &[4, 8]);
        assert_eq!(m.config.conv_input(2), (4, 14));
        assert_eq!(m.config.probe.batch, 1);
        // Legacy probes carry no kernel geometry: inherited from conv1.
        assert_eq!((m.config.probe.kh, m.config.probe.kw), (5, 5));
        assert!(m.spec("nope").is_err());
        assert_eq!(Manifest::conv_exec(1, ConvDir::Fwd, 8), "conv1_fwd_b8");
        assert_eq!(Manifest::conv_exec(2, ConvDir::Bwd, 12), "conv2_bwd_b12");
    }

    #[test]
    fn legacy_conversion_builds_the_equivalent_two_conv_graph() {
        let v = Json::parse(LEGACY_TINY_CONFIG).unwrap();
        let converted = ArchSpec::from_json(&v).unwrap();
        let derived = ArchSpec::tiny();
        assert_eq!(converted.layers, derived.layers);
        assert_eq!(converted.convs, derived.convs);
        assert_eq!(converted.param_shapes, derived.param_shapes);
        assert_eq!(converted.param_order, derived.param_order);
        assert_eq!(converted.fc_in, 200);
    }

    #[test]
    fn legacy_conversion_rejects_inconsistent_geometry() {
        // p2_out disagrees with what the graph derives -> loud failure.
        let doc = LEGACY_TINY_CONFIG.replace("\"p2_out\": 5", "\"p2_out\": 6");
        let v = Json::parse(&doc).unwrap();
        assert!(ArchSpec::from_json(&v).is_err());
        // So does a param shape that moved.
        let doc = LEGACY_TINY_CONFIG.replace("\"w2\": [8,4,5,5]", "\"w2\": [8,4,3,3]");
        let v = Json::parse(&doc).unwrap();
        assert!(ArchSpec::from_json(&v).is_err());
    }

    #[test]
    fn manifest_roundtrips_through_serialization() {
        // A graph-built native manifest must survive serialize -> parse with
        // the executable set, signatures and config intact.
        for arch in [ArchSpec::tiny(), ArchSpec::tiny_deep()] {
            let m = super::super::exec::native_manifest(arch, Path::new("/tmp"));
            let doc = m.to_json_string();
            let back = Manifest::from_json_str(&doc, Path::new("/tmp")).unwrap();
            assert_eq!(back.version, m.version);
            assert_eq!(back.config.layers, m.config.layers);
            assert_eq!(back.config.convs, m.config.convs);
            assert_eq!(back.config.param_order, m.config.param_order);
            let names: Vec<&String> = back.executables.keys().collect();
            let want: Vec<&String> = m.executables.keys().collect();
            assert_eq!(names, want, "executable set must round-trip");
            for (name, spec) in &m.executables {
                let b = back.spec(name).unwrap();
                assert_eq!(b.args, spec.args, "{name} args");
                assert_eq!(b.outs, spec.outs, "{name} outs");
                assert_eq!(b.flops, spec.flops, "{name} flops");
            }
        }
    }

    #[test]
    fn rejects_wrong_version_and_missing_file() {
        let doc = r#"{"version": 2, "config": {}, "executables": {}}"#;
        assert!(Manifest::from_json_str(doc, Path::new("/tmp")).is_err());
        // A non-synthetic executable whose artifact file is absent fails.
        let doc = format!(
            "{{\"version\": 1, \"config\": {LEGACY_TINY_CONFIG}, \"executables\": {{\
             \"probe\": {{\"file\": \"missing.hlo.txt\", \"args\": [], \"outs\": []}}}}}}"
        );
        assert!(Manifest::from_json_str(&doc, Path::new("/nonexistent-dir")).is_err());
    }
}
