//! `artifacts/manifest.json` — the contract between the python AOT pipeline
//! and the rust runtime.  The runtime never hard-codes a shape: every
//! executable's argument/output signature comes from here, and every call is
//! validated against it before touching PJRT.  Parsed with the in-tree
//! [`crate::util::json`] parser (offline build — no serde).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// `(name, shape, dtype)` triple, serialized as a JSON array.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec(pub String, pub Vec<usize>, pub String);

impl ArgSpec {
    pub fn name(&self) -> &str {
        &self.0
    }

    pub fn shape(&self) -> &[usize] {
        &self.1
    }

    pub fn dtype(&self) -> &str {
        &self.2
    }

    pub fn elements(&self) -> usize {
        self.1.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let a = v.as_arr()?;
        ensure!(a.len() == 3, "arg spec must be [name, shape, dtype]");
        Ok(ArgSpec(a[0].as_str()?.to_string(), a[1].as_usize_vec()?, a[2].as_str()?.to_string()))
    }
}

#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
    /// Nominal FLOPs of one execution (virtual-time emulation + §Perf).
    pub flops: u64,
    pub sha256: String,
}

impl ExecutableSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            args: v.get("args")?.as_arr()?.iter().map(ArgSpec::from_json).collect::<Result<_>>()?,
            outs: v.get("outs")?.as_arr()?.iter().map(ArgSpec::from_json).collect::<Result<_>>()?,
            flops: v.opt("flops").map(|f| f.as_u64()).transpose()?.unwrap_or(0),
            sha256: v.opt("sha256").and_then(|s| s.as_str().ok()).unwrap_or("").to_string(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ProbeSpec {
    pub batch: usize,
    pub in_ch: usize,
    pub img: usize,
    pub k: usize,
    /// FLOPs of one probe execution; measured time -> GFLOPS performance value.
    pub flops: u64,
}

/// Shapes of the compiled architecture (paper notation `k1:k2`).
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub k1: usize,
    pub k2: usize,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub kh: usize,
    pub kw: usize,
    pub c1_out: usize,
    pub p1_out: usize,
    pub c2_out: usize,
    pub p2_out: usize,
    pub fc_in: usize,
    pub buckets1: Vec<usize>,
    pub buckets2: Vec<usize>,
    pub batch_buckets: Vec<usize>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub param_order: Vec<String>,
    pub probe: ProbeSpec,
}

impl ArchSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let p = v.get("probe")?;
        let probe = ProbeSpec {
            batch: p.get("batch")?.as_usize()?,
            in_ch: p.get("in_ch")?.as_usize()?,
            img: p.get("img")?.as_usize()?,
            k: p.get("k")?.as_usize()?,
            flops: p.get("flops")?.as_u64()?,
        };
        let mut param_shapes = BTreeMap::new();
        for (name, shape) in v.get("param_shapes")?.as_obj()? {
            param_shapes.insert(name.clone(), shape.as_usize_vec()?);
        }
        let param_order = v
            .get("param_order")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            k1: v.get("k1")?.as_usize()?,
            k2: v.get("k2")?.as_usize()?,
            batch: v.get("batch")?.as_usize()?,
            img: v.get("img")?.as_usize()?,
            in_ch: v.get("in_ch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            kh: v.get("kh")?.as_usize()?,
            kw: v.get("kw")?.as_usize()?,
            c1_out: v.get("c1_out")?.as_usize()?,
            p1_out: v.get("p1_out")?.as_usize()?,
            c2_out: v.get("c2_out")?.as_usize()?,
            p2_out: v.get("p2_out")?.as_usize()?,
            fc_in: v.get("fc_in")?.as_usize()?,
            buckets1: v.get("buckets1")?.as_usize_vec()?,
            buckets2: v.get("buckets2")?.as_usize_vec()?,
            batch_buckets: v.get("batch_buckets")?.as_usize_vec()?,
            param_shapes,
            param_order,
            probe,
        })
    }

    /// The architecture the native backend synthesizes when no
    /// `manifest.json` is present: the `python/compile` default (16:32 @ 64,
    /// CIFAR-10 geometry), including its bucket ladders.
    pub fn native_default() -> ArchSpec {
        ArchSpec::from_geometry(16, 32, 64)
    }

    /// A deliberately small architecture (4:8 @ batch 2) for unit and
    /// integration tests — steps complete in milliseconds on one core.
    pub fn tiny() -> ArchSpec {
        ArchSpec::from_geometry(4, 8, 2)
    }

    /// Build a full spec from the paper's `k1:k2 @ batch` notation with the
    /// fixed CIFAR-10 geometry (32x32x3, 5x5 kernels, /2 pools, 10 classes)
    /// — the same derivation as `python/compile/model.py::ArchConfig`.
    pub fn from_geometry(k1: usize, k2: usize, batch: usize) -> ArchSpec {
        let (img, in_ch, num_classes, kh, kw) = (32usize, 3usize, 10usize, 5usize, 5usize);
        let c1_out = img - kh + 1;
        let p1_out = c1_out / 2;
        let c2_out = p1_out - kh + 1;
        let p2_out = c2_out / 2;
        let fc_in = k2 * p2_out * p2_out;
        let mut param_shapes = BTreeMap::new();
        param_shapes.insert("w1".into(), vec![k1, in_ch, kh, kw]);
        param_shapes.insert("b1".into(), vec![k1]);
        param_shapes.insert("w2".into(), vec![k2, k1, kh, kw]);
        param_shapes.insert("b2".into(), vec![k2]);
        param_shapes.insert("wf".into(), vec![fc_in, num_classes]);
        param_shapes.insert("bf".into(), vec![num_classes]);
        // Batch buckets: halve down to batch/8 (model.py's ladder), so the
        // data-parallel baseline finds a grad_full for every replica split.
        let mut batch_buckets = vec![batch];
        let mut bb = batch;
        while bb % 2 == 0 && bb > std::cmp::max(2, batch / 8) {
            bb /= 2;
            batch_buckets.push(bb);
        }
        batch_buckets.sort_unstable();
        // Probe sized so one round is ~milliseconds: big enough to time,
        // small enough that calibration never dominates a test run.
        let probe_img = 24usize;
        let po = probe_img - kh + 1;
        let probe = ProbeSpec {
            batch: 8,
            in_ch: 3,
            img: probe_img,
            k: 8,
            flops: 2 * (8 * po * po * 3 * kh * kw * 8) as u64,
        };
        ArchSpec {
            k1,
            k2,
            batch,
            img,
            in_ch,
            num_classes,
            kh,
            kw,
            c1_out,
            p1_out,
            c2_out,
            p2_out,
            fc_in,
            buckets1: bucket_ladder(k1),
            buckets2: bucket_ladder(k2),
            batch_buckets,
            param_shapes,
            param_order: ["w1", "b1", "w2", "b2", "wf", "bf"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            probe,
        }
    }

    /// Kernel count of conv layer `l` (1-based, matching the paper's C1/C2).
    pub fn kernels(&self, layer: usize) -> usize {
        match layer {
            1 => self.k1,
            2 => self.k2,
            _ => panic!("conv layer {layer} out of range"),
        }
    }

    pub fn buckets(&self, layer: usize) -> &[usize] {
        match layer {
            1 => &self.buckets1,
            2 => &self.buckets2,
            _ => panic!("conv layer {layer} out of range"),
        }
    }

    /// Input (channels, height) of conv layer `l`.
    pub fn conv_input(&self, layer: usize) -> (usize, usize) {
        match layer {
            1 => (self.in_ch, self.img),
            2 => (self.k1, self.p1_out),
            _ => panic!("conv layer {layer} out of range"),
        }
    }

    /// Output height of conv layer `l`.
    pub fn conv_output(&self, layer: usize) -> usize {
        match layer {
            1 => self.c1_out,
            2 => self.c2_out,
            _ => panic!("conv layer {layer} out of range"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u32,
    pub config: ArchSpec,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::from_json_str(&raw, dir)
    }

    pub fn from_json_str(raw: &str, dir: &Path) -> Result<Self> {
        let v = Json::parse(raw).context("parsing manifest.json")?;
        let version = v.get("version")?.as_usize()? as u32;
        ensure!(version == 1, "unsupported manifest version {version}");
        let config = ArchSpec::from_json(v.get("config")?)?;
        let mut executables = BTreeMap::new();
        for (name, spec) in v.get("executables")?.as_obj()? {
            let spec = ExecutableSpec::from_json(spec)
                .with_context(|| format!("executable {name:?}"))?;
            ensure!(
                dir.join(&spec.file).exists(),
                "manifest lists {name} but {} is missing",
                spec.file
            );
            executables.insert(name.clone(), spec);
        }
        Ok(Manifest { version, config, executables, dir: dir.to_path_buf() })
    }

    pub fn spec(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable named {name:?} in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.spec(name)?.file))
    }

    /// Name of the conv fwd/bwd executable for `layer` at shard bucket `kb`.
    pub fn conv_exec(layer: usize, dir: ConvDir, kb: usize) -> String {
        let d = match dir {
            ConvDir::Fwd => "fwd",
            ConvDir::Bwd => "bwd",
        };
        format!("conv{layer}_{d}_b{kb}")
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvDir {
    Fwd,
    Bwd,
}

/// Shard-size buckets for a conv layer with `k` kernels: eighths of `k`,
/// rounded up to a multiple of 4 — bounds bucket-padding waste by ~12.5 %
/// worst-case (DESIGN.md §3; mirrors `model.py::bucket_ladder`).
pub fn bucket_ladder(k: usize) -> Vec<usize> {
    let steps = 8usize;
    let mut buckets: Vec<usize> = (1..=steps)
        .map(|i| (k * i + steps - 1) / steps) // ceil(k*i/8)
        .map(|r| std::cmp::min(k, (r + 3) / 4 * 4))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    debug_assert_eq!(*buckets.last().unwrap(), k);
    buckets
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A small ArchSpec used by unit tests across the crate.
    pub fn tiny_arch() -> ArchSpec {
        ArchSpec::tiny()
    }

    #[test]
    fn derived_geometry_matches_hand_computed_tiny() {
        let a = ArchSpec::tiny();
        assert_eq!((a.k1, a.k2, a.batch), (4, 8, 2));
        assert_eq!((a.c1_out, a.p1_out, a.c2_out, a.p2_out), (28, 14, 10, 5));
        assert_eq!(a.fc_in, 200);
        assert_eq!(a.buckets1, vec![4]);
        assert_eq!(a.buckets2, vec![4, 8]);
        assert_eq!(a.batch_buckets, vec![2]);
        assert_eq!(a.param_shapes["w2"], vec![8, 4, 5, 5]);
        assert_eq!(a.param_shapes["wf"], vec![200, 10]);
    }

    #[test]
    fn native_default_matches_python_archconfig() {
        let a = ArchSpec::native_default();
        assert_eq!((a.k1, a.k2, a.batch), (16, 32, 64));
        assert_eq!(a.fc_in, 32 * 5 * 5);
        assert_eq!(a.buckets1, vec![4, 8, 12, 16]);
        assert_eq!(a.buckets2, vec![4, 8, 12, 16, 20, 24, 28, 32]);
        assert_eq!(a.batch_buckets, vec![8, 16, 32, 64]);
        assert!(a.probe.flops > 0);
    }

    #[test]
    fn bucket_ladder_covers_and_caps() {
        for k in [4usize, 16, 32, 50, 500, 1500] {
            let l = bucket_ladder(k);
            assert_eq!(*l.last().unwrap(), k, "ladder for {k} must end at {k}");
            assert!(l.windows(2).all(|w| w[0] < w[1]), "sorted/deduped for {k}");
            assert!(l.iter().all(|&b| b <= k));
        }
    }

    #[test]
    fn parses_minimal_manifest() {
        let doc = r#"{
         "version": 1,
         "config": {
           "k1": 4, "k2": 8, "batch": 2, "img": 32, "in_ch": 3,
           "num_classes": 10, "kh": 5, "kw": 5,
           "c1_out": 28, "p1_out": 14, "c2_out": 10, "p2_out": 5,
           "fc_in": 200, "buckets1": [4], "buckets2": [4, 8],
           "batch_buckets": [2],
           "param_shapes": {"w1": [4,3,5,5], "b1": [4], "w2": [8,4,5,5],
                            "b2": [8], "wf": [200,10], "bf": [10]},
           "param_order": ["w1","b1","w2","b2","wf","bf"],
           "probe": {"batch": 1, "in_ch": 1, "img": 8, "k": 1, "flops": 100}
         },
         "executables": {}
        }"#;
        let m = Manifest::from_json_str(doc, Path::new("/tmp")).unwrap();
        assert_eq!(m.config.k1, 4);
        assert_eq!(m.config.buckets(2), &[4, 8]);
        assert_eq!(m.config.conv_input(2), (4, 14));
        assert!(m.spec("nope").is_err());
        assert_eq!(Manifest::conv_exec(1, ConvDir::Fwd, 8), "conv1_fwd_b8");
        assert_eq!(Manifest::conv_exec(2, ConvDir::Bwd, 12), "conv2_bwd_b12");
    }

    #[test]
    fn rejects_wrong_version_and_missing_file() {
        let doc = r#"{"version": 2, "config": {}, "executables": {}}"#;
        assert!(Manifest::from_json_str(doc, Path::new("/tmp")).is_err());
    }
}
