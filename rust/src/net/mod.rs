//! Transports.  One trait, three implementations:
//!
//! * [`InProcLink`]  — lock-step channel pair for deterministic tests and the
//!   single-process cluster launcher (`convdist train`).  Messages still go
//!   through full encode/decode so the wire format is exercised everywhere.
//! * [`TcpLink`]     — real sockets; the paper's deployment shape (workers
//!   listen, master connects — Algorithm 1 line 2).
//! * [`ShapedLink`]  — wraps any link and meters bytes through a token-bucket
//!   bandwidth + fixed latency model, reproducing the paper's ~5 Mbps Wi-Fi.
//!   This is what lets a loopback cluster exhibit the paper's comm/conv/comp
//!   ratios (§5.3.4: "the bandwidth is approximately constant, averaging at
//!   5 Mbps").

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::proto::{frame_len, read_frame, write_frame, Message};

/// A reliable, ordered, bidirectional message link.
pub trait Link: Send {
    fn send(&mut self, msg: &Message) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;
    /// Receive with a deadline: `Ok(None)` means nothing arrived in time
    /// (the link is still healthy).  The default implementation blocks —
    /// transports that can wait a bounded time override it.  In-proc links
    /// bound the whole receive; TCP bounds the wait for the *first byte* of
    /// a frame (a frame is never abandoned mid-read, so the stream cannot
    /// desynchronize) — enough for heartbeats and gather deadlines, where a
    /// wedged worker sends nothing at all.
    fn recv_timeout(&mut self, _timeout: Duration) -> Result<Option<Message>> {
        self.recv().map(Some)
    }
    /// Cumulative bytes sent + received (Eq. 2 accounting).
    fn bytes_moved(&self) -> u64;
    /// Cumulative frames sent + received — the obs layer's per-link rate
    /// denominator (bytes alone can't separate many small control frames
    /// from one tensor frame).  Default 0 for links that don't count.
    fn frames_moved(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// In-process link
// ---------------------------------------------------------------------------

/// One endpoint of an in-process link.
pub struct InProcLink {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    bytes: u64,
    frames: u64,
}

/// A connected pair of in-process endpoints.
pub fn inproc_pair() -> (InProcLink, InProcLink) {
    let (atx, brx) = std::sync::mpsc::channel();
    let (btx, arx) = std::sync::mpsc::channel();
    (
        InProcLink { tx: atx, rx: arx, bytes: 0, frames: 0 },
        InProcLink { tx: btx, rx: brx, bytes: 0, frames: 0 },
    )
}

impl Link for InProcLink {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg)?;
        self.bytes += buf.len() as u64;
        self.frames += 1;
        self.tx.send(buf).map_err(|_| anyhow::anyhow!("in-proc peer hung up"))?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let buf = self.rx.recv().map_err(|_| anyhow::anyhow!("in-proc peer hung up"))?;
        self.bytes += buf.len() as u64;
        self.frames += 1;
        read_frame(&mut std::io::Cursor::new(buf))
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Message>> {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(buf) => {
                self.bytes += buf.len() as u64;
                self.frames += 1;
                read_frame(&mut std::io::Cursor::new(buf)).map(Some)
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!("in-proc peer hung up")),
        }
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes
    }

    fn frames_moved(&self) -> u64 {
        self.frames
    }
}

// ---------------------------------------------------------------------------
// TCP link
// ---------------------------------------------------------------------------

pub struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    bytes: u64,
    frames: u64,
}

impl TcpLink {
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        let reader = BufReader::with_capacity(
            1 << 20,
            stream.try_clone().context("cloning stream for the read half")?,
        );
        let writer = BufWriter::with_capacity(1 << 20, stream);
        Ok(Self { reader, writer, bytes: 0, frames: 0 })
    }

    /// Master side: connect to a worker's listen address (Algorithm 1
    /// `connectSocket(slave)`), retrying briefly so worker start-up order
    /// does not matter.
    pub fn connect(addr: impl ToSocketAddrs + Clone + std::fmt::Debug) -> Result<Self> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match TcpStream::connect(addr.clone()) {
                Ok(s) => return Self::from_stream(s),
                Err(e) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                    let _ = e;
                }
                Err(e) => return Err(e).with_context(|| format!("connecting to {addr:?}")),
            }
        }
    }

    /// Worker side: accept exactly one master connection.
    pub fn accept_one(listener: &TcpListener) -> Result<Self> {
        let (stream, peer) = listener.accept().context("accepting master connection")?;
        Self::from_stream(stream).with_context(|| format!("initializing link to {peer}"))
    }
}

impl Link for TcpLink {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.bytes += frame_len(msg) as u64;
        self.frames += 1;
        write_frame(&mut self.writer, msg)
    }

    fn recv(&mut self) -> Result<Message> {
        let msg = read_frame(&mut self.reader)?;
        self.bytes += frame_len(&msg) as u64;
        self.frames += 1;
        Ok(msg)
    }

    /// Bounded wait for the *start* of a frame: the socket read timeout is
    /// armed only while no frame bytes are buffered, and cleared before the
    /// full (blocking) frame read.  A timeout therefore always lands on a
    /// frame boundary — the stream never desynchronizes — and `Ok(None)`
    /// means the link is still healthy, exactly like the in-proc link.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Message>> {
        use std::io::BufRead;
        if self.reader.buffer().is_empty() {
            // set_read_timeout rejects a zero Duration; clamp up.
            let t = if timeout.is_zero() { Duration::from_millis(1) } else { timeout };
            self.reader
                .get_ref()
                .set_read_timeout(Some(t))
                .context("arming socket read timeout")?;
            // Retry EINTR inline: a benign signal (SIGCHLD, SIGPROF, …) must
            // not read as a dead link, and surfacing it as a timeout would
            // make heartbeat callers drop a healthy worker.  Each retry
            // re-arms only the *remaining* budget, so a stream of signals
            // cannot extend the deadline indefinitely.
            let deadline = Instant::now() + t;
            let waited = loop {
                match self.reader.fill_buf() {
                    Ok(buf) => break Ok(!buf.is_empty()),
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
                        }
                        if let Err(e) = self.reader.get_ref().set_read_timeout(Some(left)) {
                            break Err(e);
                        }
                    }
                    Err(e) => break Err(e),
                }
            };
            self.reader
                .get_ref()
                .set_read_timeout(None)
                .context("clearing socket read timeout")?;
            match waited {
                Ok(true) => {}
                Ok(false) => anyhow::bail!("peer closed the connection"),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(None);
                }
                Err(e) => return Err(e).context("polling socket for a frame"),
            }
        }
        self.recv().map(Some)
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes
    }

    fn frames_moved(&self) -> u64 {
        self.frames
    }
}

// ---------------------------------------------------------------------------
// Bandwidth shaping
// ---------------------------------------------------------------------------

/// Bandwidth/latency model for a link (paper: ~5 Mbps Wi-Fi, §5.3.4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// Payload bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way latency added to every frame.
    pub latency: Duration,
}

impl LinkModel {
    pub fn mbps(mbps: f64) -> Self {
        Self { bandwidth_bps: mbps * 1e6, latency: Duration::from_millis(2) }
    }

    /// Transfer time Eq. 2-style: bytes over the modeled link.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        Duration::from_secs_f64(bytes as f64 * 8.0 / self.bandwidth_bps) + self.latency
    }
}

/// Wraps a link; every `send` blocks for the modeled transfer time (the
/// receiver side is left unshaped so a frame is charged exactly once).
pub struct ShapedLink<L: Link> {
    inner: L,
    model: LinkModel,
}

impl<L: Link> ShapedLink<L> {
    pub fn new(inner: L, model: LinkModel) -> Self {
        Self { inner, model }
    }
}

impl<L: Link> Link for ShapedLink<L> {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let dt = self.model.transfer_time(frame_len(msg));
        std::thread::sleep(dt);
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<Message> {
        self.inner.recv()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Message>> {
        self.inner.recv_timeout(timeout)
    }

    fn bytes_moved(&self) -> u64 {
        self.inner.bytes_moved()
    }

    fn frames_moved(&self) -> u64 {
        self.inner.frames_moved()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = inproc_pair();
        a.send(&Message::Calibrate { rounds: 3 }).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Calibrate { rounds: 3 });
        b.send(&Message::AllOk).unwrap();
        assert_eq!(a.recv().unwrap(), Message::AllOk);
        assert!(a.bytes_moved() > 0);
        // One frame out, one frame in — on both ends and on both counters.
        assert_eq!(a.frames_moved(), 2);
        assert_eq!(b.frames_moved(), 2);
    }

    #[test]
    fn inproc_recv_timeout_expires_and_still_delivers() {
        let (mut a, mut b) = inproc_pair();
        // Nothing queued: times out cleanly, link stays healthy.
        let got = a.recv_timeout(Duration::from_millis(20)).unwrap();
        assert!(got.is_none());
        b.send(&Message::AllOk).unwrap();
        let got = a.recv_timeout(Duration::from_millis(200)).unwrap();
        assert_eq!(got, Some(Message::AllOk));
        // Peer gone: error, not a silent timeout.
        drop(b);
        assert!(a.recv_timeout(Duration::from_millis(20)).is_err());
    }

    #[test]
    fn tcp_roundtrip_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut worker = TcpLink::accept_one(&listener).unwrap();
            let msg = worker.recv().unwrap();
            worker.send(&msg).unwrap(); // echo
        });
        let mut master = TcpLink::connect(addr).unwrap();
        let sent = Message::Hello { worker_id: 7, version: 1 };
        master.send(&sent).unwrap();
        assert_eq!(master.recv().unwrap(), sent);
        h.join().unwrap();
    }

    #[test]
    fn tcp_recv_timeout_expires_and_still_delivers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h = std::thread::spawn(move || {
            let mut worker = TcpLink::accept_one(&listener).unwrap();
            // Hold the connection open but silent until signalled — the
            // wedged-but-connected case the old blocking reads could not
            // detect.
            rx.recv().unwrap();
            worker.send(&Message::AllOk).unwrap();
            // Keep the socket alive until the master has read the frame.
            rx.recv().unwrap();
        });
        let mut master = TcpLink::connect(addr).unwrap();
        // Nothing queued: the deadline expires cleanly, link stays healthy.
        let got = master.recv_timeout(Duration::from_millis(30)).unwrap();
        assert!(got.is_none(), "silent peer must time out, not error");
        // A later frame is still delivered intact over the same link.
        tx.send(()).unwrap();
        let got = master.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, Some(Message::AllOk));
        // And the link still serves plain blocking sends/recvs.
        tx.send(()).unwrap();
        h.join().unwrap();
        // Peer gone: an error, not a silent timeout (poll until the FIN
        // lands — delivery is asynchronous even on loopback).
        let mut saw_error = false;
        for _ in 0..200 {
            match master.recv_timeout(Duration::from_millis(20)) {
                Ok(None) => continue,
                Ok(Some(m)) => panic!("unexpected frame after close: {m:?}"),
                Err(_) => {
                    saw_error = true;
                    break;
                }
            }
        }
        assert!(saw_error, "closed peer must surface as an error");
    }

    #[test]
    fn shaped_link_delays_sends() {
        let (a, mut b) = inproc_pair();
        // 1 Mbps: the ~37-byte AllOk frame ~0.3ms, dominated by 20ms latency.
        let model =
            LinkModel { bandwidth_bps: 1e6, latency: Duration::from_millis(20) };
        let mut shaped = ShapedLink::new(a, model);
        let t0 = Instant::now();
        shaped.send(&Message::AllOk).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(b.recv().unwrap(), Message::AllOk);
    }

    #[test]
    fn link_model_transfer_time_scales() {
        let m = LinkModel::mbps(5.0);
        let t1 = m.transfer_time(1_000_000);
        let t2 = m.transfer_time(2_000_000);
        // 1 MB at 5 Mbps = 1.6 s (+2 ms latency).
        assert!((t1.as_secs_f64() - 1.602).abs() < 1e-3, "{t1:?}");
        assert!(t2 > t1);
    }
}
