//! L1: pure-rust CPU kernels for the paper's hot path — the native stand-in
//! for the Pallas convolution kernels (the paper's 60–90 % of training time).
//!
//! Conventions match `python/compile/kernels/ref.py` exactly: activations are
//! NCHW, kernels OIHW, convolutions are valid-padding stride-1
//! cross-correlations.  Convolutions are im2col + GEMM, rayon-parallel over
//! the batch axis (bwd reduces the kernel-gradient over per-image partials),
//! with every GEMM served by the blocked/packed/SIMD engine in
//! [`crate::linalg`] and the im2col scratch reused from thread-local
//! buffers (no per-call allocation on the hot path).  All math is f32, the
//! compute dtype the AOT pipeline used, so wire payloads and parameter
//! stores are unchanged.

use std::cell::RefCell;

use rayon::prelude::*;

use crate::linalg;

/// LRN hyper-parameters — fixed by the model definition
/// (`python/compile/model.py::lrn`), not tunable at run time.
pub const LRN_N: usize = 5;
pub const LRN_K: f32 = 2.0;
pub const LRN_ALPHA: f32 = 1e-4;
pub const LRN_BETA: f32 = 0.75;

// ---------------------------------------------------------------------------
// Per-thread conv scratch
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread `(im2col, column-gradient)` scratch, reused across batch
    /// items and training steps: the conv hot path — the paper's 60–90 % of
    /// step time — allocates nothing per call.  One pair per rayon worker;
    /// the GEMMs these buffers feed run serial inside the batch loop
    /// (`linalg`'s nested-parallelism guard), so a borrow is never held
    /// across a blocking join.
    static CONV_SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Grow-only resize: returns `buf[..len]` without zeroing previously used
/// capacity (callers fully overwrite or explicitly clear what they read).
fn scratch_slice(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

// ---------------------------------------------------------------------------
// im2col / col2im
// ---------------------------------------------------------------------------

/// Unfold one image `x[c,h,w]` into `col[c*kh*kw, oh*ow]` (valid, stride 1).
fn im2col(x: &[f32], c: usize, h: usize, w: usize, kh: usize, kw: usize, col: &mut [f32]) {
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    debug_assert_eq!(x.len(), c * h * w);
    debug_assert_eq!(col.len(), c * kh * kw * oh * ow);
    let mut r = 0usize;
    for ci in 0..c {
        for di in 0..kh {
            for dj in 0..kw {
                let row = &mut col[r * oh * ow..(r + 1) * oh * ow];
                r += 1;
                for oi in 0..oh {
                    let src = &x[(ci * h + oi + di) * w + dj..][..ow];
                    row[oi * ow..(oi + 1) * ow].copy_from_slice(src);
                }
            }
        }
    }
}

/// Fold `col[c*kh*kw, oh*ow]` back into `gx[c,h,w]` with `+=` (the adjoint
/// of [`im2col`]); `gx` must be zero-initialized by the caller.
fn col2im(col: &[f32], c: usize, h: usize, w: usize, kh: usize, kw: usize, gx: &mut [f32]) {
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let mut r = 0usize;
    for ci in 0..c {
        for di in 0..kh {
            for dj in 0..kw {
                let row = &col[r * oh * ow..(r + 1) * oh * ow];
                r += 1;
                for oi in 0..oh {
                    let dst = &mut gx[(ci * h + oi + di) * w + dj..][..ow];
                    for (d, &s) in dst.iter_mut().zip(&row[oi * ow..(oi + 1) * ow]) {
                        *d += s;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------------

/// Forward: `x[b,c,h,w] * w[k,c,kh,kw] + bias[k] -> y[b,k,oh,ow]`.
/// Same semantics as `conv2d_ref` in `python/compile/kernels/ref.py`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    c: usize,
    h: usize,
    wd: usize,
    k: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let (oh, ow) = (h - kh + 1, wd - kw + 1);
    let (ckk, ohw) = (c * kh * kw, oh * ow);
    let mut y = vec![0f32; b * k * ohw];
    y.par_chunks_mut(k * ohw)
        .zip(x.par_chunks(c * h * wd))
        .for_each(|(yi, xi)| {
            CONV_SCRATCH.with(|s| {
                let mut guard = s.borrow_mut();
                let (colbuf, _) = &mut *guard;
                let col = scratch_slice(colbuf, ckk * ohw);
                im2col(xi, c, h, wd, kh, kw, col);
                for (ki, row) in yi.chunks_mut(ohw).enumerate() {
                    row.fill(bias[ki]);
                }
                linalg::gemm(w, col, k, ckk, ohw, yi);
            });
        });
    y
}

/// Backward: given `gy[b,k,oh,ow]`, return `(gx, gw, gb)` — the input
/// cotangent, kernel gradient and bias gradient of [`conv2d_fwd`].
/// Parallel over the batch; `gw`/`gb` accumulate into one buffer pair per
/// rayon split (fold), merged at the end (reduce) — no per-image partials.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd(
    x: &[f32],
    w: &[f32],
    gy: &[f32],
    b: usize,
    c: usize,
    h: usize,
    wd: usize,
    k: usize,
    kh: usize,
    kw: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (oh, ow) = (h - kh + 1, wd - kw + 1);
    let (ckk, ohw) = (c * kh * kw, oh * ow);
    // w^T [ckk, k] so the input-cotangent GEMM reads contiguous rows.
    let mut wt = vec![0f32; ckk * k];
    for ki in 0..k {
        for r in 0..ckk {
            wt[r * k + ki] = w[ki * ckk + r];
        }
    }
    let mut gx = vec![0f32; b * c * h * wd];
    let (gw, gb) = gx
        .par_chunks_mut(c * h * wd)
        .zip(x.par_chunks(c * h * wd))
        .zip(gy.par_chunks(k * ohw))
        .fold(
            // One (gw, gb) accumulator pair per rayon split, reused across
            // the batch items it processes — the kernel-gradient GEMM
            // accumulates straight into it (no per-image partial Vecs).
            || (vec![0f32; k * ckk], vec![0f32; k]),
            |(mut aw, mut ab), ((gxi, xi), gyi)| {
                CONV_SCRATCH.with(|s| {
                    let mut guard = s.borrow_mut();
                    let (colbuf, colgbuf) = &mut *guard;
                    let col = scratch_slice(colbuf, ckk * ohw);
                    im2col(xi, c, h, wd, kh, kw, col);
                    // gw[k,ckk] += gy_i[k,ohw] * col^T
                    linalg::gemm_abt(gyi, col, k, ohw, ckk, &mut aw);
                    for (ki, gbk) in ab.iter_mut().enumerate() {
                        *gbk += gyi[ki * ohw..(ki + 1) * ohw].iter().sum::<f32>();
                    }
                    // gx: colgrad[ckk,ohw] = w^T * gy_i, back via col2im.
                    let colg = scratch_slice(colgbuf, ckk * ohw);
                    colg.fill(0.0);
                    linalg::gemm(&wt, gyi, ckk, k, ohw, colg);
                    col2im(colg, c, h, wd, kh, kw, gxi);
                });
                (aw, ab)
            },
        )
        .reduce(
            || (vec![0f32; k * ckk], vec![0f32; k]),
            |(mut aw, mut ab), (bw, bb)| {
                for (a, v) in aw.iter_mut().zip(&bw) {
                    *a += v;
                }
                for (a, v) in ab.iter_mut().zip(&bb) {
                    *a += v;
                }
                (aw, ab)
            },
        );
    (gx, gw, gb)
}

// ---------------------------------------------------------------------------
// 2x2 / stride-2 max pooling
// ---------------------------------------------------------------------------

/// `x[b,c,h,w] -> y[b,c,h/2,w/2]`; `h` and `w` must be even.
pub fn maxpool2_fwd(x: &[f32], b: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let (ph, pw) = (h / 2, w / 2);
    debug_assert_eq!(x.len(), b * c * h * w);
    let mut y = vec![0f32; b * c * ph * pw];
    y.par_chunks_mut(ph * pw).zip(x.par_chunks(h * w)).for_each(|(yc, xc)| {
        for i in 0..ph {
            for j in 0..pw {
                let a = xc[(2 * i) * w + 2 * j];
                let bq = xc[(2 * i) * w + 2 * j + 1];
                let cq = xc[(2 * i + 1) * w + 2 * j];
                let d = xc[(2 * i + 1) * w + 2 * j + 1];
                yc[i * pw + j] = a.max(bq).max(cq).max(d);
            }
        }
    });
    y
}

/// Pooling backward: route each pooled gradient to the (first, in scan
/// order) argmax of its 2x2 window in `x`.
pub fn maxpool2_bwd(x: &[f32], gp: &[f32], b: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let (ph, pw) = (h / 2, w / 2);
    debug_assert_eq!(gp.len(), b * c * ph * pw);
    let mut gx = vec![0f32; b * c * h * w];
    gx.par_chunks_mut(h * w)
        .zip(x.par_chunks(h * w))
        .zip(gp.par_chunks(ph * pw))
        .for_each(|((gxc, xc), gpc)| {
            for i in 0..ph {
                for j in 0..pw {
                    let idx = [
                        (2 * i) * w + 2 * j,
                        (2 * i) * w + 2 * j + 1,
                        (2 * i + 1) * w + 2 * j,
                        (2 * i + 1) * w + 2 * j + 1,
                    ];
                    let mut best = idx[0];
                    for &p in &idx[1..] {
                        if xc[p] > xc[best] {
                            best = p;
                        }
                    }
                    gxc[best] += gpc[i * pw + j];
                }
            }
        });
    gx
}

// ---------------------------------------------------------------------------
// Elementwise rectifier
// ---------------------------------------------------------------------------

/// `y = max(x, 0)` — shape-free elementwise forward.
pub fn relu_fwd(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

/// ReLU backward: pass the gradient where the *input* was positive.
pub fn relu_bwd(x: &[f32], gy: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), gy.len());
    x.iter().zip(gy).map(|(&v, &g)| if v > 0.0 { g } else { 0.0 }).collect()
}

// ---------------------------------------------------------------------------
// Local response normalization (AlexNet-style, across channels)
// ---------------------------------------------------------------------------

/// Channel window `[lo, hi]` of LRN at channel `ci` (zero padding clipped).
#[inline]
fn lrn_window(ci: usize, c: usize) -> (usize, usize) {
    let half = LRN_N / 2;
    (ci.saturating_sub(half), (ci + LRN_N - 1 - half).min(c - 1))
}

/// `y = x * (k + alpha * sum_{|j-i|<=2} x_j^2)^(-beta)`, matching
/// `lrn_ref` in `python/compile/kernels/ref.py`.
pub fn lrn_fwd(x: &[f32], b: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let hw = h * w;
    debug_assert_eq!(x.len(), b * c * hw);
    let mut y = vec![0f32; x.len()];
    y.par_chunks_mut(c * hw).zip(x.par_chunks(c * hw)).for_each(|(yi, xi)| {
        for p in 0..hw {
            for ci in 0..c {
                let (lo, hi) = lrn_window(ci, c);
                let mut s = 0f32;
                for j in lo..=hi {
                    let v = xi[j * hw + p];
                    s += v * v;
                }
                let d = LRN_K + LRN_ALPHA * s;
                yi[ci * hw + p] = xi[ci * hw + p] * d.powf(-LRN_BETA);
            }
        }
    });
    y
}

/// LRN backward:
/// `gx_m = gy_m * d_m^(-b) - 2*a*b * x_m * sum_{|i-m|<=2} gy_i x_i d_i^(-b-1)`
/// with `d_i = k + a * S_i` (the same clipped channel window as forward).
pub fn lrn_bwd(x: &[f32], gy: &[f32], b: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let hw = h * w;
    debug_assert_eq!(x.len(), b * c * hw);
    let mut gx = vec![0f32; x.len()];
    gx.par_chunks_mut(c * hw)
        .zip(x.par_chunks(c * hw))
        .zip(gy.par_chunks(c * hw))
        .for_each(|((gxi, xi), gyi)| {
            let mut dpow = vec![0f32; c]; // d^(-beta)
            let mut inner = vec![0f32; c]; // gy * x * d^(-beta-1)
            for p in 0..hw {
                for ci in 0..c {
                    let (lo, hi) = lrn_window(ci, c);
                    let mut s = 0f32;
                    for j in lo..=hi {
                        let v = xi[j * hw + p];
                        s += v * v;
                    }
                    let d = LRN_K + LRN_ALPHA * s;
                    let dp = d.powf(-LRN_BETA);
                    dpow[ci] = dp;
                    // d^(-beta-1) == d^(-beta) / d: one powf, not two.
                    inner[ci] = gyi[ci * hw + p] * xi[ci * hw + p] * (dp / d);
                }
                for m in 0..c {
                    let (lo, hi) = lrn_window(m, c);
                    let mut acc = 0f32;
                    for i in lo..=hi {
                        acc += inner[i];
                    }
                    gxi[m * hw + p] = gyi[m * hw + p] * dpow[m]
                        - 2.0 * LRN_ALPHA * LRN_BETA * xi[m * hw + p] * acc;
                }
            }
        });
    gx
}

// ---------------------------------------------------------------------------
// Fully connected head + softmax cross-entropy
// ---------------------------------------------------------------------------

/// `logits[b,c] = p2[b,f] * wf[f,c] + bf[c]` (`p2` is the flattened pool-2
/// output; NCHW row-major flattening matches `p2.reshape(B, -1)` in jax).
pub fn fc_logits(p2: &[f32], wf: &[f32], bf: &[f32], b: usize, f: usize, c: usize) -> Vec<f32> {
    let mut logits = vec![0f32; b * c];
    for row in logits.chunks_mut(c) {
        row.copy_from_slice(bf);
    }
    linalg::gemm(p2, wf, b, f, c, &mut logits);
    logits
}

/// Mean softmax cross-entropy over the batch; returns `(loss, dloss/dlogits)`.
pub fn softmax_xent_grad(logits: &[f32], labels: &[i32], b: usize, c: usize) -> (f32, Vec<f32>) {
    let mut g = vec![0f32; b * c];
    let mut loss = 0f64;
    for i in 0..b {
        let row = &logits[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let grow = &mut g[i * c..(i + 1) * c];
        let mut z = 0f32;
        for (gj, &l) in grow.iter_mut().zip(row) {
            let e = (l - m).exp();
            *gj = e;
            z += e;
        }
        let lab = labels[i] as usize;
        debug_assert!(lab < c, "label {lab} out of {c} classes");
        loss -= ((row[lab] - m) - z.ln()) as f64;
        for gj in grow.iter_mut() {
            *gj /= z;
        }
        grow[lab] -= 1.0;
        for gj in grow.iter_mut() {
            *gj /= b as f32;
        }
    }
    ((loss / b as f64) as f32, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    /// Direct 7-loop reference convolution — the in-tree analogue of
    /// `ref.py`'s oracle role: the im2col path must match it exactly.
    fn conv_ref(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        b: usize,
        c: usize,
        h: usize,
        wd: usize,
        k: usize,
        kh: usize,
        kw: usize,
    ) -> Vec<f32> {
        let (oh, ow) = (h - kh + 1, wd - kw + 1);
        let mut y = vec![0f32; b * k * oh * ow];
        for bi in 0..b {
            for ki in 0..k {
                for oi in 0..oh {
                    for oj in 0..ow {
                        let mut acc = bias[ki];
                        for ci in 0..c {
                            for di in 0..kh {
                                for dj in 0..kw {
                                    acc += x[((bi * c + ci) * h + oi + di) * wd + oj + dj]
                                        * w[((ki * c + ci) * kh + di) * kw + dj];
                                }
                            }
                        }
                        y[((bi * k + ki) * oh + oi) * ow + oj] = acc;
                    }
                }
            }
        }
        y
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn conv_fwd_matches_hand_computed_case() {
        // x = 1..9 in a 3x3, w = [[1,0],[0,1]], bias 0.5:
        // y[i,j] = x[i,j] + x[i+1,j+1] + 0.5.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let y = conv2d_fwd(&x, &w, &[0.5], 1, 1, 3, 3, 1, 2, 2);
        assert_eq!(y, vec![6.5, 8.5, 12.5, 14.5]);
    }

    #[test]
    fn conv_bwd_matches_hand_computed_case() {
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let gy = vec![1.0; 4];
        let (gx, gw, gb) = conv2d_bwd(&x, &w, &gy, 1, 1, 3, 3, 1, 2, 2);
        assert_eq!(gx, vec![1.0, 1.0, 0.0, 1.0, 2.0, 1.0, 0.0, 1.0, 1.0]);
        assert_eq!(gw, vec![12.0, 16.0, 24.0, 28.0]);
        assert_eq!(gb, vec![4.0]);
    }

    #[test]
    fn conv_fwd_matches_reference_on_random_shapes() {
        let mut rng = Pcg32::seed(11);
        for &(b, c, h, k, kh) in &[(2usize, 3usize, 8usize, 4usize, 3usize), (1, 1, 5, 2, 2), (3, 4, 6, 5, 5)] {
            let x: Vec<f32> = (0..b * c * h * h).map(|_| rng.next_gaussian()).collect();
            let w: Vec<f32> = (0..k * c * kh * kh).map(|_| rng.next_gaussian()).collect();
            let bias: Vec<f32> = (0..k).map(|_| rng.next_gaussian()).collect();
            let got = conv2d_fwd(&x, &w, &bias, b, c, h, h, k, kh, kh);
            let want = conv_ref(&x, &w, &bias, b, c, h, h, k, kh, kh);
            assert!(max_abs_diff(&got, &want) < 1e-4, "shape b{b} c{c} h{h} k{k} kh{kh}");
        }
    }

    #[test]
    fn conv_bwd_matches_direct_adjoint() {
        // The adjoint of a linear map is checkable exactly:
        // <conv(x), gy> == <x, gx> and likewise for w.
        let mut rng = Pcg32::seed(12);
        let (b, c, h, k, kh) = (2usize, 3usize, 7usize, 4usize, 3usize);
        let oh = h - kh + 1;
        let x: Vec<f32> = (0..b * c * h * h).map(|_| rng.next_gaussian()).collect();
        let w: Vec<f32> = (0..k * c * kh * kh).map(|_| rng.next_gaussian()).collect();
        let gy: Vec<f32> = (0..b * k * oh * oh).map(|_| rng.next_gaussian()).collect();
        let (gx, gw, gb) = conv2d_bwd(&x, &w, &gy, b, c, h, h, k, kh, kh);
        // <y(x,w,0), gy> = <x, gx> (linearity in x) = <w, gw> (linearity in w)
        let zero_bias = vec![0.0f32; k];
        let y = conv2d_fwd(&x, &w, &zero_bias, b, c, h, h, k, kh, kh);
        let ip_y: f32 = y.iter().zip(&gy).map(|(a, b)| a * b).sum();
        let ip_x: f32 = x.iter().zip(&gx).map(|(a, b)| a * b).sum();
        let ip_w: f32 = w.iter().zip(&gw).map(|(a, b)| a * b).sum();
        assert!((ip_y - ip_x).abs() < 1e-2 * ip_y.abs().max(1.0), "{ip_y} vs {ip_x}");
        assert!((ip_y - ip_w).abs() < 1e-2 * ip_y.abs().max(1.0), "{ip_y} vs {ip_w}");
        // gb is the plain per-kernel sum of gy.
        for ki in 0..k {
            let want: f32 = (0..b)
                .map(|bi| gy[(bi * k + ki) * oh * oh..(bi * k + ki + 1) * oh * oh].iter().sum::<f32>())
                .sum();
            assert!((gb[ki] - want).abs() < 1e-3);
        }
    }

    #[test]
    fn zero_padded_kernels_produce_zero_maps_and_grads() {
        // Bucket padding: rows of zero kernels must yield zero outputs (fwd)
        // and zero kernel-gradients for zero gy rows (bwd).
        let mut rng = Pcg32::seed(13);
        let (b, c, h, kh) = (2usize, 2usize, 6usize, 3usize);
        let oh = h - kh + 1;
        let mut w: Vec<f32> = (0..4 * c * kh * kh).map(|_| rng.next_gaussian()).collect();
        for v in &mut w[2 * c * kh * kh..] {
            *v = 0.0; // kernels 2..4 are padding
        }
        let x: Vec<f32> = (0..b * c * h * h).map(|_| rng.next_gaussian()).collect();
        let y = conv2d_fwd(&x, &w, &[0.0; 4], b, c, h, h, 4, kh, kh);
        for bi in 0..b {
            for ki in 2..4 {
                let row = &y[(bi * 4 + ki) * oh * oh..(bi * 4 + ki + 1) * oh * oh];
                assert!(row.iter().all(|&v| v == 0.0));
            }
        }
        let mut gy = vec![0f32; b * 4 * oh * oh];
        for bi in 0..b {
            for v in &mut gy[bi * 4 * oh * oh..bi * 4 * oh * oh + 2 * oh * oh] {
                *v = rng.next_gaussian();
            }
        }
        let (_gx, gw, gb) = conv2d_bwd(&x, &w, &gy, b, c, h, h, 4, kh, kh);
        assert!(gw[2 * c * kh * kh..].iter().all(|&v| v == 0.0));
        assert!(gb[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn maxpool_roundtrip_and_gradient_routing() {
        let x = vec![
            1.0, 2.0, 5.0, 0.0, //
            3.0, 4.0, 1.0, 1.0, //
            0.0, 0.0, 9.0, 8.0, //
            0.0, 7.0, 6.0, 5.0,
        ];
        let y = maxpool2_fwd(&x, 1, 1, 4, 4);
        assert_eq!(y, vec![4.0, 5.0, 7.0, 9.0]);
        let gx = maxpool2_bwd(&x, &[1.0, 2.0, 3.0, 4.0], 1, 1, 4, 4);
        let mut want = vec![0f32; 16];
        want[5] = 1.0; // 4.0 at (1,1)
        want[2] = 2.0; // 5.0 at (0,2)
        want[13] = 3.0; // 7.0 at (3,1)
        want[10] = 4.0; // 9.0 at (2,2)
        assert_eq!(gx, want);
    }

    #[test]
    fn relu_fwd_and_bwd_gate_on_input_sign() {
        let x = vec![-1.5f32, 0.0, 2.0, -0.1, 3.5];
        assert_eq!(relu_fwd(&x), vec![0.0, 0.0, 2.0, 0.0, 3.5]);
        let gy = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        // Gradient flows only where x > 0 (the x == 0 subgradient is 0).
        assert_eq!(relu_bwd(&x, &gy), vec![0.0, 0.0, 3.0, 0.0, 5.0]);
    }

    /// f64 LRN forward for finite differences (f32 FD is too noisy).
    fn lrn_fwd_f64(x: &[f64], c: usize, hw: usize) -> Vec<f64> {
        let mut y = vec![0f64; x.len()];
        for p in 0..hw {
            for ci in 0..c {
                let (lo, hi) = lrn_window(ci, c);
                let mut s = 0f64;
                for j in lo..=hi {
                    s += x[j * hw + p] * x[j * hw + p];
                }
                let d = LRN_K as f64 + LRN_ALPHA as f64 * s;
                y[ci * hw + p] = x[ci * hw + p] * d.powf(-(LRN_BETA as f64));
            }
        }
        y
    }

    #[test]
    fn lrn_fwd_matches_formula_and_bwd_matches_finite_differences() {
        let mut rng = Pcg32::seed(14);
        let (c, h) = (7usize, 3usize);
        let hw = h * h;
        let x: Vec<f32> = (0..c * hw).map(|_| rng.next_gaussian()).collect();
        let y = lrn_fwd(&x, 1, c, h, h);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64 = lrn_fwd_f64(&x64, c, hw);
        for (a, b) in y.iter().zip(&y64) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
        let gy: Vec<f32> = (0..c * hw).map(|_| rng.next_gaussian()).collect();
        let gx = lrn_bwd(&x, &gy, 1, c, h, h);
        // FD of L = <gy, lrn(x)> at a handful of coordinates.
        let eps = 1e-4f64;
        for probe in [0usize, 5, hw, 3 * hw + 2, c * hw - 1] {
            let mut xp = x64.clone();
            xp[probe] += eps;
            let mut xm = x64.clone();
            xm[probe] -= eps;
            let lp: f64 =
                lrn_fwd_f64(&xp, c, hw).iter().zip(&gy).map(|(a, &g)| a * g as f64).sum();
            let lm: f64 =
                lrn_fwd_f64(&xm, c, hw).iter().zip(&gy).map(|(a, &g)| a * g as f64).sum();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (gx[probe] as f64 - fd).abs() < 1e-3,
                "lrn grad at {probe}: analytic {} vs fd {fd}",
                gx[probe]
            );
        }
    }

    #[test]
    fn softmax_xent_loss_and_grad_consistent() {
        let mut rng = Pcg32::seed(15);
        let (b, c) = (4usize, 6usize);
        let logits: Vec<f32> = (0..b * c).map(|_| rng.next_gaussian()).collect();
        let labels: Vec<i32> = (0..b).map(|_| rng.next_below(c as u32) as i32).collect();
        let (loss, g) = softmax_xent_grad(&logits, &labels, b, c);
        assert!(loss > 0.0);
        // Rows of the gradient sum to zero (softmax minus one-hot).
        for i in 0..b {
            let s: f32 = g[i * c..(i + 1) * c].iter().sum();
            assert!(s.abs() < 1e-5, "row {i} sums to {s}");
        }
        // FD check on two coordinates.
        let f64_loss = |l: &[f32]| -> f64 {
            let mut total = 0f64;
            for i in 0..b {
                let row: Vec<f64> = l[i * c..(i + 1) * c].iter().map(|&v| v as f64).collect();
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let z: f64 = row.iter().map(|v| (v - m).exp()).sum();
                total -= row[labels[i] as usize] - m - z.ln();
            }
            total / b as f64
        };
        for probe in [1usize, b * c - 2] {
            let eps = 1e-3f32;
            let mut lp = logits.clone();
            lp[probe] += eps;
            let mut lm = logits.clone();
            lm[probe] -= eps;
            let fd = (f64_loss(&lp) - f64_loss(&lm)) / (2.0 * eps as f64);
            assert!((g[probe] as f64 - fd).abs() < 1e-3, "grad {probe}: {} vs {fd}", g[probe]);
        }
    }

    #[test]
    fn fc_logits_matches_manual_product() {
        let p2 = vec![1.0, 2.0, 3.0, 4.0]; // [2,2]
        let wf = vec![1.0, 0.0, 0.0, 1.0]; // [2,2] identity
        let bf = vec![0.5, -0.5];
        let l = fc_logits(&p2, &wf, &bf, 2, 2, 2);
        assert_eq!(l, vec![1.5, 1.5, 3.5, 3.5]);
    }
}
