//! `convdist compare BASE.jsonl CAND.jsonl` — cross-run regression
//! analytics over two run logs.
//!
//! The gated metrics are the ones the paper's evaluation is built on: step
//! time (p50/p95) and the Fig.-6 per-phase attribution (mean comm/conv/comp
//! ms per step). A candidate regresses when a gated metric exceeds the
//! baseline by more than `--threshold` percent. Event counts
//! (repartitions, departures, anomalies) are reported as informational
//! deltas — a re-partition storm is a symptom, not itself a failure.
//!
//! CI commits a golden baseline log (`rust/tests/fixtures/golden_run.jsonl`)
//! and runs the gate twice: golden-vs-self must pass clean, and
//! golden-vs-slowed must trip (see ci.sh).

use anyhow::{ensure, Result};

use super::runlog;

/// Phase means are compared against `max(base, FLOOR_MS)` so a
/// microsecond-scale base phase cannot turn scheduler jitter into a
/// thousand-percent "regression".
const FLOOR_MS: f64 = 0.05;

/// Aggregates of one run log, as the comparator sees it.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub steps: u64,
    pub step_p50_ms: f64,
    pub step_p95_ms: f64,
    /// Mean per-step phase cost, ms.
    pub comm_ms: f64,
    pub conv_ms: f64,
    pub comp_ms: f64,
    pub repartitions: u64,
    pub departures: u64,
    pub anomalies: u64,
}

/// Aggregate a run log (lenient tail read — a crashed candidate still
/// compares). Requires at least one step: an empty candidate is a hard
/// error, not a 100% speedup.
pub fn stats_from_text(text: &str) -> Result<RunStats> {
    let tail = runlog::read_text_tail(text)?;
    let mut s = RunStats::default();
    let mut step_ms: Vec<f64> = Vec::new();
    let (mut comm, mut conv, mut comp) = (0.0f64, 0.0f64, 0.0f64);
    for v in &tail.lines {
        match v.get("type")?.as_str()? {
            "step" => {
                let (c, k, p) = (
                    v.get("comm_us")?.as_f64()?,
                    v.get("conv_us")?.as_f64()?,
                    v.get("comp_us")?.as_f64()?,
                );
                comm += c;
                conv += k;
                comp += p;
                step_ms.push((c + k + p) / 1e3);
            }
            "repartition" => s.repartitions += 1,
            "worker_left" => s.departures += 1,
            "anomaly" => s.anomalies += 1,
            _ => {}
        }
    }
    ensure!(!step_ms.is_empty(), "run log has no step lines to compare");
    s.steps = step_ms.len() as u64;
    let n = step_ms.len() as f64;
    s.comm_ms = comm / 1e3 / n;
    s.conv_ms = conv / 1e3 / n;
    s.comp_ms = comp / 1e3 / n;
    step_ms.sort_by(|a, b| a.total_cmp(b));
    let pct =
        |q: f64| step_ms[((step_ms.len() as f64 * q).ceil() as usize).clamp(1, step_ms.len()) - 1];
    s.step_p50_ms = pct(0.50);
    s.step_p95_ms = pct(0.95);
    Ok(s)
}

pub fn stats_from_file(path: &std::path::Path) -> Result<RunStats> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    stats_from_text(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

/// One compared metric. `gated` metrics can trip the regression exit code;
/// count deltas are informational.
#[derive(Clone, Debug)]
pub struct Delta {
    pub metric: &'static str,
    pub base: f64,
    pub cand: f64,
    /// Percent change over the (floored) base.
    pub pct: f64,
    pub gated: bool,
    pub regressed: bool,
}

/// The full diff of two runs at one threshold.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub threshold_pct: f64,
    pub deltas: Vec<Delta>,
}

/// Diff `cand` against `base`; a gated metric regresses when it exceeds
/// the baseline by more than `threshold_pct` percent.
pub fn compare(base: &RunStats, cand: &RunStats, threshold_pct: f64) -> CompareReport {
    let mut deltas = Vec::new();
    let mut timed = |metric: &'static str, b: f64, c: f64| {
        let floor = b.max(FLOOR_MS);
        let pct = 100.0 * (c - floor) / floor;
        let regressed = pct > threshold_pct;
        deltas.push(Delta { metric, base: b, cand: c, pct, gated: true, regressed });
    };
    timed("step_p50_ms", base.step_p50_ms, cand.step_p50_ms);
    timed("step_p95_ms", base.step_p95_ms, cand.step_p95_ms);
    timed("comm_ms", base.comm_ms, cand.comm_ms);
    timed("conv_ms", base.conv_ms, cand.conv_ms);
    timed("comp_ms", base.comp_ms, cand.comp_ms);
    for (metric, b, c) in [
        ("repartitions", base.repartitions, cand.repartitions),
        ("departures", base.departures, cand.departures),
        ("anomalies", base.anomalies, cand.anomalies),
    ] {
        let (b, c) = (b as f64, c as f64);
        let pct = if b > 0.0 { 100.0 * (c - b) / b } else { 0.0 };
        deltas.push(Delta { metric, base: b, cand: c, pct, gated: false, regressed: false });
    }
    CompareReport { threshold_pct, deltas }
}

impl CompareReport {
    /// True when any gated metric tripped — the CLI's non-zero exit.
    pub fn regressed(&self) -> bool {
        self.deltas.iter().any(|d| d.regressed)
    }

    pub fn render_human(&self, base_steps: u64, cand_steps: u64) -> String {
        let mut out = format!(
            "compare: base {base_steps} steps vs cand {cand_steps} steps (threshold {:.1}%)\n",
            self.threshold_pct
        );
        out.push_str("  metric        base       cand     delta\n");
        for d in &self.deltas {
            out.push_str(&format!(
                "  {:<12} {:>9.3} {:>9.3}  {:>+7.1}%{}{}\n",
                d.metric,
                d.base,
                d.cand,
                d.pct,
                if d.gated { "" } else { "  (info)" },
                if d.regressed { "  << REGRESSION" } else { "" },
            ));
        }
        out.push_str(if self.regressed() {
            "result: REGRESSED\n"
        } else {
            "result: ok\n"
        });
        out
    }

    /// One JSON object per metric plus a trailing verdict line — the same
    /// hand-rendered JSONL idiom as the run log (machine-readable for CI).
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for d in &self.deltas {
            out.push_str(&format!(
                "{{\"metric\":\"{}\",\"base\":{},\"cand\":{},\"pct\":{},\"gated\":{},\"regressed\":{}}}\n",
                d.metric,
                fmt_num(d.base),
                fmt_num(d.cand),
                fmt_num(d.pct),
                d.gated,
                d.regressed,
            ));
        }
        out.push_str(&format!(
            "{{\"verdict\":\"{}\",\"threshold_pct\":{}}}\n",
            if self.regressed() { "regressed" } else { "ok" },
            fmt_num(self.threshold_pct),
        ));
        out
    }
}

fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn log_with_phase_scale(scale: f64, steps: u64) -> String {
        let mut out = String::from(
            "{\"type\":\"run_start\",\"t_us\":0,\"arch\":\"tiny\",\"devices\":3,\"steps\":10}\n",
        );
        for i in 1..=steps {
            let (c, k, p) = (
                (3000.0 * scale) as u64,
                (6000.0 * scale) as u64,
                (1000.0 * scale) as u64,
            );
            out.push_str(&format!(
                "{{\"type\":\"step\",\"t_us\":{},\"step\":{i},\"loss\":2.0,\"devices\":3,\"comm_us\":{c},\"conv_us\":{k},\"comp_us\":{p},\"bytes\":64}}\n",
                i * 10_000
            ));
        }
        out.push_str("{\"type\":\"run_end\",\"t_us\":999999,\"steps\":10}\n");
        out
    }

    #[test]
    fn identical_runs_compare_clean() {
        let base = stats_from_text(&log_with_phase_scale(1.0, 10)).unwrap();
        let rep = compare(&base, &base, 10.0);
        assert!(!rep.regressed(), "{}", rep.render_human(base.steps, base.steps));
        assert!((base.step_p50_ms - 10.0).abs() < 1e-9);
        assert!((base.conv_ms - 6.0).abs() < 1e-9);
    }

    #[test]
    fn injected_slowdown_trips_the_gate() {
        let base = stats_from_text(&log_with_phase_scale(1.0, 10)).unwrap();
        // 50% slower everywhere: well past the acceptance bar of >= 20%.
        let cand = stats_from_text(&log_with_phase_scale(1.5, 10)).unwrap();
        let rep = compare(&base, &cand, 10.0);
        assert!(rep.regressed());
        let human = rep.render_human(base.steps, cand.steps);
        assert!(human.contains("REGRESSION"), "{human}");
        assert!(human.contains("step_p50_ms"), "{human}");
        // But the same pair passes at a 100% threshold.
        assert!(!compare(&base, &cand, 100.0).regressed());
        // And an improvement never trips.
        assert!(!compare(&cand, &base, 10.0).regressed());
    }

    #[test]
    fn tiny_base_phases_are_floored_not_exploded() {
        let mut base = stats_from_text(&log_with_phase_scale(1.0, 4)).unwrap();
        let mut cand = base.clone();
        // base comp 1µs, cand 20µs: 1900% raw, but both under the 50µs
        // floor — must not regress.
        base.comp_ms = 0.001;
        cand.comp_ms = 0.020;
        assert!(!compare(&base, &cand, 10.0).regressed());
    }

    #[test]
    fn jsonl_output_parses_and_counts_are_informational() {
        let base = stats_from_text(&log_with_phase_scale(1.0, 10)).unwrap();
        let mut cand = base.clone();
        cand.repartitions = 50; // storm, but informational
        let rep = compare(&base, &cand, 10.0);
        assert!(!rep.regressed());
        for line in rep.render_jsonl().lines() {
            Json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
        }
        assert!(rep.render_jsonl().contains("\"verdict\":\"ok\""));
    }

    #[test]
    fn empty_or_step_free_logs_refuse_to_compare() {
        assert!(stats_from_text("").is_err());
        let only_start =
            "{\"type\":\"run_start\",\"t_us\":0,\"arch\":\"tiny\",\"devices\":2,\"steps\":1}\n";
        assert!(stats_from_text(only_start).is_err());
    }
}
