//! Chrome trace-event export: renders recorded spans as a `trace.json`
//! loadable in Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! Layout: one process (`pid` 0, named `convdist`), one row per device —
//! `tid` 0 is the master, `tid` d is the worker on device d, and
//! [`PHASES_TID`] is a synthetic row carrying the per-step Comm/Conv/Comp
//! attribution (the paper's Figure-6 decomposition) tiled under each step.
//! Spans are "X" (complete) events with microsecond `ts`/`dur`; row names
//! ride on "M" (metadata) `thread_name` events.

use super::{runlog::json_escape, SpanRec, PHASES_TID};

fn meta_event(tid: u32, name: &str) -> String {
    format!(
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"args\":{{\"name\":\"{}\"}}}}",
        json_escape(name)
    )
}

/// Render spans into a complete Chrome trace-event JSON document.
/// `workers` is the worker count (device rows 1..=workers get names even if
/// a worker contributed no spans).
pub fn chrome_trace_json(spans: &[SpanRec], workers: usize) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    out.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"args\":{\"name\":\"convdist\"}}",
    );
    out.push(',');
    out.push_str(&meta_event(0, "master (device 0)"));
    for d in 1..=workers {
        out.push(',');
        out.push_str(&meta_event(d as u32, &format!("worker (device {d})")));
    }
    out.push(',');
    out.push_str(&meta_event(PHASES_TID, "phases (Fig. 6 attribution)"));
    for s in spans {
        out.push(',');
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"step\":{},\"layer\":{}}}}}",
            json_escape(&s.name),
            s.cat.label(),
            s.device,
            s.ts_us,
            s.dur_us,
            s.step,
            s.layer,
        ));
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanCat;
    use crate::util::json::Json;

    #[test]
    fn export_is_valid_trace_event_json_with_named_rows() {
        let spans = vec![
            SpanRec {
                name: "step 1".into(),
                cat: SpanCat::Step,
                device: 0,
                layer: 0,
                step: 1,
                ts_us: 0,
                dur_us: 1000,
            },
            SpanRec {
                name: "conv1_fwd dev2".into(),
                cat: SpanCat::Conv,
                device: 2,
                layer: 1,
                step: 1,
                ts_us: 100,
                dur_us: 400,
            },
        ];
        let text = chrome_trace_json(&spans, 2);
        let v = Json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 4 thread_name (master, 2 workers, phases) + 2 X.
        assert_eq!(events.len(), 7);
        let mut names = Vec::new();
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap().to_string();
            assert!(matches!(ph.as_str(), "X" | "M"), "bad ph {ph}");
            e.get("pid").unwrap().as_u64().unwrap();
            if ph == "X" {
                e.get("tid").unwrap().as_u64().unwrap();
                e.get("ts").unwrap().as_u64().unwrap();
                e.get("dur").unwrap().as_u64().unwrap();
                e.get("args").unwrap().get("step").unwrap().as_u64().unwrap();
            } else if e.get("name").unwrap().as_str().unwrap() == "thread_name" {
                names.push(e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string());
            }
        }
        assert!(names.iter().any(|n| n.contains("master")), "{names:?}");
        assert!(names.iter().any(|n| n.contains("device 2")), "{names:?}");
        assert!(names.iter().any(|n| n.contains("phases")), "{names:?}");
    }
}
