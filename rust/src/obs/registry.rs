//! A cheap in-process metrics registry: counters, gauges and fixed-bucket
//! histograms with interpolated p50/p95/p99.
//!
//! Deliberately minimal — `BTreeMap<String, _>` under the caller's lock, no
//! atomics, no label dimensions.  The hot path (`DistTrainer::try_step`)
//! touches it once per step, so a map lookup is already far below the <2%
//! overhead gate enforced by `examples/bench_obs.rs`.

use std::collections::BTreeMap;

use crate::metrics::{Breakdown, SchedStats};

/// Default millisecond bucket ladder: log-ish spacing from 50µs to 60s,
/// matched to step times seen on the paper's presets (tiny preset steps run
/// single-digit ms; throttled deep fleets run tens of seconds).
pub const MS_BUCKETS: &[f64] = &[
    0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3,
    1e4, 3e4, 6e4,
];

/// A fixed-bucket histogram: `bounds` are ascending upper edges, with one
/// implicit overflow bucket above the last bound.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Self {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one observation; non-finite samples are dropped (the same
    /// policy as `SchedStats::observe_gflops` and the telemetry).
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest observed value (0 when empty). Rendered next to the
    /// quantiles so a clamped bucket estimate can't hide the true floor.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observed value (0 when empty). An observation past the last
    /// bucket bound lands in the overflow bucket and caps the quantiles,
    /// but stays exact here.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Interpolated quantile (`q` in [0,1]): walk the buckets to the target
    /// rank, interpolate linearly inside the bucket, clamp to the observed
    /// [min, max].  Exact at the resolution of the bucket ladder.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                cum += c;
                continue;
            }
            let next = cum + c;
            if (next as f64) >= target {
                let lo = if i == 0 { self.min } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() { self.bounds[i] } else { self.max };
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                let v = lo + (hi - lo) * frac;
                return v.clamp(self.min, self.max);
            }
            cum = next;
        }
        self.max
    }
}

/// Counters (monotonic u64), gauges (last-write f64) and histograms.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Observe into a millisecond histogram on the default ladder.
    pub fn observe_ms(&mut self, name: &str, ms: f64) {
        self.hists
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(MS_BUCKETS))
            .observe(ms);
    }

    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    pub fn gauges(&self) -> &BTreeMap<String, f64> {
        &self.gauges
    }

    pub fn hists(&self) -> &BTreeMap<String, Histogram> {
        &self.hists
    }

    /// Absorb one step's phase attribution: per-phase + total histograms
    /// plus cumulative phase-time counters (µs).
    pub fn absorb_breakdown(&mut self, b: &Breakdown) {
        self.inc("steps", 1);
        self.inc("comm_us_total", b.comm.as_micros() as u64);
        self.inc("conv_us_total", b.conv.as_micros() as u64);
        self.inc("comp_us_total", b.comp.as_micros() as u64);
        self.observe_ms("step_ms", b.total().as_secs_f64() * 1e3);
        self.observe_ms("comm_ms", b.comm.as_secs_f64() * 1e3);
        self.observe_ms("conv_ms", b.conv.as_secs_f64() * 1e3);
        self.observe_ms("comp_ms", b.comp.as_secs_f64() * 1e3);
    }

    /// Absorb the scheduler's lifetime counters, last-step utilization and
    /// achieved per-op GFLOP/s.
    pub fn absorb_sched(&mut self, s: &SchedStats) {
        self.set_gauge("sched.repartitions", s.repartitions as f64);
        self.set_gauge("sched.departures", s.departures as f64);
        self.set_gauge("sched.straggler_flags", s.straggler_flags as f64);
        for (d, u) in &s.utilization {
            self.set_gauge(&format!("util.dev{d}"), *u);
        }
        for (op, r) in &s.op_gflops {
            self.set_gauge(&format!("gflops.{op}"), *r);
        }
    }

    /// Absorb one link's wire totals (Eq. 2 ground truth per worker).
    pub fn absorb_link(&mut self, device: usize, bytes: u64, frames: u64) {
        self.set_gauge(&format!("net.dev{device}.bytes"), bytes as f64);
        self.set_gauge(&format!("net.dev{device}.frames"), frames as f64);
    }

    /// Human-readable summary: counters, gauges, then histogram quantiles.
    pub fn render_table(&self) -> String {
        let mut out = String::from("metrics:\n");
        for (k, v) in &self.counters {
            out.push_str(&format!("  {k:<28} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("  {k:<28} {v:.3}\n"));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!(
                "  {k:<28} n={} mean={:.3} min={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}\n",
                h.count(),
                h.mean(),
                h.min(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
                h.max(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_and_gauges() {
        let mut r = MetricsRegistry::default();
        r.inc("steps", 1);
        r.inc("steps", 2);
        r.set_gauge("util.dev0", 0.5);
        r.set_gauge("util.dev0", 0.9);
        assert_eq!(r.counters()["steps"], 3);
        assert!((r.gauges()["util.dev0"] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_on_uniform_data() {
        let mut h = Histogram::new(MS_BUCKETS);
        for i in 1..=100 {
            h.observe(i as f64); // 1..=100 ms, uniform
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        let p50 = h.quantile(0.50);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        // Bucket interpolation: within one ladder step of the exact value.
        assert!((40.0..=60.0).contains(&p50), "p50={p50}");
        assert!((90.0..=100.0).contains(&p95), "p95={p95}");
        assert!(p99 >= p95 && p99 <= 100.0, "p99={p99}");
        assert_eq!(h.quantile(1.0), 100.0);
    }

    #[test]
    fn histogram_drops_non_finite_and_handles_empty() {
        let mut h = Histogram::new(MS_BUCKETS);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn histogram_min_max_track_single_observation() {
        let mut h = Histogram::new(MS_BUCKETS);
        h.observe(3.25);
        assert_eq!(h.min(), 3.25);
        assert_eq!(h.max(), 3.25);
        assert_eq!(h.quantile(0.5), 3.25, "single sample: quantiles clamp to it");
        assert_eq!(h.quantile(0.99), 3.25);
    }

    #[test]
    fn histogram_min_max_survive_out_of_range_data() {
        let mut h = Histogram::new(MS_BUCKETS);
        // Below the first bound and far past the last (overflow bucket).
        h.observe(0.001);
        h.observe(250_000.0);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 250_000.0);
        // Bucket quantiles clamp to the observed range, never past it.
        let p99 = h.quantile(0.99);
        assert!(p99 <= 250_000.0 && p99 >= 0.001, "p99={p99}");
        // And the render exposes the exact extremes the buckets can't.
        let mut r = MetricsRegistry::default();
        r.observe_ms("spike_ms", 0.001);
        r.observe_ms("spike_ms", 250_000.0);
        let table = r.render_table();
        assert!(table.contains("max=250000.000"), "{table}");
        assert!(table.contains("min=0.001"), "{table}");
    }

    #[test]
    fn absorbs_breakdown_and_sched() {
        let mut r = MetricsRegistry::default();
        let b = Breakdown {
            comm: Duration::from_millis(2),
            conv: Duration::from_millis(6),
            comp: Duration::from_millis(2),
        };
        r.absorb_breakdown(&b);
        r.absorb_breakdown(&b);
        assert_eq!(r.counters()["steps"], 2);
        assert_eq!(r.counters()["conv_us_total"], 12_000);
        assert_eq!(r.hists()["step_ms"].count(), 2);
        let mut s = SchedStats::default();
        s.repartitions = 3;
        s.utilization = vec![(0, 1.0), (1, 0.75)];
        s.observe_gflops("conv1_fwd", 0.5, 4e9);
        r.absorb_sched(&s);
        assert!((r.gauges()["sched.repartitions"] - 3.0).abs() < 1e-12);
        assert!((r.gauges()["util.dev1"] - 0.75).abs() < 1e-12);
        assert!((r.gauges()["gflops.conv1_fwd"] - 8.0).abs() < 1e-12);
        r.absorb_link(1, 4096, 7);
        assert!((r.gauges()["net.dev1.bytes"] - 4096.0).abs() < 1e-12);
        let table = r.render_table();
        assert!(table.contains("step_ms"), "{table}");
        assert!(table.contains("p95"), "{table}");
    }
}
