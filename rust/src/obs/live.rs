//! Live metrics exposition and the `convdist top` fleet view.
//!
//! [`render_prometheus`] turns the [`MetricsRegistry`] into Prometheus text
//! exposition format (version 0.0.4) and [`MetricsServer`] serves it over a
//! deliberately tiny `std::net` HTTP listener — one thread, non-blocking
//! accept with a stop flag, snapshot-per-request — so `--metrics-addr`
//! never stalls the step loop: the only shared state is the registry lock
//! the trainer already takes once per step.
//!
//! [`TopSnapshot`] is the shared model behind `convdist top`: built either
//! from a scrape of the live endpoint or from a (possibly still-growing)
//! `run.jsonl`, and rendered as a per-device table of share, throughput,
//! phase split and health.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::health::HealthState;
use super::runlog;
use super::MetricsRegistry;

// ---------------------------------------------------------------------------
// Prometheus text rendering
// ---------------------------------------------------------------------------

fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' }).collect()
}

/// Map a registry key to a Prometheus series: `devN` path segments become a
/// `device="N"` label, `rN` segments a `replica="rN"` label, `gflops.<op>`
/// keeps the op as a label, everything else flattens with `_`. All series
/// carry the `convdist_` prefix.
fn series(key: &str) -> (String, Option<(String, String)>) {
    let parts: Vec<&str> = key.split('.').collect();
    let mut name_parts: Vec<String> = Vec::new();
    let mut label = None;
    for p in &parts {
        if let Some(d) = p.strip_prefix("dev").and_then(|d| d.parse::<u64>().ok()) {
            if label.is_none() {
                label = Some(("device".to_string(), d.to_string()));
                continue;
            }
        }
        if label.is_none()
            && p.strip_prefix('r')
                .map_or(false, |d| !d.is_empty() && d.chars().all(|c| c.is_ascii_digit()))
        {
            label = Some(("replica".to_string(), p.to_string()));
            continue;
        }
        name_parts.push(sanitize(p));
    }
    if label.is_none() && parts.len() == 2 && parts[0] == "gflops" {
        return ("convdist_gflops".to_string(), Some(("op".to_string(), parts[1].to_string())));
    }
    (format!("convdist_{}", name_parts.join("_")), label)
}

/// Prometheus label-value escaping: backslash, quote and newline.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_labels(extra: &[(String, String)]) -> String {
    if extra.is_empty() {
        return String::new();
    }
    let inner: Vec<String> =
        extra.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", inner.join(","))
}

fn push_typed(
    out: &mut String,
    typed: &mut std::collections::BTreeSet<String>,
    name: &str,
    ty: &str,
) {
    if typed.insert(name.to_string()) {
        out.push_str(&format!("# TYPE {name} {ty}\n"));
    }
}

/// Render the whole registry as Prometheus text exposition format. Health
/// gauges (`health.devN`) carry the numeric [`HealthState::code`]; the
/// mapping is documented on a `# HELP` line.
pub fn render_prometheus(reg: &MetricsRegistry) -> String {
    let mut out = String::from("# HELP convdist_up 1 while the session is live\n# TYPE convdist_up gauge\nconvdist_up 1\n");
    let mut typed = std::collections::BTreeSet::new();
    for (k, v) in reg.counters() {
        let (name, label) = series(k);
        push_typed(&mut out, &mut typed, &name, "counter");
        let labels: Vec<_> = label.into_iter().collect();
        out.push_str(&format!("{name}{} {v}\n", fmt_labels(&labels)));
    }
    for (k, v) in reg.gauges() {
        let (name, label) = series(k);
        if name == "convdist_health" && typed.insert(name.clone()) {
            out.push_str(
                "# HELP convdist_health 0=healthy 1=degraded 2=straggling 3=lost\n# TYPE convdist_health gauge\n",
            );
        } else {
            push_typed(&mut out, &mut typed, &name, "gauge");
        }
        let labels: Vec<_> = label.into_iter().collect();
        out.push_str(&format!("{name}{} {v}\n", fmt_labels(&labels)));
    }
    for (k, h) in reg.hists() {
        let (name, label) = series(k);
        push_typed(&mut out, &mut typed, &name, "summary");
        let base: Vec<_> = label.into_iter().collect();
        for (q, v) in [(0.5, h.quantile(0.5)), (0.95, h.quantile(0.95)), (0.99, h.quantile(0.99))]
        {
            let mut labels = base.clone();
            labels.push(("quantile".to_string(), format!("{q}")));
            out.push_str(&format!("{name}{} {v}\n", fmt_labels(&labels)));
        }
        let l = fmt_labels(&base);
        out.push_str(&format!("{name}_sum{l} {}\n", h.sum()));
        out.push_str(&format!("{name}_count{l} {}\n", h.count()));
        for (suffix, v) in [("min", h.min()), ("max", h.max())] {
            let n = format!("{name}_{suffix}");
            push_typed(&mut out, &mut typed, &n, "gauge");
            out.push_str(&format!("{n}{l} {v}\n"));
        }
    }
    out
}

/// Parse Prometheus text back into `(name, labels) -> value` — enough for
/// `convdist top` to scrape a live endpoint (and for tests to round-trip
/// the renderer). Labels are normalized to sorted `k="v"` joined by `,`.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<(String, String), f64>> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = || anyhow::anyhow!("metrics line {}: unparseable {line:?}", i + 1);
        let (series, value) = line.rsplit_once(' ').ok_or_else(err)?;
        let value: f64 = value.parse().map_err(|_| err())?;
        let (name, labels) = match series.split_once('{') {
            None => (series.to_string(), String::new()),
            Some((n, rest)) => {
                let body = rest.strip_suffix('}').ok_or_else(err)?;
                let mut kvs: Vec<&str> = body.split(',').filter(|s| !s.is_empty()).collect();
                kvs.sort_unstable();
                (n.to_string(), kvs.join(","))
            }
        };
        out.insert((name, labels), value);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// The HTTP listener
// ---------------------------------------------------------------------------

/// Snapshot provider: called once per scrape, under no lock of its own.
pub type MetricsProvider = Arc<dyn Fn() -> String + Send + Sync>;

/// A one-thread HTTP listener serving the provider's snapshot on every
/// request (any path — scrapers use `/metrics`). Stops on [`stop`] or drop.
///
/// [`stop`]: MetricsServer::stop
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks an ephemeral port —
    /// read it back from [`addr`](MetricsServer::addr)) and start serving.
    pub fn start(addr: &str, provider: MetricsProvider) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics endpoint {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let thread = std::thread::Builder::new()
            .name("convdist-metrics".into())
            .spawn(move || serve_loop(listener, provider, flag))?;
        Ok(Self { addr, stop, thread: Some(thread) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the serve loop and join it. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(listener: TcpListener, provider: MetricsProvider, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Per-connection errors (slow client, reset) only lose that
                // scrape; the listener keeps serving.
                let _ = serve_one(stream, &provider);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn serve_one(mut stream: TcpStream, provider: &MetricsProvider) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // Read until the end of the request head (we serve the same body for
    // every path) with a small cap against garbage peers.
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 256];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < 8192 {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let body = provider();
    let resp = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

/// Minimal HTTP GET against a metrics endpoint; returns the response body.
pub fn http_get(addr: &str) -> Result<String> {
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("no address for {addr}"))?;
    let mut stream = TcpStream::connect_timeout(&sock, Duration::from_secs(2))
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.write_all(
        format!("GET /metrics HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut text = String::new();
    stream.read_to_string(&mut text)?;
    match text.split_once("\r\n\r\n") {
        Some((head, body)) => {
            let status = head.lines().next().unwrap_or("");
            if !status.contains("200") {
                bail!("{addr} answered {status:?}");
            }
            Ok(body.to_string())
        }
        None => bail!("{addr} returned no HTTP response"),
    }
}

// ---------------------------------------------------------------------------
// convdist top
// ---------------------------------------------------------------------------

/// One device's row in the `top` table. `share`/`gflops` are `None` when
/// the source doesn't carry them (a run log before its metrics snapshot).
#[derive(Clone, Debug)]
pub struct DeviceRow {
    pub device: usize,
    pub health: HealthState,
    pub share: Option<f64>,
    pub gflops: Option<f64>,
}

/// The fleet view behind `convdist top`: built from a live scrape or a
/// tailed run log, rendered as one table.
#[derive(Clone, Debug, Default)]
pub struct TopSnapshot {
    pub steps: u64,
    pub step_ms_p50: f64,
    pub step_ms_p95: f64,
    /// (comm, conv, comp) cumulative microseconds.
    pub phase_us: (f64, f64, f64),
    pub repartitions: u64,
    pub departures: u64,
    pub anomalies: u64,
    pub devices: Vec<DeviceRow>,
    /// True when a trailing partial line was skipped (live tail).
    pub truncated: bool,
}

impl TopSnapshot {
    /// Build from a Prometheus scrape of a live endpoint.
    pub fn from_prometheus(text: &str) -> Result<Self> {
        let map = parse_prometheus(text)?;
        let get = |name: &str, labels: &str| map.get(&(name.to_string(), labels.to_string()));
        let scalar = |name: &str| get(name, "").copied().unwrap_or(0.0);
        let mut devices: BTreeMap<usize, DeviceRow> = BTreeMap::new();
        for ((name, labels), v) in &map {
            let Some(d) = labels
                .strip_prefix("device=\"")
                .and_then(|r| r.strip_suffix('"'))
                .and_then(|r| r.parse::<usize>().ok())
            else {
                continue;
            };
            let row = devices.entry(d).or_insert(DeviceRow {
                device: d,
                health: HealthState::Healthy,
                share: None,
                gflops: None,
            });
            match name.as_str() {
                "convdist_health" => {
                    row.health = HealthState::from_code(*v as u8).unwrap_or(HealthState::Healthy)
                }
                "convdist_share" => row.share = Some(*v),
                "convdist_throughput" => row.gflops = Some(*v),
                _ => {}
            }
        }
        Ok(Self {
            steps: scalar("convdist_steps") as u64,
            step_ms_p50: get("convdist_step_ms", "quantile=\"0.5\"").copied().unwrap_or(0.0),
            step_ms_p95: get("convdist_step_ms", "quantile=\"0.95\"").copied().unwrap_or(0.0),
            phase_us: (
                scalar("convdist_comm_us_total"),
                scalar("convdist_conv_us_total"),
                scalar("convdist_comp_us_total"),
            ),
            repartitions: scalar("convdist_sched_repartitions") as u64,
            departures: scalar("convdist_sched_departures") as u64,
            anomalies: scalar("convdist_anomalies") as u64,
            devices: devices.into_values().collect(),
            truncated: false,
        })
    }

    /// Build from a run log, tolerating a trailing partial line (live tail).
    pub fn from_runlog(text: &str) -> Result<Self> {
        let tail = runlog::read_text_tail(text)?;
        let mut snap = Self { truncated: tail.truncated, ..Self::default() };
        let mut n_devices = 0usize;
        let mut health: BTreeMap<usize, HealthState> = BTreeMap::new();
        let mut step_ms: Vec<f64> = Vec::new();
        for v in &tail.lines {
            match v.get("type")?.as_str()? {
                "run_start" => n_devices = v.get("devices")?.as_usize()?,
                "step" => {
                    snap.steps += 1;
                    let (c, k, p) = (
                        v.get("comm_us")?.as_f64()?,
                        v.get("conv_us")?.as_f64()?,
                        v.get("comp_us")?.as_f64()?,
                    );
                    snap.phase_us.0 += c;
                    snap.phase_us.1 += k;
                    snap.phase_us.2 += p;
                    step_ms.push((c + k + p) / 1e3);
                }
                "repartition" => snap.repartitions += 1,
                "worker_left" => snap.departures += 1,
                "anomaly" => snap.anomalies += 1,
                "health" => {
                    let d = v.get("device")?.as_usize()?;
                    let to = HealthState::from_label(v.get("to")?.as_str()?)
                        .unwrap_or(HealthState::Healthy);
                    health.insert(d, to);
                }
                _ => {}
            }
        }
        step_ms.sort_by(|a, b| a.total_cmp(b));
        let pct = |q: f64| {
            if step_ms.is_empty() {
                0.0
            } else {
                step_ms[((step_ms.len() as f64 * q).ceil() as usize).clamp(1, step_ms.len()) - 1]
            }
        };
        snap.step_ms_p50 = pct(0.50);
        snap.step_ms_p95 = pct(0.95);
        snap.devices = (0..n_devices)
            .map(|d| DeviceRow {
                device: d,
                health: health.get(&d).copied().unwrap_or(HealthState::Healthy),
                share: None,
                gflops: None,
            })
            .collect();
        Ok(snap)
    }

    /// Render the table `convdist top` prints.
    pub fn render(&self) -> String {
        let total = (self.phase_us.0 + self.phase_us.1 + self.phase_us.2).max(1.0);
        let mut out = format!(
            "fleet: {} steps  step p50 {:.3} ms  p95 {:.3} ms  comm {:.1}% conv {:.1}% comp {:.1}%\n",
            self.steps,
            self.step_ms_p50,
            self.step_ms_p95,
            100.0 * self.phase_us.0 / total,
            100.0 * self.phase_us.1 / total,
            100.0 * self.phase_us.2 / total,
        );
        out.push_str(&format!(
            "       repartitions {}  departures {}  anomalies {}{}\n",
            self.repartitions,
            self.departures,
            self.anomalies,
            if self.truncated { "  (tail: partial line skipped)" } else { "" },
        ));
        out.push_str("  dev  role    health      share   GFLOP/s\n");
        for r in &self.devices {
            let role = if r.device == 0 { "master" } else { "worker" };
            let share = r.share.map_or("     -".to_string(), |s| format!("{:5.1}%", 100.0 * s));
            let gf = r.gflops.map_or("      -".to_string(), |g| format!("{g:7.2}"));
            out.push_str(&format!(
                "  {:>3}  {role}  {:<10}  {share}  {gf}\n",
                r.device,
                r.health.label()
            ));
        }
        if self.devices.is_empty() {
            out.push_str("  (no devices yet — log has no run_start line)\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> MetricsRegistry {
        let mut reg = MetricsRegistry::default();
        reg.inc("steps", 12);
        reg.inc("comm_us_total", 30_000);
        reg.inc("conv_us_total", 60_000);
        reg.inc("comp_us_total", 10_000);
        reg.inc("anomalies", 1);
        reg.set_gauge("sched.repartitions", 2.0);
        reg.set_gauge("sched.departures", 1.0);
        reg.set_gauge("util.dev1", 0.75);
        reg.set_gauge("health.dev0", 0.0);
        reg.set_gauge("health.dev1", 1.0);
        reg.set_gauge("share.dev0", 0.6);
        reg.set_gauge("share.dev1", 0.4);
        reg.set_gauge("throughput.dev1", 3.5);
        reg.set_gauge("share.r0", 0.5);
        reg.set_gauge("throughput.r1", 120.0);
        reg.inc("allreduce.bytes", 2048);
        reg.set_gauge("gflops.conv1_fwd", 8.0);
        reg.set_gauge("net.dev1.bytes", 4096.0);
        for ms in [8.0, 9.0, 10.0, 11.0] {
            reg.observe_ms("step_ms", ms);
        }
        reg
    }

    #[test]
    fn prometheus_rendering_round_trips_and_labels_devices() {
        let text = render_prometheus(&sample_registry());
        assert!(text.contains("# TYPE convdist_steps counter"), "{text}");
        assert!(text.contains("convdist_health{device=\"1\"} 1"), "{text}");
        assert!(text.contains("convdist_util{device=\"1\"} 0.75"), "{text}");
        assert!(text.contains("convdist_net_bytes{device=\"1\"} 4096"), "{text}");
        assert!(text.contains("convdist_gflops{op=\"conv1_fwd\"} 8"), "{text}");
        assert!(text.contains("convdist_share{replica=\"r0\"} 0.5"), "{text}");
        assert!(text.contains("convdist_throughput{replica=\"r1\"} 120"), "{text}");
        assert!(text.contains("convdist_allreduce_bytes 2048"), "{text}");
        assert!(text.contains("convdist_step_ms_count 4"), "{text}");
        assert!(text.contains("quantile=\"0.95\""), "{text}");
        let map = parse_prometheus(&text).unwrap();
        assert_eq!(map[&("convdist_steps".into(), "".into())], 12.0);
        assert_eq!(map[&("convdist_share".into(), "device=\"0\"".into())], 0.6);
        // Every non-comment line parsed.
        let n_lines = text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).count();
        assert_eq!(map.len(), n_lines);
    }

    #[test]
    fn server_serves_snapshots_until_stopped() {
        let reg = std::sync::Mutex::new(sample_registry());
        let provider: MetricsProvider =
            Arc::new(move || render_prometheus(&reg.lock().unwrap()));
        let mut srv = MetricsServer::start("127.0.0.1:0", provider).unwrap();
        let addr = srv.addr().to_string();
        for _ in 0..2 {
            let body = http_get(&addr).unwrap();
            assert!(body.starts_with("# HELP convdist_up"), "{body}");
            assert!(body.contains("convdist_health{device=\"1\"} 1"), "{body}");
        }
        srv.stop();
        assert!(http_get(&addr).is_err(), "server must stop accepting");
    }

    #[test]
    fn top_snapshot_from_scrape_and_runlog_agree_on_health() {
        let text = render_prometheus(&sample_registry());
        let snap = TopSnapshot::from_prometheus(&text).unwrap();
        assert_eq!(snap.steps, 12);
        assert_eq!(snap.devices.len(), 2);
        assert_eq!(snap.devices[1].health, HealthState::Degraded);
        assert_eq!(snap.anomalies, 1);
        let table = snap.render();
        assert!(table.contains("degraded"), "{table}");
        assert!(table.contains("conv 60.0%"), "{table}");

        // Same fleet story as a (truncated) run log.
        let log = concat!(
            "{\"type\":\"run_start\",\"t_us\":0,\"arch\":\"tiny\",\"devices\":2,\"steps\":12}\n",
            "{\"type\":\"step\",\"t_us\":9,\"step\":1,\"loss\":2.0,\"devices\":2,\"comm_us\":3000,\"conv_us\":5000,\"comp_us\":1000,\"bytes\":64}\n",
            "{\"type\":\"health\",\"t_us\":10,\"step\":1,\"device\":1,\"from\":\"healthy\",\"to\":\"degraded\",\"ratio\":2.1}\n",
            "{\"type\":\"anomaly\",\"t_us\":11,\"step\":1,\"step_ms\":9,\"median_ms\":4,\"mad_ms\":0.5}\n",
            "{\"type\":\"step\",\"t_us\":19,\"step\":2,\"loss\":1.9,\"devi"
        );
        let snap = TopSnapshot::from_runlog(log).unwrap();
        assert!(snap.truncated);
        assert_eq!(snap.steps, 1);
        assert_eq!(snap.devices[1].health, HealthState::Degraded);
        assert_eq!(snap.devices[0].health, HealthState::Healthy);
        assert_eq!(snap.anomalies, 1);
        let table = snap.render();
        assert!(table.contains("degraded"), "{table}");
        assert!(table.contains("partial line skipped"), "{table}");
    }
}
