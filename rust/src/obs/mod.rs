//! Fleet-wide observability: spans, a metrics registry, and durable sinks.
//!
//! The paper's entire argument is an observability claim — Figures 6 and 8
//! decompose every step into Comm/Conv/Comp to show where heterogeneous
//! fleets lose their speedup.  This module turns the transient stdout
//! breakdown into a durable, queryable record of a run:
//!
//! * **Spans** ([`SpanRec`]) — `step → phase(comm|conv|comp) → op` intervals
//!   with device/worker/layer attribution.  The master records its own
//!   scatter/gather/compute intervals; workers measure their conv ops
//!   locally and ship them back piggybacked on the gather
//!   (`proto::Message::SpanReport`), so worker-side spans land in the
//!   master's timeline re-anchored at the gather receive time.
//! * **Metrics** ([`MetricsRegistry`]) — counters, gauges and fixed-bucket
//!   histograms (p50/p95/p99) absorbing [`Breakdown`], `SchedStats`,
//!   per-link byte/frame counts and achieved GFLOP/s.
//! * **Sinks** — a JSONL run log (`run.jsonl`, one event per line, schema in
//!   [`runlog`] and DESIGN.md §11, parseable by the in-tree `util::json`)
//!   and a Chrome trace-event export (`trace.json`, loadable in Perfetto or
//!   `chrome://tracing`, master and every worker as rows).
//!
//! Wiring: `SessionBuilder::observe(ObsConfig)` attaches an [`ObsHandle`]
//! to the trainer; `convdist run --trace out/ --metrics` drives it from the
//! CLI and `convdist report out/run.jsonl` summarizes a finished run.
//! Tracing must stay cheap — `examples/bench_obs.rs` gates the overhead at
//! <2% of step time on the tiny preset.

pub mod compare;
pub mod health;
pub mod live;
mod registry;
pub mod report;
pub mod runlog;
mod trace;

pub use health::{
    AnomalyDetector, FleetHealth, HealthConfig, HealthState, HealthTransition, StepAnomaly,
};
pub use live::MetricsServer;
pub use registry::{Histogram, MetricsRegistry, MS_BUCKETS};
pub use trace::chrome_trace_json;

use std::fs;
use std::io::{BufWriter, Write};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::metrics::Breakdown;
use crate::session::Event;

/// The virtual trace row ("thread id") that carries the per-step
/// Comm/Conv/Comp phase attribution — the paper's Figure-6 decomposition —
/// tiled under each step span.  Real devices use their device id as the row.
pub const PHASES_TID: u32 = 1000;

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// What to observe and where the sinks live.
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    /// When set, write `run.jsonl` and `trace.json` under this directory
    /// (created if missing) and record spans.
    pub dir: Option<PathBuf>,
    /// Collect the metrics registry and render a summary table at the end.
    pub metrics: bool,
    /// When set, serve the registry + health states as Prometheus text on
    /// this address for the lifetime of the session (the CLI's
    /// `--metrics-addr 127.0.0.1:9184`; implies `metrics`).
    pub metrics_addr: Option<String>,
}

impl ObsConfig {
    /// Full tracing + metrics into `dir` (the CLI's `--trace out/`).
    pub fn trace_to(dir: impl Into<PathBuf>) -> Self {
        Self { dir: Some(dir.into()), metrics: true, metrics_addr: None }
    }

    /// Registry only — no files on disk (the CLI's bare `--metrics`).
    pub fn metrics_only() -> Self {
        Self { dir: None, metrics: true, metrics_addr: None }
    }

    /// Serve live metrics on `addr` (no files unless `dir` is also set).
    pub fn serve(mut self, addr: impl Into<String>) -> Self {
        self.metrics_addr = Some(addr.into());
        self.metrics = true;
        self
    }

    /// Whether spans are recorded and sinks written.
    pub fn tracing(&self) -> bool {
        self.dir.is_some()
    }

    /// Whether any observability is requested at all.
    pub fn enabled(&self) -> bool {
        self.tracing() || self.metrics || self.metrics_addr.is_some()
    }
}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// Span category — doubles as the Chrome trace-event `cat` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanCat {
    /// A whole training step (master row).
    Step,
    /// Transfer / wait time (scatter, gather, worker serve overhead).
    Comm,
    /// Convolution compute (master shard, worker shards).
    Conv,
    /// Non-conv compute (LRN/pool/FC/loss/optimizer).
    Comp,
    /// Cross-replica gradient all-reduce (DESIGN.md §14).
    Allreduce,
}

impl SpanCat {
    pub fn label(&self) -> &'static str {
        match self {
            SpanCat::Step => "step",
            SpanCat::Comm => "comm",
            SpanCat::Conv => "conv",
            SpanCat::Comp => "comp",
            SpanCat::Allreduce => "allreduce",
        }
    }
}

/// One closed interval on a device's timeline, in microseconds since the
/// observability epoch (`Observability::new`).  Durations measured under
/// virtual throttles are virtual time and may exceed the enclosing wall
/// interval — that is expected and documented (DESIGN.md §11).
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub name: String,
    pub cat: SpanCat,
    /// Device id: 0 = master, `d` = worker on device `d`, [`PHASES_TID`] =
    /// the synthetic phase-attribution row.
    pub device: u32,
    pub layer: u32,
    pub step: u64,
    pub ts_us: u64,
    pub dur_us: u64,
}

// ---------------------------------------------------------------------------
// Handle (shared, cheap, cloneable)
// ---------------------------------------------------------------------------

struct Inner {
    spans: Vec<SpanRec>,
    registry: MetricsRegistry,
    log: Option<BufWriter<fs::File>>,
}

struct Shared {
    t0: Instant,
    tracing: bool,
    inner: Mutex<Inner>,
}

/// Cheap cloneable handle threaded through the trainer and session.  All
/// methods are no-ops along whichever axes the [`ObsConfig`] disabled, so
/// call sites never branch.
#[derive(Clone)]
pub struct ObsHandle {
    shared: Arc<Shared>,
}

impl ObsHandle {
    /// Microseconds since the observability epoch.
    pub fn now_us(&self) -> u64 {
        self.shared.t0.elapsed().as_micros() as u64
    }

    pub fn tracing(&self) -> bool {
        self.shared.tracing
    }

    /// Record a closed span (and mirror it into the run log).
    pub fn span(&self, rec: SpanRec) {
        if !self.shared.tracing {
            return;
        }
        let mut inner = self.shared.inner.lock().expect("obs lock");
        if let Some(log) = inner.log.as_mut() {
            let _ = writeln!(log, "{}", runlog::span_line(&rec));
        }
        inner.spans.push(rec);
    }

    /// Tile the step's Comm/Conv/Comp phase attribution (the exact values
    /// the printed `Breakdown` carries) onto the [`PHASES_TID`] row,
    /// anchored at the step's start so the rows line up in Perfetto.
    pub fn phase_spans(&self, step: u64, start_us: u64, b: &Breakdown) {
        let mut cursor = start_us;
        for (cat, d) in [
            (SpanCat::Comm, b.comm),
            (SpanCat::Conv, b.conv),
            (SpanCat::Comp, b.comp),
        ] {
            let dur = d.as_micros() as u64;
            self.span(SpanRec {
                name: format!("phase {}", cat.label()),
                cat,
                device: PHASES_TID,
                layer: 0,
                step,
                ts_us: cursor,
                dur_us: dur,
            });
            cursor += dur;
        }
    }

    /// Mirror a session [`Event`] into the run log.
    pub fn event(&self, ev: &Event) {
        let ts = self.now_us();
        let mut inner = self.shared.inner.lock().expect("obs lock");
        if let Some(log) = inner.log.as_mut() {
            let _ = writeln!(log, "{}", runlog::event_line(ts, ev));
        }
    }

    /// Access the metrics registry under the lock; the closure's return
    /// value passes through (snapshot renderers use this to read without a
    /// second locking API).
    pub fn metrics<T>(&self, f: impl FnOnce(&mut MetricsRegistry) -> T) -> T {
        let mut inner = self.shared.inner.lock().expect("obs lock");
        f(&mut inner.registry)
    }
}

// ---------------------------------------------------------------------------
// Observability (owner: sinks + lifecycle)
// ---------------------------------------------------------------------------

/// Owns the sinks for one run: opened by `SessionBuilder::build`, finished
/// (idempotently) by `Session::shutdown` or `Session::finish_obs`.
pub struct Observability {
    handle: ObsHandle,
    dir: Option<PathBuf>,
    metrics: bool,
    workers: usize,
    finished: bool,
}

impl Observability {
    /// Open the sinks and write the `run_start` line.  `devices` counts the
    /// master; `steps` is the planned step count.
    pub fn new(cfg: &ObsConfig, arch: &str, devices: usize, steps: usize) -> Result<Self> {
        let log = match &cfg.dir {
            Some(dir) => {
                fs::create_dir_all(dir)
                    .with_context(|| format!("creating trace dir {}", dir.display()))?;
                let path = dir.join("run.jsonl");
                let file = fs::File::create(&path)
                    .with_context(|| format!("creating {}", path.display()))?;
                let mut w = BufWriter::new(file);
                writeln!(w, "{}", runlog::run_start_line(0, arch, devices, steps))?;
                w.flush()?;
                Some(w)
            }
            None => None,
        };
        let handle = ObsHandle {
            shared: Arc::new(Shared {
                t0: Instant::now(),
                tracing: cfg.tracing(),
                inner: Mutex::new(Inner {
                    spans: Vec::new(),
                    registry: MetricsRegistry::default(),
                    log,
                }),
            }),
        };
        Ok(Self {
            handle,
            dir: cfg.dir.clone(),
            metrics: cfg.metrics,
            workers: devices.saturating_sub(1),
            finished: false,
        })
    }

    pub fn handle(&self) -> ObsHandle {
        self.handle.clone()
    }

    /// Flush the sinks: write the `metrics` + `run_end` lines, export
    /// `trace.json`, and (when metrics are on) return the rendered summary
    /// table.  Idempotent — the second call is a no-op returning `None`.
    pub fn finish(&mut self, steps_done: u64) -> Result<Option<String>> {
        if self.finished {
            return Ok(None);
        }
        self.finished = true;
        let ts = self.handle.now_us();
        let mut inner = self.handle.shared.inner.lock().expect("obs lock");
        if let Some(log) = inner.log.as_mut() {
            let metrics_line = runlog::metrics_line(ts, &inner.registry);
            writeln!(log, "{metrics_line}")?;
            writeln!(log, "{}", runlog::run_end_line(ts, steps_done))?;
            log.flush()?;
        }
        inner.log = None;
        if let Some(dir) = &self.dir {
            let json = chrome_trace_json(&inner.spans, self.workers);
            let path = dir.join("trace.json");
            fs::write(&path, json)
                .with_context(|| format!("writing {}", path.display()))?;
        }
        Ok(if self.metrics { Some(inner.registry.render_table()) } else { None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("convdist_obs_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn sinks_written_and_finish_is_idempotent() {
        let dir = tmpdir("sinks");
        let mut obs =
            Observability::new(&ObsConfig::trace_to(&dir), "tiny", 3, 2).unwrap();
        let h = obs.handle();
        assert!(h.tracing());
        h.span(SpanRec {
            name: "conv1_fwd dev1".into(),
            cat: SpanCat::Conv,
            device: 1,
            layer: 1,
            step: 1,
            ts_us: 10,
            dur_us: 90,
        });
        h.phase_spans(
            1,
            0,
            &Breakdown {
                comm: Duration::from_micros(40),
                conv: Duration::from_micros(90),
                comp: Duration::from_micros(20),
            },
        );
        h.metrics(|m| m.inc("steps", 1));
        let table = obs.finish(2).unwrap();
        assert!(table.is_some());
        assert!(obs.finish(2).unwrap().is_none(), "finish must be idempotent");
        let log = fs::read_to_string(dir.join("run.jsonl")).unwrap();
        for line in log.lines() {
            let v = crate::util::json::Json::parse(line).unwrap();
            runlog::validate_line(&v).unwrap();
        }
        assert!(log.contains("\"type\":\"run_start\""));
        assert!(log.contains("\"type\":\"run_end\""));
        let trace = fs::read_to_string(dir.join("trace.json")).unwrap();
        let v = crate::util::json::Json::parse(&trace).unwrap();
        assert!(!v.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_only_config_writes_no_files() {
        let mut obs = Observability::new(&ObsConfig::metrics_only(), "tiny", 2, 1).unwrap();
        let h = obs.handle();
        assert!(!h.tracing());
        // Spans are dropped without tracing; metrics still accumulate.
        h.span(SpanRec {
            name: "x".into(),
            cat: SpanCat::Step,
            device: 0,
            layer: 0,
            step: 1,
            ts_us: 0,
            dur_us: 1,
        });
        h.metrics(|m| m.inc("steps", 1));
        let table = obs.finish(1).unwrap().unwrap();
        assert!(table.contains("steps"), "{table}");
    }
}
