//! The JSONL run log: one event per line, hand-rendered (offline build, no
//! serde) and parsed back with the in-tree [`crate::util::json::Json`] —
//! the same arrangement `analysis::diag::render_jsonl` uses.
//!
//! Schema (every line is an object with a `type` tag; all timestamps are
//! microseconds since the observability epoch; extra keys are allowed so
//! the schema can grow without breaking old readers):
//!
//! | `type`        | required fields                                                        |
//! |---------------|------------------------------------------------------------------------|
//! | `run_start`   | `t_us`, `arch` (str), `devices`, `steps`                               |
//! | `step`        | `t_us`, `step`, `loss`, `devices`, `comm_us`, `conv_us`, `comp_us`, `bytes` |
//! | `repartition` | `t_us`, `step`                                                         |
//! | `rebalance`   | `t_us`, `step`, `shares` (arr of numbers)                              |
//! | `worker_left` | `t_us`, `step`, `devices_left`                                         |
//! | `eval`        | `t_us`, `step`, `accuracy`                                             |
//! | `checkpoint`  | `t_us`, `step`, `path` (str)                                           |
//! | `span`        | `t_us`, `name` (str), `cat` (`step\|comm\|conv\|comp\|allreduce`), `device`, `layer`, `step`, `dur_us` |
//! | `metrics`     | `t_us`, `counters` (obj), `gauges` (obj), `hists` (obj)                |
//! | `health`      | `t_us`, `step`, `device`, `from` (state), `to` (state), `ratio`        |
//! | `anomaly`     | `t_us`, `step`, `step_ms`, `median_ms`, `mad_ms`                       |
//! | `run_end`     | `t_us`, `steps`                                                        |
//!
//! (`state` is one of `healthy|degraded|straggling|lost`; see `obs::health`.)
//!
//! [`validate_line`] is the single schema authority: the obs tests, the
//! `convdist report` subcommand and the CI gate all call it.

use anyhow::{bail, ensure, Result};

use super::{MetricsRegistry, SpanRec};
use crate::session::Event;
use crate::util::json::Json;

/// Escape a string for embedding in a JSON literal (same contract as
/// `analysis::diag`'s private helper).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a float as a JSON number (non-finite values have no JSON
/// rendering; they collapse to 0 rather than corrupt the line).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

pub fn run_start_line(t_us: u64, arch: &str, devices: usize, steps: usize) -> String {
    format!(
        "{{\"type\":\"run_start\",\"t_us\":{t_us},\"arch\":\"{}\",\"devices\":{devices},\"steps\":{steps}}}",
        json_escape(arch)
    )
}

pub fn run_end_line(t_us: u64, steps: u64) -> String {
    format!("{{\"type\":\"run_end\",\"t_us\":{t_us},\"steps\":{steps}}}")
}

pub fn span_line(s: &SpanRec) -> String {
    format!(
        "{{\"type\":\"span\",\"t_us\":{},\"name\":\"{}\",\"cat\":\"{}\",\"device\":{},\"layer\":{},\"step\":{},\"dur_us\":{}}}",
        s.ts_us,
        json_escape(&s.name),
        s.cat.label(),
        s.device,
        s.layer,
        s.step,
        s.dur_us,
    )
}

/// Mirror a session [`Event`] into its run-log line.
pub fn event_line(t_us: u64, ev: &Event) -> String {
    match ev {
        Event::StepCompleted { step, loss, devices, breakdown, bytes_moved } => format!(
            "{{\"type\":\"step\",\"t_us\":{t_us},\"step\":{step},\"loss\":{},\"devices\":{devices},\"comm_us\":{},\"conv_us\":{},\"comp_us\":{},\"bytes\":{bytes_moved}}}",
            num(*loss as f64),
            breakdown.comm.as_micros(),
            breakdown.conv.as_micros(),
            breakdown.comp.as_micros(),
        ),
        Event::Repartitioned { step } => {
            format!("{{\"type\":\"repartition\",\"t_us\":{t_us},\"step\":{step}}}")
        }
        Event::Rebalanced { step, shares } => {
            let shares =
                shares.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",");
            format!("{{\"type\":\"rebalance\",\"t_us\":{t_us},\"step\":{step},\"shares\":[{shares}]}}")
        }
        Event::WorkerLeft { step, devices_left } => format!(
            "{{\"type\":\"worker_left\",\"t_us\":{t_us},\"step\":{step},\"devices_left\":{devices_left}}}"
        ),
        Event::EvalDone { step, accuracy } => format!(
            "{{\"type\":\"eval\",\"t_us\":{t_us},\"step\":{step},\"accuracy\":{}}}",
            num(*accuracy as f64)
        ),
        Event::CheckpointSaved { step, path } => format!(
            "{{\"type\":\"checkpoint\",\"t_us\":{t_us},\"step\":{step},\"path\":\"{}\"}}",
            json_escape(&path.display().to_string())
        ),
        Event::HealthChanged { step, device, from, to, ratio } => format!(
            "{{\"type\":\"health\",\"t_us\":{t_us},\"step\":{step},\"device\":{device},\"from\":\"{}\",\"to\":\"{}\",\"ratio\":{}}}",
            from.label(),
            to.label(),
            num(*ratio),
        ),
        Event::AnomalyFlagged { step, step_ms, median_ms, mad_ms } => format!(
            "{{\"type\":\"anomaly\",\"t_us\":{t_us},\"step\":{step},\"step_ms\":{},\"median_ms\":{},\"mad_ms\":{}}}",
            num(*step_ms),
            num(*median_ms),
            num(*mad_ms),
        ),
    }
}

/// The end-of-run metrics snapshot as one line.
pub fn metrics_line(t_us: u64, reg: &MetricsRegistry) -> String {
    let mut out = format!("{{\"type\":\"metrics\",\"t_us\":{t_us},\"counters\":{{");
    for (i, (k, v)) in reg.counters().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{v}", json_escape(k)));
    }
    out.push_str("},\"gauges\":{");
    for (i, (k, v)) in reg.gauges().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{}", json_escape(k), num(*v)));
    }
    out.push_str("},\"hists\":{");
    for (i, (k, h)) in reg.hists().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\"{}\":{{\"count\":{},\"mean\":{},\"min\":{},\"p50\":{},\"p95\":{},\"p99\":{},\"max\":{}}}",
            json_escape(k),
            h.count(),
            num(h.mean()),
            num(h.min()),
            num(h.quantile(0.50)),
            num(h.quantile(0.95)),
            num(h.quantile(0.99)),
            num(h.max()),
        ));
    }
    out.push_str("}}");
    out
}

fn req_num(v: &Json, key: &str) -> Result<f64> {
    v.get(key)?.as_f64()
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)?.as_str()
}

/// Validate one parsed run-log line against the schema table above.
/// Unknown `type` tags and missing/mistyped required fields are errors;
/// extra fields are allowed.
pub fn validate_line(v: &Json) -> Result<()> {
    let ty = req_str(v, "type")?.to_string();
    req_num(v, "t_us")?;
    match ty.as_str() {
        "run_start" => {
            req_str(v, "arch")?;
            req_num(v, "devices")?;
            req_num(v, "steps")?;
        }
        "step" => {
            for k in ["step", "loss", "devices", "comm_us", "conv_us", "comp_us", "bytes"] {
                req_num(v, k)?;
            }
        }
        "repartition" => {
            req_num(v, "step")?;
        }
        "rebalance" => {
            req_num(v, "step")?;
            for s in v.get("shares")?.as_arr()? {
                s.as_f64()?;
            }
        }
        "worker_left" => {
            req_num(v, "step")?;
            req_num(v, "devices_left")?;
        }
        "eval" => {
            req_num(v, "step")?;
            req_num(v, "accuracy")?;
        }
        "checkpoint" => {
            req_num(v, "step")?;
            req_str(v, "path")?;
        }
        "span" => {
            req_str(v, "name")?;
            let cat = req_str(v, "cat")?;
            ensure!(
                matches!(cat, "step" | "comm" | "conv" | "comp" | "allreduce"),
                "span cat {cat:?} not one of step|comm|conv|comp|allreduce"
            );
            for k in ["device", "layer", "step", "dur_us"] {
                req_num(v, k)?;
            }
        }
        "metrics" => {
            v.get("counters")?.as_obj()?;
            v.get("gauges")?.as_obj()?;
            v.get("hists")?.as_obj()?;
        }
        "health" => {
            req_num(v, "step")?;
            req_num(v, "device")?;
            req_num(v, "ratio")?;
            for k in ["from", "to"] {
                let s = req_str(v, k)?;
                ensure!(
                    crate::obs::HealthState::from_label(s).is_some(),
                    "health {k} {s:?} not one of healthy|degraded|straggling|lost"
                );
            }
        }
        "anomaly" => {
            for k in ["step", "step_ms", "median_ms", "mad_ms"] {
                req_num(v, k)?;
            }
        }
        "run_end" => {
            req_num(v, "steps")?;
        }
        other => bail!("unknown run-log line type {other:?}"),
    }
    Ok(())
}

/// Parse and validate a whole run log; errors carry the 1-based line number.
pub fn validate_text(text: &str) -> Result<Vec<Json>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("run log line {}: {e}", i + 1))?;
        validate_line(&v).map_err(|e| anyhow::anyhow!("run log line {}: {e}", i + 1))?;
        out.push(v);
    }
    ensure!(!out.is_empty(), "run log is empty");
    Ok(out)
}

/// A lenient read of a possibly-in-flight run log (see [`read_text_tail`]).
pub struct TailRead {
    pub lines: Vec<Json>,
    /// True when the final line was dropped as a partial write.
    pub truncated: bool,
}

/// Parse a run log that may still be written to (`convdist top` on a live
/// `run.jsonl`, the compare tool on a crashed run). Interior corruption is
/// still a hard error with its 1-based line number, but a *final* line that
/// fails to parse or validate while the text lacks a trailing newline is
/// treated as a partial write and skipped (`truncated: true`). An empty
/// log is fine here — the caller renders "no steps yet".
pub fn read_text_tail(text: &str) -> Result<TailRead> {
    let complete_tail = text.ends_with('\n') || text.is_empty();
    let lines: Vec<(usize, &str)> = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .collect();
    let mut out = Vec::new();
    let mut truncated = false;
    let last = lines.len().saturating_sub(1);
    for (idx, (lineno, line)) in lines.iter().enumerate() {
        let parsed = Json::parse(line).and_then(|v| {
            validate_line(&v)?;
            Ok(v)
        });
        match parsed {
            Ok(v) => out.push(v),
            Err(_) if idx == last && !complete_tail => truncated = true,
            Err(e) => bail!("run log line {}: {e}", lineno + 1),
        }
    }
    Ok(TailRead { lines: out, truncated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Breakdown;
    use crate::obs::SpanCat;
    use std::time::Duration;

    #[test]
    fn every_event_variant_round_trips_through_the_validator() {
        let b = Breakdown {
            comm: Duration::from_micros(10),
            conv: Duration::from_micros(20),
            comp: Duration::from_micros(5),
        };
        let events = vec![
            Event::StepCompleted {
                step: 1,
                loss: 2.25,
                devices: 3,
                breakdown: b,
                bytes_moved: 1024,
            },
            Event::Repartitioned { step: 2 },
            Event::Rebalanced { step: 2, shares: vec![40, 24] },
            Event::WorkerLeft { step: 2, devices_left: 2 },
            Event::EvalDone { step: 3, accuracy: 0.125 },
            Event::CheckpointSaved { step: 2, path: "out/step2 \"x\".ckpt".into() },
            Event::HealthChanged {
                step: 4,
                device: 1,
                from: crate::obs::HealthState::Healthy,
                to: crate::obs::HealthState::Degraded,
                ratio: 2.5,
            },
            Event::AnomalyFlagged { step: 5, step_ms: 120.0, median_ms: 40.0, mad_ms: 2.0 },
        ];
        for ev in &events {
            let line = event_line(42, ev);
            let v = Json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            validate_line(&v).unwrap_or_else(|e| panic!("{line}: {e}"));
        }
        // Step numbers survive the round trip.
        let v = Json::parse(&event_line(7, &events[0])).unwrap();
        assert_eq!(v.get("step").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("conv_us").unwrap().as_u64().unwrap(), 20);
    }

    #[test]
    fn span_metrics_and_lifecycle_lines_validate() {
        let s = SpanRec {
            name: "conv1_fwd dev1 \"q\"".into(),
            cat: SpanCat::Conv,
            device: 1,
            layer: 1,
            step: 4,
            ts_us: 100,
            dur_us: 50,
        };
        let mut reg = MetricsRegistry::default();
        reg.inc("steps", 3);
        reg.set_gauge("util.dev0", 0.5);
        reg.observe_ms("step_ms", 12.0);
        for line in [
            run_start_line(0, "tiny", 3, 5),
            span_line(&s),
            metrics_line(9, &reg),
            run_end_line(10, 5),
        ] {
            let v = Json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            validate_line(&v).unwrap_or_else(|e| panic!("{line}: {e}"));
        }
    }

    #[test]
    fn validator_rejects_bad_lines() {
        for bad in [
            r#"{"t_us":0}"#,                                     // no type
            r#"{"type":"nope","t_us":0}"#,                       // unknown type
            r#"{"type":"step","t_us":0,"step":1}"#,              // missing fields
            r#"{"type":"step","step":1}"#,                       // missing t_us
            r#"{"type":"span","t_us":0,"name":"x","cat":"io","device":0,"layer":0,"step":1,"dur_us":1}"#, // bad cat
            r#"{"type":"eval","t_us":0,"step":1,"accuracy":"hi"}"#, // mistyped
            r#"{"type":"health","t_us":0,"step":1,"device":0,"from":"healthy","to":"zombie","ratio":1.0}"#, // bad state
            r#"{"type":"anomaly","t_us":0,"step":1,"step_ms":9.0}"#, // missing fields
            r#"{"type":"rebalance","t_us":0,"step":1}"#,             // missing shares
            r#"{"type":"rebalance","t_us":0,"step":1,"shares":["a"]}"#, // mistyped shares
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(validate_line(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn validate_text_reports_line_numbers() {
        let text = format!("{}\nnot json\n", run_start_line(0, "tiny", 2, 1));
        let err = validate_text(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(validate_text("").is_err(), "empty log must be rejected");
    }

    #[test]
    fn tail_read_tolerates_a_partial_final_line_only() {
        let start = run_start_line(0, "tiny", 2, 3);
        // Partial trailing write (no newline): skipped, flagged.
        let text = format!("{start}\n{{\"type\":\"st");
        let r = read_text_tail(&text).unwrap();
        assert!(r.truncated);
        assert_eq!(r.lines.len(), 1);
        // Same garbage but newline-terminated: a real corruption, line 2.
        let text = format!("{start}\n{{\"type\":\"st\n");
        let err = read_text_tail(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // Interior corruption is always fatal even without a trailing \n.
        let text = format!("{start}\ngarbage\n{start}");
        let err = read_text_tail(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // Empty logs are fine for tailing.
        let r = read_text_tail("").unwrap();
        assert!(r.lines.is_empty() && !r.truncated);
    }

    #[test]
    fn metrics_line_carries_hist_min_max() {
        let mut reg = MetricsRegistry::default();
        reg.observe_ms("step_ms", 5.0);
        reg.observe_ms("step_ms", 40.0);
        let v = Json::parse(&metrics_line(1, &reg)).unwrap();
        let h = v.get("hists").unwrap().get("step_ms").unwrap();
        assert_eq!(h.get("min").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(h.get("max").unwrap().as_f64().unwrap(), 40.0);
    }
}
