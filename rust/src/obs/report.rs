//! `convdist report run.jsonl` — summarize a finished run log into the
//! paper's Figure-6-style phase table.
//!
//! Strict by design: every line is schema-validated first
//! ([`super::runlog::validate_text`]), so the subcommand doubles as the CI
//! gate that a `--trace` run produced a well-formed log.

use anyhow::Result;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

fn mib(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0)
}

/// Validate `text` (a whole run.jsonl) and render the summary table.
pub fn summarize(text: &str) -> Result<String> {
    let lines = super::runlog::validate_text(text)?;
    let mut arch = String::from("?");
    let mut devices = 0u64;
    let mut planned = 0u64;
    let mut step_ms: Vec<f64> = Vec::new();
    let (mut comm_us, mut conv_us, mut comp_us) = (0.0f64, 0.0f64, 0.0f64);
    let mut bytes = 0.0f64;
    let mut last_loss = f64::NAN;
    let mut eval: Option<f64> = None;
    let (mut repartitions, mut worker_left, mut checkpoints, mut spans) = (0u64, 0u64, 0u64, 0u64);
    for v in &lines {
        match v.get("type")?.as_str()? {
            "run_start" => {
                arch = v.get("arch")?.as_str()?.to_string();
                devices = v.get("devices")?.as_u64()?;
                planned = v.get("steps")?.as_u64()?;
            }
            "step" => {
                let (c, v_, p) = (
                    v.get("comm_us")?.as_f64()?,
                    v.get("conv_us")?.as_f64()?,
                    v.get("comp_us")?.as_f64()?,
                );
                comm_us += c;
                conv_us += v_;
                comp_us += p;
                step_ms.push((c + v_ + p) / 1e3);
                bytes += v.get("bytes")?.as_f64()?;
                last_loss = v.get("loss")?.as_f64()?;
            }
            "repartition" => repartitions += 1,
            "worker_left" => worker_left += 1,
            "checkpoint" => checkpoints += 1,
            "eval" => eval = Some(v.get("accuracy")?.as_f64()?),
            "span" => spans += 1,
            _ => {}
        }
    }
    if step_ms.is_empty() {
        // A valid log with zero steps (crashed before step 1, or a live log
        // tailed too early) still deserves a summary, not a panic or a
        // divide-by-zero.
        return Ok(format!(
            "run summary: arch {arch}, {devices} devices, 0/{planned} steps, {spans} spans\n  \
             no steps recorded — the run ended (or was sampled) before the first step completed\n"
        ));
    }
    let total_us = (comm_us + conv_us + comp_us).max(1.0);
    let mut sorted = step_ms.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mean = step_ms.iter().sum::<f64>() / step_ms.len() as f64;
    let mut out = String::new();
    out.push_str(&format!(
        "run summary: arch {arch}, {devices} devices, {}/{planned} steps, {spans} spans\n",
        step_ms.len()
    ));
    out.push_str(&format!(
        "  step time: mean {mean:.3} ms  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms\n",
        percentile(&sorted, 0.50),
        percentile(&sorted, 0.95),
        percentile(&sorted, 0.99),
    ));
    out.push_str("  phase totals (Fig. 6 attribution):\n");
    for (label, us) in [("comm", comm_us), ("conv", conv_us), ("comp", comp_us)] {
        out.push_str(&format!(
            "    {label}  {:9.3} s  ({:4.1}%)\n",
            us / 1e6,
            100.0 * us / total_us
        ));
    }
    out.push_str(&format!("  final loss {last_loss:.4}"));
    if let Some(acc) = eval {
        out.push_str(&format!("  eval accuracy {:.2}%", 100.0 * acc));
    }
    out.push('\n');
    out.push_str(&format!(
        "  bytes moved {:.2} MiB  repartitions {repartitions}  departures {worker_left}  checkpoints {checkpoints}\n",
        mib(bytes)
    ));
    Ok(out)
}

/// Parse + summarize a run-log file (also re-exported to the CLI).
pub fn summarize_file(path: &std::path::Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    summarize(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Breakdown;
    use crate::obs::runlog;
    use crate::session::Event;
    use std::time::Duration;

    fn step_line(t: u64, step: u64, comm: u64, conv: u64, comp: u64) -> String {
        runlog::event_line(
            t,
            &Event::StepCompleted {
                step,
                loss: 2.0,
                devices: 3,
                breakdown: Breakdown {
                    comm: Duration::from_micros(comm),
                    conv: Duration::from_micros(conv),
                    comp: Duration::from_micros(comp),
                },
                bytes_moved: 2048,
            },
        )
    }

    #[test]
    fn summarize_aggregates_phases_and_events() {
        let log = [
            runlog::run_start_line(0, "tiny", 3, 2),
            step_line(10, 1, 100, 300, 100),
            runlog::event_line(11, &Event::Repartitioned { step: 1 }),
            step_line(20, 2, 100, 300, 100),
            runlog::event_line(21, &Event::EvalDone { step: 2, accuracy: 0.25 }),
            runlog::run_end_line(30, 2),
        ]
        .join("\n");
        let out = summarize(&log).unwrap();
        assert!(out.contains("arch tiny, 3 devices, 2/2 steps"), "{out}");
        assert!(out.contains("conv      0.001 s  (60.0%)"), "{out}");
        assert!(out.contains("repartitions 1"), "{out}");
        assert!(out.contains("eval accuracy 25.00%"), "{out}");
    }

    #[test]
    fn summarize_rejects_invalid_logs_but_handles_step_free_ones() {
        assert!(summarize("{\"type\":\"bogus\",\"t_us\":0}").is_err());
        // A schema-valid log with zero steps renders a clear summary
        // instead of erroring (regression: used to refuse, and the CLI's
        // RunReport printer divided by zero on the same shape).
        let log =
            [runlog::run_start_line(0, "tiny", 2, 5), runlog::run_end_line(10, 0)].join("\n");
        let out = summarize(&log).unwrap();
        assert!(out.contains("0/5 steps"), "{out}");
        assert!(out.contains("no steps recorded"), "{out}");
        assert!(!out.contains("NaN"), "{out}");
    }
}
