//! Per-worker health states and online step-time anomaly detection — the
//! live tier of the observability stack (DESIGN.md §12).
//!
//! The paper's heterogeneous fleets fail gradually, not atomically: a
//! thermally-throttled device first runs a little slow (*degraded*), then
//! slow enough to dominate the step (*straggling*), and only sometimes
//! disappears outright (*lost*).  [`FleetHealth`] condenses the signals the
//! master already collects — the per-device EWMA sec-per-GFLOP telemetry,
//! heartbeat drops and gather-timeout drops — into one state per device,
//! emitting a [`HealthTransition`] whenever a device changes state.  The
//! session mirrors transitions into the run log (`health` lines) and the
//! metrics registry (`health.devN` gauges), which is what `--metrics-addr`
//! and `convdist top` render.
//!
//! [`AnomalyDetector`] watches the step-time series itself: a rolling
//! median/MAD window flags steps whose total time is a high outlier
//! (`anomaly` run-log lines) — the first visible symptom of a fleet going
//! out of balance, often steps before the re-partition policy reacts.

use std::collections::VecDeque;

use crate::sched::FleetTelemetry;

/// Health of one device, ordered by severity.  `Lost` is terminal — device
/// ids are never reused within a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    /// Noticeably slower than the fleet median (ratio >= `degraded_ratio`).
    Degraded,
    /// Slow enough to dominate the step (ratio >= `straggler_ratio`).
    Straggling,
    /// Dropped from the fleet: crashed, left, heartbeat-silent or past the
    /// gather deadline.
    Lost,
}

impl HealthState {
    pub fn label(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Straggling => "straggling",
            HealthState::Lost => "lost",
        }
    }

    /// Numeric code for gauges (`health.devN`): 0 healthy, 1 degraded,
    /// 2 straggling, 3 lost.
    pub fn code(&self) -> u8 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Degraded => 1,
            HealthState::Straggling => 2,
            HealthState::Lost => 3,
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "healthy" => Some(HealthState::Healthy),
            "degraded" => Some(HealthState::Degraded),
            "straggling" => Some(HealthState::Straggling),
            "lost" => Some(HealthState::Lost),
            _ => None,
        }
    }

    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(HealthState::Healthy),
            1 => Some(HealthState::Degraded),
            2 => Some(HealthState::Straggling),
            3 => Some(HealthState::Lost),
            _ => None,
        }
    }
}

/// Thresholds for the slowness ladder, as ratios of a device's EWMA rate
/// (seconds per GFLOP) over the fleet median.
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    /// Enter `Degraded` at `rate >= degraded_ratio * median`.
    pub degraded_ratio: f64,
    /// Enter `Straggling` at `rate >= straggler_ratio * median`.
    pub straggler_ratio: f64,
    /// Ignore devices with fewer telemetry samples than this (calibration
    /// seeds one sample per device, so the default kicks in on step 1).
    pub min_samples: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { degraded_ratio: 1.6, straggler_ratio: 3.0, min_samples: 1 }
    }
}

/// One state change, in the order it must appear in the run log.
#[derive(Clone, Debug)]
pub struct HealthTransition {
    pub device: usize,
    pub from: HealthState,
    pub to: HealthState,
    /// Rate-over-median ratio that drove the change (0 for `Lost` — a
    /// membership fact, not a slowness measurement).
    pub ratio: f64,
}

/// The per-device health state machine.  Severity moves at most one level
/// per update in either direction — a device degrading 8x overnight still
/// walks Healthy → Degraded → Straggling, so the run log always shows the
/// full escalation path — except `Lost`, which is immediate (membership is
/// a fact, not an estimate).  Recovery requires clearing the entry
/// threshold with 20% margin (hysteresis against flapping on EWMA noise).
pub struct FleetHealth {
    states: Vec<HealthState>,
    cfg: HealthConfig,
}

impl FleetHealth {
    pub fn new(n_devices: usize, cfg: HealthConfig) -> Self {
        Self { states: vec![HealthState::Healthy; n_devices], cfg }
    }

    pub fn states(&self) -> &[HealthState] {
        &self.states
    }

    pub fn state(&self, device: usize) -> HealthState {
        self.states[device]
    }

    fn severity(s: HealthState) -> u8 {
        match s {
            HealthState::Healthy => 0,
            HealthState::Degraded => 1,
            HealthState::Straggling => 2,
            HealthState::Lost => 3,
        }
    }

    fn at_severity(level: u8) -> HealthState {
        match level {
            0 => HealthState::Healthy,
            1 => HealthState::Degraded,
            _ => HealthState::Straggling,
        }
    }

    /// Fold the current telemetry into the state machine.  `active` is the
    /// alive device-id set (master included); anything outside it is
    /// `Lost`.  Returns the transitions in device order.
    pub fn update(
        &mut self,
        active: &[usize],
        telemetry: &FleetTelemetry,
    ) -> Vec<HealthTransition> {
        let mut rates: Vec<f64> = active
            .iter()
            .filter(|&&d| telemetry.samples(d) >= self.cfg.min_samples)
            .filter_map(|&d| telemetry.rate(d))
            .collect();
        rates.sort_by(|a, b| a.total_cmp(b));
        let median = match rates.len() {
            0 => None,
            n => Some((rates[(n - 1) / 2] + rates[n / 2]) / 2.0),
        };
        let mut out = Vec::new();
        for d in 0..self.states.len() {
            let cur = self.states[d];
            let next = if !active.contains(&d) {
                HealthState::Lost
            } else if cur == HealthState::Lost {
                // Terminal: a dropped device id never rejoins this run.
                HealthState::Lost
            } else {
                let ratio = match (median, telemetry.rate(d)) {
                    (Some(m), Some(r))
                        if m > 0.0 && telemetry.samples(d) >= self.cfg.min_samples =>
                    {
                        r / m
                    }
                    _ => continue, // no estimate yet: hold the current state
                };
                let target = if ratio >= self.cfg.straggler_ratio {
                    2
                } else if ratio >= self.cfg.degraded_ratio {
                    1
                } else {
                    0
                };
                let cur_sev = Self::severity(cur);
                let next_sev = if target > cur_sev {
                    cur_sev + 1 // escalate one level per step
                } else if target < cur_sev {
                    // De-escalate only with 20% margin below the level's
                    // own entry threshold.
                    let exit = match cur_sev {
                        2 => self.cfg.straggler_ratio,
                        _ => self.cfg.degraded_ratio,
                    };
                    if ratio < exit / 1.25 {
                        cur_sev - 1
                    } else {
                        cur_sev
                    }
                } else {
                    cur_sev
                };
                if next_sev != cur_sev {
                    let to = Self::at_severity(next_sev);
                    out.push(HealthTransition { device: d, from: cur, to, ratio });
                    self.states[d] = to;
                }
                continue;
            };
            if next != cur {
                out.push(HealthTransition { device: d, from: cur, to: next, ratio: 0.0 });
                self.states[d] = next;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Step-time anomaly detection
// ---------------------------------------------------------------------------

/// A step whose total time is a high outlier against the rolling window.
#[derive(Clone, Debug)]
pub struct StepAnomaly {
    pub step_ms: f64,
    pub median_ms: f64,
    pub mad_ms: f64,
}

/// Rolling median/MAD outlier detector over step times.  Median/MAD rather
/// than mean/σ so a single slow step cannot drag the baseline after itself;
/// only *high* outliers flag (a surprisingly fast step is not a problem).
pub struct AnomalyDetector {
    window: VecDeque<f64>,
    cap: usize,
    k: f64,
    min_n: usize,
}

impl Default for AnomalyDetector {
    fn default() -> Self {
        Self::new(32, 5.0, 8)
    }
}

impl AnomalyDetector {
    /// `cap`: window length; `k`: flag at `median + k * scale` where
    /// `scale = max(1.4826 * MAD, 5% of median)`; `min_n`: observations
    /// before any flagging (warmup).
    pub fn new(cap: usize, k: f64, min_n: usize) -> Self {
        Self { window: VecDeque::with_capacity(cap), cap: cap.max(4), k, min_n: min_n.max(2) }
    }

    fn median(sorted: &[f64]) -> f64 {
        let n = sorted.len();
        (sorted[(n - 1) / 2] + sorted[n / 2]) / 2.0
    }

    /// Feed one step time (ms); returns the anomaly verdict *against the
    /// window so far* (the new sample joins the window afterwards, so a
    /// spike cannot vouch for itself).
    pub fn observe(&mut self, step_ms: f64) -> Option<StepAnomaly> {
        let verdict = if step_ms.is_finite() && self.window.len() >= self.min_n {
            let mut sorted: Vec<f64> = self.window.iter().copied().collect();
            sorted.sort_by(|a, b| a.total_cmp(b));
            let median = Self::median(&sorted);
            let mut devs: Vec<f64> = sorted.iter().map(|v| (v - median).abs()).collect();
            devs.sort_by(|a, b| a.total_cmp(b));
            let mad = Self::median(&devs);
            // Floor the scale: a near-constant window (virtual throttles,
            // idle fleets) has MAD ~ 0 and would flag harmless jitter.
            let scale = (1.4826 * mad).max(0.05 * median).max(1e-3);
            if step_ms > median + self.k * scale {
                Some(StepAnomaly { step_ms, median_ms: median, mad_ms: mad })
            } else {
                None
            }
        } else {
            None
        };
        if step_ms.is_finite() {
            if self.window.len() == self.cap {
                self.window.pop_front();
            }
            self.window.push_back(step_ms);
        }
        verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn telem(rates: &[(usize, f64)], n: usize) -> FleetTelemetry {
        let mut t = FleetTelemetry::new(n, 1.0); // alpha 1: rate = last sample
        for &(d, r) in rates {
            t.record(d, r, 1e9); // seconds per GFLOP == seconds here
        }
        t
    }

    #[test]
    fn escalates_one_level_per_update_and_recovers_with_hysteresis() {
        let cfg = HealthConfig::default();
        let mut h = FleetHealth::new(3, cfg);
        let active = vec![0, 1, 2];
        // Device 1 is 8x the median: must still pass through Degraded.
        let t = telem(&[(0, 0.5), (1, 4.0), (2, 0.5)], 3);
        let tr = h.update(&active, &t);
        assert_eq!(tr.len(), 1);
        assert_eq!((tr[0].device, tr[0].to), (1, HealthState::Degraded));
        let tr = h.update(&active, &t);
        assert_eq!((tr[0].from, tr[0].to), (HealthState::Degraded, HealthState::Straggling));
        assert!(tr[0].ratio > 3.0, "ratio {}", tr[0].ratio);
        // Steady state: no more transitions.
        assert!(h.update(&active, &t).is_empty());
        // Recovery to just under the straggler threshold is NOT enough
        // (hysteresis); 20% under it is.
        let t = telem(&[(0, 0.5), (1, 1.4), (2, 0.5)], 3);
        assert!(h.update(&active, &t).is_empty(), "flapped without margin");
        let t = telem(&[(0, 0.5), (1, 1.0), (2, 0.5)], 3);
        let tr = h.update(&active, &t);
        assert_eq!(tr[0].to, HealthState::Degraded, "recovery also steps one level");
        let t = telem(&[(0, 0.5), (1, 0.55), (2, 0.5)], 3);
        let tr = h.update(&active, &t);
        assert_eq!(tr[0].to, HealthState::Healthy);
    }

    #[test]
    fn departure_is_lost_immediately_and_terminal() {
        let mut h = FleetHealth::new(3, HealthConfig::default());
        let t = telem(&[(0, 0.5), (1, 0.5), (2, 0.5)], 3);
        assert!(h.update(&[0, 1, 2], &t).is_empty());
        let tr = h.update(&[0, 2], &t);
        assert_eq!(tr.len(), 1);
        assert_eq!((tr[0].device, tr[0].to), (1, HealthState::Lost));
        // Still gone next update: no repeated transition, state stays Lost.
        assert!(h.update(&[0, 2], &t).is_empty());
        assert_eq!(h.state(1), HealthState::Lost);
        // Even if the id reappears in the active set, Lost is terminal.
        assert!(h.update(&[0, 1, 2], &t).is_empty());
        assert_eq!(h.state(1), HealthState::Lost);
    }

    #[test]
    fn no_estimate_holds_the_current_state() {
        let mut h = FleetHealth::new(2, HealthConfig::default());
        let t = FleetTelemetry::new(2, 0.5); // no samples at all
        assert!(h.update(&[0, 1], &t).is_empty());
        assert_eq!(h.state(0), HealthState::Healthy);
    }

    #[test]
    fn labels_and_codes_round_trip() {
        for s in [
            HealthState::Healthy,
            HealthState::Degraded,
            HealthState::Straggling,
            HealthState::Lost,
        ] {
            assert_eq!(HealthState::from_label(s.label()), Some(s));
            assert_eq!(HealthState::from_code(s.code()), Some(s));
        }
        assert_eq!(HealthState::from_label("zombie"), None);
        assert_eq!(HealthState::from_code(9), None);
    }

    #[test]
    fn anomaly_detector_flags_high_outliers_only_after_warmup() {
        let mut det = AnomalyDetector::new(16, 5.0, 8);
        // Warmup: even a 10x sample does not flag before min_n.
        assert!(det.observe(1000.0).is_none());
        for _ in 0..8 {
            assert!(det.observe(100.0).is_none());
        }
        // Uniform window: moderate jitter stays quiet (floored scale)...
        assert!(det.observe(104.0).is_none());
        // ...a 2x step flags against median 100 (scale floor = 5ms, k=5)...
        let a = det.observe(200.0).expect("2x step must flag");
        assert!((a.median_ms - 100.0).abs() < 5.0, "{a:?}");
        // ...and a *fast* outlier never flags.
        assert!(det.observe(10.0).is_none());
    }

    #[test]
    fn anomaly_detector_window_slides() {
        let mut det = AnomalyDetector::new(8, 5.0, 4);
        for _ in 0..8 {
            det.observe(10.0);
        }
        // Regime change: the first slow step flags, but once the window
        // fills with the new regime the detector re-baselines.
        assert!(det.observe(100.0).is_some());
        let mut flagged = 0;
        for _ in 0..12 {
            if det.observe(100.0).is_some() {
                flagged += 1;
            }
        }
        assert!(flagged <= 4, "detector never re-baselined: {flagged} flags");
        assert!(det.observe(100.0).is_none());
    }

    #[test]
    fn anomaly_detector_ignores_non_finite() {
        let mut det = AnomalyDetector::new(8, 5.0, 2);
        for _ in 0..4 {
            det.observe(10.0);
        }
        assert!(det.observe(f64::NAN).is_none());
        assert!(det.observe(f64::INFINITY).is_none());
        assert!(det.observe(10.5).is_none(), "NaN must not poison the window");
    }
}
