//! Replica groups: hybrid data×model parallelism (DESIGN.md §14).
//!
//! One fleet already splits a conv layer's *kernels* over heterogeneous
//! devices (Eq. 1 model parallelism).  This tier runs **N whole fleets** in
//! parallel, each training the identical network on a disjoint slice of the
//! global batch, and makes them exchange gradients after every backward
//! pass — synchronous data parallelism *across* fleets composed with the
//! paper's model parallelism *inside* each fleet.
//!
//! The contract per step:
//!
//! 1. every replica runs forward+backward on its slice
//!    ([`DistTrainer::step_grads`]), producing slice-mean gradients;
//! 2. each gradient set is pre-scaled by `slice / global_batch`, so the
//!    all-reduce **sum** ([`ReduceFabric::all_reduce`]) is exactly the
//!    global-batch mean gradient — the same tensor a single fleet at the
//!    full batch would have computed;
//! 3. every replica applies the identical reduced gradients
//!    ([`DistTrainer::step_apply`]), keeping parameters, momentum and step
//!    counters in lockstep on all replicas forever after.
//!
//! Because the training executables are shape-pinned to their batch, each
//! replica owns a full `ArchSpec`/`Runtime`/worker-fleet stack built at its
//! slice size ([`ArchSpec::with_batch`]); the slices may therefore be
//! *uneven*, and a [`ShareRebalancer`] fed by per-replica step wall times
//! can propose new slices when one fleet is persistently slower — the
//! batch-level analogue of the kernel-level adaptive re-partitioner.

mod allreduce;

pub use allreduce::{AllReduce, ReduceFabric};

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::cluster::{
    spawn_workers_traced, DistTrainer, InprocCluster, StepResult, WorkerSource,
};
use crate::config::TrainerConfig;
use crate::data::Batch;
use crate::devices::{Throttle, ThrottlePlan};
use crate::model::{Grads, Params};
use crate::net::LinkModel;
use crate::obs::{ObsHandle, SpanCat, SpanRec};
use crate::runtime::{ArchSpec, Runtime};
use crate::sched::{AdaptiveConfig, FleetTelemetry, RebalanceConfig, ShareRebalancer};
use crate::tensor::Tensor;

/// What the replica tier is asked to run (`replica` config section /
/// `SessionBuilder::replicas`).
#[derive(Clone, Copy, Debug)]
pub struct ReplicaSpec {
    /// Number of replica fleets; `1` = the classic single-fleet path.
    pub count: usize,
    /// Gradient all-reduce strategy.
    pub allreduce: AllReduce,
    /// All-reduce chunk size in f32 elements (`replica.chunk_kb`).
    pub chunk_elems: usize,
    /// Cross-replica batch-share rebalancing knobs.
    pub rebalance: RebalanceConfig,
}

impl Default for ReplicaSpec {
    fn default() -> Self {
        Self {
            count: 1,
            allreduce: AllReduce::Master,
            chunk_elems: 64 * 1024,
            rebalance: RebalanceConfig::default(),
        }
    }
}

/// Per-fleet composition knobs, shared by every replica: each replica fleet
/// is built exactly like the single-fleet session would build its one fleet.
pub struct FleetOpts {
    /// One worker per entry, throttled to emulate a heterogeneous device.
    pub plans: Vec<ThrottlePlan>,
    /// Bandwidth/latency shaping on every master↔worker link.
    pub shape: Option<LinkModel>,
    /// Master-device compute throttle.
    pub master_throttle: Throttle,
    /// Adaptive re-partitioning config (per fleet, unchanged semantics).
    pub adaptive: AdaptiveConfig,
    /// Worker-side span tracing (applied to replica 0's fleet only — one
    /// traced fleet keeps the timeline readable).
    pub trace: bool,
}

/// Split `batch` into `n` near-even slices (remainder to the first fleets).
pub fn split_slices(batch: usize, n: usize) -> Vec<usize> {
    (0..n).map(|r| batch / n + usize::from(r < batch % n)).collect()
}

/// N replica fleets in lockstep.  Replica 0's trainer/cluster are owned by
/// the caller (they double as the session's primary fleet: checkpoints,
/// events and device telemetry read from it); this set owns replicas
/// `1..N` plus the reduction fabric and the batch-share rebalancer.
pub struct ReplicaSet {
    arch: ArchSpec,
    spec: ReplicaSpec,
    cfg: TrainerConfig,
    fleet: FleetOpts,
    /// Trainers of replicas `1..N` (`trainers[r - 1]` is replica `r`).
    trainers: Vec<DistTrainer>,
    clusters: Vec<InprocCluster>,
    fabric: ReduceFabric,
    slices: Vec<usize>,
    rebalancer: ShareRebalancer,
    obs: Option<ObsHandle>,
    rounds: u64,
}

impl ReplicaSet {
    /// Build `spec.count` replica fleets over `arch`'s global batch.
    /// Returns replica 0's trainer + cluster (the caller's primary fleet)
    /// and the set holding the rest.
    pub fn build(
        arch: &ArchSpec,
        spec: ReplicaSpec,
        cfg: &TrainerConfig,
        fleet: FleetOpts,
    ) -> Result<(DistTrainer, InprocCluster, ReplicaSet)> {
        let n = spec.count;
        ensure!(n >= 2, "a replica set needs at least 2 replicas, got {n}");
        let batch = arch.batch;
        ensure!(batch >= n, "global batch {batch} cannot feed {n} replicas with ≥1 sample each");
        let slices = split_slices(batch, n);
        let mut trainers = Vec::with_capacity(n);
        let mut clusters = Vec::with_capacity(n);
        for (r, &s) in slices.iter().enumerate() {
            let (t, c) = build_fleet(arch, s, cfg, &fleet, r == 0 && fleet.trace)?;
            trainers.push(t);
            clusters.push(c);
        }
        let t0 = trainers.remove(0);
        let c0 = clusters.remove(0);
        let mut set = ReplicaSet {
            arch: arch.clone(),
            fabric: ReduceFabric::new(n, spec.allreduce, spec.chunk_elems),
            rebalancer: ShareRebalancer::new(n, fleet.adaptive.alpha, spec.rebalance),
            spec,
            cfg: cfg.clone(),
            fleet,
            trainers,
            clusters,
            slices,
            obs: None,
            rounds: 0,
        };
        // Replica 0 seeds the shared parameter state: all fleets init from
        // the same seed so they already agree, but the broadcast makes the
        // invariant structural rather than coincidental.
        set.sync_params_from(&t0, 0)?;
        Ok((t0, c0, set))
    }

    pub fn count(&self) -> usize {
        self.slices.len()
    }

    /// Current per-replica batch slices (`slices[0]` feeds replica 0).
    pub fn slices(&self) -> &[usize] {
        &self.slices
    }

    pub fn strategy(&self) -> AllReduce {
        self.fabric.strategy()
    }

    /// Replica `r`'s trainer for `r >= 1` (replica 0 is caller-owned).
    pub fn trainer(&self, r: usize) -> &DistTrainer {
        &self.trainers[r - 1]
    }

    /// Total bytes the gradient fabric has moved (all rounds).
    pub fn allreduce_bytes(&self) -> u64 {
        self.fabric.bytes_moved()
    }

    /// All-reduce rounds completed.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Per-replica EWMA step-time telemetry (seconds per sample).
    pub fn telemetry(&self) -> &FleetTelemetry {
        self.rebalancer.telemetry()
    }

    /// Devices across the whole set, primary fleet included.
    pub fn total_devices(&self, t0: &DistTrainer) -> usize {
        1 + t0.alive_workers()
            + self.trainers.iter().map(|t| 1 + t.alive_workers()).sum::<usize>()
    }

    /// Attach the observability sink for all-reduce spans and counters.
    /// (Replica fleets keep `obs = None` on their trainers — only the
    /// primary fleet traces steps, or every span would appear N times.)
    pub fn attach_obs(&mut self, obs: ObsHandle) {
        self.obs = Some(obs);
    }

    /// One synchronous hybrid step over the global `batch`: slice, step all
    /// fleets to their gradients, all-reduce, commit everywhere.  Returns
    /// the merged [`StepResult`] plus a slice-rebalance proposal when the
    /// step-time telemetry justifies one (the caller decides whether to
    /// [`Self::apply_slices`] — it implies fleet rebuilds).
    pub fn step(
        &mut self,
        t0: &mut DistTrainer,
        batch: &Batch,
    ) -> Result<(StepResult, Option<Vec<usize>>)> {
        let total: usize = self.slices.iter().sum();
        ensure!(
            batch.len() == total,
            "replica step fed a batch of {}, global batch is {total}",
            batch.len()
        );
        let parts = self.slice_batch(batch)?;
        let seq = (t0.steps_done() + 1) as u32;

        // ---- local forward+backward on every fleet, timed for the rebalancer
        let mut pend = Vec::with_capacity(self.count());
        let t = Instant::now();
        pend.push(t0.step_grads(&parts[0])?);
        self.rebalancer.record(0, t.elapsed().as_secs_f64(), self.slices[0]);
        for (i, tr) in self.trainers.iter_mut().enumerate() {
            let t = Instant::now();
            pend.push(tr.step_grads(&parts[i + 1])?);
            self.rebalancer.record(i + 1, t.elapsed().as_secs_f64(), self.slices[i + 1]);
        }
        let losses: Vec<f32> = pend.iter().map(|p| p.loss()).collect();

        // ---- all-reduce: pre-scale by batch share so the sum is the
        // global-batch mean gradient
        let bytes0 = self.fabric.bytes_moved();
        let obs_t0 = self.obs.as_ref().map(|o| o.now_us());
        let ar_t0 = Instant::now();
        let mut grads: Vec<Grads> = pend
            .iter()
            .zip(&self.slices)
            .map(|(p, &s)| {
                let mut g = p.grads().clone();
                g.scale(s as f32 / total as f32);
                g
            })
            .collect();
        let names = t0.params.names().to_vec();
        self.fabric.all_reduce(&mut grads, &names, seq)?;
        let ar_wall = ar_t0.elapsed();
        let ar_bytes = self.fabric.bytes_moved() - bytes0;
        self.rounds += 1;
        if let Some(o) = &self.obs {
            if o.tracing() {
                let now = o.now_us();
                let ts = obs_t0.unwrap_or(now);
                o.span(SpanRec {
                    name: format!("allreduce {seq}"),
                    cat: SpanCat::Allreduce,
                    device: 0,
                    layer: 0,
                    step: seq as u64,
                    ts_us: ts,
                    dur_us: now.saturating_sub(ts),
                });
            }
            o.metrics(|m| {
                m.inc("allreduce.bytes", ar_bytes);
                m.inc("allreduce.rounds", 1);
            });
        }

        // ---- commit the identical reduced gradients on every replica
        let mut pend = pend.into_iter();
        let mut p0 = pend.next().expect("replica 0 pending step");
        // The fabric wait is communication time in the primary breakdown.
        p0.record_comm(ar_wall);
        let mut result = t0.step_apply(p0, Some(&grads[0]))?;
        for (i, (tr, p)) in self.trainers.iter_mut().zip(pend).enumerate() {
            let r = tr.step_apply(p, Some(&grads[i + 1]))?;
            result.breakdown.add(&r.breakdown);
            result.bytes_moved += r.bytes_moved;
            result.devices += r.devices;
            result.repartitioned |= r.repartitioned;
        }
        result.bytes_moved += ar_bytes;
        // Loss over the global batch = slice-weighted mean of slice losses.
        result.loss = losses
            .iter()
            .zip(&self.slices)
            .map(|(l, &s)| l * s as f32 / total as f32)
            .sum();

        let proposal = self.rebalancer.propose(t0.steps_done(), &self.slices);
        Ok((result, proposal))
    }

    /// Slice-weighted eval accuracy over the global `batch` (each fleet's
    /// `eval_full` is shape-pinned to its slice, so every replica evaluates
    /// its own share: the weighted mean is exactly the global accuracy).
    pub fn eval_accuracy(&self, t0: &DistTrainer, batch: &Batch) -> Result<f32> {
        let total: usize = self.slices.iter().sum();
        ensure!(
            batch.len() == total,
            "replica eval fed a batch of {}, global batch is {total}",
            batch.len()
        );
        let parts = self.slice_batch(batch)?;
        let mut acc = 0f32;
        for (r, part) in parts.iter().enumerate() {
            let t = if r == 0 { t0 } else { &self.trainers[r - 1] };
            acc += t.eval_accuracy(part)? * self.slices[r] as f32 / total as f32;
        }
        Ok(acc)
    }

    /// Re-sync every replica to replica 0's state after a checkpoint
    /// restore: parameters go over the fabric (the wire broadcast the
    /// resume path is specified to use), momentum and the step counter are
    /// installed directly.
    pub fn sync_from(
        &mut self,
        t0: &DistTrainer,
        velocity: Vec<(String, Tensor)>,
        step: u64,
    ) -> Result<()> {
        self.sync_params_from(t0, step as u32)?;
        for t in &mut self.trainers {
            t.optimizer_mut().import_velocity(velocity.clone());
            t.set_steps_done(step);
        }
        Ok(())
    }

    fn sync_params_from(&mut self, t0: &DistTrainer, seq: u32) -> Result<()> {
        let mut dst: Vec<Params> = self.trainers.iter().map(|t| t.params.clone()).collect();
        self.fabric.broadcast_params(&t0.params, &mut dst, seq)?;
        for (t, p) in self.trainers.iter_mut().zip(dst) {
            t.params = p;
        }
        Ok(())
    }

    /// Adopt new batch slices: every replica whose slice changed gets a
    /// fresh fleet at the new batch size (executables are shape-pinned),
    /// with parameters, momentum and step counter handed over.  Expensive
    /// by design — the rebalancer's cooldown/threshold keep it rare.
    pub fn apply_slices(
        &mut self,
        t0: &mut DistTrainer,
        c0: &mut Option<InprocCluster>,
        new: &[usize],
    ) -> Result<()> {
        ensure!(new.len() == self.count(), "{} slices for {} replicas", new.len(), self.count());
        ensure!(
            new.iter().sum::<usize>() == self.slices.iter().sum::<usize>(),
            "slice proposal changes the global batch"
        );
        ensure!(new.iter().all(|&s| s > 0), "a replica cannot train 0 samples");
        for r in 0..new.len() {
            if new[r] == self.slices[r] {
                continue;
            }
            let (mut fresh, fresh_cluster) =
                build_fleet(&self.arch, new[r], &self.cfg, &self.fleet, r == 0 && self.fleet.trace)?;
            let old_t = if r == 0 { &*t0 } else { &self.trainers[r - 1] };
            fresh.params.load_named(&old_t.params.to_named())?;
            fresh.optimizer_mut().import_velocity(old_t.optimizer().export_velocity());
            fresh.set_steps_done(old_t.steps_done());
            if r == 0 {
                if let Some(o) = &self.obs {
                    fresh.attach_obs(o.clone());
                }
                let old = std::mem::replace(t0, fresh);
                old.shutdown()?;
                if let Some(old_c) = c0.replace(fresh_cluster) {
                    old_c.join()?;
                }
            } else {
                let old = std::mem::replace(&mut self.trainers[r - 1], fresh);
                old.shutdown()?;
                let old_c = std::mem::replace(&mut self.clusters[r - 1], fresh_cluster);
                old_c.join()?;
            }
            self.slices[r] = new[r];
        }
        Ok(())
    }

    /// Tear down replicas `1..N` (the caller shuts replica 0 down itself).
    pub fn shutdown(self) -> Result<()> {
        for t in self.trainers {
            t.shutdown()?;
        }
        for c in self.clusters {
            c.join()?;
        }
        Ok(())
    }

    fn slice_batch(&self, batch: &Batch) -> Result<Vec<Batch>> {
        let mut parts = Vec::with_capacity(self.count());
        let mut off = 0;
        for &s in &self.slices {
            parts.push(batch.slice(off, off + s)?);
            off += s;
        }
        Ok(parts)
    }
}

/// One replica fleet at batch `slice`: arch rebuilt at the slice size, own
/// runtime, own in-process workers, own trainer — exactly the single-fleet
/// construction, repeated per replica.
fn build_fleet(
    arch: &ArchSpec,
    slice: usize,
    cfg: &TrainerConfig,
    fleet: &FleetOpts,
    trace: bool,
) -> Result<(DistTrainer, InprocCluster)> {
    let arch_r = arch.with_batch(slice)?;
    let rt = Runtime::for_arch(arch_r.clone());
    let mut cluster =
        spawn_workers_traced(WorkerSource::Arch(arch_r), &fleet.plans, fleet.shape, trace)?;
    let links = cluster.take_links();
    let trainer = DistTrainer::new(rt, links, cfg, fleet.master_throttle, fleet.adaptive)?;
    Ok((trainer, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slices_are_near_even_and_sum_to_the_batch() {
        assert_eq!(split_slices(16, 2), vec![8, 8]);
        assert_eq!(split_slices(16, 3), vec![6, 5, 5]);
        assert_eq!(split_slices(5, 4), vec![2, 1, 1, 1]);
        for (b, n) in [(64, 2), (64, 3), (7, 7), (100, 6)] {
            let s = split_slices(b, n);
            assert_eq!(s.iter().sum::<usize>(), b);
            assert!(s.iter().max().unwrap() - s.iter().min().unwrap() <= 1);
        }
    }
}
