//! Chunked gradient all-reduce over the [`crate::net::Link`] framing.
//!
//! Two strategies, selected by `replica.allreduce`:
//!
//! * **master** — replica 0 is the reduction root: every other replica
//!   ships its gradient chunks up (`GradChunk`), the root accumulates them
//!   *in replica index order* and broadcasts the result back
//!   (`GradReduced`).
//! * **ring** — a pipelined chain: chunks flow 0 → 1 → … → N-1, each hop
//!   adding its own contribution, then the fully reduced chunks flow back
//!   N-1 → … → 0.  Accumulation is `partial + own` at every hop, i.e. the
//!   identical left-associated `((g0 + g1) + g2) + …` sum the master root
//!   computes — which is why `allreduce=master` and `allreduce=ring`
//!   produce bit-identical parameters (a tested invariant, not an
//!   accident; IEEE-754 addition is commutative but not associative, so
//!   the *order* of accumulation is part of the wire contract).
//!
//! Tensors are flattened and cut into `chunk_elems`-sized pieces so large
//! conv-kernel gradients pipeline through the fabric instead of traveling
//! as one frame per tensor.  Both ends of every link live in the
//! single-threaded orchestrator (the in-proc channel is unbounded, so
//! send-then-recv on the same thread cannot deadlock), and every frame
//! still crosses the full encode/decode path — the same bytes a
//! multi-process deployment would put on a socket.

use anyhow::{bail, ensure, Result};

use crate::model::{Grads, Params};
use crate::net::{inproc_pair, InProcLink, Link};
use crate::proto::{Message, WireTensor};

/// Cross-replica gradient reduction strategy (`replica.allreduce`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AllReduce {
    /// Master-rooted reduce + broadcast (replica 0 is the root).
    #[default]
    Master,
    /// Chunk-pipelined chain reduce/broadcast around the replica ring.
    Ring,
}

impl AllReduce {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "master" => Ok(AllReduce::Master),
            "ring" => Ok(AllReduce::Ring),
            other => bail!("unknown allreduce strategy {other:?} (try: master, ring)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AllReduce::Master => "master",
            AllReduce::Ring => "ring",
        }
    }
}

/// The link fabric between replicas.  `pairs[i]` is an in-proc link pair;
/// under `Master` it connects the root to replica `i + 1`, under `Ring` it
/// connects replica `i` to replica `i + 1`.  Either way the `.0` end sees
/// every frame exactly once (as sender or receiver), so summing bytes over
/// the `.0` ends counts fabric traffic without double-counting.
pub struct ReduceFabric {
    strategy: AllReduce,
    chunk_elems: usize,
    pairs: Vec<(InProcLink, InProcLink)>,
    n: usize,
}

impl ReduceFabric {
    pub fn new(n: usize, strategy: AllReduce, chunk_elems: usize) -> Self {
        let pairs = (1..n).map(|_| inproc_pair()).collect();
        Self { strategy, chunk_elems: chunk_elems.max(1), pairs, n }
    }

    pub fn strategy(&self) -> AllReduce {
        self.strategy
    }

    /// Bytes moved over the fabric, each frame counted once.
    pub fn bytes_moved(&self) -> u64 {
        self.pairs.iter().map(|(a, _)| a.bytes_moved()).sum()
    }

    /// Synchronously all-reduce (sum) the gradients of every replica:
    /// afterwards all `grads[r]` hold the identical reduced tensors.
    /// Callers pre-scale each replica's gradients by its batch share, so
    /// the plain sum is the global-batch mean gradient.  `seq` tags every
    /// frame of the round (the global step), so a desynchronized peer is a
    /// loud error instead of a silent gradient mixup.
    pub fn all_reduce(&mut self, grads: &mut [Grads], names: &[String], seq: u32) -> Result<()> {
        ensure!(grads.len() == self.n, "{} grad sets for {} replicas", grads.len(), self.n);
        for (pi, name) in names.iter().enumerate() {
            let len = grads[0].get(name)?.data().len();
            let mut off = 0;
            while off < len {
                let hi = (off + self.chunk_elems).min(len);
                match self.strategy {
                    AllReduce::Master => self.reduce_chunk_master(grads, name, pi, off, hi, seq)?,
                    AllReduce::Ring => self.reduce_chunk_ring(grads, name, pi, off, hi, seq)?,
                }
                off = hi;
            }
        }
        Ok(())
    }

    /// Master-rooted reduce + broadcast of one chunk.
    fn reduce_chunk_master(
        &mut self,
        grads: &mut [Grads],
        name: &str,
        param: usize,
        off: usize,
        hi: usize,
        seq: u32,
    ) -> Result<()> {
        // Replicas 1..n ship their chunk to the root.
        for r in 1..self.n {
            let wt = wire_chunk(grads[r].get(name)?.data(), off, hi);
            let msg = Message::GradChunk { seq, param: param as u32, offset: off as u32, data: wt };
            self.pairs[r - 1].1.send(&msg)?;
        }
        // Root accumulates in replica index order — the exact associativity
        // the ring chain reproduces.
        for r in 1..self.n {
            let msg = self.pairs[r - 1].0.recv()?;
            let data = expect_chunk(msg, false, seq, param, off, hi - off)?;
            let dst = grad_chunk_mut(&mut grads[0], name, off, hi)?;
            for (d, s) in dst.iter_mut().zip(&data) {
                *d += *s;
            }
        }
        // Broadcast the reduced chunk back down.
        let reduced = grads[0].get(name)?.data()[off..hi].to_vec();
        for r in 1..self.n {
            let wt = WireTensor { shape: vec![(hi - off) as u32], data: reduced.clone() };
            let msg =
                Message::GradReduced { seq, param: param as u32, offset: off as u32, data: wt };
            self.pairs[r - 1].0.send(&msg)?;
        }
        for r in 1..self.n {
            let msg = self.pairs[r - 1].1.recv()?;
            let data = expect_chunk(msg, true, seq, param, off, hi - off)?;
            grad_chunk_mut(&mut grads[r], name, off, hi)?.copy_from_slice(&data);
        }
        Ok(())
    }

    /// Chain reduce (0 → … → N-1) + chain broadcast (N-1 → … → 0) of one
    /// chunk: every hop adds its own contribution to the incoming partial,
    /// keeping the master root's left-associated summation order.
    fn reduce_chunk_ring(
        &mut self,
        grads: &mut [Grads],
        name: &str,
        param: usize,
        off: usize,
        hi: usize,
        seq: u32,
    ) -> Result<()> {
        let wt = wire_chunk(grads[0].get(name)?.data(), off, hi);
        let msg = Message::GradChunk { seq, param: param as u32, offset: off as u32, data: wt };
        self.pairs[0].0.send(&msg)?;
        for r in 1..self.n {
            let msg = self.pairs[r - 1].1.recv()?;
            let partial = expect_chunk(msg, false, seq, param, off, hi - off)?;
            let own = grad_chunk_mut(&mut grads[r], name, off, hi)?;
            for (o, p) in own.iter_mut().zip(&partial) {
                *o = *p + *o;
            }
            if r + 1 < self.n {
                let wt = wire_chunk(grads[r].get(name)?.data(), off, hi);
                let msg =
                    Message::GradChunk { seq, param: param as u32, offset: off as u32, data: wt };
                self.pairs[r].0.send(&msg)?;
            }
        }
        for r in (0..self.n - 1).rev() {
            let wt = wire_chunk(grads[r + 1].get(name)?.data(), off, hi);
            let msg =
                Message::GradReduced { seq, param: param as u32, offset: off as u32, data: wt };
            self.pairs[r].1.send(&msg)?;
            let got = self.pairs[r].0.recv()?;
            let data = expect_chunk(got, true, seq, param, off, hi - off)?;
            grad_chunk_mut(&mut grads[r], name, off, hi)?.copy_from_slice(&data);
        }
        Ok(())
    }

    /// Ship replica 0's parameters to every other replica over the fabric
    /// (checkpoint-resume broadcast, DESIGN.md §14): `GradReduced` frames
    /// carry the chunks — same wire layout, `seq` = checkpoint step.
    pub fn broadcast_params(&mut self, src: &Params, dst: &mut [Params], seq: u32) -> Result<()> {
        ensure!(dst.len() + 1 == self.n, "{} targets for {} replicas", dst.len(), self.n);
        for (pi, name) in src.names().to_vec().iter().enumerate() {
            let data = src.get(name)?.data();
            let mut off = 0;
            while off < data.len() {
                let hi = (off + self.chunk_elems).min(data.len());
                match self.strategy {
                    AllReduce::Master => {
                        for r in 1..self.n {
                            let wt = wire_chunk(data, off, hi);
                            self.pairs[r - 1].0.send(&Message::GradReduced {
                                seq,
                                param: pi as u32,
                                offset: off as u32,
                                data: wt,
                            })?;
                        }
                        for r in 1..self.n {
                            let msg = self.pairs[r - 1].1.recv()?;
                            let chunk = expect_chunk(msg, true, seq, pi, off, hi - off)?;
                            dst[r - 1].get_mut(name)?.data_mut()[off..hi].copy_from_slice(&chunk);
                        }
                    }
                    AllReduce::Ring => {
                        // Forward down the chain; each hop keeps a copy.
                        let wt = wire_chunk(data, off, hi);
                        self.pairs[0].0.send(&Message::GradReduced {
                            seq,
                            param: pi as u32,
                            offset: off as u32,
                            data: wt,
                        })?;
                        for r in 1..self.n {
                            let msg = self.pairs[r - 1].1.recv()?;
                            let chunk = expect_chunk(msg, true, seq, pi, off, hi - off)?;
                            dst[r - 1].get_mut(name)?.data_mut()[off..hi].copy_from_slice(&chunk);
                            if r + 1 < self.n {
                                let wt = WireTensor {
                                    shape: vec![(hi - off) as u32],
                                    data: chunk,
                                };
                                self.pairs[r].0.send(&Message::GradReduced {
                                    seq,
                                    param: pi as u32,
                                    offset: off as u32,
                                    data: wt,
                                })?;
                            }
                        }
                    }
                }
                off = hi;
            }
        }
        Ok(())
    }
}

fn wire_chunk(data: &[f32], off: usize, hi: usize) -> WireTensor {
    WireTensor { shape: vec![(hi - off) as u32], data: data[off..hi].to_vec() }
}

fn grad_chunk_mut<'a>(g: &'a mut Grads, name: &str, off: usize, hi: usize) -> Result<&'a mut [f32]> {
    let t = g
        .tensors
        .get_mut(name)
        .ok_or_else(|| anyhow::anyhow!("no grad {name}"))?;
    Ok(&mut t.data_mut()[off..hi])
}

/// Unpack a `GradChunk` (`reduced = false`) or `GradReduced` (`true`),
/// checking round tag, parameter index, offset and length — a mismatch
/// means the replicas desynchronized and must be a loud error.
fn expect_chunk(
    msg: Message,
    reduced: bool,
    seq: u32,
    param: usize,
    off: usize,
    len: usize,
) -> Result<Vec<f32>> {
    let (tag, got_seq, got_param, got_off, data) = match msg {
        Message::GradChunk { seq, param, offset, data } if !reduced => {
            ("GradChunk", seq, param, offset, data)
        }
        Message::GradReduced { seq, param, offset, data } if reduced => {
            ("GradReduced", seq, param, offset, data)
        }
        other => bail!(
            "all-reduce desync: expected {}, got {}",
            if reduced { "GradReduced" } else { "GradChunk" },
            other.tag()
        ),
    };
    ensure!(
        got_seq == seq && got_param == param as u32 && got_off == off as u32,
        "all-reduce desync: {tag} (seq {got_seq}, param {got_param}, offset {got_off}) \
         where (seq {seq}, param {param}, offset {off}) was expected"
    );
    ensure!(data.data.len() == len, "{tag} chunk carries {} elems, expected {len}", data.data.len());
    Ok(data.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Params;
    use crate::runtime::ArchSpec;

    fn grads_with(params: &Params, fill: f32) -> Grads {
        let mut g = Grads::zeros_like(params);
        for (i, t) in g.tensors.values_mut().enumerate() {
            for (j, v) in t.data_mut().iter_mut().enumerate() {
                *v = fill + i as f32 + (j % 7) as f32 * 0.25;
            }
        }
        g
    }

    fn names(params: &Params) -> Vec<String> {
        params.names().to_vec()
    }

    #[test]
    fn master_and_ring_reduce_to_the_same_bits() {
        let arch = ArchSpec::tiny();
        let params = Params::init(&arch, 3).unwrap();
        for n in [2usize, 3, 4] {
            let base: Vec<Grads> =
                (0..n).map(|r| grads_with(&params, 0.5 * r as f32 + 0.125)).collect();
            let mut via_master = base.clone();
            let mut via_ring = base.clone();
            // A tiny chunk size forces multi-chunk tensors through the wire.
            ReduceFabric::new(n, AllReduce::Master, 13)
                .all_reduce(&mut via_master, &names(&params), 7)
                .unwrap();
            ReduceFabric::new(n, AllReduce::Ring, 13)
                .all_reduce(&mut via_ring, &names(&params), 7)
                .unwrap();
            for name in params.names() {
                let m = via_master[0].get(name).unwrap().data();
                // Every replica converged on the same tensors…
                for g in &via_master[1..] {
                    assert_eq!(m, g.get(name).unwrap().data(), "{name} master fan-out");
                }
                for g in &via_ring {
                    let r = g.get(name).unwrap().data();
                    // …and master vs ring agree bit for bit.
                    assert_eq!(m.len(), r.len());
                    for (a, b) in m.iter().zip(r) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{name} master vs ring (n={n})");
                    }
                }
                // Spot-check the value: left-associated sum of contributions.
                let mut want = base[0].get(name).unwrap().clone();
                for g in &base[1..] {
                    want.axpy(1.0, g.get(name).unwrap()).unwrap();
                }
                assert_eq!(want.data(), m, "{name} reduced value (n={n})");
            }
        }
    }

    #[test]
    fn both_strategies_move_the_same_bytes() {
        let arch = ArchSpec::tiny();
        let params = Params::init(&arch, 3).unwrap();
        let base: Vec<Grads> = (0..3).map(|r| grads_with(&params, r as f32)).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        let mut fm = ReduceFabric::new(3, AllReduce::Master, 64);
        let mut fr = ReduceFabric::new(3, AllReduce::Ring, 64);
        fm.all_reduce(&mut a, &names(&params), 1).unwrap();
        fr.all_reduce(&mut b, &names(&params), 1).unwrap();
        assert!(fm.bytes_moved() > 0);
        // Chain reduce + chain broadcast moves 2(N-1) chunk frames per
        // chunk, same as root gather + root broadcast: ring ≤ master.
        assert!(fr.bytes_moved() <= fm.bytes_moved(), "{} vs {}", fr.bytes_moved(), fm.bytes_moved());
    }

    #[test]
    fn param_broadcast_reaches_every_replica_over_both_fabrics() {
        let arch = ArchSpec::tiny();
        let src = Params::init(&arch, 42).unwrap();
        for strategy in [AllReduce::Master, AllReduce::Ring] {
            let mut dst = vec![Params::init(&arch, 1).unwrap(), Params::init(&arch, 2).unwrap()];
            let mut fabric = ReduceFabric::new(3, strategy, 17);
            fabric.broadcast_params(&src, &mut dst, 9).unwrap();
            for d in &dst {
                assert_eq!(src.max_abs_diff(d).unwrap(), 0.0, "{:?}", strategy);
            }
        }
    }

    #[test]
    fn desync_is_a_loud_error() {
        let arch = ArchSpec::tiny();
        let params = Params::init(&arch, 3).unwrap();
        let mut grads: Vec<Grads> = (0..2).map(|_| Grads::zeros_like(&params)).collect();
        let mut fabric = ReduceFabric::new(2, AllReduce::Master, 64);
        // Smuggle a stale frame into the fabric: the next round must refuse it.
        fabric.pairs[0]
            .1
            .send(&Message::GradChunk {
                seq: 99,
                param: 0,
                offset: 0,
                data: WireTensor { shape: vec![1], data: vec![1.0] },
            })
            .unwrap();
        let err = fabric.all_reduce(&mut grads, &names(&params), 1).unwrap_err();
        assert!(err.to_string().contains("desync"), "{err:#}");
    }

    #[test]
    fn strategy_parse_round_trips() {
        assert_eq!(AllReduce::parse("master").unwrap(), AllReduce::Master);
        assert_eq!(AllReduce::parse("ring").unwrap(), AllReduce::Ring);
        assert_eq!(AllReduce::parse(AllReduce::Ring.name()).unwrap(), AllReduce::Ring);
        assert!(AllReduce::parse("tree").is_err());
    }
}
