//! Parameter store + optimizer.
//!
//! The master owns the full parameter set (the paper's master "is in charge
//! of training the remaining network", §4.1.2); gradients come back from the
//! backend executables and the update runs here in rust — identical code
//! path for the distributed trainer and both baselines, so loss curves are
//! directly comparable.

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Result};

use crate::runtime::ArchSpec;
use crate::tensor::{Pcg32, Tensor};

/// Named parameter tensors in manifest order
/// (`conv1.w conv1.b … convN.w convN.b fc.w fc.b`).
#[derive(Clone, Debug)]
pub struct Params {
    order: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl Params {
    /// Kaiming-uniform init: `U(±sqrt(6 / fan_in))` for weights, zero bias.
    pub fn init(arch: &ArchSpec, seed: u64) -> Result<Self> {
        let mut tensors = BTreeMap::new();
        for (i, name) in arch.param_order.iter().enumerate() {
            let shape = arch
                .param_shapes
                .get(name)
                .ok_or_else(|| anyhow!("param {name} missing from manifest"))?
                .clone();
            let mut rng = Pcg32::seed_stream(seed, i as u64);
            // Rank-1 params are biases (zero-init); weights get Kaiming.
            let t = if shape.len() == 1 {
                Tensor::zeros(&shape)
            } else {
                // fan_in: conv OIHW -> C*KH*KW; fc [in, out] -> in.
                let fan_in: usize = if shape.len() == 4 {
                    shape[1] * shape[2] * shape[3]
                } else {
                    shape[0]
                };
                let a = (6.0f32 / fan_in as f32).sqrt();
                Tensor::uniform(&shape, a, &mut rng)
            };
            tensors.insert(name.clone(), t);
        }
        Ok(Self { order: arch.param_order.clone(), tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("no param {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.tensors.get_mut(name).ok_or_else(|| anyhow!("no param {name}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let slot = self.get_mut(name)?;
        ensure!(slot.shape() == t.shape(), "param {name} shape change");
        *slot = t;
        Ok(())
    }

    /// Tensors in manifest order — the exact argument order the fused
    /// executables expect.
    pub fn in_order(&self) -> Vec<Tensor> {
        self.order.iter().map(|n| self.tensors[n].clone()).collect()
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// Clone out `(name, tensor)` pairs in manifest order — the parameter
    /// section of a session checkpoint.
    pub fn to_named(&self) -> Vec<(String, Tensor)> {
        self.order.iter().map(|n| (n.clone(), self.tensors[n].clone())).collect()
    }

    /// Restore from `(name, tensor)` pairs.  Every entry must name an
    /// existing parameter with an unchanged shape, and every parameter must
    /// be covered — a checkpoint from a different architecture fails loudly.
    pub fn load_named(&mut self, entries: &[(String, Tensor)]) -> Result<()> {
        ensure!(
            entries.len() == self.order.len(),
            "checkpoint has {} parameters, architecture has {}",
            entries.len(),
            self.order.len()
        );
        for (name, t) in entries {
            self.set(name, t.clone())?;
        }
        Ok(())
    }

    pub fn l2norm(&self) -> f32 {
        self.tensors.values().map(|t| t.l2norm().powi(2)).sum::<f32>().sqrt()
    }

    /// Max |a-b| across all parameters (distributed-vs-single check).
    pub fn max_abs_diff(&self, other: &Params) -> Result<f32> {
        let mut worst = 0f32;
        for name in &self.order {
            worst = worst.max(self.tensors[name].max_abs_diff(&other.tensors[name])?);
        }
        Ok(worst)
    }
}

/// Gradients, same naming/order as [`Params`].
#[derive(Clone, Debug)]
pub struct Grads {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Grads {
    pub fn zeros_like(params: &Params) -> Self {
        let tensors =
            params.order.iter().map(|n| (n.clone(), Tensor::zeros(params.tensors[n].shape()))).collect();
        Self { tensors }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("no grad {name}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    /// `self += s * other` (data-parallel gradient averaging).
    pub fn axpy(&mut self, s: f32, other: &Grads) -> Result<()> {
        for (name, t) in &mut self.tensors {
            t.axpy(s, other.get(name)?)?;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for t in self.tensors.values_mut() {
            t.scale(s);
        }
    }
}

/// SGD with classical momentum and decoupled weight decay.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: BTreeMap<String, Tensor>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        Self { lr, momentum, weight_decay, velocity: BTreeMap::new() }
    }

    /// Clone out the momentum buffers, sorted by name — the optimizer-state
    /// section of a session checkpoint.  Parameters that have never been
    /// stepped have no entry (their velocity is implicitly zero).
    pub fn export_velocity(&self) -> Vec<(String, Tensor)> {
        self.velocity.iter().map(|(n, t)| (n.clone(), t.clone())).collect()
    }

    /// Replace the momentum buffers (checkpoint restore).  Shape agreement
    /// with the parameters is re-checked on the next `step`.
    pub fn import_velocity(&mut self, entries: Vec<(String, Tensor)>) {
        self.velocity = entries.into_iter().collect();
    }

    /// `v = μv + g + λθ;  θ -= lr·v`
    ///
    /// Fused in-place update: one pass over each parameter, no per-step
    /// tensor clones (the velocity and parameter buffers are mutated
    /// directly; only a missing velocity entry allocates, once).
    pub fn step(&mut self, params: &mut Params, grads: &Grads) -> Result<()> {
        let (lr, mu, wd) = (self.lr, self.momentum, self.weight_decay);
        for (name, p) in params.tensors.iter_mut() {
            let g = grads.get(name)?;
            ensure!(g.shape() == p.shape(), "grad/param shape mismatch for {name}");
            let v = self
                .velocity
                .entry(name.clone())
                .or_insert_with(|| Tensor::zeros(p.shape()));
            ensure!(v.shape() == p.shape(), "velocity/param shape mismatch for {name}");
            for ((vv, pv), &gv) in
                v.data_mut().iter_mut().zip(p.data_mut().iter_mut()).zip(g.data())
            {
                *vv = mu * *vv + gv + wd * *pv;
                *pv -= lr * *vv;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tiny_arch;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let arch = tiny_arch();
        let a = Params::init(&arch, 42).unwrap();
        let b = Params::init(&arch, 42).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.0);
        let c = Params::init(&arch, 43).unwrap();
        assert!(a.max_abs_diff(&c).unwrap() > 0.0);
        // Kaiming bound for conv1.w: sqrt(6/75) ≈ 0.283.
        let w1 = a.get("conv1.w").unwrap();
        let bound = (6.0f32 / 75.0).sqrt();
        assert!(w1.data().iter().all(|v| v.abs() <= bound));
        assert!(a.get("conv1.b").unwrap().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let arch = tiny_arch();
        let mut p = Params::init(&arch, 1).unwrap();
        let before = p.get("fc.w").unwrap().data()[0];
        let mut g = Grads::zeros_like(&p);
        let mut gwf = Tensor::zeros(&[200, 10]);
        gwf.data_mut()[0] = 2.0;
        g.set("fc.w", gwf);
        let mut opt = Sgd::new(0.1, 0.0, 0.0);
        opt.step(&mut p, &g).unwrap();
        let after = p.get("fc.w").unwrap().data()[0];
        assert!((after - (before - 0.2)).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let arch = tiny_arch();
        let mut p = Params::init(&arch, 1).unwrap();
        let mut g = Grads::zeros_like(&p);
        let mut gwf = Tensor::zeros(&[200, 10]);
        gwf.data_mut()[0] = 1.0;
        g.set("fc.w", gwf);
        let start = p.get("fc.w").unwrap().data()[0];
        let mut opt = Sgd::new(0.1, 0.9, 0.0);
        opt.step(&mut p, &g).unwrap(); // v=1,   Δ=-0.1
        opt.step(&mut p, &g).unwrap(); // v=1.9, Δ=-0.19
        let got = p.get("fc.w").unwrap().data()[0];
        assert!((got - (start - 0.29)).abs() < 1e-6, "{got} vs {}", start - 0.29);
    }

    #[test]
    fn grads_axpy_average() {
        let arch = tiny_arch();
        let p = Params::init(&arch, 1).unwrap();
        let mut acc = Grads::zeros_like(&p);
        let mut g1 = Grads::zeros_like(&p);
        let mut t = Tensor::zeros(&[10]);
        t.data_mut()[3] = 4.0;
        g1.set("fc.b", t);
        acc.axpy(0.5, &g1).unwrap();
        acc.axpy(0.5, &g1).unwrap();
        assert_eq!(acc.get("fc.b").unwrap().data()[3], 4.0);
    }
}
