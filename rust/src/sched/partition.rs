//! Workload partitioner — Eq. 1 of the paper.
//!
//! Given per-device probe times `t_i`, the share of the conv workload for
//! device `i` is
//!
//! ```text
//!          max(t)/t_i
//! w_i = ----------------          (Eq. 1)
//!        Σ_j max(t)/t_j
//! ```
//!
//! i.e. proportional to relative speed.  The partitioner turns those shares
//! into integer *kernel shard* ranges `[lo, hi)` over a conv layer's K axis,
//! then rounds each shard up to the nearest compiled bucket (HLO shapes are
//! static — DESIGN.md §3) with zero-padding.

use std::fmt;

use anyhow::{ensure, Result};

/// Eq. 1: normalized workload shares from probe times (seconds).
pub fn workload_shares(probe_times: &[f64]) -> Result<Vec<f64>> {
    ensure!(!probe_times.is_empty(), "no devices");
    ensure!(
        probe_times.iter().all(|&t| t.is_finite() && t > 0.0),
        "probe times must be positive and finite: {probe_times:?}"
    );
    let tmax = probe_times.iter().cloned().fold(f64::MIN, f64::max);
    let inv: Vec<f64> = probe_times.iter().map(|&t| tmax / t).collect();
    let total: f64 = inv.iter().sum();
    Ok(inv.iter().map(|&v| v / total).collect())
}

/// A contiguous kernel shard assigned to one device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Device index (0 = master itself — Algorithm 1 convolves on the
    /// master too, lines 15–17).
    pub device: usize,
    /// Kernel range `[lo, hi)` in the layer's K axis.
    pub lo: usize,
    pub hi: usize,
    /// Compiled bucket the shard executes under (`hi - lo <= bucket`);
    /// kernels are zero-padded up to this and outputs sliced back down.
    pub bucket: usize,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }

    /// Fraction of the executed bucket that is padding waste.
    pub fn waste(&self) -> f64 {
        if self.bucket == 0 {
            0.0
        } else {
            1.0 - self.len() as f64 / self.bucket as f64
        }
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dev{}=[{},{})b{}", self.device, self.lo, self.hi, self.bucket)
    }
}

/// Borrowing display adapter for a whole shard table, so master logs and
/// examples can print readable partition maps:
/// `dev0=[0,6)b8 dev1=[6,16)b12`.
pub struct ShardTable<'a>(pub &'a [Shard]);

impl fmt::Display for ShardTable<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0.is_empty() {
            return write!(f, "(empty)");
        }
        for (i, s) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

/// Largest-remainder apportionment of `k` kernels by `shares` — exact sum,
/// no device starved unless its share rounds to zero kernels and `k` is
/// smaller than the device count.
pub fn apportion(k: usize, shares: &[f64]) -> Result<Vec<usize>> {
    ensure!(!shares.is_empty(), "no shares");
    let sum: f64 = shares.iter().sum();
    ensure!((sum - 1.0).abs() < 1e-6, "shares must sum to 1, got {sum}");
    let raw: Vec<f64> = shares.iter().map(|s| s * k as f64).collect();
    let mut counts: Vec<usize> = raw.iter().map(|r| r.floor() as usize).collect();
    let mut rem: usize = k - counts.iter().sum::<usize>();
    // Hand out the remainder by descending fractional part (stable order on
    // ties so the split is deterministic).
    let mut idx: Vec<usize> = (0..shares.len()).collect();
    idx.sort_by(|&a, &b| {
        let fa = raw[a] - raw[a].floor();
        let fb = raw[b] - raw[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    let mut pos = 0usize;
    while rem > 0 {
        counts[idx[pos % idx.len()]] += 1;
        rem -= 1;
        pos += 1;
    }
    debug_assert_eq!(counts.iter().sum::<usize>(), k);
    Ok(counts)
}

/// Round `n` up to the smallest bucket that fits; error if none does.
pub fn fit_bucket(n: usize, buckets: &[usize]) -> Result<usize> {
    buckets
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .ok_or_else(|| anyhow::anyhow!("no bucket fits shard of {n} (buckets {buckets:?})"))
}

/// Full partition of one conv layer: Eq. 1 shares -> contiguous shard ranges
/// -> bucket assignment.
///
/// Degenerate shares are handled explicitly: a device whose Eq. 1 share
/// rounds to zero kernels is *dropped from the shard table* — it idles for
/// the layer rather than receiving an empty `[lo, lo)` range padded up to a
/// whole bucket of zero-kernel work.  Its share is redistributed by the
/// largest-remainder apportionment (the counts always sum to `k` exactly),
/// so the returned shards tile `[0, k)` with no gaps and no empty entries.
pub fn partition_layer(k: usize, probe_times: &[f64], buckets: &[usize]) -> Result<Vec<Shard>> {
    ensure!(k > 0, "cannot partition a zero-kernel layer");
    let shares = workload_shares(probe_times)?;
    let counts = apportion(k, &shares)?;
    let mut shards = Vec::new();
    let mut lo = 0usize;
    for (device, &n) in counts.iter().enumerate() {
        if n == 0 {
            // Share rounded to zero kernels: drop the device for this layer.
            continue;
        }
        let bucket = fit_bucket(n, buckets)?;
        shards.push(Shard { device, lo, hi: lo + n, bucket });
        lo += n;
    }
    ensure!(lo == k, "partition covers {lo} of {k} kernels");
    debug_assert!(shards.iter().all(|s| !s.is_empty()), "empty shard in table");
    Ok(shards)
}

/// Eq. 1 partition of a whole network: one shard table per conv layer,
/// every layer split over the *same* device times (the paper partitions
/// each conv with the same calibration).  `layers[i]` is conv layer `i+1`'s
/// `(kernel_count, bucket_ladder)`.  Devices in the returned tables are
/// positional (index into `probe_times`) — callers with a sparse fleet
/// remap them to fleet ids.
pub fn partition_network(
    layers: &[(usize, &[usize])],
    probe_times: &[f64],
) -> Result<Vec<Vec<Shard>>> {
    layers
        .iter()
        .map(|&(k, buckets)| partition_layer(k, probe_times, buckets))
        .collect()
}

/// Predicted *relative* conv time of a partition: every device runs in
/// parallel, each takes `bucket_i * t_i` (bucketed work at that device's
/// speed); the layer finishes when the slowest shard does.  Used by tests to
/// assert Eq. 1 actually balances, by the simulator for what-if splits, and
/// by the adaptive policy to predict the payoff of a re-partition before
/// committing to it.
pub fn bottleneck_cost(shards: &[Shard], probe_times: &[f64]) -> f64 {
    shards
        .iter()
        .map(|s| s.bucket as f64 * probe_times[s.device])
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_matches_paper_example() {
        // Paper §4.1.1: devices finishing in 10s and 20s get performance
        // values [2, 1] -> shares [2/3, 1/3].
        let shares = workload_shares(&[10.0, 20.0]).unwrap();
        assert!((shares[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((shares[1] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn equal_devices_split_equally() {
        let shares = workload_shares(&[5.0; 4]).unwrap();
        for s in shares {
            assert!((s - 0.25).abs() < 1e-12);
        }
        let counts = apportion(100, &[0.25; 4]).unwrap();
        assert_eq!(counts, vec![25; 4]);
    }

    #[test]
    fn apportion_exact_sum_with_awkward_shares() {
        let shares = workload_shares(&[1.0, 2.0, 3.0, 7.0]).unwrap();
        let counts = apportion(50, &shares).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), 50);
        // Fastest device (t=1) must get the most kernels.
        assert!(counts[0] > counts[3]);
    }

    #[test]
    fn partition_covers_layer_without_overlap() {
        let buckets = [4, 8, 12, 16, 20, 24, 28, 32];
        let shards = partition_layer(32, &[1.0, 2.0, 4.0], &buckets).unwrap();
        let mut covered = 0;
        let mut prev_hi = 0;
        for s in &shards {
            assert_eq!(s.lo, prev_hi, "shards must tile contiguously");
            assert!(s.len() <= s.bucket);
            prev_hi = s.hi;
            covered += s.len();
        }
        assert_eq!(covered, 32);
    }

    #[test]
    fn tiny_layer_fewer_kernels_than_devices() {
        let buckets = [1, 2, 3];
        let shards = partition_layer(2, &[1.0, 1.0, 1.0], &buckets).unwrap();
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 2);
        assert!(shards.len() <= 2, "at most 2 non-empty shards for 2 kernels");
    }

    #[test]
    fn degenerate_share_is_dropped_and_redistributed() {
        // Device 2 is 10^4x slower: its Eq. 1 share of 8 kernels rounds to
        // zero, so it must not appear in the table at all — no `[lo, lo)`
        // empty shard, no padded bucket of pure zero-kernel work.
        let buckets = [1, 2, 3, 4, 5, 6, 7, 8];
        let shards = partition_layer(8, &[1.0, 1.0, 1e4], &buckets).unwrap();
        assert!(shards.iter().all(|s| s.device != 2), "zero-share device still scheduled");
        assert!(shards.iter().all(|s| !s.is_empty()), "empty shard in table");
        let covered: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(covered, 8, "dropped share must be redistributed to the survivors");
    }

    #[test]
    fn partition_network_tables_one_per_layer() {
        // A 3-conv network: every layer tiles [0, k) over the same devices.
        let (b1, b2, b3) = (vec![4usize], vec![4usize, 6], vec![4usize, 8]);
        let layers: Vec<(usize, &[usize])> = vec![(4, &b1), (6, &b2), (8, &b3)];
        let times = [1.0, 2.0, 4.0];
        let tables = partition_network(&layers, &times).unwrap();
        assert_eq!(tables.len(), 3);
        for (li, (shards, &(k, _))) in tables.iter().zip(&layers).enumerate() {
            let covered: usize = shards.iter().map(|s| s.len()).sum();
            assert_eq!(covered, k, "layer {} must be fully covered", li + 1);
            let mut prev_hi = 0;
            for s in shards {
                assert_eq!(s.lo, prev_hi, "layer {} tiles contiguously", li + 1);
                prev_hi = s.hi;
            }
        }
        // Fastest device never gets fewer kernels than the slowest.
        for shards in &tables {
            let len_of = |d: usize| shards.iter().find(|s| s.device == d).map_or(0, |s| s.len());
            assert!(len_of(0) >= len_of(2));
        }
    }

    #[test]
    fn zero_kernel_layer_rejected() {
        assert!(partition_layer(0, &[1.0, 1.0], &[1, 2]).is_err());
    }

    #[test]
    fn shard_display_is_readable() {
        let s = Shard { device: 1, lo: 6, hi: 16, bucket: 12 };
        assert_eq!(s.to_string(), "dev1=[6,16)b12");
        let t = [Shard { device: 0, lo: 0, hi: 6, bucket: 8 }, s];
        assert_eq!(ShardTable(&t).to_string(), "dev0=[0,6)b8 dev1=[6,16)b12");
        assert_eq!(ShardTable(&[]).to_string(), "(empty)");
    }

    #[test]
    fn balanced_beats_naive_on_heterogeneous_devices() {
        // Paper §4.1.1's argument: equal split on a 2x-speed pair is slower
        // than the Eq. 1 split.
        let times = [10.0, 20.0];
        let buckets: Vec<usize> = (1..=30).collect();
        let balanced = partition_layer(30, &times, &buckets).unwrap();
        let naive = vec![
            Shard { device: 0, lo: 0, hi: 15, bucket: 15 },
            Shard { device: 1, lo: 15, hi: 30, bucket: 15 },
        ];
        assert!(
            bottleneck_cost(&balanced, &times) < bottleneck_cost(&naive, &times),
            "Eq.1 split must beat equal split"
        );
    }

    #[test]
    fn rejects_bad_probe_times() {
        assert!(workload_shares(&[]).is_err());
        assert!(workload_shares(&[1.0, 0.0]).is_err());
        assert!(workload_shares(&[1.0, f64::NAN]).is_err());
        assert!(workload_shares(&[1.0, -2.0]).is_err());
    }

    #[test]
    fn fit_bucket_picks_smallest_sufficient() {
        assert_eq!(fit_bucket(5, &[4, 8, 16]).unwrap(), 8);
        assert_eq!(fit_bucket(8, &[4, 8, 16]).unwrap(), 8);
        assert!(fit_bucket(17, &[4, 8, 16]).is_err());
    }
}
