//! Feedback-driven re-partitioning policy — the adaptive half of the
//! scheduler.
//!
//! The paper computes the Eq. 1 partition **once**, from a static
//! calibration probe, and assumes device speeds never change.  This module
//! closes the loop: given the *smoothed observed* per-device rates from
//! [`super::telemetry`], [`AdaptivePolicy`] predicts what a fresh Eq. 1
//! partition would cost (the simulator's bottleneck model, applied to the
//! live tables) and orders a re-shard only when the predicted payoff
//! clears a configurable threshold — with hysteresis and a cooldown so
//! bucket changes (and the executable warmups they trigger) stay rare.
//!
//! The policy is deliberately side-effect free: it returns a [`Decision`]
//! and the master (or the simulator in `sim::trajectory`) applies it.
//! That separation is what lets `sim` predict the payoff of adaptation
//! offline with the *same* decision logic the live cluster runs.

use std::time::Duration;

use anyhow::{ensure, Result};

use super::partition::{partition_layer, Shard};

/// Knobs of the adaptive scheduler.  `Default` is the enabled configuration
/// used by `--adaptive` runs; [`AdaptiveConfig::disabled`] is the static
/// paper behavior (and the `SessionBuilder` default).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Master switch: when false the scheduler is the paper's static Eq. 1
    /// partition — no telemetry-driven re-shards, no heartbeats, no gather
    /// deadlines (shard tables and numerics identical to the static path).
    pub enabled: bool,
    /// EWMA weight of the newest timing sample (0 < alpha <= 1).
    pub alpha: f64,
    /// Steps to observe before the policy may order its first re-shard.
    pub warmup_steps: u64,
    /// Re-partition when predicted step-time gain exceeds `1 + threshold`.
    pub imbalance_threshold: f64,
    /// After a trigger, the predicted gain must first fall back below
    /// `1 + threshold - hysteresis` before the policy re-arms — keeps a
    /// gain hovering at the threshold from re-triggering every cooldown.
    pub hysteresis: f64,
    /// Minimum steps between re-partitions.
    pub cooldown_steps: u64,
    /// Straggler flag: EWMA rate beyond `k`·σ above the fleet mean…
    pub straggler_k: f64,
    /// …and beyond this multiple of the fleet median (σ-noise guard).
    pub straggler_min_ratio: f64,
    /// Ping workers every this many steps (0 = no heartbeats).
    pub heartbeat_every: u64,
    /// A worker that does not `Pong` within this window is dropped.
    ///
    /// Deadline caveat (applies to `gather_timeout` too): in-proc links
    /// bound the whole receive; `TcpLink` bounds the wait for the *first
    /// byte* of a frame (the read timeout is cleared once a frame starts,
    /// so the stream never desynchronizes).  A totally silent worker is
    /// therefore detected on every transport; one that trickles a frame
    /// byte-by-byte is only caught over TCP when the socket errors.
    pub heartbeat_timeout: Duration,
    /// Optional per-result deadline during gather: a worker that exceeds it
    /// is dropped and the step retried on the survivors (elastic
    /// membership).  `None` = wait forever, as the static path does.  See
    /// the transport caveat on [`AdaptiveConfig::heartbeat_timeout`].
    pub gather_timeout: Option<Duration>,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            alpha: 0.4,
            warmup_steps: 2,
            imbalance_threshold: 0.25,
            hysteresis: 0.10,
            cooldown_steps: 3,
            straggler_k: 1.0,
            straggler_min_ratio: 2.0,
            heartbeat_every: 8,
            heartbeat_timeout: Duration::from_secs(5),
            gather_timeout: None,
        }
    }
}

impl AdaptiveConfig {
    /// The static paper behavior (the `SessionBuilder` default).
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// One conv layer as the policy sees it: geometry plus the current table.
pub struct LayerPlan<'a> {
    /// Kernels in the layer's K axis.
    pub k: usize,
    /// Compiled shard buckets.
    pub buckets: &'a [usize],
    /// The shard table currently in force.
    pub current: &'a [Shard],
    /// FLOPs of one kernel (forward is fine — constant training factors
    /// scale every layer equally and cancel in the gain ratio's spirit;
    /// what matters is the relative layer weight).
    pub flops_per_kernel: f64,
}

/// What the policy wants done after a step.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    Keep,
    /// New shard tables, one per [`LayerPlan`] in call order, with `device`
    /// already remapped to fleet device ids.
    Repartition(Vec<Vec<Shard>>),
}

/// Predicted cost of a set of shard tables under per-device rates
/// (seconds per FLOP, indexable by `Shard::device`): each layer finishes
/// with its slowest bucketed shard, layers run back to back.
pub fn predicted_cost(tables: &[&[Shard]], plans: &[LayerPlan], rate_of: &[f64]) -> f64 {
    tables
        .iter()
        .zip(plans)
        .map(|(t, p)| {
            t.iter()
                .map(|s| {
                    let r = rate_of.get(s.device).copied().unwrap_or(f64::INFINITY);
                    s.bucket as f64 * p.flops_per_kernel * r
                })
                .fold(0.0, f64::max)
        })
        .sum()
}

/// Per-device utilization of the current tables: the fraction of the
/// predicted step bottleneck each device spends busy.  Aligned with
/// `active`; 1.0 = the device is the bottleneck everywhere, 0.0 = idle.
pub fn utilization(plans: &[LayerPlan], active: &[usize], rates: &[f64]) -> Vec<f64> {
    let mut busy = vec![0.0f64; active.len()];
    let mut denom = 0.0f64;
    for p in plans {
        let mut layer_max = 0.0f64;
        for s in p.current {
            if let Some(pos) = active.iter().position(|&d| d == s.device) {
                let t = s.bucket as f64 * p.flops_per_kernel * rates[pos];
                busy[pos] += t;
                layer_max = layer_max.max(t);
            }
        }
        denom += layer_max;
    }
    if denom <= 0.0 || !denom.is_finite() {
        return vec![0.0; active.len()];
    }
    busy.into_iter().map(|b| (b / denom).clamp(0.0, 1.0)).collect()
}

/// The re-partitioning state machine (threshold + hysteresis + cooldown).
#[derive(Clone, Debug)]
pub struct AdaptivePolicy {
    cfg: AdaptiveConfig,
    last_repartition: Option<u64>,
    armed: bool,
}

impl AdaptivePolicy {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        Self { cfg, last_repartition: None, armed: true }
    }

    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    pub fn last_repartition(&self) -> Option<u64> {
        self.last_repartition
    }

    /// Consult the policy after step `step`.  `active` lists the alive
    /// device ids, `rates` their smoothed seconds-per-GFLOP (same order).
    /// Returns `Keep`, or `Repartition` with fresh Eq. 1 tables computed
    /// over the observed rates, when all of the following hold: the warmup
    /// is over, the cooldown since the last re-shard has elapsed, the
    /// policy is armed (hysteresis) and the predicted gain of the candidate
    /// tables exceeds `1 + imbalance_threshold`.
    pub fn decide(
        &mut self,
        step: u64,
        plans: &[LayerPlan],
        active: &[usize],
        rates: &[f64],
    ) -> Result<Decision> {
        ensure!(active.len() == rates.len(), "active/rates length mismatch");
        if !self.cfg.enabled || active.len() < 2 || step < self.cfg.warmup_steps {
            return Ok(Decision::Keep);
        }
        // Rates indexable by device id (the current tables name devices by
        // fleet id, not by position in `active`).
        let max_dev = active.iter().copied().max().unwrap_or(0);
        let mut by_dev = vec![f64::INFINITY; max_dev + 1];
        for (&d, &r) in active.iter().zip(rates) {
            by_dev[d] = r;
        }
        // Candidate tables: Eq. 1 over the smoothed observed rates.
        let mut candidate: Vec<Vec<Shard>> = Vec::with_capacity(plans.len());
        for p in plans {
            let mut shards = partition_layer(p.k, rates, p.buckets)?;
            for s in &mut shards {
                s.device = active[s.device];
            }
            candidate.push(shards);
        }
        let now: Vec<&[Shard]> = plans.iter().map(|p| p.current).collect();
        let cand: Vec<&[Shard]> = candidate.iter().map(|c| c.as_slice()).collect();
        let cost_now = predicted_cost(&now, plans, &by_dev);
        let cost_new = predicted_cost(&cand, plans, &by_dev);
        if !cost_new.is_finite() || cost_new <= 0.0 {
            return Ok(Decision::Keep);
        }
        // `cost_now` may be +inf (a dead device still in the table): the
        // gain is then +inf and the re-shard fires unconditionally.
        let gain = cost_now / cost_new;
        if gain <= 1.0 + (self.cfg.imbalance_threshold - self.cfg.hysteresis).max(0.0) {
            self.armed = true;
        }
        let cooled = match self.last_repartition {
            None => true,
            Some(at) => step.saturating_sub(at) >= self.cfg.cooldown_steps,
        };
        if self.armed && cooled && gain > 1.0 + self.cfg.imbalance_threshold {
            self.armed = false;
            self.last_repartition = Some(step);
            return Ok(Decision::Repartition(candidate));
        }
        Ok(Decision::Keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FPK1: f64 = 7.5e6;
    const FPK2: f64 = 5.1e6;

    fn table(k: usize, buckets: &[usize], rates: &[f64]) -> Vec<Shard> {
        partition_layer(k, rates, buckets).unwrap()
    }

    fn plans<'a>(
        b1: &'a [usize],
        b2: &'a [usize],
        t1: &'a [Shard],
        t2: &'a [Shard],
    ) -> [LayerPlan<'a>; 2] {
        [
            LayerPlan { k: 16, buckets: b1, current: t1, flops_per_kernel: FPK1 },
            LayerPlan { k: 32, buckets: b2, current: t2, flops_per_kernel: FPK2 },
        ]
    }

    #[test]
    fn keeps_when_balanced() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let rates = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &rates), table(32, &b2, &rates));
        let mut p = AdaptivePolicy::new(AdaptiveConfig { warmup_steps: 0, ..Default::default() });
        let d = p.decide(5, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &rates).unwrap();
        assert_eq!(d, Decision::Keep);
    }

    #[test]
    fn repartitions_on_8x_degradation_then_cools_down() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        let degraded = [1.0, 8.0, 1.0, 1.0];
        let cfg = AdaptiveConfig { warmup_steps: 0, cooldown_steps: 3, ..Default::default() };
        let mut p = AdaptivePolicy::new(cfg);
        let d = p.decide(4, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
        let Decision::Repartition(tables) = d else { panic!("must repartition, got {d:?}") };
        assert_eq!(tables.len(), 2);
        // The slow device's layer-2 shard shrank.
        let old = t2.iter().find(|s| s.device == 1).unwrap().len();
        let new = tables[1].iter().find(|s| s.device == 1).map_or(0, |s| s.len());
        assert!(new < old, "slow device shard must shrink: {old} -> {new}");
        // Applying the candidate leaves nothing to gain: Keep…
        let d2 = p
            .decide(5, &plans(&b1, &b2, &tables[0], &tables[1]), &[0, 1, 2, 3], &degraded)
            .unwrap();
        assert_eq!(d2, Decision::Keep);
        // …and even a *new* imbalance stays parked until the cooldown ends.
        let degraded2 = [1.0, 8.0, 8.0, 1.0];
        let d3 = p
            .decide(6, &plans(&b1, &b2, &tables[0], &tables[1]), &[0, 1, 2, 3], &degraded2)
            .unwrap();
        assert_eq!(d3, Decision::Keep, "cooldown must hold");
        let d4 = p
            .decide(7, &plans(&b1, &b2, &tables[0], &tables[1]), &[0, 1, 2, 3], &degraded2)
            .unwrap();
        assert!(matches!(d4, Decision::Repartition(_)), "cooldown elapsed");
    }

    #[test]
    fn hysteresis_requires_rearm_before_second_trigger() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        let degraded = [1.0, 8.0, 1.0, 1.0];
        let cfg = AdaptiveConfig { warmup_steps: 0, cooldown_steps: 0, ..Default::default() };
        let mut p = AdaptivePolicy::new(cfg);
        let d = p.decide(0, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
        assert!(matches!(d, Decision::Repartition(_)));
        // The master ignores the decision (tables unchanged), so the gain
        // stays above the threshold: disarmed, no second trigger even with
        // a zero cooldown.
        let d2 = p.decide(1, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
        assert_eq!(d2, Decision::Keep, "must stay disarmed while gain is high");
        // Gain returns to ~1 (balance restored): re-arms…
        let d3 = p.decide(2, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &even).unwrap();
        assert_eq!(d3, Decision::Keep);
        // …so the next imbalance triggers again.
        let d4 = p.decide(3, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
        assert!(matches!(d4, Decision::Repartition(_)));
    }

    #[test]
    fn dead_device_in_table_forces_repartition() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        // Device 3 vanished from `active`: its shard cost is +inf.
        let mut p = AdaptivePolicy::new(AdaptiveConfig { warmup_steps: 0, ..Default::default() });
        let d = p.decide(9, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2], &[1.0, 1.0, 1.0]).unwrap();
        let Decision::Repartition(tables) = d else { panic!("must evict the dead device") };
        assert!(tables.iter().flatten().all(|s| s.device != 3));
        assert_eq!(tables[0].iter().map(|s| s.len()).sum::<usize>(), 16);
        assert_eq!(tables[1].iter().map(|s| s.len()).sum::<usize>(), 32);
    }

    #[test]
    fn warmup_blocks_early_decisions() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        let degraded = [1.0, 8.0, 1.0, 1.0];
        let mut p = AdaptivePolicy::new(AdaptiveConfig { warmup_steps: 3, ..Default::default() });
        for step in 0..3 {
            let d = p.decide(step, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
            assert_eq!(d, Decision::Keep, "step {step} is inside the warmup");
        }
        let d = p.decide(3, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded).unwrap();
        assert!(matches!(d, Decision::Repartition(_)));
    }

    #[test]
    fn disabled_policy_always_keeps() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        let mut p = AdaptivePolicy::new(AdaptiveConfig::disabled());
        let d = p
            .decide(100, &plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &[1.0, 50.0, 1.0, 1.0])
            .unwrap();
        assert_eq!(d, Decision::Keep);
    }

    #[test]
    fn utilization_balanced_fleet_is_high_everywhere() {
        let (b1, b2) = (vec![4, 8, 12, 16], vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let even = [1.0, 1.0, 1.0, 1.0];
        let (t1, t2) = (table(16, &b1, &even), table(32, &b2, &even));
        let u = utilization(&plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &even);
        assert_eq!(u.len(), 4);
        assert!(u.iter().all(|&x| (0.99..=1.0).contains(&x)), "balanced util {u:?}");
        // Degrade a device without re-sharding: it becomes the bottleneck
        // (util 1.0) while everyone else idles at the barrier.
        let degraded = [1.0, 8.0, 1.0, 1.0];
        let u2 = utilization(&plans(&b1, &b2, &t1, &t2), &[0, 1, 2, 3], &degraded);
        assert!(u2[1] > 0.99, "straggler busy the whole step: {u2:?}");
        assert!(u2[0] < 0.2, "healthy devices stall at the barrier: {u2:?}");
    }
}
