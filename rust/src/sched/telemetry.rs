//! Per-device timing telemetry for the adaptive scheduler.
//!
//! The master's gather loop sees, for every conv shard it hands out, the
//! pure compute seconds the device reported and the nominal FLOPs of the
//! bucket executable that ran.  Normalizing seconds by FLOPs gives a
//! shard-size-independent *rate* (seconds per GFLOP — the exact analog of
//! the paper's §4.1.1 calibration probe, but measured continuously on the
//! real workload).  [`FleetTelemetry`] keeps an exponentially weighted
//! moving average of that rate per device, plus an EW variance, so the
//! policy in [`super::adaptive`] can re-run Eq. 1 over *smoothed observed*
//! speeds and flag stragglers whose rate drifts away from the fleet.

/// Exponentially weighted mean + variance of a scalar observation stream
/// (West's recurrence: `var <- (1-a)(var + a d^2)` with `d = x - mean`).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    mean: f64,
    var: f64,
    n: u64,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1], got {alpha}");
        Self { alpha, mean: 0.0, var: 0.0, n: 0 }
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.n += 1;
        if self.n == 1 {
            self.mean = x;
            self.var = 0.0;
            return;
        }
        let d = x - self.mean;
        let incr = self.alpha * d;
        self.mean += incr;
        self.var = (1.0 - self.alpha) * (self.var + d * incr);
    }

    /// Smoothed value; `None` before the first observation.
    pub fn value(&self) -> Option<f64> {
        (self.n > 0).then_some(self.mean)
    }

    pub fn std(&self) -> f64 {
        self.var.max(0.0).sqrt()
    }

    pub fn samples(&self) -> u64 {
        self.n
    }
}

/// EWMA rate (seconds per GFLOP) per device; index = device id
/// (0 = master, i+1 = worker i), matching `cluster::master`.
#[derive(Clone, Debug)]
pub struct FleetTelemetry {
    devices: Vec<Ewma>,
}

impl FleetTelemetry {
    pub fn new(n_devices: usize, alpha: f64) -> Self {
        Self { devices: vec![Ewma::new(alpha); n_devices] }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Record one observed execution: `seconds` of pure compute over a
    /// nominal `flops` of work.  Non-positive work or non-finite timings are
    /// ignored (e.g. a dead device's `INFINITY` calibration slot).
    pub fn record(&mut self, device: usize, seconds: f64, flops: f64) {
        let bad = !flops.is_finite() || flops <= 0.0 || !seconds.is_finite() || seconds <= 0.0;
        if device >= self.devices.len() || bad {
            return;
        }
        self.devices[device].observe(seconds / (flops / 1e9));
    }

    /// Smoothed rate of one device in seconds per GFLOP.
    pub fn rate(&self, device: usize) -> Option<f64> {
        self.devices.get(device).and_then(|e| e.value())
    }

    pub fn samples(&self, device: usize) -> u64 {
        self.devices.get(device).map_or(0, |e| e.samples())
    }

    /// Smoothed rates for `devices`, provided every one of them has at
    /// least `min_samples` observations — otherwise `None` (the policy must
    /// not act on speeds it has never measured).
    pub fn rates_for(&self, devices: &[usize], min_samples: u64) -> Option<Vec<f64>> {
        devices
            .iter()
            .map(|&d| {
                let e = self.devices.get(d)?;
                if e.samples() < min_samples || !e.mean.is_finite() {
                    return None;
                }
                Some(e.mean)
            })
            .collect()
    }

    /// Straggler detection: among `devices`, flag those whose EWMA rate
    /// drifts beyond `k`·σ above the fleet mean.  The `min_ratio` guard
    /// (rate must also exceed `min_ratio` × the fleet median) keeps a
    /// homogeneous fleet — where σ is numerically tiny and *everything*
    /// sits within noise of the mean — from flagging healthy devices.
    pub fn stragglers(&self, devices: &[usize], k: f64, min_ratio: f64) -> Vec<usize> {
        let rates: Vec<(usize, f64)> = devices
            .iter()
            .filter_map(|&d| self.rate(d).map(|r| (d, r)))
            .collect();
        if rates.len() < 2 {
            return vec![];
        }
        let n = rates.len() as f64;
        let mean = rates.iter().map(|(_, r)| r).sum::<f64>() / n;
        let var = rates.iter().map(|(_, r)| (r - mean) * (r - mean)).sum::<f64>() / n;
        let sigma = var.sqrt();
        let mut sorted: Vec<f64> = rates.iter().map(|&(_, r)| r).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        rates
            .into_iter()
            .filter(|&(_, r)| r > mean + k * sigma && r > min_ratio * median)
            .map(|(d, _)| d)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_sample_initializes() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), None);
        e.observe(4.0);
        assert_eq!(e.value(), Some(4.0));
        assert_eq!(e.std(), 0.0);
        assert_eq!(e.samples(), 1);
    }

    #[test]
    fn ewma_tracks_a_level_shift() {
        let mut e = Ewma::new(0.5);
        for _ in 0..10 {
            e.observe(1.0);
        }
        assert!((e.value().unwrap() - 1.0).abs() < 1e-12);
        // An 8x jump: the EWMA must cover most of the distance in 3 samples.
        for _ in 0..3 {
            e.observe(8.0);
        }
        let v = e.value().unwrap();
        assert!(v > 6.0 && v < 8.0, "EWMA after shift: {v}");
        assert!(e.std() > 0.0, "variance must register the shift");
    }

    #[test]
    fn ewma_ignores_non_finite() {
        let mut e = Ewma::new(0.3);
        e.observe(2.0);
        e.observe(f64::INFINITY);
        e.observe(f64::NAN);
        assert_eq!(e.samples(), 1);
        assert_eq!(e.value(), Some(2.0));
    }

    #[test]
    fn record_normalizes_by_flops() {
        let mut t = FleetTelemetry::new(2, 1.0);
        // 0.02 s over 2 GFLOP and 0.01 s over 1 GFLOP are the same rate.
        t.record(0, 0.02, 2e9);
        t.record(1, 0.01, 1e9);
        assert!((t.rate(0).unwrap() - 0.01).abs() < 1e-12);
        assert!((t.rate(0).unwrap() - t.rate(1).unwrap()).abs() < 1e-12);
        // Bad observations are dropped, out-of-range devices ignored.
        t.record(0, f64::INFINITY, 1e9);
        t.record(0, 0.01, 0.0);
        t.record(99, 0.01, 1e9);
        assert_eq!(t.samples(0), 1);
    }

    #[test]
    fn rates_for_requires_samples_on_every_device() {
        let mut t = FleetTelemetry::new(3, 0.5);
        t.record(0, 0.01, 1e9);
        t.record(1, 0.02, 1e9);
        assert!(t.rates_for(&[0, 1, 2], 1).is_none(), "device 2 never measured");
        t.record(2, 0.04, 1e9);
        let r = t.rates_for(&[0, 1, 2], 1).unwrap();
        assert_eq!(r.len(), 3);
        assert!(r[2] > r[0]);
        assert!(t.rates_for(&[0, 1, 2], 2).is_none(), "min_samples not reached");
    }

    #[test]
    fn straggler_flagged_homogeneous_fleet_quiet() {
        let mut t = FleetTelemetry::new(4, 0.5);
        for d in 0..4 {
            // Near-identical rates with tiny jitter: nobody is a straggler
            // even though sigma is almost zero (min_ratio guard).
            t.record(d, 0.0100 + d as f64 * 1e-6, 1e9);
        }
        let devs = [0, 1, 2, 3];
        assert!(t.stragglers(&devs, 1.0, 2.0).is_empty());
        // Device 3 degrades 8x: flagged.
        for _ in 0..4 {
            t.record(3, 0.08, 1e9);
        }
        assert_eq!(t.stragglers(&devs, 1.0, 2.0), vec![3]);
    }
}
