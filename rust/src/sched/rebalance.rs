//! Cross-replica batch-share rebalancer (DESIGN.md §14).
//!
//! Inside one fleet, Eq. 1 shards conv *kernels* over heterogeneous devices.
//! Across replica fleets the unit of work is the *batch slice*, and the same
//! logic applies one level up: a replica whose fleet is slower than its
//! peers should train fewer samples per step, or the synchronous all-reduce
//! waits on it every step.  The rebalancer reuses the adaptive tier's EWMA
//! telemetry ([`FleetTelemetry`], one slot per replica, seconds-per-sample)
//! and the Eq. 1 largest-remainder apportionment to propose new slices
//! ∝ observed speed, with a change threshold and a step cooldown so noise
//! does not thrash the (expensive) fleet rebuild a slice change implies.

use super::{apportion, FleetTelemetry};

/// Rebalance knobs (`replica.rebalance_*` in the config schema).
#[derive(Clone, Copy, Debug)]
pub struct RebalanceConfig {
    /// Propose at most every this many steps; `0` disables rebalancing
    /// (the default — slice changes rebuild the affected replica's fleet).
    pub every: u64,
    /// Minimum relative slice change that justifies a rebuild: a proposal is
    /// dropped unless some replica's slice would change by at least
    /// `threshold - 1` of its current value (e.g. `1.25` → a ≥25% shift).
    pub threshold: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        Self { every: 0, threshold: 1.25 }
    }
}

/// Proposes new per-replica batch slices from smoothed step-time telemetry.
pub struct ShareRebalancer {
    cfg: RebalanceConfig,
    telemetry: FleetTelemetry,
    last: u64,
}

impl ShareRebalancer {
    pub fn new(replicas: usize, alpha: f64, cfg: RebalanceConfig) -> Self {
        Self { cfg, telemetry: FleetTelemetry::new(replicas, alpha), last: 0 }
    }

    /// Feed one replica's step wall time.  `samples` is its batch slice, so
    /// the stored rate is seconds per sample — scale-free across replicas
    /// of different slice sizes, which is all apportionment needs.
    pub fn record(&mut self, replica: usize, seconds: f64, samples: usize) {
        // FleetTelemetry normalizes seconds over GFLOPs; feeding samples as
        // "GFLOPs" yields seconds-per-sample rates.  Only ratios matter.
        self.telemetry.record(replica, seconds, samples as f64 * 1e9);
    }

    /// The per-replica EWMA telemetry (rates are seconds per sample).
    pub fn telemetry(&self) -> &FleetTelemetry {
        &self.telemetry
    }

    /// Propose new slices (same sum, each ≥ 1) after `step`, or `None` when
    /// rebalancing is off, on cooldown, under-sampled, or the proposed shift
    /// is below the change threshold.
    pub fn propose(&mut self, step: u64, slices: &[usize]) -> Option<Vec<usize>> {
        let n = slices.len();
        if self.cfg.every == 0 || n < 2 || step < self.last + self.cfg.every {
            return None;
        }
        let replicas: Vec<usize> = (0..n).collect();
        let rates = self.telemetry.rates_for(&replicas, 1)?;
        if rates.iter().any(|r| !r.is_finite() || *r <= 0.0) {
            return None;
        }
        // Due now: start the cooldown whether or not the proposal clears the
        // threshold, so a stable fleet is not re-examined every step.
        self.last = step;
        let speeds: Vec<f64> = rates.iter().map(|r| 1.0 / r).collect();
        let total_speed: f64 = speeds.iter().sum();
        let shares: Vec<f64> = speeds.iter().map(|s| s / total_speed).collect();
        let batch: usize = slices.iter().sum();
        let mut new = apportion(batch, &shares).ok()?;
        // Every replica keeps at least one sample (a zero-sample replica has
        // no gradient and would desync the lockstep parameter state).
        loop {
            let Some(starved) = new.iter().position(|&s| s == 0) else { break };
            let richest = (0..n).max_by_key(|&i| new[i])?;
            if new[richest] <= 1 {
                return None;
            }
            new[richest] -= 1;
            new[starved] += 1;
        }
        let significant = new.iter().zip(slices).any(|(&a, &b)| {
            let (a, b) = (a as f64, b as f64);
            a.max(b) / a.min(b) >= self.cfg.threshold
        });
        if significant && new != slices {
            Some(new)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balancer(every: u64, threshold: f64) -> ShareRebalancer {
        ShareRebalancer::new(2, 0.5, RebalanceConfig { every, threshold })
    }

    #[test]
    fn disabled_and_single_replica_never_propose() {
        let mut r = balancer(0, 1.0);
        r.record(0, 1.0, 8);
        r.record(1, 4.0, 8);
        assert!(r.propose(10, &[8, 8]).is_none(), "every=0 must disable");
        let mut one = ShareRebalancer::new(1, 0.5, RebalanceConfig { every: 1, threshold: 1.0 });
        one.record(0, 1.0, 8);
        assert!(one.propose(10, &[16]).is_none());
    }

    #[test]
    fn slow_replica_loses_share_and_sum_is_preserved() {
        let mut r = balancer(1, 1.1);
        // Replica 1 is 3x slower per sample.
        for _ in 0..4 {
            r.record(0, 1.0, 8);
            r.record(1, 3.0, 8);
        }
        let new = r.propose(5, &[8, 8]).expect("imbalance must trigger");
        assert_eq!(new.iter().sum::<usize>(), 16);
        assert!(new[0] > new[1], "fast replica must gain: {new:?}");
        assert!(new[1] >= 1, "no replica may starve: {new:?}");
    }

    #[test]
    fn cooldown_and_threshold_gate_proposals() {
        let mut r = balancer(10, 1.1);
        for _ in 0..4 {
            r.record(0, 1.0, 8);
            r.record(1, 3.0, 8);
        }
        assert!(r.propose(5, &[8, 8]).is_none(), "inside cooldown window");
        assert!(r.propose(10, &[8, 8]).is_some(), "due at every=10");
        assert!(r.propose(11, &[8, 8]).is_none(), "cooldown restarts");
        // Balanced fleet: proposal exists but is below the 10% threshold.
        let mut even = balancer(1, 1.1);
        for _ in 0..4 {
            even.record(0, 1.0, 8);
            even.record(1, 1.02, 8);
        }
        assert!(even.propose(2, &[8, 8]).is_none(), "near-even rates must not thrash");
    }

    #[test]
    fn extreme_imbalance_still_leaves_one_sample() {
        let mut r = balancer(1, 1.0);
        for _ in 0..4 {
            r.record(0, 0.001, 8);
            r.record(1, 10.0, 8);
        }
        let new = r.propose(2, &[4, 4]).expect("imbalance must trigger");
        assert_eq!(new, vec![7, 1]);
    }
}
