//! Scheduling: Eq. 1 workload partitioning plus the adaptive feedback loop
//! built on top of it.
//!
//! * [`partition`] — the paper's static partitioner: Eq. 1 shares from
//!   probe times, largest-remainder apportionment into contiguous kernel
//!   shards, bucket fitting (DESIGN.md §3).
//! * [`telemetry`] — per-device EWMA timing telemetry fed by the master's
//!   gather loop: seconds-per-GFLOP rates, EW variance, straggler flags.
//! * [`adaptive`] — the re-partitioning policy: predicts the payoff of a
//!   fresh Eq. 1 split over the *smoothed observed* rates and orders a
//!   re-shard behind threshold + hysteresis + cooldown (DESIGN.md §5).
//! * [`rebalance`] — the same idea one level up: cross-replica batch-share
//!   apportionment over per-replica step-time telemetry (DESIGN.md §14).
//!
//! The split keeps policy and mechanism separate: `partition` is pure
//! math, `telemetry` pure measurement, `adaptive` a side-effect-free state
//! machine.  `cluster::master` wires them to the live fleet and
//! `sim::trajectory` runs the identical policy offline for what-if
//! payoff prediction.

mod adaptive;
mod partition;
mod rebalance;
mod telemetry;

pub use adaptive::{
    predicted_cost, utilization, AdaptiveConfig, AdaptivePolicy, Decision, LayerPlan,
};
pub use partition::{
    apportion, bottleneck_cost, fit_bucket, partition_layer, partition_network, workload_shares,
    Shard, ShardTable,
};
pub use rebalance::{RebalanceConfig, ShareRebalancer};
pub use telemetry::{Ewma, FleetTelemetry};
