//! Experiment configuration — JSON in, validated structs out.
//!
//! One file describes a full run: device roster, bandwidth model, trainer
//! knobs.  The CLI, the examples and the figure harness all consume the same
//! struct, so every experiment is replayable from a checked-in config.
//! (JSON rather than TOML: the offline build carries its own JSON parser,
//! `util::json`.)

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Human name for logs/CSV.
    pub name: String,
    pub trainer: TrainerConfig,
    pub cluster: ClusterConfig,
    pub network: NetworkConfig,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainerConfig {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Log every `log_every` steps.
    pub log_every: usize,
    /// Calibration probe repetitions (paper's "quick test").
    pub calib_rounds: u32,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self {
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 42,
            log_every: 10,
            calib_rounds: 3,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Worker count *excluding* the master (the master also convolves —
    /// Algorithm 1 lines 15-17 — so `devices = workers + 1`).
    pub workers: usize,
    /// Device roster: "paper-cpus", "paper-gpus", "highend-cpus",
    /// "highend-gpus", "mobile-gpus", or "uniform".
    pub devices: String,
    /// Throttle real executions to the roster's relative speeds.
    pub throttle: bool,
    /// Worker listen addresses for TCP mode; empty = in-process threads.
    pub worker_addrs: Vec<String>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { workers: 3, devices: "paper-cpus".into(), throttle: false, worker_addrs: vec![] }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Link bandwidth in Mbps (paper measured ~5 Mbps on Wi-Fi).
    pub bandwidth_mbps: f64,
    /// One-way latency in milliseconds.
    pub latency_ms: f64,
    /// Apply the shaping to real links (otherwise links run at native
    /// loopback speed and comm time is measured, not modeled).
    pub shaped: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self { bandwidth_mbps: 5.0, latency_ms: 2.0, shaped: false }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            trainer: TrainerConfig::default(),
            cluster: ClusterConfig::default(),
            network: NetworkConfig::default(),
        }
    }
}

/// Checked field extraction: errors on unknown keys so typos fail loudly.
fn check_keys(v: &Json, allowed: &[&str], section: &str) -> Result<()> {
    for key in v.as_obj()?.keys() {
        ensure!(allowed.contains(&key.as_str()), "unknown key {key:?} in {section}");
    }
    Ok(())
}

impl ExperimentConfig {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing experiment config JSON")?;
        check_keys(&v, &["name", "trainer", "cluster", "network"], "config root")?;
        let mut cfg = ExperimentConfig {
            name: v.get("name")?.as_str()?.to_string(),
            ..Default::default()
        };
        if let Some(t) = v.opt("trainer") {
            check_keys(
                t,
                &["steps", "lr", "momentum", "weight_decay", "seed", "log_every", "calib_rounds"],
                "trainer",
            )?;
            let d = &mut cfg.trainer;
            if let Some(x) = t.opt("steps") {
                d.steps = x.as_usize()?;
            }
            if let Some(x) = t.opt("lr") {
                d.lr = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("momentum") {
                d.momentum = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("weight_decay") {
                d.weight_decay = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("seed") {
                d.seed = x.as_u64()?;
            }
            if let Some(x) = t.opt("log_every") {
                d.log_every = x.as_usize()?.max(1);
            }
            if let Some(x) = t.opt("calib_rounds") {
                d.calib_rounds = x.as_usize()? as u32;
            }
        }
        if let Some(c) = v.opt("cluster") {
            check_keys(c, &["workers", "devices", "throttle", "worker_addrs"], "cluster")?;
            let d = &mut cfg.cluster;
            if let Some(x) = c.opt("workers") {
                d.workers = x.as_usize()?;
            }
            if let Some(x) = c.opt("devices") {
                d.devices = x.as_str()?.to_string();
            }
            if let Some(x) = c.opt("throttle") {
                d.throttle = x.as_bool()?;
            }
            if let Some(x) = c.opt("worker_addrs") {
                d.worker_addrs =
                    x.as_arr()?.iter().map(|a| Ok(a.as_str()?.to_string())).collect::<Result<_>>()?;
            }
        }
        if let Some(n) = v.opt("network") {
            check_keys(n, &["bandwidth_mbps", "latency_ms", "shaped"], "network")?;
            let d = &mut cfg.network;
            if let Some(x) = n.opt("bandwidth_mbps") {
                d.bandwidth_mbps = x.as_f64()?;
            }
            if let Some(x) = n.opt("latency_ms") {
                d.latency_ms = x.as_f64()?;
            }
            if let Some(x) = n.opt("shaped") {
                d.shaped = x.as_bool()?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json_str(&text)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.trainer.steps > 0, "steps must be > 0");
        ensure!(self.trainer.lr > 0.0, "lr must be > 0");
        ensure!(
            (0.0..1.0).contains(&self.trainer.momentum),
            "momentum must be in [0,1), got {}",
            self.trainer.momentum
        );
        ensure!(self.network.bandwidth_mbps > 0.0, "bandwidth must be > 0");
        ensure!(
            self.cluster.worker_addrs.is_empty()
                || self.cluster.worker_addrs.len() == self.cluster.workers,
            "worker_addrs ({}) must match workers ({})",
            self.cluster.worker_addrs.len(),
            self.cluster.workers
        );
        let known =
            ["paper-cpus", "paper-gpus", "highend-cpus", "highend-gpus", "mobile-gpus", "uniform"];
        ensure!(
            known.contains(&self.cluster.devices.as_str()),
            "unknown device roster {:?} (expected one of {known:?})",
            self.cluster.devices
        );
        Ok(())
    }

    /// Resolve the device roster, master first, sized `workers + 1`.
    pub fn device_profiles(&self) -> Vec<crate::devices::DeviceProfile> {
        use crate::devices::*;
        let n = self.cluster.workers + 1;
        let catalog = match self.cluster.devices.as_str() {
            "paper-gpus" => paper_gpus(),
            "highend-cpus" => highend_cpus(),
            "highend-gpus" => highend_gpus(),
            "mobile-gpus" => {
                // §5.4.1: desktop master + mobile workers.
                let mut v = vec![paper_gpus()[0].clone()];
                v.extend(std::iter::repeat(mobile_gpu()).take(self.cluster.workers));
                return v;
            }
            "uniform" => {
                return vec![DeviceProfile::new("uniform", DeviceKind::Cpu, 30.0); n];
            }
            _ => paper_cpus(),
        };
        let mut rng = crate::tensor::Pcg32::seed(self.trainer.seed);
        sample_cluster(&catalog, n, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_json() {
        let cfg = ExperimentConfig::from_json_str(r#"{"name": "quick"}"#).unwrap();
        assert_eq!(cfg.cluster.workers, 3);
        assert_eq!(cfg.network.bandwidth_mbps, 5.0);
        assert_eq!(cfg.trainer.steps, 200);
    }

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{
              "name": "hetero",
              "trainer": {"steps": 50, "lr": 0.1, "seed": 7},
              "cluster": {"workers": 2, "devices": "paper-gpus", "throttle": true},
              "network": {"bandwidth_mbps": 25.0, "shaped": true}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.trainer.steps, 50);
        assert_eq!(cfg.cluster.workers, 2);
        assert!(cfg.cluster.throttle);
        assert!(cfg.network.shaped);
        assert_eq!(cfg.network.bandwidth_mbps, 25.0);
    }

    #[test]
    fn rejects_bad_values_and_typos() {
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "trainer": {"momentum": 1.5}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "cluster": {"devices": "quantum"}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"nmae": "typo"}"#).is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "trainer": {"stepz": 1}}"#
        )
        .is_err());
    }

    #[test]
    fn device_roster_sizes() {
        let mut cfg = ExperimentConfig::from_json_str(r#"{"name": "r"}"#).unwrap();
        cfg.cluster.workers = 7;
        assert_eq!(cfg.device_profiles().len(), 8);
        cfg.cluster.devices = "mobile-gpus".into();
        let profs = cfg.device_profiles();
        assert_eq!(profs.len(), 8);
        assert!(profs[0].gflops > profs[1].gflops * 5.0, "desktop master, mobile workers");
    }

    #[test]
    fn worker_addr_mismatch_rejected() {
        let r = ExperimentConfig::from_json_str(
            r#"{"name": "x", "cluster": {"workers": 2, "worker_addrs": ["a:1"]}}"#,
        );
        assert!(r.is_err());
    }
}
