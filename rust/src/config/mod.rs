//! Experiment configuration — JSON in, validated structs out.
//!
//! One file describes a full run: device roster, bandwidth model, trainer
//! knobs.  The CLI, the examples and the figure harness all consume the same
//! struct, so every experiment is replayable from a checked-in config.
//! (JSON rather than TOML: the offline build carries its own JSON parser,
//! `util::json`.)

use std::path::Path;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::sched::AdaptiveConfig;
use crate::util::json::Json;

/// The `arch` field of an experiment config: which network trains.
#[derive(Clone, Debug, PartialEq)]
pub enum ArchChoice {
    /// A named `ArchSpec::preset` (`"arch": "deep_cifar"`).
    Preset(String),
    /// An inline layer graph (`"arch": {"layers": [...], ...}`), stored in
    /// its canonical `ArchSpec::to_json` form so configs compare and
    /// round-trip structurally.
    Graph(String),
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Human name for logs/CSV.
    pub name: String,
    /// Architecture: preset name or inline graph.  `None` = the artifact
    /// directory decides (a `manifest.json` pins it, else the native
    /// default) — the pre-session behavior, unchanged.
    pub arch: Option<ArchChoice>,
    pub trainer: TrainerConfig,
    pub cluster: ClusterConfig,
    pub network: NetworkConfig,
    /// Adaptive re-partitioning policy (disabled by default — the static
    /// Eq.1 plan from calibration stands for the whole run).
    pub adaptive: AdaptiveConfig,
    /// Observability: serve live Prometheus metrics on this address for the
    /// run's lifetime (`"obs": {"metrics_addr": "127.0.0.1:9184"}`); the CLI
    /// `--metrics-addr` flag overrides it.  `None` = no endpoint.
    pub metrics_addr: Option<String>,
    /// Dynamic-batcher knobs for `convdist serve`
    /// (`"serve": {"max_delay_ms": 5, "max_batch": 4}`).  `None` = the CLI
    /// default: hold requests up to 5 ms and batch up to the largest
    /// `batch_buckets` rung.
    pub serve: Option<ServeConfig>,
    /// Replica tier (DESIGN.md §14): `{"replica": {"count": 2, "allreduce":
    /// "ring"}}` trains N data-parallel fleets with a synchronous gradient
    /// all-reduce.  `None` = the classic single-fleet run.
    pub replica: Option<ReplicaConfig>,
}

/// The `replica` section: how many replica fleets train data-parallel, how
/// their gradients are reduced, and when batch slices rebalance.  The
/// static analyzer (diagnostic C010) rejects degenerate combinations
/// (`count: 0`, a ring of one, slices below the arch's bucket ladder).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaConfig {
    /// Replica fleet count; 1 = single-fleet (the replica tier stays off).
    pub count: usize,
    /// Gradient all-reduce strategy: `"master"` (rooted) or `"ring"`.
    pub allreduce: crate::replica::AllReduce,
    /// All-reduce chunk size in KiB of f32 gradient data per frame.
    pub chunk_kb: usize,
    /// Propose slice rebalances at most every N steps; 0 = off.
    pub rebalance_every: u64,
    /// Minimum max/min slice-change ratio that justifies a fleet rebuild.
    pub rebalance_threshold: f64,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        let r = crate::sched::RebalanceConfig::default();
        Self {
            count: 1,
            allreduce: crate::replica::AllReduce::Master,
            chunk_kb: 256,
            rebalance_every: r.every,
            rebalance_threshold: r.threshold,
        }
    }
}

impl ReplicaConfig {
    /// Lower into the session/replica-tier spec (chunk KiB -> f32 elems).
    pub fn to_spec(&self) -> crate::replica::ReplicaSpec {
        crate::replica::ReplicaSpec {
            count: self.count,
            allreduce: self.allreduce,
            chunk_elems: (self.chunk_kb * 1024 / 4).max(1),
            rebalance: crate::sched::RebalanceConfig {
                every: self.rebalance_every,
                threshold: self.rebalance_threshold,
            },
        }
    }
}

/// The `serve` section: how long the dynamic batcher may hold a request
/// hoping for companions, and the largest batch it may coalesce.  The
/// static analyzer (diagnostic C009) rejects values the arch's
/// `batch_buckets` ladder cannot cover.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Latency budget: a request waits at most this long before its batch
    /// dispatches, full or not.
    pub max_delay_ms: u64,
    /// Coalesce at most this many requests per forward pass.  1 = batcher
    /// off (every request runs alone on the smallest rung).
    pub max_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { max_delay_ms: 5, max_batch: 1 }
    }
}

impl ServeConfig {
    /// The CLI default when the config has no `serve` section: batch up to
    /// the largest rung of the (ascending) batch ladder.
    pub fn for_ladder(rungs: &[usize]) -> Self {
        Self { max_delay_ms: 5, max_batch: rungs.last().copied().unwrap_or(1) }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainerConfig {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Log every `log_every` steps.
    pub log_every: usize,
    /// Calibration probe repetitions (paper's "quick test").
    pub calib_rounds: u32,
    /// Auto-checkpoint every N steps during `Session::run` (to the
    /// session's checkpoint dir, emitting `Event::CheckpointSaved`).
    /// `None` = no periodic checkpoints.  The static analyzer rejects 0
    /// and values >= `steps` (diagnostic C008).
    pub checkpoint_every: Option<usize>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self {
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 42,
            log_every: 10,
            calib_rounds: 3,
            checkpoint_every: None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Worker count *excluding* the master (the master also convolves —
    /// Algorithm 1 lines 15-17 — so `devices = workers + 1`).
    pub workers: usize,
    /// Device roster: "paper-cpus", "paper-gpus", "highend-cpus",
    /// "highend-gpus", "mobile-gpus", or "uniform".
    pub devices: String,
    /// Throttle real executions to the roster's relative speeds.
    pub throttle: bool,
    /// Worker listen addresses for TCP mode; empty = in-process threads.
    pub worker_addrs: Vec<String>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { workers: 3, devices: "paper-cpus".into(), throttle: false, worker_addrs: vec![] }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Link bandwidth in Mbps (paper measured ~5 Mbps on Wi-Fi).
    pub bandwidth_mbps: f64,
    /// One-way latency in milliseconds.
    pub latency_ms: f64,
    /// Apply the shaping to real links (otherwise links run at native
    /// loopback speed and comm time is measured, not modeled).
    pub shaped: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self { bandwidth_mbps: 5.0, latency_ms: 2.0, shaped: false }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            arch: None,
            trainer: TrainerConfig::default(),
            cluster: ClusterConfig::default(),
            network: NetworkConfig::default(),
            adaptive: AdaptiveConfig::disabled(),
            metrics_addr: None,
            serve: None,
            replica: None,
        }
    }
}

/// Checked field extraction: errors on unknown keys so typos fail loudly.
fn check_keys(v: &Json, allowed: &[&str], section: &str) -> Result<()> {
    for key in v.as_obj()?.keys() {
        ensure!(allowed.contains(&key.as_str()), "unknown key {key:?} in {section}");
    }
    Ok(())
}

impl ExperimentConfig {
    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing experiment config JSON")?;
        check_keys(
            &v,
            &["name", "arch", "trainer", "cluster", "network", "adaptive", "obs", "serve", "replica"],
            "config root",
        )?;
        let mut cfg = ExperimentConfig {
            name: v.get("name")?.as_str()?.to_string(),
            ..Default::default()
        };
        if let Some(a) = v.opt("arch") {
            cfg.arch = Some(match a {
                Json::Str(name) => ArchChoice::Preset(name.clone()),
                Json::Obj(_) => {
                    // Parse eagerly so a malformed inline graph fails at
                    // config load, then keep the canonical serialization.
                    let spec = crate::runtime::ArchSpec::from_json(a)
                        .context("parsing inline arch graph in config")?;
                    ArchChoice::Graph(spec.to_json())
                }
                other => anyhow::bail!(
                    "arch must be a preset name or a graph object, got {other:?}"
                ),
            });
        }
        if let Some(t) = v.opt("trainer") {
            check_keys(
                t,
                &[
                    "steps",
                    "lr",
                    "momentum",
                    "weight_decay",
                    "seed",
                    "log_every",
                    "calib_rounds",
                    "checkpoint_every",
                ],
                "trainer",
            )?;
            let d = &mut cfg.trainer;
            if let Some(x) = t.opt("steps") {
                d.steps = x.as_usize()?;
            }
            if let Some(x) = t.opt("lr") {
                d.lr = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("momentum") {
                d.momentum = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("weight_decay") {
                d.weight_decay = x.as_f64()? as f32;
            }
            if let Some(x) = t.opt("seed") {
                d.seed = x.as_u64()?;
            }
            if let Some(x) = t.opt("log_every") {
                d.log_every = x.as_usize()?.max(1);
            }
            if let Some(x) = t.opt("calib_rounds") {
                d.calib_rounds = x.as_usize()? as u32;
            }
            if let Some(x) = t.opt("checkpoint_every") {
                d.checkpoint_every = match x {
                    Json::Null => None,
                    x => Some(x.as_usize()?),
                };
            }
        }
        if let Some(c) = v.opt("cluster") {
            check_keys(c, &["workers", "devices", "throttle", "worker_addrs"], "cluster")?;
            let d = &mut cfg.cluster;
            if let Some(x) = c.opt("workers") {
                d.workers = x.as_usize()?;
            }
            if let Some(x) = c.opt("devices") {
                d.devices = x.as_str()?.to_string();
            }
            if let Some(x) = c.opt("throttle") {
                d.throttle = x.as_bool()?;
            }
            if let Some(x) = c.opt("worker_addrs") {
                d.worker_addrs =
                    x.as_arr()?.iter().map(|a| Ok(a.as_str()?.to_string())).collect::<Result<_>>()?;
            }
        }
        if let Some(n) = v.opt("network") {
            check_keys(n, &["bandwidth_mbps", "latency_ms", "shaped"], "network")?;
            let d = &mut cfg.network;
            if let Some(x) = n.opt("bandwidth_mbps") {
                d.bandwidth_mbps = x.as_f64()?;
            }
            if let Some(x) = n.opt("latency_ms") {
                d.latency_ms = x.as_f64()?;
            }
            if let Some(x) = n.opt("shaped") {
                d.shaped = x.as_bool()?;
            }
        }
        if let Some(a) = v.opt("adaptive") {
            check_keys(
                a,
                &[
                    "enabled",
                    "alpha",
                    "warmup_steps",
                    "imbalance_threshold",
                    "hysteresis",
                    "cooldown_steps",
                    "straggler_k",
                    "straggler_min_ratio",
                    "heartbeat_every",
                    "heartbeat_timeout_ms",
                    "gather_timeout_ms",
                ],
                "adaptive",
            )?;
            let ms = |x: &Json| -> Result<Duration> {
                let ms = x.as_f64()?;
                ensure!(ms >= 0.0 && ms.is_finite(), "timeout must be >= 0 ms, got {ms}");
                Ok(Duration::from_secs_f64(ms / 1e3))
            };
            let d = &mut cfg.adaptive;
            if let Some(x) = a.opt("enabled") {
                d.enabled = x.as_bool()?;
            }
            if let Some(x) = a.opt("alpha") {
                d.alpha = x.as_f64()?;
            }
            if let Some(x) = a.opt("warmup_steps") {
                d.warmup_steps = x.as_u64()?;
            }
            if let Some(x) = a.opt("imbalance_threshold") {
                d.imbalance_threshold = x.as_f64()?;
            }
            if let Some(x) = a.opt("hysteresis") {
                d.hysteresis = x.as_f64()?;
            }
            if let Some(x) = a.opt("cooldown_steps") {
                d.cooldown_steps = x.as_u64()?;
            }
            if let Some(x) = a.opt("straggler_k") {
                d.straggler_k = x.as_f64()?;
            }
            if let Some(x) = a.opt("straggler_min_ratio") {
                d.straggler_min_ratio = x.as_f64()?;
            }
            if let Some(x) = a.opt("heartbeat_every") {
                d.heartbeat_every = x.as_u64()?;
            }
            if let Some(x) = a.opt("heartbeat_timeout_ms") {
                d.heartbeat_timeout = ms(x)?;
            }
            if let Some(x) = a.opt("gather_timeout_ms") {
                d.gather_timeout = match x {
                    Json::Null => None,
                    x => Some(ms(x)?),
                };
            }
        }
        if let Some(o) = v.opt("obs") {
            check_keys(o, &["metrics_addr"], "obs")?;
            if let Some(x) = o.opt("metrics_addr") {
                cfg.metrics_addr = match x {
                    Json::Null => None,
                    x => Some(x.as_str()?.to_string()),
                };
            }
        }
        if let Some(s) = v.opt("serve") {
            check_keys(s, &["max_delay_ms", "max_batch"], "serve")?;
            let mut d = ServeConfig::default();
            if let Some(x) = s.opt("max_delay_ms") {
                d.max_delay_ms = x.as_u64()?;
            }
            if let Some(x) = s.opt("max_batch") {
                d.max_batch = x.as_usize()?;
            }
            cfg.serve = Some(d);
        }
        if let Some(r) = v.opt("replica") {
            check_keys(
                r,
                &["count", "allreduce", "chunk_kb", "rebalance_every", "rebalance_threshold"],
                "replica",
            )?;
            let mut d = ReplicaConfig::default();
            if let Some(x) = r.opt("count") {
                d.count = x.as_usize()?;
            }
            if let Some(x) = r.opt("allreduce") {
                d.allreduce = crate::replica::AllReduce::parse(x.as_str()?)?;
            }
            if let Some(x) = r.opt("chunk_kb") {
                d.chunk_kb = x.as_usize()?;
            }
            if let Some(x) = r.opt("rebalance_every") {
                d.rebalance_every = x.as_u64()?;
            }
            if let Some(x) = r.opt("rebalance_threshold") {
                d.rebalance_threshold = x.as_f64()?;
            }
            cfg.replica = Some(d);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json_str(&text)
    }

    /// Serialize — the inverse of [`ExperimentConfig::from_json_str`].  An
    /// `ExperimentConfig` is the on-disk form of a `SessionBuilder`, so a
    /// composed run can be written out and replayed with
    /// `convdist run --config`.
    pub fn to_json_string(&self) -> String {
        // Full JSON string escape (control characters included), so any
        // name/roster/address survives the write -> parse round trip.
        let esc = |s: &str| -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        };
        let arch = match &self.arch {
            None => String::new(),
            Some(ArchChoice::Preset(name)) => format!("\n  \"arch\": \"{}\",", esc(name)),
            Some(ArchChoice::Graph(json)) => format!("\n  \"arch\": {json},"),
        };
        let t = &self.trainer;
        let c = &self.cluster;
        let n = &self.network;
        let addrs: Vec<String> = c.worker_addrs.iter().map(|a| format!("\"{}\"", esc(a))).collect();
        // Millisecond timeouts: f64 `{}` is shortest-round-trip, so the
        // value survives write -> parse exactly.
        let ad = &self.adaptive;
        let gather_ms = match ad.gather_timeout {
            None => "null".to_string(),
            Some(d) => format!("{}", d.as_secs_f64() * 1e3),
        };
        let adaptive = format!(
            "\n  \"adaptive\": {{\"enabled\": {}, \"alpha\": {}, \"warmup_steps\": {}, \
             \"imbalance_threshold\": {}, \"hysteresis\": {}, \"cooldown_steps\": {}, \
             \"straggler_k\": {}, \"straggler_min_ratio\": {}, \"heartbeat_every\": {}, \
             \"heartbeat_timeout_ms\": {}, \"gather_timeout_ms\": {gather_ms}}},",
            ad.enabled,
            ad.alpha,
            ad.warmup_steps,
            ad.imbalance_threshold,
            ad.hysteresis,
            ad.cooldown_steps,
            ad.straggler_k,
            ad.straggler_min_ratio,
            ad.heartbeat_every,
            ad.heartbeat_timeout.as_secs_f64() * 1e3,
        );
        // Absent when None so older configs compare and round-trip exactly.
        let ckpt = match t.checkpoint_every {
            None => String::new(),
            Some(n) => format!(", \"checkpoint_every\": {n}"),
        };
        let obs = match &self.metrics_addr {
            None => String::new(),
            Some(addr) => format!(",\n  \"obs\": {{\"metrics_addr\": \"{}\"}}", esc(addr)),
        };
        let serve = match &self.serve {
            None => String::new(),
            Some(s) => format!(
                ",\n  \"serve\": {{\"max_delay_ms\": {}, \"max_batch\": {}}}",
                s.max_delay_ms, s.max_batch
            ),
        };
        let replica = match &self.replica {
            None => String::new(),
            Some(r) => format!(
                ",\n  \"replica\": {{\"count\": {}, \"allreduce\": \"{}\", \"chunk_kb\": {}, \
                 \"rebalance_every\": {}, \"rebalance_threshold\": {}}}",
                r.count,
                r.allreduce.name(),
                r.chunk_kb,
                r.rebalance_every,
                r.rebalance_threshold
            ),
        };
        format!(
            "{{\n  \"name\": \"{}\",{arch}{adaptive}\n  \"trainer\": {{\"steps\": {}, \"lr\": {}, \
             \"momentum\": {}, \"weight_decay\": {}, \"seed\": {}, \"log_every\": {}, \
             \"calib_rounds\": {}{ckpt}}},\n  \"cluster\": {{\"workers\": {}, \"devices\": \"{}\", \
             \"throttle\": {}, \"worker_addrs\": [{}]}},\n  \"network\": {{\"bandwidth_mbps\": {}, \
             \"latency_ms\": {}, \"shaped\": {}}}{obs}{serve}{replica}\n}}",
            esc(&self.name),
            t.steps,
            t.lr,
            t.momentum,
            t.weight_decay,
            t.seed,
            t.log_every,
            t.calib_rounds,
            c.workers,
            esc(&c.devices),
            c.throttle,
            addrs.join(", "),
            n.bandwidth_mbps,
            n.latency_ms,
            n.shaped
        )
    }

    pub fn validate(&self) -> Result<()> {
        match &self.arch {
            Some(ArchChoice::Preset(name)) => ensure!(
                crate::runtime::ArchSpec::preset(name).is_some(),
                "unknown arch preset {name:?} (try: default, tiny, deep_cifar, tiny_deep)"
            ),
            Some(ArchChoice::Graph(json)) => {
                crate::runtime::ArchSpec::from_json_str(json)
                    .context("validating inline arch graph")?;
            }
            None => {}
        }
        ensure!(self.trainer.steps > 0, "steps must be > 0");
        ensure!(self.trainer.lr > 0.0, "lr must be > 0");
        ensure!(
            (0.0..1.0).contains(&self.trainer.momentum),
            "momentum must be in [0,1), got {}",
            self.trainer.momentum
        );
        ensure!(self.network.bandwidth_mbps > 0.0, "bandwidth must be > 0");
        ensure!(
            self.cluster.worker_addrs.is_empty()
                || self.cluster.worker_addrs.len() == self.cluster.workers,
            "worker_addrs ({}) must match workers ({})",
            self.cluster.worker_addrs.len(),
            self.cluster.workers
        );
        let known =
            ["paper-cpus", "paper-gpus", "highend-cpus", "highend-gpus", "mobile-gpus", "uniform"];
        ensure!(
            known.contains(&self.cluster.devices.as_str()),
            "unknown device roster {:?} (expected one of {known:?})",
            self.cluster.devices
        );
        let a = &self.adaptive;
        ensure!(
            a.alpha > 0.0 && a.alpha <= 1.0,
            "adaptive.alpha must be in (0, 1], got {}",
            a.alpha
        );
        ensure!(
            a.imbalance_threshold >= 0.0 && a.imbalance_threshold.is_finite(),
            "adaptive.imbalance_threshold must be >= 0, got {}",
            a.imbalance_threshold
        );
        ensure!(
            a.hysteresis >= 0.0 && a.hysteresis.is_finite(),
            "adaptive.hysteresis must be >= 0, got {}",
            a.hysteresis
        );
        ensure!(
            a.straggler_k >= 0.0 && a.straggler_min_ratio >= 1.0,
            "adaptive straggler knobs out of range: straggler_k {} (>= 0), \
             straggler_min_ratio {} (>= 1)",
            a.straggler_k,
            a.straggler_min_ratio
        );
        Ok(())
    }

    /// Resolve the device roster, master first, sized `workers + 1`.
    pub fn device_profiles(&self) -> Vec<crate::devices::DeviceProfile> {
        use crate::devices::*;
        let n = self.cluster.workers + 1;
        let catalog = match self.cluster.devices.as_str() {
            "paper-gpus" => paper_gpus(),
            "highend-cpus" => highend_cpus(),
            "highend-gpus" => highend_gpus(),
            "mobile-gpus" => {
                // §5.4.1: desktop master + mobile workers.
                let mut v = vec![paper_gpus()[0].clone()];
                v.extend(std::iter::repeat(mobile_gpu()).take(self.cluster.workers));
                return v;
            }
            "uniform" => {
                return vec![DeviceProfile::new("uniform", DeviceKind::Cpu, 30.0); n];
            }
            _ => paper_cpus(),
        };
        let mut rng = crate::tensor::Pcg32::seed(self.trainer.seed);
        sample_cluster(&catalog, n, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_json() {
        let cfg = ExperimentConfig::from_json_str(r#"{"name": "quick"}"#).unwrap();
        assert_eq!(cfg.cluster.workers, 3);
        assert_eq!(cfg.network.bandwidth_mbps, 5.0);
        assert_eq!(cfg.trainer.steps, 200);
    }

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{
              "name": "hetero",
              "trainer": {"steps": 50, "lr": 0.1, "seed": 7},
              "cluster": {"workers": 2, "devices": "paper-gpus", "throttle": true},
              "network": {"bandwidth_mbps": 25.0, "shaped": true}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.trainer.steps, 50);
        assert_eq!(cfg.cluster.workers, 2);
        assert!(cfg.cluster.throttle);
        assert!(cfg.network.shaped);
        assert_eq!(cfg.network.bandwidth_mbps, 25.0);
    }

    #[test]
    fn rejects_bad_values_and_typos() {
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "trainer": {"momentum": 1.5}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "cluster": {"devices": "quantum"}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"nmae": "typo"}"#).is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "bad", "trainer": {"stepz": 1}}"#
        )
        .is_err());
    }

    #[test]
    fn device_roster_sizes() {
        let mut cfg = ExperimentConfig::from_json_str(r#"{"name": "r"}"#).unwrap();
        cfg.cluster.workers = 7;
        assert_eq!(cfg.device_profiles().len(), 8);
        cfg.cluster.devices = "mobile-gpus".into();
        let profs = cfg.device_profiles();
        assert_eq!(profs.len(), 8);
        assert!(profs[0].gflops > profs[1].gflops * 5.0, "desktop master, mobile workers");
    }

    #[test]
    fn arch_field_preset_and_inline_graph() {
        let cfg = ExperimentConfig::from_json_str(r#"{"name": "p", "arch": "deep_cifar"}"#)
            .unwrap();
        assert_eq!(cfg.arch, Some(ArchChoice::Preset("deep_cifar".into())));

        let inline = crate::runtime::ArchSpec::tiny().to_json();
        let cfg =
            ExperimentConfig::from_json_str(&format!(r#"{{"name": "g", "arch": {inline}}}"#))
                .unwrap();
        let Some(ArchChoice::Graph(json)) = &cfg.arch else {
            panic!("expected inline graph, got {:?}", cfg.arch)
        };
        let spec = crate::runtime::ArchSpec::from_json_str(json).unwrap();
        assert_eq!(spec.label(), "4:8");

        // Unknown preset, malformed graph, wrong JSON type: all loud.
        assert!(ExperimentConfig::from_json_str(r#"{"name": "x", "arch": "quantum"}"#).is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"name": "x", "arch": {"layers": []}}"#)
            .is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"name": "x", "arch": 7}"#).is_err());
    }

    #[test]
    fn config_roundtrips_through_serialization() {
        // No arch, preset arch, inline-graph arch: parse(to_json(x)) == x.
        let mut cfg = ExperimentConfig::from_json_str(
            r#"{
              "name": "rt",
              "trainer": {"steps": 7, "lr": 0.125, "seed": 9},
              "cluster": {"workers": 2, "devices": "uniform", "throttle": true},
              "network": {"bandwidth_mbps": 25.0, "shaped": true}
            }"#,
        )
        .unwrap();
        for arch in [
            None,
            Some(ArchChoice::Preset("tiny".into())),
            Some(ArchChoice::Graph(crate::runtime::ArchSpec::tiny_deep().to_json())),
        ] {
            cfg.arch = arch;
            let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
            assert_eq!(back, cfg);
        }
        // TCP addresses survive too.
        cfg.cluster.worker_addrs = vec!["a:1".into(), "b:2".into()];
        cfg.cluster.workers = 2;
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
        // checkpoint_every survives (and is absent from JSON when None).
        assert!(!cfg.to_json_string().contains("checkpoint_every"));
        cfg.trainer.checkpoint_every = Some(3);
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
        // metrics_addr survives (and the obs section is absent when None).
        assert!(!cfg.to_json_string().contains("\"obs\""));
        cfg.metrics_addr = Some("127.0.0.1:9184".into());
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
        // serve section survives (and is absent when None).
        assert!(!cfg.to_json_string().contains("\"serve\""));
        cfg.serve = Some(ServeConfig { max_delay_ms: 7, max_batch: 4 });
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
        // replica section survives (and is absent when None).
        assert!(!cfg.to_json_string().contains("\"replica\""));
        cfg.replica = Some(ReplicaConfig {
            count: 4,
            allreduce: crate::replica::AllReduce::Ring,
            chunk_kb: 64,
            rebalance_every: 8,
            rebalance_threshold: 1.5,
        });
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
        // And hostile strings: quotes, backslashes, control characters.
        cfg.name = "we\"ird\\name\nwith\tctrl\u{1}".into();
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn adaptive_section_parses_and_roundtrips() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{
              "name": "ad",
              "adaptive": {"enabled": true, "alpha": 0.5, "warmup_steps": 4,
                           "imbalance_threshold": 0.3, "hysteresis": 0.05,
                           "cooldown_steps": 6, "heartbeat_every": 16,
                           "heartbeat_timeout_ms": 2500, "gather_timeout_ms": 250}
            }"#,
        )
        .unwrap();
        assert!(cfg.adaptive.enabled);
        assert_eq!(cfg.adaptive.warmup_steps, 4);
        assert_eq!(cfg.adaptive.heartbeat_timeout, Duration::from_millis(2500));
        assert_eq!(cfg.adaptive.gather_timeout, Some(Duration::from_millis(250)));
        let back = ExperimentConfig::from_json_str(&cfg.to_json_string()).unwrap();
        assert_eq!(back, cfg);

        // `null` means "wait forever"; bad knobs and typoed keys are loud.
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "x", "adaptive": {"gather_timeout_ms": null}}"#,
        )
        .unwrap();
        assert_eq!(cfg.adaptive.gather_timeout, None);
        assert!(
            ExperimentConfig::from_json_str(r#"{"name": "x", "adaptive": {"alpha": 0.0}}"#)
                .is_err()
        );
        assert!(
            ExperimentConfig::from_json_str(r#"{"name": "x", "adaptive": {"warmup": 1}}"#)
                .is_err()
        );
    }

    #[test]
    fn checkpoint_every_parses_and_null_means_none() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "c", "trainer": {"steps": 10, "checkpoint_every": 4}}"#,
        )
        .unwrap();
        assert_eq!(cfg.trainer.checkpoint_every, Some(4));
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "c", "trainer": {"checkpoint_every": null}}"#,
        )
        .unwrap();
        assert_eq!(cfg.trainer.checkpoint_every, None);
        // Out-of-range values parse here; the static analyzer (C008) is the
        // gate that refuses to run them.
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "c", "trainer": {"checkpoint_every": 0}}"#
        )
        .is_ok());
    }

    #[test]
    fn obs_section_parses_and_null_means_none() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "o", "obs": {"metrics_addr": "0.0.0.0:9184"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.metrics_addr.as_deref(), Some("0.0.0.0:9184"));
        let cfg =
            ExperimentConfig::from_json_str(r#"{"name": "o", "obs": {"metrics_addr": null}}"#)
                .unwrap();
        assert_eq!(cfg.metrics_addr, None);
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "o", "obs": {"metrics_adr": "x"}}"#
        )
        .is_err());
    }

    #[test]
    fn serve_section_parses_with_defaults_and_rejects_typos() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "s", "serve": {"max_delay_ms": 10, "max_batch": 8}}"#,
        )
        .unwrap();
        assert_eq!(cfg.serve, Some(ServeConfig { max_delay_ms: 10, max_batch: 8 }));
        // Partial section: the other knob takes its default.
        let cfg =
            ExperimentConfig::from_json_str(r#"{"name": "s", "serve": {"max_batch": 2}}"#)
                .unwrap();
        assert_eq!(cfg.serve, Some(ServeConfig { max_delay_ms: 5, max_batch: 2 }));
        // No section at all: None (the CLI derives a ladder-aware default).
        let cfg = ExperimentConfig::from_json_str(r#"{"name": "s"}"#).unwrap();
        assert_eq!(cfg.serve, None);
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "s", "serve": {"max_bacth": 2}}"#
        )
        .is_err());
        // Out-of-ladder values parse here; the static analyzer (C009) is the
        // gate that refuses to serve them.
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "s", "serve": {"max_batch": 0}}"#
        )
        .is_ok());
    }

    #[test]
    fn replica_section_parses_with_defaults_and_rejects_bad_input() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"name": "r", "replica": {"count": 2, "allreduce": "ring"}}"#,
        )
        .unwrap();
        let r = cfg.replica.unwrap();
        assert_eq!(r.count, 2);
        assert_eq!(r.allreduce, crate::replica::AllReduce::Ring);
        assert_eq!(r.chunk_kb, 256, "unset knobs take defaults");
        assert_eq!(r.rebalance_every, 0);
        let spec = r.to_spec();
        assert_eq!(spec.chunk_elems, 256 * 1024 / 4);
        // No section at all: None (single-fleet path).
        let cfg = ExperimentConfig::from_json_str(r#"{"name": "r"}"#).unwrap();
        assert_eq!(cfg.replica, None);
        // Unknown strategy and typoed keys are loud.
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "r", "replica": {"allreduce": "tree"}}"#
        )
        .is_err());
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "r", "replica": {"cnt": 2}}"#
        )
        .is_err());
        // Degenerate counts parse here; the static analyzer (C010) is the
        // gate that refuses to run them.
        assert!(ExperimentConfig::from_json_str(
            r#"{"name": "r", "replica": {"count": 0}}"#
        )
        .is_ok());
    }

    #[test]
    fn worker_addr_mismatch_rejected() {
        let r = ExperimentConfig::from_json_str(
            r#"{"name": "x", "cluster": {"workers": 2, "worker_addrs": ["a:1"]}}"#,
        );
        assert!(r.is_err());
    }
}
