//! # convdist
//!
//! A production-grade reproduction of *"Distributed learning of CNNs on
//! heterogeneous CPU/GPU architectures"* (Marques, Falcão, Alexandre, 2017):
//! model-parallel CNN training where **only the convolutional layers are
//! distributed**, each device receiving the same inputs but a kernel shard
//! proportional to its calibrated speed (Eq. 1 of the paper).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — master/worker coordination, calibration,
//!   Eq. 1 workload partitioning, wire protocol, transports (in-proc, TCP,
//!   bandwidth-shaped), SGD, data pipeline, analytic scalability simulator,
//!   and the data-parallel baseline.  Run composition goes through the
//!   unified [`session`] API: one `SessionBuilder` picks arch source ×
//!   topology × scheduling, observes via events, and checkpoints/resumes
//!   (DESIGN.md §9).
//! * **L2** — the executable contract ([`runtime`]): a typed layer graph
//!   ([`runtime::ArchSpec`], DESIGN.md §8) from which shape inference
//!   derives the named segments of the CNN (per-conv kernel shards, the
//!   master-resident mid segments, a generic FC head, fused full-network
//!   grad), validated against a manifest and served by a pluggable
//!   `Backend`.
//! * **L1** — the convolution/pool/LRN/FC kernels, the paper's 60–90 % hot
//!   spot.  Default: pure-rust CPU kernels ([`kernels`]), rayon-parallel
//!   over the batch axis, with every GEMM served by the blocked, packed,
//!   SIMD-dispatched engine in [`linalg`] — a clean checkout builds and
//!   trains offline with no artifacts.  Optional (`--features pjrt`): the
//!   original AOT-HLO PJRT path over `python/compile/` artifacts.

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod data;
pub mod devices;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod proto;
pub mod replica;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod session;
pub mod sim;
pub mod tensor;
pub mod util;

/// Default artifact directory, relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifact directory: `$CONVDIST_ARTIFACTS` or ./artifacts,
/// walking up from the current directory (so tests/benches work from any
/// cargo working dir).
///
/// With the default native backend no `manifest.json` is required: if the
/// walk finds none, the fallback `./artifacts` path is returned and
/// `runtime::Runtime::open` synthesizes a manifest from
/// [`runtime::ArchSpec::native_default`].  A `manifest.json`, when present,
/// still wins — it pins the architecture (and feeds the `pjrt` backend).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("CONVDIST_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
