//! `convdist serve` — forward-only inference over the distributed fleet.
//!
//! The paper's Eq. 1 argument (conv layers dominate and shard cleanly by
//! kernel range) applies unchanged to inference: a [`ForwardEngine`] runs
//! the same scatter/convolve/gather loop as `DistTrainer::dist_conv_fwd`,
//! but with no gradients, no optimizer state and no labels — the head runs
//! the `head_logits_n{B}` executable instead of `head_grad`.
//!
//! Serving traffic arrives one image at a time, so a [`ServeServer`] fronts
//! the engine with a **dynamic batcher**: concurrent client requests are
//! coalesced up to a latency budget ([`ServeConfig::max_delay_ms`] /
//! [`ServeConfig::max_batch`]), the arch's `batch_buckets` ladder picks the
//! padded batch shape (exactly the bucket trick the kernel dimension already
//! uses), partial batches are zero-padded, and logits rows are de-multiplexed
//! back per request.  Zero-padding is exact: every image's logits row is
//! independent of the other rows, so a padded batch is bit-identical to the
//! unpadded forward pass (the equivalence test pins this).
//!
//! Wire protocol (the existing `net` framing):
//! `InferRequest { id, image[C,H,W] }` -> `InferReply { id, logits[classes] }`,
//! plus `Drain` for a graceful shutdown: stop accepting, answer everything
//! queued, tell the fleet `TrainOver`.

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cluster::PROTO_VERSION;
use crate::config::ServeConfig;
use crate::model::Params;
use crate::net::{Link, TcpLink};
use crate::obs::{ObsHandle, SpanCat, SpanRec};
use crate::proto::{Message, WireTensor};
use crate::runtime::{ArchSpec, ConvDir, Manifest, Runtime};
use crate::sched::{partition_network, Shard};
use crate::session::Checkpoint;
use crate::tensor::{Pcg32, Tensor, Value};

// ---------------------------------------------------------------------------
// Checkpoint -> Params (the model-artifact load path)
// ---------------------------------------------------------------------------

/// Materialize the parameter set a checkpoint carries, validated against the
/// serving architecture.  Every failure names the checkpoint source and the
/// expected-vs-found shapes — a serve deployment must never panic on a stale
/// or foreign artifact.
pub fn params_from_checkpoint(
    arch: &ArchSpec,
    ckpt: &Checkpoint,
    source: &str,
) -> Result<Params> {
    let label = arch.label();
    ensure!(
        ckpt.arch_label == label,
        "checkpoint {source} is for arch {} but the server runs {label}",
        ckpt.arch_label
    );
    // Seed is irrelevant: every tensor is overwritten below; init only
    // builds the manifest-ordered name/shape skeleton.
    let mut params = Params::init(arch, 0)?;
    let want = params.names().len();
    ensure!(
        ckpt.params.len() == want,
        "checkpoint {source} has {} parameters, arch {label} wants {want}",
        ckpt.params.len(),
    );
    for (name, t) in &ckpt.params {
        let expect = params
            .get(name)
            .map_err(|_| anyhow!("checkpoint {source}: param {name:?} is not in arch {label}"))?;
        ensure!(
            expect.shape() == t.shape(),
            "checkpoint {source}: param {name} has shape {:?}, arch {label} expects {:?}",
            t.shape(),
            expect.shape()
        );
    }
    params
        .load_named(&ckpt.params)
        .with_context(|| format!("loading params from checkpoint {source}"))?;
    Ok(params)
}

// ---------------------------------------------------------------------------
// ForwardEngine
// ---------------------------------------------------------------------------

/// The forward-only master: owns the loaded parameters and the worker links,
/// runs the distributed conv shard forward path at any batch rung on the
/// arch's `batch_buckets` ladder.  No gradient or optimizer allocations —
/// the executable set is `conv*_fwd_b*_n*` / `mid*_fwd_n*` / `head_logits_n*`
/// (plus the legacy names when the rung equals the training batch).
pub struct ForwardEngine {
    rt: Arc<Runtime>,
    workers: Vec<Box<dyn Link>>,
    params: Params,
    /// Per conv layer, the Eq. 1 shard table from the calibration probe.
    shards: Vec<Vec<Shard>>,
    seq: u32,
}

impl ForwardEngine {
    /// Handshake the fleet, run the calibration probe and Eq. 1-partition
    /// every conv layer.  `links` speak the worker protocol (Hello first).
    pub fn new(
        rt: Arc<Runtime>,
        mut workers: Vec<Box<dyn Link>>,
        params: Params,
        calib_rounds: u32,
    ) -> Result<Self> {
        for (i, w) in workers.iter_mut().enumerate() {
            match w.recv()? {
                Message::Hello { version, .. } => {
                    ensure!(version == PROTO_VERSION, "worker {i} protocol v{version}");
                }
                other => bail!("worker {i}: expected Hello, got {}", other.tag()),
            }
        }
        let mut engine = Self { rt, workers, params, shards: vec![], seq: 0 };
        let times = engine.calibrate(calib_rounds)?;
        engine.partition(&times)?;
        Ok(engine)
    }

    /// Number of worker links (devices = workers + 1).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The batch rung ladder (ascending) the batcher may pick from.
    pub fn batch_rungs(&self) -> &[usize] {
        &self.rt.arch().batch_buckets
    }

    /// Same probe as the trainer's calibration (paper §4.1.1): master probes
    /// itself while the slaves probe, minimum over `rounds`.
    fn calibrate(&mut self, rounds: u32) -> Result<Vec<f64>> {
        for w in self.workers.iter_mut() {
            w.send(&Message::Calibrate { rounds })?;
        }
        let my_secs = {
            let p = self.rt.arch().probe.clone();
            let mut rng = Pcg32::seed_stream(0xCA11B, 0);
            let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
            let w = Tensor::randn(&[p.k, p.in_ch, p.kh, p.kw], &mut rng);
            let b = Tensor::zeros(&[p.k]);
            let args = [Value::F32(x), Value::F32(w), Value::F32(b)];
            let _ = self.rt.execute("probe", &args)?; // absorb compile
            let mut best = f64::MAX;
            for _ in 0..rounds.max(1) {
                let (_, real) = self.rt.execute_timed("probe", &args)?;
                best = best.min(real.as_secs_f64());
            }
            best
        };
        let mut times = vec![my_secs];
        for (i, w) in self.workers.iter_mut().enumerate() {
            match w.recv()? {
                Message::CalibrateResult { seconds } => times.push(seconds),
                Message::Error { reason } => bail!("worker {i} calibration failed: {reason}"),
                other => bail!("worker {i}: expected CalibrateResult, got {}", other.tag()),
            }
        }
        Ok(times)
    }

    fn partition(&mut self, times: &[f64]) -> Result<()> {
        let arch = self.rt.arch().clone();
        let layers: Vec<(usize, &[usize])> =
            (1..=arch.num_convs()).map(|l| (arch.kernels(l), arch.buckets(l))).collect();
        self.shards = partition_network(&layers, times)?;
        Ok(())
    }

    /// The forward exec name for a conv shard at batch `n`: legacy name on
    /// the training batch (byte-identical to the training hot path), the
    /// `_n{batch}` serving family elsewhere — mirrors the worker's dispatch.
    fn conv_exec(&self, layer: usize, bucket: usize, n: usize) -> String {
        if n == self.rt.arch().batch {
            Manifest::conv_exec(layer, ConvDir::Fwd, bucket)
        } else {
            format!("conv{layer}_fwd_b{bucket}_n{n}")
        }
    }

    /// Distributed forward pass: `images [n, C, H, W]` -> `logits [n, classes]`.
    /// `n` must sit exactly on the `batch_buckets` ladder (the batcher pads
    /// up to a rung before calling this).
    pub fn forward(&mut self, images: &Tensor) -> Result<Tensor> {
        let arch = self.rt.arch().clone();
        let shp = images.shape();
        ensure!(
            shp.len() == 4 && shp[1] == arch.in_ch && shp[2] == arch.img && shp[3] == arch.img,
            "image batch shape {shp:?} does not match arch {}x{}x{}",
            arch.in_ch,
            arch.img,
            arch.img
        );
        let n = shp[0];
        ensure!(
            arch.batch_buckets.contains(&n),
            "batch {n} is not on the arch's batch ladder {:?}",
            arch.batch_buckets
        );
        let nconv = arch.num_convs();
        let mut p = images.clone();
        for l in 1..=nconv {
            let w = self.params.get(&ArchSpec::conv_weight(l))?.clone();
            let b = self.params.get(&ArchSpec::conv_bias(l))?.clone();
            let shards = self.shards[l - 1].clone();
            let y = self.dist_conv_fwd(l, n, &p, &w, &b, &shards)?;
            let mid =
                if n == arch.batch { format!("mid{l}_fwd") } else { format!("mid{l}_fwd_n{n}") };
            let outs = self.rt.execute(&mid, &[Value::F32(y)])?;
            p = outs.into_iter().next().unwrap().as_f32()?.clone();
        }
        let wf = self.params.get(ArchSpec::FC_W)?.clone();
        let bf = self.params.get(ArchSpec::FC_B)?.clone();
        let outs = self.rt.execute(
            &format!("head_logits_n{n}"),
            &[Value::F32(p), Value::F32(wf), Value::F32(bf)],
        )?;
        Ok(outs.into_iter().next().unwrap().as_f32()?.clone())
    }

    /// One scatter/convolve/gather round — the same loop as the trainer's
    /// `dist_conv_fwd`, minus telemetry and phase attribution.
    fn dist_conv_fwd(
        &mut self,
        layer: usize,
        n: usize,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        shards: &[Shard],
    ) -> Result<Tensor> {
        self.seq += 1;
        let seq = self.seq;
        for s in shards.iter().filter(|s| s.device != 0) {
            let wk = w.slice_axis0(s.lo, s.hi)?;
            let bk = b.slice_axis0(s.lo, s.hi)?;
            let msg = Message::ConvWork {
                seq,
                layer: layer as u8,
                dir: 0,
                bucket: s.bucket as u32,
                inputs: WireTensor::from(x),
                kernels: WireTensor::from(&wk),
                extra: Some(WireTensor::from(&bk)),
            };
            self.workers[s.device - 1].send(&msg)?;
        }
        let mut parts: Vec<(usize, Tensor)> = Vec::with_capacity(shards.len());
        if let Some(s) = shards.iter().find(|s| s.device == 0) {
            let exec = self.conv_exec(layer, s.bucket, n);
            let wk = w.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
            let bk = b.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
            let args = [Value::F32(x.clone()), Value::F32(wk), Value::F32(bk)];
            let outs = self.rt.execute(&exec, &args)?;
            let y = outs.into_iter().next().unwrap().as_f32()?.slice_axis1(0, s.len())?;
            parts.push((s.lo, y));
        }
        for s in shards.iter().filter(|s| s.device != 0) {
            let mut outputs = self.recv_result(s.device - 1, seq)?;
            ensure!(outputs.len() == 1, "fwd ConvResult must carry 1 tensor");
            parts.push((s.lo, outputs.remove(0).into_tensor()?));
        }
        parts.sort_by_key(|(lo, _)| *lo);
        let tensors: Vec<Tensor> = parts.into_iter().map(|(_, t)| t).collect();
        Tensor::concat_axis1(&tensors)
    }

    /// Gather one worker's ConvResult for round `seq`, discarding stale
    /// replies and piggybacked span reports (the serving master does not
    /// merge worker traces).
    fn recv_result(&mut self, worker: usize, seq: u32) -> Result<Vec<WireTensor>> {
        loop {
            match self.workers[worker].recv()? {
                Message::ConvResult { seq: got, outputs, .. } => {
                    if got == seq {
                        return Ok(outputs);
                    }
                    ensure!(got < seq, "worker {worker} replied from the future: {got} > {seq}");
                }
                Message::SpanReport { .. } | Message::Pong { .. } => {}
                Message::Leave { reason, .. } => bail!("worker {worker} left the fleet: {reason}"),
                Message::Error { reason } => bail!("worker failed: {reason}"),
                other => bail!("expected ConvResult, got {}", other.tag()),
            }
        }
    }

    /// Tell every worker the session is over (`TrainOver` — the worker loop
    /// has a single shutdown message for both modes).
    pub fn shutdown(mut self) -> Result<()> {
        for w in self.workers.iter_mut() {
            let _ = w.send(&Message::TrainOver);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Dynamic batcher
// ---------------------------------------------------------------------------

/// Smallest ladder rung that covers `n` requests (`None` when `n` exceeds
/// the ladder — the caller caps batches at the largest rung).
pub fn rung_for(rungs: &[usize], n: usize) -> Option<usize> {
    rungs.iter().copied().find(|&r| r >= n)
}

/// Stack per-request images `[C, H, W]` into one `[rung, C, H, W]` batch,
/// zero-padding the tail rows.
pub fn stack_images(images: &[&Tensor], rung: usize) -> Result<Tensor> {
    ensure!(!images.is_empty(), "empty batch");
    ensure!(rung >= images.len(), "rung {rung} below batch size {}", images.len());
    let per = images[0].shape().to_vec();
    ensure!(per.len() == 3, "request image must be [C, H, W], got {per:?}");
    let isz: usize = per.iter().product();
    let mut data = vec![0.0f32; rung * isz];
    for (i, img) in images.iter().enumerate() {
        ensure!(
            img.shape() == per.as_slice(),
            "request {i} shape {:?} differs from {per:?}",
            img.shape()
        );
        data[i * isz..(i + 1) * isz].copy_from_slice(img.data());
    }
    let mut shape = vec![rung];
    shape.extend_from_slice(&per);
    Tensor::new(shape, data)
}

/// One admitted request waiting for its logits row.
struct Pending {
    id: u64,
    image: Tensor,
    enqueued: Instant,
    /// Run-log timestamp at admission (0 when tracing is off).
    ts_us: u64,
    tx: mpsc::Sender<Result<Tensor>>,
}

#[derive(Default)]
struct QueueState {
    q: VecDeque<Pending>,
    draining: bool,
}

/// The shared request queue: handler threads push, the single dispatch
/// thread pops batches.  A condvar covers both "work arrived" and "drain".
#[derive(Default)]
struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Queue {
    /// Admit a request; returns the queue depth after the push, or an error
    /// once draining started.
    fn push(&self, p: Pending) -> Result<usize> {
        let mut st = self.state.lock().unwrap();
        if st.draining {
            bail!("server is draining");
        }
        st.q.push_back(p);
        let depth = st.q.len();
        self.cv.notify_all();
        Ok(depth)
    }

    fn drain(&self) {
        self.state.lock().unwrap().draining = true;
        self.cv.notify_all();
    }

    /// Pop the next batch: wait for a first request, then hold it up to
    /// `max_delay` hoping for companions, capped at `max_batch`.  `None`
    /// once draining and empty — the dispatch loop's exit condition.
    fn pop_batch(&self, max_batch: usize, max_delay: Duration) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.q.is_empty() {
                break;
            }
            if st.draining {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        let deadline = st.q.front().unwrap().enqueued + max_delay;
        while st.q.len() < max_batch && !st.draining {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
        let k = st.q.len().min(max_batch);
        Some(st.q.drain(..k).collect())
    }
}

/// Shared serving gauges backing the metrics snapshot and the drain report.
#[derive(Default)]
struct ServeStats {
    inflight: AtomicU64,
    served: AtomicU64,
}

// ---------------------------------------------------------------------------
// The TCP front-end
// ---------------------------------------------------------------------------

/// A running serve front-end: an accept loop (one handler thread per client
/// connection) feeding the batcher queue, and one dispatch thread that owns
/// the [`ForwardEngine`].  Lives until a client sends [`Message::Drain`];
/// [`ServeServer::join`] then returns the engine for fleet shutdown.
pub struct ServeServer {
    addr: SocketAddr,
    queue: Arc<Queue>,
    stop: Arc<AtomicBool>,
    stats: Arc<ServeStats>,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<ForwardEngine>>,
}

impl ServeServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back from
    /// [`addr`](ServeServer::addr)) and start accepting inference traffic.
    pub fn start(
        engine: ForwardEngine,
        addr: &str,
        cfg: ServeConfig,
        obs: Option<ObsHandle>,
    ) -> Result<Self> {
        let arch = engine.runtime().arch().clone();
        let rungs = engine.batch_rungs().to_vec();
        ensure!(!rungs.is_empty(), "arch has an empty batch ladder");
        let top = *rungs.last().unwrap();
        ensure!(
            cfg.max_batch >= 1 && cfg.max_batch <= top,
            "serve.max_batch {} is outside the batch ladder {:?} (1..={top})",
            cfg.max_batch,
            rungs
        );
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve endpoint {addr}"))?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let queue = Arc::new(Queue::default());
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServeStats::default());
        let t0 = Instant::now();

        let dq = queue.clone();
        let dobs = obs.clone();
        let dstats = stats.clone();
        let dispatch = std::thread::Builder::new()
            .name("convdist-serve-dispatch".into())
            .spawn(move || {
                let mut engine = engine;
                dispatch_loop(&mut engine, &dq, &cfg, &rungs, dobs.as_ref(), &dstats, t0);
                engine
            })?;

        let aq = queue.clone();
        let astop = stop.clone();
        let astats = stats.clone();
        let accept = std::thread::Builder::new()
            .name("convdist-serve-accept".into())
            .spawn(move || accept_loop(listener, aq, astop, obs, astats, arch))?;

        Ok(Self { addr: bound, queue, stop, stats, accept: Some(accept), dispatch: Some(dispatch) })
    }

    /// The bound address (resolves an ephemeral `:0` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests answered so far.
    pub fn requests_served(&self) -> u64 {
        self.stats.served.load(Ordering::Relaxed)
    }

    /// Ask the server to drain from the owning side (tests; clients send
    /// [`Message::Drain`] instead).
    pub fn begin_drain(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.drain();
    }

    /// Block until drained: the accept loop stops, every queued request is
    /// answered, and the engine comes back for fleet shutdown (along with
    /// the final requests-served count).
    pub fn join(mut self) -> Result<(ForwardEngine, u64)> {
        if let Some(a) = self.accept.take() {
            a.join().map_err(|_| anyhow!("serve accept thread panicked"))?;
        }
        // Belt and braces: a Drain handler already did both of these.
        self.queue.drain();
        let engine = match self.dispatch.take() {
            Some(d) => d.join().map_err(|_| anyhow!("serve dispatch thread panicked"))?,
            None => bail!("serve dispatch thread already taken"),
        };
        Ok((engine, self.stats.served.load(Ordering::Relaxed)))
    }
}

fn dispatch_loop(
    engine: &mut ForwardEngine,
    queue: &Queue,
    cfg: &ServeConfig,
    rungs: &[usize],
    obs: Option<&ObsHandle>,
    stats: &ServeStats,
    t0: Instant,
) {
    let max_delay = Duration::from_millis(cfg.max_delay_ms);
    while let Some(batch) = queue.pop_batch(cfg.max_batch, max_delay) {
        if batch.is_empty() {
            continue;
        }
        run_batch(engine, batch, rungs, obs, stats, t0);
    }
}

/// Pad one popped batch up to its ladder rung, run the distributed forward
/// pass, de-multiplex the logits rows back per request, and record the
/// serving metrics (latency / queue-depth histograms, QPS, counters).
fn run_batch(
    engine: &mut ForwardEngine,
    batch: Vec<Pending>,
    rungs: &[usize],
    obs: Option<&ObsHandle>,
    stats: &ServeStats,
    t0: Instant,
) {
    let k = batch.len();
    let rung = rung_for(rungs, k).unwrap_or_else(|| *rungs.last().unwrap());
    let images: Vec<&Tensor> = batch.iter().map(|p| &p.image).collect();
    let result = stack_images(&images, rung).and_then(|stacked| engine.forward(&stacked));
    if let Some(h) = obs {
        h.metrics(|m| {
            m.inc("serve_batches", 1);
            m.inc("serve_requests", k as u64);
            m.inc("serve_padded_rows", (rung - k) as u64);
            m.observe_ms("serve_batch_size", k as f64);
        });
    }
    match result {
        Ok(logits) => {
            let ncls = logits.shape()[1];
            for (i, p) in batch.into_iter().enumerate() {
                let row = logits.data()[i * ncls..(i + 1) * ncls].to_vec();
                let row = Tensor::new(vec![ncls], row).expect("logits row");
                finish_request(&p, obs, stats, t0);
                let _ = p.tx.send(Ok(row));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in batch {
                finish_request(&p, obs, stats, t0);
                let _ = p.tx.send(Err(anyhow!("inference failed: {msg}")));
            }
        }
    }
}

/// Per-request bookkeeping at reply time: latency histogram, in-flight
/// gauge, QPS gauge, and a run-log span covering queue wait + compute.
fn finish_request(p: &Pending, obs: Option<&ObsHandle>, stats: &ServeStats, t0: Instant) {
    let served = stats.served.fetch_add(1, Ordering::Relaxed) + 1;
    let inflight = stats.inflight.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
    let latency_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
    if let Some(h) = obs {
        let qps = served as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        h.metrics(|m| {
            m.observe_ms("serve_request_ms", latency_ms);
            m.set_gauge("serve_inflight", inflight as f64);
            m.set_gauge("serve_qps", qps);
        });
        if h.tracing() {
            let now = h.now_us();
            h.span(SpanRec {
                name: format!("infer {}", p.id),
                cat: SpanCat::Comp,
                device: 0,
                layer: 0,
                step: p.id,
                ts_us: p.ts_us,
                dur_us: now.saturating_sub(p.ts_us),
            });
        }
    }
}

/// Non-blocking accept with a stop flag (the same poll/sleep shape as the
/// metrics endpoint): one handler thread per connection, all joined before
/// the accept loop returns so `join` sees every admitted request queued.
fn accept_loop(
    listener: TcpListener,
    queue: Arc<Queue>,
    stop: Arc<AtomicBool>,
    obs: Option<ObsHandle>,
    stats: Arc<ServeStats>,
    arch: ArchSpec,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let q = queue.clone();
                let s = stop.clone();
                let o = obs.clone();
                let st = stats.clone();
                let a = arch.clone();
                if let Ok(h) = std::thread::Builder::new()
                    .name("convdist-serve-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &q, &s, o.as_ref(), &st, &a);
                    })
                {
                    handlers.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// One client connection: sequential request/reply over the shared framing.
/// Concurrency comes from connections, not pipelining — a client that wants
/// parallel in-flight requests opens parallel connections (what
/// `examples/bench_serve.rs` does).
fn handle_conn(
    stream: std::net::TcpStream,
    queue: &Queue,
    stop: &AtomicBool,
    obs: Option<&ObsHandle>,
    stats: &ServeStats,
    arch: &ArchSpec,
) -> Result<()> {
    let mut link = TcpLink::from_stream(stream)?;
    loop {
        let msg = match link.recv_timeout(Duration::from_millis(100)) {
            Ok(Some(m)) => m,
            Ok(None) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(_) => return Ok(()), // peer hung up
        };
        match msg {
            Message::InferRequest { id, image } => {
                let image = match image.into_tensor() {
                    Ok(t) => t,
                    Err(e) => {
                        link.send(&Message::Error { reason: format!("request {id}: {e:#}") })?;
                        continue;
                    }
                };
                let want = [arch.in_ch, arch.img, arch.img];
                if image.shape() != want {
                    link.send(&Message::Error {
                        reason: format!(
                            "request {id}: image shape {:?} does not match arch {want:?}",
                            image.shape()
                        ),
                    })?;
                    continue;
                }
                let (tx, rx) = mpsc::channel();
                let ts_us = obs.map_or(0, |h| h.now_us());
                let pending = Pending { id, image, enqueued: Instant::now(), ts_us, tx };
                match queue.push(pending) {
                    Ok(depth) => {
                        stats.inflight.fetch_add(1, Ordering::Relaxed);
                        if let Some(h) = obs {
                            h.metrics(|m| m.observe_ms("serve_queue_depth", depth as f64));
                        }
                    }
                    Err(e) => {
                        link.send(&Message::Error { reason: format!("request {id}: {e:#}") })?;
                        continue;
                    }
                }
                match rx.recv() {
                    Ok(Ok(row)) => link.send(&Message::InferReply {
                        id,
                        logits: WireTensor::from(&row),
                    })?,
                    Ok(Err(e)) => {
                        link.send(&Message::Error { reason: format!("request {id}: {e:#}") })?
                    }
                    Err(_) => {
                        link.send(&Message::Error {
                            reason: format!("request {id}: server shut down mid-request"),
                        })?
                    }
                }
            }
            Message::Drain => {
                stop.store(true, Ordering::Relaxed);
                queue.drain();
                link.send(&Message::AllOk)?;
                return Ok(());
            }
            other => {
                link.send(&Message::Error {
                    reason: format!("unexpected message for serve: {}", other.tag()),
                })?;
                return Ok(());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client helper
// ---------------------------------------------------------------------------

/// A minimal serve client over one connection: send an image, block for the
/// logits row.  Used by `convdist infer`, the CI smoke gate and the load
/// generator.
pub struct ServeClient {
    link: TcpLink,
    next_id: u64,
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Self { link: TcpLink::connect(addr)?, next_id: 1 })
    }

    /// Classify one `[C, H, W]` image; returns the `[classes]` logits row.
    pub fn classify(&mut self, image: &Tensor) -> Result<Tensor> {
        let id = self.next_id;
        self.next_id += 1;
        self.link.send(&Message::InferRequest { id, image: WireTensor::from(image) })?;
        match self.link.recv()? {
            Message::InferReply { id: got, logits } => {
                ensure!(got == id, "reply for request {got}, expected {id}");
                logits.into_tensor()
            }
            Message::Error { reason } => bail!("server error: {reason}"),
            other => bail!("expected InferReply, got {}", other.tag()),
        }
    }

    /// Graceful shutdown: the server stops accepting, finishes the queue and
    /// tears the fleet down.  Consumes the client.
    pub fn drain(mut self) -> Result<()> {
        self.link.send(&Message::Drain)?;
        match self.link.recv()? {
            Message::AllOk => Ok(()),
            Message::Error { reason } => bail!("drain refused: {reason}"),
            other => bail!("expected AllOk, got {}", other.tag()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_selection_walks_the_ladder() {
        let rungs = [2, 4, 8];
        assert_eq!(rung_for(&rungs, 1), Some(2));
        assert_eq!(rung_for(&rungs, 2), Some(2));
        assert_eq!(rung_for(&rungs, 3), Some(4));
        assert_eq!(rung_for(&rungs, 8), Some(8));
        assert_eq!(rung_for(&rungs, 9), None, "past the ladder: caller caps at max_batch");
    }

    #[test]
    fn stack_images_zero_pads_the_tail_rows() {
        let a = Tensor::new(vec![1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(vec![1, 2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let batch = stack_images(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape(), &[4, 1, 2, 2]);
        assert_eq!(&batch.data()[..8], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert!(batch.data()[8..].iter().all(|&v| v == 0.0), "pad rows must be zero");
        // Mismatched request shapes are refused, not silently reshaped.
        let c = Tensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
        assert!(stack_images(&[&a, &c], 4).is_err());
        assert!(stack_images(&[&a, &b], 1).is_err(), "rung below batch size");
    }

    #[test]
    fn checkpoint_params_validate_arch_and_shapes() {
        let arch = ArchSpec::tiny();
        let good = Params::init(&arch, 7).unwrap();
        let ckpt = Checkpoint {
            step: 3,
            arch_label: arch.label(),
            params: good.to_named(),
            velocity: vec![],
        };
        let loaded = params_from_checkpoint(&arch, &ckpt, "model.ckpt").unwrap();
        assert_eq!(loaded.names(), good.names());

        // Wrong arch label: named error, no panic.
        let mut wrong = ckpt.clone();
        wrong.arch_label = "other-arch".into();
        let err = params_from_checkpoint(&arch, &wrong, "model.ckpt").unwrap_err();
        assert!(err.to_string().contains("model.ckpt"), "{err}");
        assert!(err.to_string().contains("other-arch"), "{err}");

        // Mismatched tensor shape: error names the param and both shapes.
        let mut bad = ckpt.clone();
        bad.params[0].1 = Tensor::zeros(&[1, 1, 1, 1]);
        let err = params_from_checkpoint(&arch, &bad, "model.ckpt").unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("expects"), "{text}");
        assert!(text.contains("[1, 1, 1, 1]"), "{text}");

        // Truncated param set: count mismatch named.
        let mut short = ckpt;
        short.params.pop();
        let err = params_from_checkpoint(&arch, &short, "model.ckpt").unwrap_err();
        assert!(err.to_string().contains("parameters"), "{err}");
    }

    #[test]
    fn queue_batches_up_to_the_budget_and_drains() {
        let q = Queue::default();
        let push = |q: &Queue, id: u64| {
            // The receiver is dropped: these tests only watch the queue
            // itself and never deliver a reply.
            let (tx, _rx) = mpsc::channel();
            q.push(Pending {
                id,
                image: Tensor::zeros(&[1, 1, 1]),
                enqueued: Instant::now(),
                ts_us: 0,
                tx,
            })
        };
        assert_eq!(push(&q, 1).unwrap(), 1);
        assert_eq!(push(&q, 2).unwrap(), 2);
        assert_eq!(push(&q, 3).unwrap(), 3);
        // max_batch 2: first pop takes exactly 2, oldest first.
        let b = q.pop_batch(2, Duration::from_millis(0)).unwrap();
        assert_eq!(b.iter().map(|p| p.id).collect::<Vec<_>>(), vec![1, 2]);
        let b = q.pop_batch(2, Duration::from_millis(0)).unwrap();
        assert_eq!(b.iter().map(|p| p.id).collect::<Vec<_>>(), vec![3]);
        // Draining: pushes refused, pop returns None once empty.
        q.drain();
        assert!(push(&q, 4).is_err());
        assert!(q.pop_batch(2, Duration::from_millis(0)).is_none());
    }
}
