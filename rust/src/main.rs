//! `convdist` — CLI for the distributed-CNN-training reproduction.
//!
//! ```text
//! convdist train     [--config exp.json] [--workers N] [--steps N]
//!                    [--throttle] [--shaped]
//! convdist worker    [--listen 127.0.0.1:7701] [--id N] [--slowdown X]
//! convdist master    --workers host:port,host:port [--config exp.json] [--steps N]
//! convdist calibrate [--rounds N]
//! convdist figures   [--id fig5|table4|...] [--csv]
//! convdist baseline  [--kind single|dp] [--replicas N] [--steps N]
//! convdist stats
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use convdist::baselines::{DataParallelTrainer, SingleDeviceTrainer};
use convdist::cluster::{spawn_inproc, spawn_inproc_arch, worker_loop, DistTrainer, WorkerOptions};
use convdist::config::{ExperimentConfig, TrainerConfig};
use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::net::{LinkModel, TcpLink};
use convdist::runtime::{ArchSpec, Runtime};
use convdist::sim::figures;
use convdist::util::cli::Args;

const USAGE: &str = "usage: convdist <train|worker|master|calibrate|figures|baseline> [options]
  train      --config F --workers N --steps N --throttle --shaped
  worker     --listen ADDR --id N --slowdown X
  master     --workers a:p,b:p --config F --steps N
  calibrate  --rounds N
  figures    --id ID --csv          (IDs: table1 fig5 fig6 fig7 fig8 table4 table5
                                          fig9 fig10 fig11 fig12 fig13 amdahl)
  baseline   --kind single|dp --replicas N --steps N
common: --artifacts DIR --arch NAME   (NAME: default|tiny|deep_cifar|tiny_deep;
                                       only without a manifest.json — a manifest
                                       pins the architecture)";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "master" => cmd_master(&args),
        "calibrate" => cmd_calibrate(&args),
        "figures" => cmd_figures(&args),
        "baseline" => cmd_baseline(&args),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn artifacts_path(args: &Args) -> std::path::PathBuf {
    match args.opt("artifacts") {
        Some(p) => p.into(),
        None => convdist::artifacts_dir(),
    }
}

fn arch_preset(args: &Args) -> Result<Option<ArchSpec>> {
    match args.opt("arch") {
        None => Ok(None),
        Some(name) => Ok(Some(ArchSpec::preset(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown --arch preset {name:?} (try: default, tiny, deep_cifar, tiny_deep)"
            )
        })?)),
    }
}

fn open_runtime(args: &Args) -> Result<Arc<Runtime>> {
    let dir = artifacts_path(args);
    // `--arch NAME` selects a synthesized preset (e.g. the 3-conv
    // `deep_cifar`) — only meaningful without a pinned manifest.
    let rt = match arch_preset(args)? {
        Some(arch) => {
            if dir.join("manifest.json").exists() {
                bail!(
                    "--arch conflicts with {}/manifest.json, which pins the architecture",
                    dir.display()
                );
            }
            Runtime::for_arch(arch)
        }
        None => Runtime::open(&dir)?,
    };
    eprintln!(
        "runtime: platform={} arch={} batch={} ({} conv layers, {} executables)",
        rt.platform(),
        rt.arch().label(),
        rt.arch().batch,
        rt.arch().num_convs(),
        rt.manifest().executables.len()
    );
    Ok(rt)
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.opt("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    if let Some(w) = args.get_opt::<usize>("workers").ok().flatten() {
        cfg.cluster.workers = w;
    }
    if let Some(s) = args.get_opt::<usize>("steps")? {
        cfg.trainer.steps = s;
    }
    if args.flag("throttle") {
        cfg.cluster.throttle = true;
    }
    if args.flag("shaped") {
        cfg.network.shaped = true;
    }
    Ok(cfg)
}

fn run_training(rt: Arc<Runtime>, mut trainer: DistTrainer, tcfg: &TrainerConfig) -> Result<()> {
    let arch = rt.arch().clone();
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, tcfg.seed);
    eprintln!("calibration (probe seconds): {:?}", trainer.probe_times());
    for layer in 1..=arch.num_convs() {
        let k = arch.kernels(layer);
        let shards: Vec<String> = trainer
            .shards(layer)
            .iter()
            .map(|s| format!("dev{}:{}..{} (b{})", s.device, s.lo, s.hi, s.bucket))
            .collect();
        eprintln!("conv{layer} ({k} kernels) -> {}", shards.join(" "));
    }
    let mut total = convdist::metrics::Breakdown::default();
    for step in 0..tcfg.steps {
        let batch = ds.batch(arch.batch, step)?;
        let res = trainer.step(&batch)?;
        total.add(&res.breakdown);
        if step % tcfg.log_every == 0 || step + 1 == tcfg.steps {
            eprintln!(
                "step {step:>4}  loss {:.4}  devices {}  {}  wire {:.2} MiB",
                res.loss,
                res.devices,
                res.breakdown,
                res.bytes_moved as f64 / (1 << 20) as f64
            );
        }
    }
    let eval = ds.batch(arch.batch, tcfg.steps + 1)?;
    let acc = trainer.eval_accuracy(&eval)?;
    eprintln!("final held-out accuracy: {:.1}%", acc * 100.0);
    eprintln!("cumulative: {total}");
    if std::env::var("CONVDIST_STATS").is_ok() {
        eprintln!("master-runtime executable stats (slowest first):");
        for (name, s) in rt.stats() {
            eprintln!(
                "  {name:28} {:>5} calls  {:>10.3?} total  {:>9.3?}/call",
                s.calls,
                s.total,
                s.total / s.calls.max(1) as u32
            );
        }
    }
    trainer.shutdown()?;
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = open_runtime(args)?;
    let profiles = cfg.device_profiles();
    let throttles = if cfg.cluster.throttle {
        // Virtual-time emulation: fastest device pinned at 2 virtual GFLOPS
        // so sleeps dominate the host's real compute (see devices::Throttle).
        Throttle::virtual_cluster(&profiles, 2.0)
    } else {
        vec![Throttle::none(); profiles.len()]
    };
    eprintln!(
        "cluster: {} workers + master, devices={} throttle={} shaped={}",
        cfg.cluster.workers, cfg.cluster.devices, cfg.cluster.throttle, cfg.network.shaped
    );
    let shape = cfg.network.shaped.then(|| LinkModel {
        bandwidth_bps: cfg.network.bandwidth_mbps * 1e6,
        latency: std::time::Duration::from_secs_f64(cfg.network.latency_ms / 1e3),
    });
    // With `--arch` the workers must resolve the same synthesized graph as
    // the master — pass it explicitly instead of re-opening the artifacts.
    let mut cluster = if args.opt("arch").is_some() {
        spawn_inproc_arch(rt.arch().clone(), &throttles[1..], shape)
    } else {
        spawn_inproc(artifacts_path(args), &throttles[1..], shape)
    };
    let trainer = DistTrainer::new(rt.clone(), cluster.take_links(), &cfg.trainer, throttles[0])?;
    run_training(rt, trainer, &cfg.trainer)?;
    cluster.handles.into_iter().try_for_each(|h| h.join().unwrap())?;
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let listen = args.opt("listen").unwrap_or("127.0.0.1:7701");
    let id: u32 = args.get("id", 1)?;
    let slowdown: f64 = args.get("slowdown", 1.0)?;
    let listener = std::net::TcpListener::bind(listen)?;
    eprintln!("worker {id} listening on {listen} (slowdown {slowdown}x)");
    let link = TcpLink::accept_one(&listener)?;
    let opts = WorkerOptions::new(id, Throttle::new(slowdown.max(1.0)));
    worker_loop(link, rt, opts)?;
    eprintln!("worker {id}: TrainOver received, shutting down");
    Ok(())
}

fn cmd_master(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = open_runtime(args)?;
    let workers = args.require("workers")?;
    let mut links: Vec<Box<dyn convdist::net::Link>> = Vec::new();
    for addr in workers.split(',').filter(|s| !s.is_empty()) {
        eprintln!("connecting to worker {addr}");
        links.push(Box::new(TcpLink::connect(addr.trim())?));
    }
    if links.is_empty() {
        bail!("no worker addresses given");
    }
    let trainer = DistTrainer::new(rt.clone(), links, &cfg.trainer, Throttle::none())?;
    run_training(rt, trainer, &cfg.trainer)
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let rounds: u32 = args.get("rounds", 5)?;
    let probe = rt.arch().probe.clone();
    let mut rng = convdist::tensor::Pcg32::seed(1);
    let x =
        convdist::tensor::Tensor::randn(&[probe.batch, probe.in_ch, probe.img, probe.img], &mut rng);
    let w = convdist::tensor::Tensor::randn(&[probe.k, probe.in_ch, probe.kh, probe.kw], &mut rng);
    let b = convdist::tensor::Tensor::zeros(&[probe.k]);
    let args_v = [x.into(), w.into(), b.into()];
    let _ = rt.execute("probe", &args_v)?;
    let mut best = f64::MAX;
    for i in 0..rounds.max(1) {
        let (_, d) = rt.execute_timed("probe", &args_v)?;
        eprintln!("round {i}: {:.6}s", d.as_secs_f64());
        best = best.min(d.as_secs_f64());
    }
    let gflops = probe.flops as f64 / best / 1e9;
    println!("probe best: {best:.6}s  ->  {gflops:.2} effective GFLOPS");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let figs = match args.opt("id") {
        Some(id) => vec![figures::generate(id).ok_or_else(|| {
            anyhow::anyhow!("unknown figure id {id:?} (try fig5..fig13, table1/4/5, amdahl)")
        })?],
        None => figures::all(),
    };
    for f in figs {
        if args.flag("csv") {
            println!("# {}", f.id);
            print!("{}", f.to_csv());
        } else {
            println!("{}", f.render());
        }
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let mut tcfg = TrainerConfig::default();
    if let Some(s) = args.get_opt::<usize>("steps")? {
        tcfg.steps = s;
    }
    let replicas: usize = args.get("replicas", 2)?;
    let arch = rt.arch().clone();
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, tcfg.seed);
    match args.opt("kind").unwrap_or("single") {
        "single" => {
            let mut t = SingleDeviceTrainer::new(rt, &tcfg, Throttle::none())?;
            for step in 0..tcfg.steps {
                let batch = ds.batch(arch.batch, step)?;
                let (loss, b) = t.step(&batch)?;
                if step % tcfg.log_every == 0 {
                    eprintln!("step {step:>4}  loss {loss:.4}  {b}");
                }
            }
        }
        "dp" => {
            let mut t = DataParallelTrainer::new(rt, &tcfg, vec![Throttle::none(); replicas])?;
            for step in 0..tcfg.steps {
                let batch = ds.batch(arch.batch, step)?;
                let (loss, b) = t.step(&batch)?;
                if step % tcfg.log_every == 0 {
                    eprintln!("step {step:>4}  loss {loss:.4}  replicas {replicas}  {b}");
                }
            }
        }
        other => bail!("unknown baseline kind {other:?} (single|dp)"),
    }
    Ok(())
}
