//! `convdist` — CLI for the distributed-CNN-training reproduction.
//!
//! ```text
//! convdist run       [--config exp.json] [--workers N] [--steps N]
//!                    [--throttle] [--shaped] [--arch NAME]
//!                    [--replicas N] [--allreduce master|ring]
//!                    [--save ckpt] [--resume ckpt]
//!                    [--trace out/] [--metrics]
//! convdist train     (alias of run)
//! convdist worker    [--listen 127.0.0.1:7701] [--id N] [--slowdown X]
//!                    [--trace]
//! convdist master    --workers host:port,host:port [--config exp.json] [--steps N]
//!                    [--trace out/] [--metrics]
//! convdist calibrate [--rounds N]
//! convdist figures   [--id fig5|table4|...] [--csv]
//! convdist baseline  [--kind single|dp] [--replicas N] [--steps N]
//! convdist check     [--config exp.json] [--graph arch.json] [--arch NAME]
//!                    [--format jsonl]
//! convdist report    out/run.jsonl
//! convdist top       <host:port | out/run.jsonl>
//! convdist compare   BASE.jsonl CAND.jsonl [--threshold PCT] [--format jsonl]
//! ```
//!
//! Every training subcommand composes a [`convdist::session::Session`] from
//! the experiment config plus flag overrides — the CLI is a thin shell over
//! `SessionBuilder::from_experiment`.

use std::sync::Arc;

use anyhow::{bail, Result};

use convdist::analysis;
use convdist::baselines::{DataParallelTrainer, SingleDeviceTrainer};
use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::config::{ExperimentConfig, ServeConfig, TrainerConfig};
use convdist::data::default_dataset;
use convdist::devices::Throttle;
use convdist::net::TcpLink;
use convdist::obs::ObsConfig;
use convdist::runtime::{ArchSpec, Runtime};
use convdist::serve::ServeClient;
use convdist::session::{ArchSource, Event, RunReport, Session, SessionBuilder};
use convdist::sim::figures;
use convdist::util::cli::Args;

const USAGE: &str = "usage: convdist <run|train|worker|master|calibrate|figures|baseline> [options]
  run        --config F --workers N --steps N --throttle --shaped
             --replicas N --allreduce master|ring
             (N >= 2 data-parallel replica fleets, each Eq.1-sharded,
              synchronous gradient all-reduce between steps)
             --save CKPT --resume CKPT     (train is an alias)
             --trace DIR --metrics    (DIR gets run.jsonl + trace.json;
                                       bare --metrics = summary table only)
             --metrics-addr HOST:PORT (serve live Prometheus text for the
                                       lifetime of the run)
  worker     --listen ADDR --id N --slowdown X --trace
             (--trace ships per-op spans back to the master's timeline)
  master     --workers a:p,b:p --config F --steps N --trace DIR --metrics
             --metrics-addr HOST:PORT
  calibrate  --rounds N
  figures    --id ID --csv          (IDs: table1 fig5 fig6 fig7 fig8 table4 table5
                                          fig9 fig10 fig11 fig12 fig13 amdahl)
  baseline   --kind single|dp --replicas N --steps N
  check      --config F | --graph F | --arch NAME   [--format human|jsonl]
             (static analyzer; no source = the default experiment config;
              exits non-zero on any deny-level diagnostic)
  report     RUN.jsonl              (schema-validate a --trace run log and
                                     print the Fig. 6-style phase summary)
  top        HOST:PORT | RUN.jsonl  (one-shot fleet view: per-device share,
                                     GFLOP/s and health, from a live
                                     --metrics-addr endpoint or a run log)
  compare    BASE.jsonl CAND.jsonl  [--threshold PCT] [--format human|jsonl]
                                    (cross-run regression gate over step-time
                                     p50/p95 and phase means; exits non-zero
                                     when the candidate regresses)
  serve      --ckpt CKPT [--config F] [--addr HOST:PORT] [--workers N]
             [--max-batch K] [--max-delay-ms D] [--metrics-addr HOST:PORT]
                                    (forward-only inference over the fleet
                                     with dynamic batching; drains and exits
                                     when a client sends --drain)
  infer      --addr HOST:PORT [--requests N] [--concurrency C] [--drain]
                                    (load client: send N random images over C
                                     connections, print latency percentiles;
                                     --drain shuts the server down after)
common: --artifacts DIR --arch NAME   (NAME: default|tiny|deep_cifar|tiny_deep;
                                       only without a manifest.json — a manifest
                                       pins the architecture)";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if !matches!(args.command.as_str(), "report" | "top" | "compare") {
        if let Some(p) = args.positional.first() {
            bail!("unexpected positional argument {p:?}\n{USAGE}");
        }
    }
    match args.command.as_str() {
        "run" | "train" => cmd_run(&args),
        "worker" => cmd_worker(&args),
        "master" => cmd_master(&args),
        "calibrate" => cmd_calibrate(&args),
        "figures" => cmd_figures(&args),
        "baseline" => cmd_baseline(&args),
        "check" => cmd_check(&args),
        "report" => cmd_report(&args),
        "top" => cmd_top(&args),
        "compare" => cmd_compare(&args),
        "serve" => cmd_serve(&args),
        "infer" => cmd_infer(&args),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn artifacts_path(args: &Args) -> std::path::PathBuf {
    match args.opt("artifacts") {
        Some(p) => p.into(),
        None => convdist::artifacts_dir(),
    }
}

/// `--arch NAME` as an [`ArchSource`], erroring when a pinned manifest in
/// the (possibly `--artifacts`-overridden) directory conflicts with it.
fn arch_override(args: &Args) -> Result<Option<ArchSource>> {
    let Some(name) = args.opt("arch") else { return Ok(None) };
    let dir = artifacts_path(args);
    if dir.join("manifest.json").exists() {
        bail!(
            "--arch conflicts with {}/manifest.json, which pins the architecture",
            dir.display()
        );
    }
    Ok(Some(ArchSource::Preset(name.to_string())))
}

/// The `--arch` / `--artifacts` override for session subcommands: an
/// explicit preset wins over the config's `arch` field; otherwise an
/// explicit artifact dir wins over a config without `arch`.
fn apply_arch_override(
    args: &Args,
    cfg: &ExperimentConfig,
    b: SessionBuilder,
) -> Result<SessionBuilder> {
    if let Some(source) = arch_override(args)? {
        return Ok(b.arch(source));
    }
    if cfg.arch.is_none() {
        return Ok(b.arch(ArchSource::Artifacts(artifacts_path(args))));
    }
    Ok(b)
}

/// Runtime for the non-session subcommands (worker / calibrate / baseline):
/// `--arch NAME` selects a synthesized preset — only without a pinned
/// manifest — else the artifact directory decides.  Resolution is
/// `ArchSource::resolve`, the same site the session builder uses.
fn open_runtime(args: &Args) -> Result<Arc<Runtime>> {
    let source = match arch_override(args)? {
        Some(source) => source,
        None => ArchSource::Artifacts(artifacts_path(args)),
    };
    Ok(source.resolve()?.0)
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.opt("config") {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    if let Some(w) = args.get_opt::<usize>("workers").ok().flatten() {
        cfg.cluster.workers = w;
    }
    if let Some(s) = args.get_opt::<usize>("steps")? {
        cfg.trainer.steps = s;
    }
    if args.flag("throttle") {
        cfg.cluster.throttle = true;
    }
    if args.flag("shaped") {
        cfg.network.shaped = true;
    }
    if let Some(n) = args.get_opt::<usize>("replicas")? {
        let mut r = cfg.replica.unwrap_or_default();
        r.count = n;
        cfg.replica = Some(r);
    }
    if let Some(s) = args.opt("allreduce") {
        let mut r = cfg.replica.unwrap_or_default();
        r.allreduce = convdist::replica::AllReduce::parse(s)?;
        cfg.replica = Some(r);
    }
    Ok(cfg)
}

/// `--trace DIR` / `--metrics` / `--metrics-addr` as an [`ObsConfig`].
/// `--trace` implies the metrics registry; a bare `--metrics` keeps
/// everything in memory and only prints the summary table.  The live
/// endpoint address comes from `--metrics-addr`, falling back to the
/// config's `obs.metrics_addr`; either implies `--metrics`.
fn obs_config(args: &Args, cfg: &ExperimentConfig) -> ObsConfig {
    let obs = match args.opt("trace") {
        Some(dir) => ObsConfig::trace_to(dir),
        None if args.flag("metrics") => ObsConfig::metrics_only(),
        None => ObsConfig::default(),
    };
    match args.opt("metrics-addr").or_else(|| cfg.metrics_addr.as_deref()) {
        Some(addr) => obs.serve(addr),
        None => obs,
    }
}

/// Flush the observability sinks and print the metrics table + sink paths.
/// Safe to call unconditionally: without `--trace`/`--metrics` it is a
/// no-op, and `Session::shutdown` finishing a second time is idempotent.
fn finish_obs(session: &mut Session, args: &Args) -> Result<()> {
    if let Some(table) = session.finish_obs()? {
        eprintln!("{table}");
    }
    if let Some(dir) = args.opt("trace") {
        let dir = std::path::Path::new(dir);
        eprintln!(
            "trace written: {} (run log), {} (load in Perfetto / chrome://tracing)",
            dir.join("run.jsonl").display(),
            dir.join("trace.json").display()
        );
    }
    Ok(())
}

/// The standard logging observer: step lines at `log_every`, re-shard /
/// departure / eval / checkpoint notices always.  `steps` is the length of
/// this run; the last step of the run is always logged (the global `step`
/// counter continues across a resume, so it cannot serve as the bound).
fn logging_observer(log_every: usize, steps: usize) -> impl FnMut(&Event) + Send {
    let mut seen = 0usize;
    move |ev: &Event| match ev {
        Event::StepCompleted { step, loss, devices, breakdown, bytes_moved } => {
            seen += 1;
            let idx = step.saturating_sub(1);
            if idx % log_every as u64 == 0 || seen == steps {
                eprintln!(
                    "step {idx:>4}  loss {loss:.4}  devices {devices}  {breakdown}  wire {:.2} MiB",
                    *bytes_moved as f64 / (1 << 20) as f64
                );
            }
        }
        Event::Repartitioned { step } => eprintln!("step {step}: fleet re-sharded"),
        Event::Rebalanced { step, shares } => {
            eprintln!("step {step}: replica batch slices rebalanced to {shares:?}")
        }
        Event::WorkerLeft { step, devices_left } => {
            eprintln!("step {step}: worker left ({devices_left} devices remain)")
        }
        Event::EvalDone { accuracy, .. } => {
            eprintln!("final held-out accuracy: {:.1}%", accuracy * 100.0)
        }
        Event::CheckpointSaved { step, path } => {
            eprintln!("checkpoint @ step {step} -> {}", path.display())
        }
        Event::HealthChanged { step, device, from, to, ratio } => eprintln!(
            "step {step}: dev{device} {} -> {} (step-time ratio {ratio:.2}x)",
            from.label(),
            to.label()
        ),
        Event::AnomalyFlagged { step, step_ms, median_ms, .. } => eprintln!(
            "step {step}: anomalous step time {step_ms:.1} ms (rolling median {median_ms:.1} ms)"
        ),
    }
}

fn print_session_banner(session: &Session) {
    if let Some(addr) = session.metrics_addr() {
        eprintln!("live metrics: http://{addr}/metrics  (convdist top {addr})");
    }
    let rt = session.runtime();
    eprintln!(
        "runtime: platform={} arch={} batch={} ({} conv layers, {} executables)",
        rt.platform(),
        rt.arch().label(),
        rt.arch().batch,
        rt.arch().num_convs(),
        rt.manifest().executables.len()
    );
    eprintln!("calibration (probe seconds): {:?}", session.trainer().probe_times());
    let arch = rt.arch();
    for layer in 1..=arch.num_convs() {
        let k = arch.kernels(layer);
        let shards: Vec<String> = session
            .trainer()
            .shards(layer)
            .iter()
            .map(|s| format!("dev{}:{}..{} (b{})", s.device, s.lo, s.hi, s.bucket))
            .collect();
        eprintln!("conv{layer} ({k} kernels) -> {}", shards.join(" "));
    }
}

fn print_report(report: &RunReport) {
    if report.steps_run == 0 {
        eprintln!("run: no steps recorded (wall {:.1}s)", report.wall.as_secs_f64());
        return;
    }
    eprintln!(
        "run: {} steps (from step {})  final loss {:.4}  wire {:.2} MiB  wall {:.1}s",
        report.steps_run,
        report.first_step,
        report.final_loss(),
        report.bytes_moved as f64 / (1 << 20) as f64,
        report.wall.as_secs_f64()
    );
    eprintln!("cumulative: {}", report.cumulative);
    if report.repartitions > 0 || report.departures > 0 {
        eprintln!(
            "scheduler: {} re-shards, {} departures",
            report.repartitions, report.departures
        );
    }
}

/// `CONVDIST_STATS=1`: dump per-executable timing from the master runtime.
fn maybe_print_stats(session: &Session) {
    if std::env::var("CONVDIST_STATS").is_err() {
        return;
    }
    eprintln!("master-runtime executable stats (slowest first):");
    for (name, s) in session.runtime().stats() {
        eprintln!(
            "  {name:28} {:>5} calls  {:>10.3?} total  {:>9.3?}/call",
            s.calls,
            s.total,
            s.total / s.calls.max(1) as u32
        );
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    eprintln!(
        "cluster: {} workers + master, devices={} throttle={} shaped={}",
        cfg.cluster.workers, cfg.cluster.devices, cfg.cluster.throttle, cfg.network.shaped
    );
    let mut builder = SessionBuilder::from_experiment(&cfg)?
        .observe(obs_config(args, &cfg))
        .on_event(logging_observer(cfg.trainer.log_every, cfg.trainer.steps));
    builder = apply_arch_override(args, &cfg, builder)?;
    if let Some(ckpt) = args.opt("resume") {
        builder = builder.resume_from(ckpt);
    }
    let mut session = builder.build()?;
    print_session_banner(&session);
    let report = session.run()?;
    print_report(&report);
    if let Some(path) = args.opt("save") {
        session.save_checkpoint(path)?;
    }
    maybe_print_stats(&session);
    finish_obs(&mut session, args)?;
    session.shutdown()
}

fn cmd_worker(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let listen = args.opt("listen").unwrap_or("127.0.0.1:7701");
    let id: u32 = args.get("id", 1)?;
    let slowdown: f64 = args.get("slowdown", 1.0)?;
    let listener = std::net::TcpListener::bind(listen)?;
    eprintln!("worker {id} listening on {listen} (slowdown {slowdown}x)");
    let link = TcpLink::accept_one(&listener)?;
    let opts =
        WorkerOptions::new(id, Throttle::new(slowdown.max(1.0))).traced(args.flag("trace"));
    worker_loop(link, rt, opts)?;
    eprintln!("worker {id}: TrainOver received, shutting down");
    Ok(())
}

fn cmd_master(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let workers = args.require("workers")?;
    let addrs: Vec<String> =
        workers.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect();
    if addrs.is_empty() {
        bail!("no worker addresses given");
    }
    let mut builder = SessionBuilder::from_experiment(&cfg)?
        .tcp(addrs)
        .observe(obs_config(args, &cfg))
        .on_event(logging_observer(cfg.trainer.log_every, cfg.trainer.steps));
    builder = apply_arch_override(args, &cfg, builder)?;
    let mut session = builder.build()?;
    print_session_banner(&session);
    let report = session.run()?;
    print_report(&report);
    maybe_print_stats(&session);
    finish_obs(&mut session, args)?;
    session.shutdown()
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let rounds: u32 = args.get("rounds", 5)?;
    let probe = rt.arch().probe.clone();
    let mut rng = convdist::tensor::Pcg32::seed(1);
    let x =
        convdist::tensor::Tensor::randn(&[probe.batch, probe.in_ch, probe.img, probe.img], &mut rng);
    let w = convdist::tensor::Tensor::randn(&[probe.k, probe.in_ch, probe.kh, probe.kw], &mut rng);
    let b = convdist::tensor::Tensor::zeros(&[probe.k]);
    let args_v = [x.into(), w.into(), b.into()];
    let _ = rt.execute("probe", &args_v)?;
    let mut best = f64::MAX;
    for i in 0..rounds.max(1) {
        let (_, d) = rt.execute_timed("probe", &args_v)?;
        eprintln!("round {i}: {:.6}s", d.as_secs_f64());
        best = best.min(d.as_secs_f64());
    }
    let gflops = probe.flops as f64 / best / 1e9;
    println!("probe best: {best:.6}s  ->  {gflops:.2} effective GFLOPS");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let figs = match args.opt("id") {
        Some(id) => vec![figures::generate(id).ok_or_else(|| {
            anyhow::anyhow!("unknown figure id {id:?} (try fig5..fig13, table1/4/5, amdahl)")
        })?],
        None => figures::all(),
    };
    for f in figs {
        if args.flag("csv") {
            println!("# {}", f.id);
            print!("{}", f.to_csv());
        } else {
            println!("{}", f.render());
        }
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let mut tcfg = TrainerConfig::default();
    if let Some(s) = args.get_opt::<usize>("steps")? {
        tcfg.steps = s;
    }
    let replicas: usize = args.get("replicas", 2)?;
    let arch = rt.arch().clone();
    let mut ds = default_dataset(arch.img, arch.in_ch, arch.num_classes, tcfg.seed);
    match args.opt("kind").unwrap_or("single") {
        "single" => {
            let mut t = SingleDeviceTrainer::new(rt, &tcfg, Throttle::none())?;
            for step in 0..tcfg.steps {
                let batch = ds.batch(arch.batch, step)?;
                let (loss, b) = t.step(&batch)?;
                if step % tcfg.log_every == 0 {
                    eprintln!("step {step:>4}  loss {loss:.4}  {b}");
                }
            }
        }
        "dp" => {
            let mut t = DataParallelTrainer::new(rt, &tcfg, vec![Throttle::none(); replicas])?;
            for step in 0..tcfg.steps {
                let batch = ds.batch(arch.batch, step)?;
                let (loss, b) = t.step(&batch)?;
                if step % tcfg.log_every == 0 {
                    eprintln!("step {step:>4}  loss {loss:.4}  replicas {replicas}  {b}");
                }
            }
        }
        other => bail!("unknown baseline kind {other:?} (single|dp)"),
    }
    Ok(())
}

/// `convdist check`: run the static analyzer over a config file, a graph
/// JSON file and/or a named preset (any combination; reports merge).  With
/// no source, the default experiment config — what `convdist run` without
/// `--config` would build — is pre-flighted.  Exits non-zero on any
/// deny-level diagnostic, so CI can gate on it directly.
fn cmd_check(args: &Args) -> Result<()> {
    let jsonl = match args.opt("format") {
        None | Some("human") => false,
        Some("jsonl") => true,
        Some(other) => bail!("unknown --format {other:?} (human|jsonl)"),
    };
    let mut rep = analysis::Report::new();
    let mut sources = 0usize;
    if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        rep.merge(analysis::check_config_text(&text));
        sources += 1;
    }
    if let Some(path) = args.opt("graph") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        rep.merge(analysis::check_graph_text(&text));
        sources += 1;
    }
    if let Some(name) = args.opt("arch") {
        let Some(spec) = ArchSpec::preset(name) else {
            bail!("unknown arch preset {name:?} (try: default, tiny, deep_cifar, tiny_deep)");
        };
        rep.merge(analysis::check_spec(&spec));
        // Plan pass against the default roster and bandwidth, so a bare
        // `check --arch` still exercises Eq.1 feasibility.
        let cfg = ExperimentConfig::default();
        rep.merge(analysis::check_plan(
            &spec,
            &cfg.device_profiles(),
            &analysis::PlanCheckOptions {
                bandwidth_mbps: cfg.network.bandwidth_mbps,
                adaptive: Some(cfg.adaptive),
            },
        ));
        sources += 1;
    }
    if sources == 0 {
        rep.merge(analysis::check_experiment(&ExperimentConfig::default()));
    }
    if jsonl {
        print!("{}", rep.render_jsonl());
    } else {
        print!("{}", rep.render_human());
    }
    let denies = rep.count(analysis::Severity::Deny);
    if denies > 0 {
        bail!("check failed: {denies} deny-level diagnostic(s)");
    }
    eprintln!(
        "check passed: {} warning(s), {} note(s)",
        rep.count(analysis::Severity::Warn),
        rep.count(analysis::Severity::Note)
    );
    Ok(())
}

/// `convdist report run.jsonl`: schema-validate a `--trace` run log and
/// print the paper's Figure-6-style phase summary.  Exits non-zero on any
/// malformed line, so CI can gate traced runs on it directly.
fn cmd_report(args: &Args) -> Result<()> {
    let Some(path) = args.positional.first() else {
        bail!("usage: convdist report <run.jsonl>");
    };
    print!("{}", convdist::obs::report::summarize_file(std::path::Path::new(path))?);
    Ok(())
}

/// `convdist top <host:port | run.jsonl>`: one-shot fleet view — per-device
/// share, throughput and health — scraped from a live `--metrics-addr`
/// endpoint or reconstructed from a (possibly still-growing) run log.
fn cmd_top(args: &Args) -> Result<()> {
    use convdist::obs::live;
    let Some(target) = args.positional.first() else {
        bail!("usage: convdist top <host:port | run.jsonl>");
    };
    let path = std::path::Path::new(target);
    let snap = if path.exists() {
        // Lenient tail read: a log being written right now may end mid-line.
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {target}: {e}"))?;
        live::TopSnapshot::from_runlog(&text)
            .map_err(|e| anyhow::anyhow!("{target}: {e}"))?
    } else if target.contains(':') {
        let body = live::http_get(target)?;
        live::TopSnapshot::from_prometheus(&body)?
    } else {
        bail!("{target}: neither a run log on disk nor a host:port address");
    };
    print!("{}", snap.render());
    Ok(())
}

/// `convdist compare BASE.jsonl CAND.jsonl`: diff two run logs on step-time
/// p50/p95 and per-phase means; exit non-zero when any gated metric is more
/// than `--threshold` percent slower than the baseline.
fn cmd_compare(args: &Args) -> Result<()> {
    use convdist::obs::compare;
    let (Some(base_path), Some(cand_path)) = (args.positional.first(), args.positional.get(1))
    else {
        bail!("usage: convdist compare BASE.jsonl CAND.jsonl [--threshold PCT] [--format jsonl]");
    };
    let jsonl = match args.opt("format") {
        None | Some("human") => false,
        Some("jsonl") => true,
        Some(other) => bail!("unknown --format {other:?} (human|jsonl)"),
    };
    let threshold: f64 = args.get("threshold", 10.0)?;
    if !threshold.is_finite() || threshold < 0.0 {
        bail!("--threshold must be a non-negative percentage, got {threshold}");
    }
    let base = compare::stats_from_file(std::path::Path::new(base_path))?;
    let cand = compare::stats_from_file(std::path::Path::new(cand_path))?;
    let rep = compare::compare(&base, &cand, threshold);
    if jsonl {
        print!("{}", rep.render_jsonl());
    } else {
        print!("{}", rep.render_human(base.steps, cand.steps));
    }
    if rep.regressed() {
        bail!("compare failed: candidate regressed past the {threshold}% threshold");
    }
    Ok(())
}

/// `convdist serve`: forward-only inference over the distributed fleet with
/// dynamic batching (DESIGN.md §13).  The checkpoint supplies the weights,
/// the config (or flags) the fleet topology and batcher budgets; the server
/// runs until a client sends `Drain` (`convdist infer --drain`).
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ckpt = args.require("ckpt")?;
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7800");
    eprintln!(
        "cluster: {} workers + master, devices={} throttle={}",
        cfg.cluster.workers, cfg.cluster.devices, cfg.cluster.throttle
    );
    let mut builder = SessionBuilder::from_experiment(&cfg)?.observe(obs_config(args, &cfg));
    builder = apply_arch_override(args, &cfg, builder)?;
    let session = builder.inference(ckpt)?;
    let rt = session.runtime().clone();
    let ladder = rt.arch().batch_buckets.clone();
    let mut scfg = cfg.serve.unwrap_or_else(|| ServeConfig::for_ladder(&ladder));
    if let Some(k) = args.get_opt::<usize>("max-batch")? {
        scfg.max_batch = k;
    }
    if let Some(d) = args.get_opt::<u64>("max-delay-ms")? {
        scfg.max_delay_ms = d;
    }
    eprintln!(
        "runtime: platform={} arch={} ({} conv layers, {} executables)",
        rt.platform(),
        rt.arch().label(),
        rt.arch().num_convs(),
        rt.manifest().executables.len()
    );
    let serving = session.serve(addr, scfg)?;
    if let Some(a) = serving.metrics_addr() {
        eprintln!("live metrics: http://{a}/metrics  (convdist top {a})");
    }
    eprintln!(
        "serving on {}  (batcher: max_batch {}, max_delay {} ms, ladder {:?})",
        serving.addr(),
        scfg.max_batch,
        scfg.max_delay_ms,
        ladder
    );
    let served = serving.join()?;
    eprintln!("drained: {served} request(s) served");
    Ok(())
}

/// `convdist infer`: load client for a `convdist serve` endpoint.  Sends
/// `--requests` random images (shaped by the local arch resolution — use
/// the same `--arch`/`--config` as the server) over `--concurrency`
/// connections and prints latency percentiles; `--drain` then shuts the
/// server down gracefully.
fn cmd_infer(args: &Args) -> Result<()> {
    use std::time::Instant;
    let addr = args.require("addr")?.to_string();
    let requests: usize = args.get("requests", 8)?;
    let concurrency: usize = args.get("concurrency", 2)?;
    if requests == 0 || concurrency == 0 {
        bail!("--requests and --concurrency must be at least 1");
    }
    let arch = match args.opt("config") {
        Some(_) => {
            let cfg = load_config(args)?;
            SessionBuilder::from_experiment(&cfg)?.resolve_arch()?
        }
        None => open_runtime(args)?.arch().clone(),
    };
    let shape = [arch.in_ch, arch.img, arch.img];
    let workers: Vec<std::thread::JoinHandle<Result<Vec<f64>>>> = (0..concurrency)
        .map(|t| {
            let addr = addr.clone();
            let quota = requests / concurrency + usize::from(t < requests % concurrency);
            std::thread::spawn(move || -> Result<Vec<f64>> {
                let mut latencies = Vec::with_capacity(quota);
                if quota == 0 {
                    return Ok(latencies);
                }
                let mut client = ServeClient::connect(&addr)?;
                let mut rng = convdist::tensor::Pcg32::seed_stream(0x1F0, t as u64);
                for _ in 0..quota {
                    let image = convdist::tensor::Tensor::randn(&shape, &mut rng);
                    let start = Instant::now();
                    let logits = client.classify(&image)?;
                    latencies.push(start.elapsed().as_secs_f64() * 1e3);
                    anyhow::ensure!(
                        logits.data().iter().all(|v| v.is_finite()),
                        "non-finite logits from server"
                    );
                }
                Ok(latencies)
            })
        })
        .collect();
    let mut latencies = Vec::with_capacity(requests);
    for w in workers {
        let ls = w.join().map_err(|_| anyhow::anyhow!("infer client thread panicked"))??;
        latencies.extend(ls);
    }
    latencies.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p).round() as usize];
    println!(
        "{} request(s) ok over {} connection(s): p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        latencies.len(),
        concurrency,
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    if args.flag("drain") {
        ServeClient::connect(&addr)?.drain()?;
        eprintln!("drain acknowledged by {addr}");
    }
    Ok(())
}
