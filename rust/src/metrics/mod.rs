//! Timing breakdowns in the paper's vocabulary.
//!
//! Figures 6 and 8 split a training step into exactly three phases:
//! * **Comm.** — master↔slave transfer time,
//! * **Conv.** — convolution time "by the slowest node" (not cumulative),
//! * **Comp.** — everything that is not a convolution (LRN, pool, FC, loss,
//!   optimizer).
//!
//! [`Breakdown`] carries those three durations through the whole system:
//! real cluster runs fill it from wall clocks, the analytic simulator fills
//! it from the Eq. 2 model, and the figure harness prints either.

use std::fmt;
use std::time::{Duration, Instant};

/// Comm/Conv/Comp split of one step (or one averaged step).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub comm: Duration,
    pub conv: Duration,
    pub comp: Duration,
}

impl Breakdown {
    pub fn total(&self) -> Duration {
        self.comm + self.conv + self.comp
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.comm += other.comm;
        self.conv += other.conv;
        self.comp += other.comp;
    }

    pub fn scale(&self, f: f64) -> Breakdown {
        Breakdown {
            comm: self.comm.mul_f64(f),
            conv: self.conv.mul_f64(f),
            comp: self.comp.mul_f64(f),
        }
    }

    /// Phase percentages `(comm, conv, comp)` — the paper quotes e.g.
    /// "communication time rising from 19% with 2 GPUs to 30%".
    pub fn percentages(&self) -> (f64, f64, f64) {
        let t = self.total().as_secs_f64();
        if t == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            100.0 * self.comm.as_secs_f64() / t,
            100.0 * self.conv.as_secs_f64() / t,
            100.0 * self.comp.as_secs_f64() / t,
        )
    }

    /// Speedup of `self` relative to a reference breakdown.
    pub fn speedup_vs(&self, reference: &Breakdown) -> f64 {
        reference.total().as_secs_f64() / self.total().as_secs_f64()
    }
}

impl fmt::Display for Breakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (pc, pv, pp) = self.percentages();
        write!(
            f,
            "total {:8.3}s  comm {:7.3}s ({pc:4.1}%)  conv {:7.3}s ({pv:4.1}%)  comp {:7.3}s ({pp:4.1}%)",
            self.total().as_secs_f64(),
            self.comm.as_secs_f64(),
            self.conv.as_secs_f64(),
            self.comp.as_secs_f64(),
        )
    }
}

/// Accumulates phase time with explicit start/stop, panicking on misuse in
/// debug builds (a phase left open is a bookkeeping bug).  Release builds
/// recover gracefully instead: the open span is dropped, nothing is
/// recorded, and [`PhaseTimer::misuse`] counts the incident so the obs
/// layer can surface it as a `phase_timer_misuse` metric.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    pub breakdown: Breakdown,
    open: Option<(Phase, Instant)>,
    misuse: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Comm,
    Conv,
    Comp,
}

impl PhaseTimer {
    pub fn start(&mut self, phase: Phase) {
        debug_assert!(self.open.is_none(), "phase {:?} still open", self.open);
        if self.open.take().is_some() {
            self.misuse += 1; // release: drop the open span, keep going
        }
        self.open = Some((phase, Instant::now()));
    }

    pub fn stop(&mut self) {
        debug_assert!(self.open.is_some(), "stop() without start()");
        match self.open.take() {
            Some((phase, t0)) => self.record(phase, t0.elapsed()),
            None => self.misuse += 1, // release: nothing to close
        }
    }

    /// Misuse incidents survived in release builds (start-over-open or
    /// stop-without-start); always 0 in debug builds, which panic instead.
    pub fn misuse(&self) -> u64 {
        self.misuse
    }

    /// Record an externally measured duration (e.g. a worker-reported conv
    /// time, or a simulated comm time).
    pub fn record(&mut self, phase: Phase, d: Duration) {
        match phase {
            Phase::Comm => self.breakdown.comm += d,
            Phase::Conv => self.breakdown.conv += d,
            Phase::Comp => self.breakdown.comp += d,
        }
    }

    /// Run `f`, attributing its wall time to `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed());
        out
    }
}

/// Adaptive-scheduler observability: re-partition events, membership churn
/// and the latest per-device utilization — what a production operator
/// watches to see the feedback loop working (ROADMAP north-star).  Filled
/// by `cluster::master`, printed by examples and the CLI.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SchedStats {
    /// Telemetry-driven Eq. 1 re-shards ordered by the policy.
    pub repartitions: u64,
    /// Workers dropped (error, timeout or graceful `Leave`).
    pub departures: u64,
    /// Straggler-detector hits (a device beyond k·σ of the fleet).
    pub straggler_flags: u64,
    /// `(device id, utilization in [0,1])` of the last examined step,
    /// master first.  Utilization = busy time / step bottleneck.
    pub utilization: Vec<(usize, f64)>,
    /// Achieved GFLOP/s of the most recent execution of each conv op
    /// (keyed by layer + direction, e.g. `conv1_fwd` — deliberately not by
    /// bucket, so adaptive re-shards don't accumulate dead entries) as seen
    /// by the master's gather loop — the raw, per-op counterpart of the
    /// telemetry's smoothed seconds-per-GFLOP, kept so the EWMA rates can
    /// be sanity-checked against the measured `linalg` engine peak (an op
    /// rate far below the GEMM peak means framing, not arithmetic, is the
    /// bottleneck).
    pub op_gflops: Vec<(String, f64)>,
}

impl SchedStats {
    /// Record one executed op: `flops` of nominal work in `seconds` of pure
    /// compute.  Keeps the *latest* achieved GFLOP/s per op — smoothing
    /// lives in `sched::telemetry`; this is the raw observable.  Non-finite
    /// or non-positive observations are dropped, like the telemetry's.
    pub fn observe_gflops(&mut self, op: &str, seconds: f64, flops: f64) {
        let bad = !seconds.is_finite() || seconds <= 0.0 || !flops.is_finite() || flops <= 0.0;
        if bad {
            return;
        }
        let rate = flops / 1e9 / seconds;
        match self.op_gflops.iter_mut().find(|(o, _)| o == op) {
            Some((_, r)) => *r = rate,
            None => self.op_gflops.push((op.to_string(), rate)),
        }
    }
}

impl fmt::Display for SchedStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "repartitions {}  departures {}  straggler flags {}  util",
            self.repartitions, self.departures, self.straggler_flags
        )?;
        if self.utilization.is_empty() {
            write!(f, " n/a")?;
        }
        for (d, u) in &self.utilization {
            write!(f, " dev{d}={:.0}%", 100.0 * u)?;
        }
        if !self.op_gflops.is_empty() {
            write!(f, "  gflops")?;
            for (op, r) in &self.op_gflops {
                write!(f, " {op}={r:.2}")?;
            }
        }
        Ok(())
    }
}

/// RFC-4180 CSV quoting: fields containing commas, quotes or newlines are
/// wrapped in double quotes with embedded quotes doubled, so composite
/// labels like `cpu,4` stay one field for downstream parsers.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// One figure/table row as emitted by the harness: label + series of
/// (x, value) points; rendered as aligned text or CSV.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn to_csv(&self) -> String {
        let label = csv_field(&self.label);
        let mut s = String::new();
        for (x, y) in &self.points {
            s.push_str(&format!("{label},{x},{y}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_percentages_sum_to_100() {
        let b = Breakdown {
            comm: Duration::from_millis(100),
            conv: Duration::from_millis(300),
            comp: Duration::from_millis(100),
        };
        let (c, v, p) = b.percentages();
        assert!((c + v + p - 100.0).abs() < 1e-9);
        assert!((c - 20.0).abs() < 1e-9);
        assert!((v - 60.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_vs_reference() {
        let one = Breakdown { conv: Duration::from_secs(10), ..Default::default() };
        let four = Breakdown {
            conv: Duration::from_secs(2),
            comm: Duration::from_secs(1),
            ..Default::default()
        };
        assert!((four.speedup_vs(&one) - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn phase_timer_attributes_time() {
        let mut t = PhaseTimer::default();
        t.time(Phase::Conv, || std::thread::sleep(Duration::from_millis(5)));
        t.record(Phase::Comm, Duration::from_millis(7));
        assert!(t.breakdown.conv >= Duration::from_millis(5));
        assert_eq!(t.breakdown.comm, Duration::from_millis(7));
        assert_eq!(t.breakdown.comp, Duration::ZERO);
    }

    #[test]
    fn sched_stats_display() {
        let mut s = SchedStats::default();
        assert_eq!(s.to_string(), "repartitions 0  departures 0  straggler flags 0  util n/a");
        s.repartitions = 2;
        s.departures = 1;
        s.straggler_flags = 3;
        s.utilization = vec![(0, 0.93), (2, 0.505)];
        let out = s.to_string();
        assert!(out.contains("repartitions 2"), "{out}");
        assert!(out.contains("dev0=93%"), "{out}");
        assert!(out.contains("dev2=50%"), "{out}");
        s.observe_gflops("conv1_fwd", 0.5, 4e9);
        let out = s.to_string();
        assert!(out.contains("gflops conv1_fwd=8.00"), "{out}");
    }

    #[test]
    fn observe_gflops_keeps_latest_per_op_and_drops_bad_samples() {
        let mut s = SchedStats::default();
        s.observe_gflops("conv1_fwd", 1.0, 2e9);
        s.observe_gflops("conv2_bwd", 0.5, 3e9);
        assert_eq!(s.op_gflops.len(), 2);
        assert!((s.op_gflops[0].1 - 2.0).abs() < 1e-12);
        assert!((s.op_gflops[1].1 - 6.0).abs() < 1e-12);
        // Latest observation wins (no averaging here).
        s.observe_gflops("conv1_fwd", 1.0, 4e9);
        assert_eq!(s.op_gflops.len(), 2);
        assert!((s.op_gflops[0].1 - 4.0).abs() < 1e-12);
        // Bad samples are dropped, like FleetTelemetry::record's.
        s.observe_gflops("conv1_fwd", 0.0, 1e9);
        s.observe_gflops("conv1_fwd", f64::INFINITY, 1e9);
        s.observe_gflops("conv1_fwd", 1.0, -1.0);
        assert!((s.op_gflops[0].1 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn observed_gflops_is_the_reciprocal_of_the_telemetry_rate() {
        // The sanity-check link: telemetry smooths seconds-per-GFLOP, this
        // records GFLOP-per-second of the same observation.
        let mut s = SchedStats::default();
        let (secs, flops) = (0.02, 5e9);
        s.observe_gflops("probe", secs, flops);
        let sec_per_gflop = secs / (flops / 1e9);
        assert!((s.op_gflops[0].1 - 1.0 / sec_per_gflop).abs() < 1e-9);
    }

    #[test]
    fn series_csv() {
        let mut s = Series::new("cpu4");
        s.push(1.0, 1.5);
        s.push(2.0, 2.5);
        assert_eq!(s.to_csv(), "cpu4,1,1.5\ncpu4,2,2.5\n");
    }

    #[test]
    fn series_csv_quotes_composite_labels() {
        // RFC-4180: a label with a comma must be quoted...
        let mut s = Series::new("cpu,4");
        s.push(1.0, 1.5);
        assert_eq!(s.to_csv(), "\"cpu,4\",1,1.5\n");
        // ...and embedded quotes doubled inside the quoted field.
        let mut q = Series::new("8\" node");
        q.push(2.0, 3.0);
        assert_eq!(q.to_csv(), "\"8\"\" node\",2,3\n");
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "stop() without start()")]
    fn phase_timer_stop_without_start_panics_in_debug() {
        PhaseTimer::default().stop();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "still open")]
    fn phase_timer_double_start_panics_in_debug() {
        let mut t = PhaseTimer::default();
        t.start(Phase::Comm);
        t.start(Phase::Conv);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn phase_timer_recovers_from_misuse_in_release() {
        let mut t = PhaseTimer::default();
        t.stop(); // stop without start: counted, nothing recorded
        assert_eq!(t.misuse(), 1);
        assert_eq!(t.breakdown, Breakdown::default());
        t.start(Phase::Comm);
        t.start(Phase::Conv); // drops the open Comm span
        t.stop();
        assert_eq!(t.misuse(), 2);
        assert_eq!(t.breakdown.comm, Duration::ZERO);
        assert!(t.breakdown.conv >= Duration::ZERO);
    }
}
