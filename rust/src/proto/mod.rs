//! Wire protocol between master and slave nodes — the rust rendering of the
//! paper's Algorithms 1 & 2 socket traffic.
//!
//! Frame layout (all little-endian):
//!
//! ```text
//! +--------+--------+------------+-----------------+--------+
//! | magic  | msg id | payload len| payload bytes   | crc32  |
//! | u32    | u8     | u32        | ...             | u32    |
//! +--------+--------+------------+-----------------+--------+
//! ```
//!
//! Tensor payloads are `[rank u32][dims u32...][raw f32/i32 bytes]` — the
//! paper sends raw doubles over sockets and notes the slave "knows how much
//! data to read from the socket and how it should reshape it, since data read
//! from sockets comes in vector form" (§4.1.2); we ship the dims in-band so a
//! frame is self-describing, and use f32 (the compute dtype) instead of f64,
//! halving Eq. 2's upload volume at zero accuracy cost.

mod frame;
mod message;

pub use frame::{crc32, frame_len, read_frame, write_frame, FRAME_MAGIC, MAX_PAYLOAD};
pub use message::{Message, WireSpan, WireTensor};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Pcg32, Tensor};
    use std::io::Cursor;

    fn roundtrip(msg: &Message) -> Message {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg).unwrap();
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn roundtrip_all_variants() {
        let mut rng = Pcg32::seed(1);
        let t = Tensor::randn(&[2, 3, 4, 4], &mut rng);
        let msgs = vec![
            Message::Hello { worker_id: 3, version: 1 },
            Message::Calibrate { rounds: 5 },
            Message::CalibrateResult { seconds: 0.12345 },
            Message::ConvWork {
                seq: 9,
                layer: 2,
                dir: 1,
                bucket: 8,
                inputs: WireTensor::from(&t),
                kernels: WireTensor::from(&t),
                extra: Some(WireTensor::from(&t)),
            },
            Message::ConvResult { seq: 9, outputs: vec![WireTensor::from(&t)], seconds: 1.5 },
            Message::AllOk,
            Message::TrainOver,
            Message::Error { reason: "boom".into() },
            Message::Ping { nonce: 77 },
            Message::Pong { nonce: 77 },
            Message::Leave { worker_id: 2, reason: "preempted".into() },
            Message::ShardUpdate { layer: 2, lo: 6, hi: 16, bucket: 12 },
            Message::SpanReport {
                worker_id: 1,
                seq: 9,
                spans: vec![WireSpan {
                    kind: WireSpan::KIND_CONV,
                    layer: 2,
                    dir: 1,
                    bucket: 8,
                    start_us: 5,
                    dur_us: 100,
                }],
            },
        ];
        for m in msgs {
            assert_eq!(roundtrip(&m), m);
        }
    }

    #[test]
    fn tensor_payload_roundtrip_preserves_shape_and_bits() {
        let mut rng = Pcg32::seed(2);
        let t = Tensor::randn(&[5, 7], &mut rng);
        let wt = WireTensor::from(&t);
        let back = wt.to_tensor().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn crc32_known_answers() {
        // IEEE CRC-32 test vectors ("check" value for "123456789").
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // Slicing path (>= 8 bytes) agrees with the byte path on a split.
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 3) as u8).collect();
        let full = crc32(&data);
        assert_ne!(full, 0);
    }

    #[test]
    fn corrupt_crc_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::AllOk).unwrap();
        let n = buf.len();
        buf[n - 1] ^= 0xff;
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("crc"), "{err}");
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Message::AllOk).unwrap();
        buf[0] ^= 0xff;
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Message::CalibrateResult { seconds: 1.0 },
        )
        .unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn oversized_length_rejected_without_allocation() {
        // Hand-craft a frame header claiming a 1 TiB payload.
        let mut buf = Vec::new();
        buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        buf.push(0x06); // AllOk id
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }
}
