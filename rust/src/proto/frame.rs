//! Length-prefixed, CRC-checked frames over any `Read`/`Write` stream.

use std::io::{Read, Write};

use super::message::Message;

pub const FRAME_MAGIC: u32 = 0xC0_4D_15_77; // "COnvDIST"
/// 1 GiB — far above any Eq. 2 payload in our configs; rejects garbage
/// lengths before allocating.
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// CRC-32 (IEEE 802.3, reflected), slicing-by-8.
///
/// §Perf note: the original bitwise implementation capped frame
/// encode/decode at ~140 MiB/s — with ~29 MiB on the wire per training step
/// that was ~25 % of the unthrottled step's Comm time.  Slicing-by-8
/// (8 × 256-entry tables, built once) moves ~8 bytes per iteration;
/// measured ~9x faster on the 1.6 MiB ConvWork frame (EXPERIMENTS.md §Perf).
pub fn crc32(data: &[u8]) -> u32 {
    !crc32_feed(0xffff_ffff, data)
}

static CRC_TABLES: std::sync::OnceLock<[[u32; 256]; 8]> = std::sync::OnceLock::new();

fn crc_tables() -> &'static [[u32; 256]; 8] {
    CRC_TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256u32 {
            let mut crc = i;
            for _ in 0..8 {
                let mask = (crc & 1).wrapping_neg();
                crc = (crc >> 1) ^ (0xedb8_8320 & mask);
            }
            t[0][i as usize] = crc;
        }
        for i in 0..256usize {
            let mut crc = t[0][i];
            for k in 1..8 {
                crc = t[0][(crc & 0xff) as usize] ^ (crc >> 8);
                t[k][i] = crc;
            }
        }
        t
    })
}

fn crc32_feed(mut crc: u32, data: &[u8]) -> u32 {
    let t = crc_tables();
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes(c[0..4].try_into().unwrap()) ^ crc;
        let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
        crc = t[7][(lo & 0xff) as usize]
            ^ t[6][((lo >> 8) & 0xff) as usize]
            ^ t[5][((lo >> 16) & 0xff) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xff) as usize]
            ^ t[2][((hi >> 8) & 0xff) as usize]
            ^ t[1][((hi >> 16) & 0xff) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    crc
}

/// Frame checksum covers the id byte and the length header as well as the
/// payload, so a corrupted header can never silently change the message
/// type (caught by `prop_corrupted_frames_error_never_panic`).
fn frame_crc(id: u8, len: u32, payload: &[u8]) -> u32 {
    let mut crc = crc32_feed(0xffff_ffff, &[id]);
    crc = crc32_feed(crc, &len.to_le_bytes());
    !crc32_feed(crc, payload)
}

/// Serialize `msg` and write one frame.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> anyhow::Result<()> {
    let (id, payload) = msg.encode();
    w.write_all(&FRAME_MAGIC.to_le_bytes())?;
    w.write_all(&[id])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.write_all(&frame_crc(id, payload.len() as u32, &payload).to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read and decode one frame.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Message> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    anyhow::ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#x}");
    let id = head[4];
    let len = u32::from_le_bytes(head[5..9].try_into().unwrap());
    anyhow::ensure!(len <= MAX_PAYLOAD, "frame payload {len} exceeds limit");
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut crc_buf = [0u8; 4];
    r.read_exact(&mut crc_buf)?;
    let crc = u32::from_le_bytes(crc_buf);
    let actual = frame_crc(id, len, &payload);
    anyhow::ensure!(crc == actual, "crc mismatch: frame {crc:#x} != computed {actual:#x}");
    Message::decode(id, &payload)
}

/// Size in bytes of the frame that `msg` would serialize to — the byte count
/// the bandwidth shaper charges (Eq. 2 is stated in elements; this is the
/// same quantity in bytes, plus fixed 13-byte framing overhead).
pub fn frame_len(msg: &Message) -> usize {
    let (_, payload) = msg.encode();
    payload.len() + 13
}
