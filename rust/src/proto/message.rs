//! Message bodies — the vocabulary of Algorithms 1 & 2.
//!
//! Hand-rolled little-endian encoding (no serde on the hot path): tensor
//! payloads dominate every frame and are copied at memcpy speed.

use anyhow::{bail, ensure, Result};

use crate::tensor::Tensor;

/// A tensor on the wire: shape + raw f32 data.
#[derive(Clone, Debug, PartialEq)]
pub struct WireTensor {
    pub shape: Vec<u32>,
    pub data: Vec<f32>,
}

impl WireTensor {
    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::new(self.shape.iter().map(|&d| d as usize).collect(), self.data.clone())
    }

    pub fn into_tensor(self) -> Result<Tensor> {
        Tensor::new(self.shape.iter().map(|&d| d as usize).collect(), self.data)
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(self.data.len() as u32).to_le_bytes());
        // Bulk-copy the f32 payload as bytes (little-endian hosts only,
        // which PJRT CPU already assumes).
        //
        // SAFETY: `data` is a live `Vec<f32>`, so `data.as_ptr()` is valid
        // for reads of `data.len() * 4` bytes; f32 has no padding or invalid
        // bit patterns, and any alignment is fine when reinterpreting *down*
        // to u8 (align 1).  The borrow of `self.data` outlives `bytes`.
        debug_assert_eq!(
            self.data.as_ptr() as usize % std::mem::align_of::<f32>(),
            0,
            "Vec<f32> allocation must be f32-aligned"
        );
        let bytes =
            unsafe { std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4) };
        debug_assert_eq!(bytes.len(), self.data.len() * 4);
        out.extend_from_slice(bytes);
    }

    fn decode_from(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let rank = take_u32(buf, pos)? as usize;
        ensure!(rank <= 8, "tensor rank {rank} too large");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(take_u32(buf, pos)?);
        }
        let n = take_u32(buf, pos)? as usize;
        let expect: u64 = shape.iter().map(|&d| d as u64).product();
        ensure!(expect == n as u64, "tensor payload {n} != shape product {expect}");
        ensure!(buf.len() >= *pos + n * 4, "tensor payload truncated");
        let mut data = vec![0f32; n];
        let src = &buf[*pos..*pos + n * 4];
        // SAFETY: `src` is an in-bounds slice of exactly `n * 4` bytes
        // (checked by the `ensure!` above); the destination is a freshly
        // allocated `Vec<f32>` of `n` elements, i.e. `n * 4` writable bytes
        // that cannot overlap a borrowed input buffer.  Byte-wise copy
        // (u8 -> u8) has no alignment requirement on either side, and every
        // bit pattern is a valid f32.
        debug_assert_eq!(src.len(), n * 4);
        debug_assert_eq!(
            data.as_ptr() as usize % std::mem::align_of::<f32>(),
            0,
            "Vec<f32> allocation must be f32-aligned"
        );
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), data.as_mut_ptr() as *mut u8, n * 4);
        }
        *pos += n * 4;
        Ok(Self { shape, data })
    }

    pub fn size_bytes(&self) -> usize {
        8 + self.shape.len() * 4 + self.data.len() * 4
    }
}

impl From<&Tensor> for WireTensor {
    fn from(t: &Tensor) -> Self {
        Self {
            shape: t.shape().iter().map(|&d| d as u32).collect(),
            data: t.data().to_vec(),
        }
    }
}

/// One timed interval measured on a worker, shipped back piggybacked on the
/// gather (`Message::SpanReport`) so worker-side conv spans land in the
/// master's timeline.  Times are microseconds relative to the worker's own
/// handling of the `ConvWork` frame — the master re-anchors them at the
/// gather receive time (the two clocks are never compared directly).
#[derive(Clone, Debug, PartialEq)]
pub struct WireSpan {
    /// [`WireSpan::KIND_CONV`] (pure conv compute) or
    /// [`WireSpan::KIND_SERVE`] (whole frame handling: decode + compute +
    /// encode — the non-conv remainder is wire/serialization overhead).
    pub kind: u8,
    pub layer: u8,
    /// 0 = forward, 1 = backward (mirrors `ConvWork::dir`).
    pub dir: u8,
    pub bucket: u32,
    pub start_us: u64,
    pub dur_us: u64,
}

impl WireSpan {
    pub const KIND_CONV: u8 = 0;
    pub const KIND_SERVE: u8 = 1;

    fn encode_into(&self, out: &mut Vec<u8>) {
        out.push(self.kind);
        out.push(self.layer);
        out.push(self.dir);
        out.extend_from_slice(&self.bucket.to_le_bytes());
        out.extend_from_slice(&self.start_us.to_le_bytes());
        out.extend_from_slice(&self.dur_us.to_le_bytes());
    }

    fn decode_from(buf: &[u8], pos: &mut usize) -> Result<Self> {
        ensure!(buf.len() >= *pos + 3, "WireSpan truncated");
        let (kind, layer, dir) = (buf[*pos], buf[*pos + 1], buf[*pos + 2]);
        *pos += 3;
        Ok(Self {
            kind,
            layer,
            dir,
            bucket: take_u32(buf, pos)?,
            start_us: take_u64(buf, pos)?,
            dur_us: take_u64(buf, pos)?,
        })
    }
}

/// Everything master and slaves say to each other.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Slave -> master on connect.
    Hello { worker_id: u32, version: u32 },
    /// Master -> slave: run the calibration probe `rounds` times, report the
    /// best time (paper §4.1.1: "a quick test is performed on all machines").
    Calibrate { rounds: u32 },
    /// Slave -> master: probe seconds (minimum over rounds).
    CalibrateResult { seconds: f64 },
    /// Master -> slave: convolve these inputs with this kernel shard
    /// (Algorithm 1 lines 9–13: "All slaves receive same inputs but
    /// different kernels").  `dir` 0 = forward, 1 = backward; backward packs
    /// the output-cotangent slice in `extra`.
    ConvWork {
        /// Scatter-round sequence number; echoed in `ConvResult` so the
        /// master can discard stale replies after an aborted step (worker
        /// failure triggers a re-partition + retry — see cluster::master).
        seq: u32,
        layer: u8,
        dir: u8,
        bucket: u32,
        inputs: WireTensor,
        kernels: WireTensor,
        /// fwd: bias [K]; bwd: gy slice [B,K,H,W].
        extra: Option<WireTensor>,
    },
    /// Slave -> master: the produced feature maps (fwd: `[y]`; bwd:
    /// `[gx_partial, gw, gb]`), plus the pure compute seconds so the master
    /// can attribute Conv vs Comm time in the Figure 6/8 breakdowns.
    ConvResult { seq: u32, outputs: Vec<WireTensor>, seconds: f64 },
    /// Master -> slave after gathering a batch (Algorithm 1 line 21).
    AllOk,
    /// Master -> slave: training finished, shut down (Algorithm 1 line 28).
    TrainOver,
    /// Either direction: fatal error with reason.
    Error { reason: String },
    /// Master -> slave: liveness heartbeat; the slave echoes `nonce` in
    /// [`Message::Pong`].  An unresponsive slave is dropped from the fleet
    /// (elastic membership — beyond the paper's protocol).
    Ping { nonce: u32 },
    /// Slave -> master: heartbeat reply.
    Pong { nonce: u32 },
    /// Slave -> master: graceful departure.  The master re-absorbs the
    /// slave's kernel range into the survivors and retries the batch.
    Leave { worker_id: u32, reason: String },
    /// Master -> slave after a re-partition: the slave's new shard of
    /// `layer` (`[lo, hi)`, compiled bucket `bucket`; `bucket == 0` means
    /// no shard — the slave idles for that layer).  Purely advisory: the
    /// slave pre-warms the bucket executables so the re-sharded fleet does
    /// not pay preparation time on the next scatter.
    ShardUpdate { layer: u8, lo: u32, hi: u32, bucket: u32 },
    /// Slave -> master, immediately before the matching `ConvResult` when
    /// the worker runs with tracing on: the spans it measured while serving
    /// scatter round `seq`.  Piggybacked on the gather — no extra round
    /// trip — and safely ignored by masters that are not tracing.
    SpanReport { worker_id: u32, seq: u32, spans: Vec<WireSpan> },
    /// Client -> serve frontend: classify one image (`[C, H, W]` — the
    /// batch axis is the server's to choose).  `id` is echoed in the reply
    /// so a client may pipeline requests over one connection.
    InferRequest { id: u64, image: WireTensor },
    /// Serve frontend -> client: the logits row for request `id`.
    InferReply { id: u64, logits: WireTensor },
    /// Client -> serve frontend: stop accepting connections, finish every
    /// queued request, then shut the fleet down (graceful drain).
    Drain,
    /// Replica -> replica during the gradient all-reduce (DESIGN.md §14):
    /// one chunk of the flattened gradient of parameter index `param`
    /// (manifest order), starting at element `offset`.  `seq` is the
    /// all-reduce round (the global step), echoed back so stale chunks from
    /// an aborted round are discarded.  Chunking lets large conv-kernel
    /// tensors pipeline through a ring instead of serializing whole.
    GradChunk { seq: u32, param: u32, offset: u32, data: WireTensor },
    /// Root/ring tail -> replica: the fully reduced chunk (same addressing
    /// as the matching [`Message::GradChunk`]); every replica applies the
    /// identical bytes, keeping parameters in bit-for-bit lockstep.
    GradReduced { seq: u32, param: u32, offset: u32, data: WireTensor },
}

const ID_HELLO: u8 = 0x01;
const ID_CALIBRATE: u8 = 0x02;
const ID_CALIBRATE_RESULT: u8 = 0x03;
const ID_CONV_WORK: u8 = 0x04;
const ID_CONV_RESULT: u8 = 0x05;
const ID_ALL_OK: u8 = 0x06;
const ID_TRAIN_OVER: u8 = 0x07;
const ID_ERROR: u8 = 0x08;
const ID_PING: u8 = 0x09;
const ID_PONG: u8 = 0x0A;
const ID_LEAVE: u8 = 0x0B;
const ID_SHARD_UPDATE: u8 = 0x0C;
const ID_SPAN_REPORT: u8 = 0x0D;
const ID_INFER_REQUEST: u8 = 0x0E;
const ID_INFER_REPLY: u8 = 0x0F;
const ID_DRAIN: u8 = 0x10;
const ID_GRAD_CHUNK: u8 = 0x11;
const ID_GRAD_REDUCED: u8 = 0x12;

impl Message {
    /// -> (message id, payload bytes)
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut out = Vec::new();
        match self {
            Message::Hello { worker_id, version } => {
                out.extend_from_slice(&worker_id.to_le_bytes());
                out.extend_from_slice(&version.to_le_bytes());
                (ID_HELLO, out)
            }
            Message::Calibrate { rounds } => {
                out.extend_from_slice(&rounds.to_le_bytes());
                (ID_CALIBRATE, out)
            }
            Message::CalibrateResult { seconds } => {
                out.extend_from_slice(&seconds.to_le_bytes());
                (ID_CALIBRATE_RESULT, out)
            }
            Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.push(*layer);
                out.push(*dir);
                out.extend_from_slice(&bucket.to_le_bytes());
                inputs.encode_into(&mut out);
                kernels.encode_into(&mut out);
                out.push(extra.is_some() as u8);
                if let Some(e) = extra {
                    e.encode_into(&mut out);
                }
                (ID_CONV_WORK, out)
            }
            Message::ConvResult { seq, outputs, seconds } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&seconds.to_le_bytes());
                out.push(outputs.len() as u8);
                for t in outputs {
                    t.encode_into(&mut out);
                }
                (ID_CONV_RESULT, out)
            }
            Message::AllOk => (ID_ALL_OK, out),
            Message::TrainOver => (ID_TRAIN_OVER, out),
            Message::Error { reason } => {
                out.extend_from_slice(reason.as_bytes());
                (ID_ERROR, out)
            }
            Message::Ping { nonce } => {
                out.extend_from_slice(&nonce.to_le_bytes());
                (ID_PING, out)
            }
            Message::Pong { nonce } => {
                out.extend_from_slice(&nonce.to_le_bytes());
                (ID_PONG, out)
            }
            Message::Leave { worker_id, reason } => {
                out.extend_from_slice(&worker_id.to_le_bytes());
                out.extend_from_slice(reason.as_bytes());
                (ID_LEAVE, out)
            }
            Message::ShardUpdate { layer, lo, hi, bucket } => {
                out.push(*layer);
                out.extend_from_slice(&lo.to_le_bytes());
                out.extend_from_slice(&hi.to_le_bytes());
                out.extend_from_slice(&bucket.to_le_bytes());
                (ID_SHARD_UPDATE, out)
            }
            Message::SpanReport { worker_id, seq, spans } => {
                out.extend_from_slice(&worker_id.to_le_bytes());
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&(spans.len() as u32).to_le_bytes());
                for s in spans {
                    s.encode_into(&mut out);
                }
                (ID_SPAN_REPORT, out)
            }
            Message::InferRequest { id, image } => {
                out.extend_from_slice(&id.to_le_bytes());
                image.encode_into(&mut out);
                (ID_INFER_REQUEST, out)
            }
            Message::InferReply { id, logits } => {
                out.extend_from_slice(&id.to_le_bytes());
                logits.encode_into(&mut out);
                (ID_INFER_REPLY, out)
            }
            Message::Drain => (ID_DRAIN, out),
            Message::GradChunk { seq, param, offset, data } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&param.to_le_bytes());
                out.extend_from_slice(&offset.to_le_bytes());
                data.encode_into(&mut out);
                (ID_GRAD_CHUNK, out)
            }
            Message::GradReduced { seq, param, offset, data } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&param.to_le_bytes());
                out.extend_from_slice(&offset.to_le_bytes());
                data.encode_into(&mut out);
                (ID_GRAD_REDUCED, out)
            }
        }
    }

    pub fn decode(id: u8, buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let msg = match id {
            ID_HELLO => Message::Hello {
                worker_id: take_u32(buf, &mut pos)?,
                version: take_u32(buf, &mut pos)?,
            },
            ID_CALIBRATE => Message::Calibrate { rounds: take_u32(buf, &mut pos)? },
            ID_CALIBRATE_RESULT => Message::CalibrateResult { seconds: take_f64(buf, &mut pos)? },
            ID_CONV_WORK => {
                let seq = take_u32(buf, &mut pos)?;
                ensure!(buf.len() >= pos + 2, "ConvWork truncated");
                let layer = buf[pos];
                let dir = buf[pos + 1];
                pos += 2;
                let bucket = take_u32(buf, &mut pos)?;
                let inputs = WireTensor::decode_from(buf, &mut pos)?;
                let kernels = WireTensor::decode_from(buf, &mut pos)?;
                ensure!(buf.len() > pos, "ConvWork missing extra flag");
                let has_extra = buf[pos] != 0;
                pos += 1;
                let extra = if has_extra {
                    Some(WireTensor::decode_from(buf, &mut pos)?)
                } else {
                    None
                };
                Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra }
            }
            ID_CONV_RESULT => {
                let seq = take_u32(buf, &mut pos)?;
                let seconds = take_f64(buf, &mut pos)?;
                ensure!(buf.len() > pos, "ConvResult missing count");
                let n = buf[pos] as usize;
                pos += 1;
                let mut outputs = Vec::with_capacity(n);
                for _ in 0..n {
                    outputs.push(WireTensor::decode_from(buf, &mut pos)?);
                }
                Message::ConvResult { seq, outputs, seconds }
            }
            ID_ALL_OK => Message::AllOk,
            ID_TRAIN_OVER => Message::TrainOver,
            ID_ERROR => Message::Error { reason: String::from_utf8_lossy(buf).into_owned() },
            ID_PING => Message::Ping { nonce: take_u32(buf, &mut pos)? },
            ID_PONG => Message::Pong { nonce: take_u32(buf, &mut pos)? },
            ID_LEAVE => {
                let worker_id = take_u32(buf, &mut pos)?;
                let reason = String::from_utf8_lossy(&buf[pos..]).into_owned();
                Message::Leave { worker_id, reason }
            }
            ID_SHARD_UPDATE => {
                ensure!(!buf.is_empty(), "ShardUpdate missing layer");
                let layer = buf[pos];
                pos += 1;
                Message::ShardUpdate {
                    layer,
                    lo: take_u32(buf, &mut pos)?,
                    hi: take_u32(buf, &mut pos)?,
                    bucket: take_u32(buf, &mut pos)?,
                }
            }
            ID_SPAN_REPORT => {
                let worker_id = take_u32(buf, &mut pos)?;
                let seq = take_u32(buf, &mut pos)?;
                let n = take_u32(buf, &mut pos)? as usize;
                ensure!(n <= 4096, "SpanReport span count {n} too large");
                let mut spans = Vec::with_capacity(n);
                for _ in 0..n {
                    spans.push(WireSpan::decode_from(buf, &mut pos)?);
                }
                Message::SpanReport { worker_id, seq, spans }
            }
            ID_INFER_REQUEST => Message::InferRequest {
                id: take_u64(buf, &mut pos)?,
                image: WireTensor::decode_from(buf, &mut pos)?,
            },
            ID_INFER_REPLY => Message::InferReply {
                id: take_u64(buf, &mut pos)?,
                logits: WireTensor::decode_from(buf, &mut pos)?,
            },
            ID_DRAIN => Message::Drain,
            ID_GRAD_CHUNK => Message::GradChunk {
                seq: take_u32(buf, &mut pos)?,
                param: take_u32(buf, &mut pos)?,
                offset: take_u32(buf, &mut pos)?,
                data: WireTensor::decode_from(buf, &mut pos)?,
            },
            ID_GRAD_REDUCED => Message::GradReduced {
                seq: take_u32(buf, &mut pos)?,
                param: take_u32(buf, &mut pos)?,
                offset: take_u32(buf, &mut pos)?,
                data: WireTensor::decode_from(buf, &mut pos)?,
            },
            other => bail!("unknown message id {other:#x}"),
        };
        Ok(msg)
    }

    /// Short tag for logging/metrics.
    pub fn tag(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "Hello",
            Message::Calibrate { .. } => "Calibrate",
            Message::CalibrateResult { .. } => "CalibrateResult",
            Message::ConvWork { .. } => "ConvWork",
            Message::ConvResult { .. } => "ConvResult",
            Message::AllOk => "AllOk",
            Message::TrainOver => "TrainOver",
            Message::Error { .. } => "Error",
            Message::Ping { .. } => "Ping",
            Message::Pong { .. } => "Pong",
            Message::Leave { .. } => "Leave",
            Message::ShardUpdate { .. } => "ShardUpdate",
            Message::SpanReport { .. } => "SpanReport",
            Message::InferRequest { .. } => "InferRequest",
            Message::InferReply { .. } => "InferReply",
            Message::Drain => "Drain",
            Message::GradChunk { .. } => "GradChunk",
            Message::GradReduced { .. } => "GradReduced",
        }
    }
}

fn take_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(buf.len() >= *pos + 4, "payload truncated at {pos}");
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

fn take_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    ensure!(buf.len() >= *pos + 8, "payload truncated at {pos}");
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

fn take_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    ensure!(buf.len() >= *pos + 8, "payload truncated at {pos}");
    let v = f64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wt(shape: &[u32]) -> WireTensor {
        let n: u64 = shape.iter().map(|&d| d as u64).product();
        WireTensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| i as f32 * 0.5 - 1.0).collect(),
        }
    }

    #[test]
    fn wire_tensor_round_trips_odd_lengths() {
        // Odd element counts exercise every tail case of the byte-cast
        // copies; `[]` is a scalar (empty product = 1), `[0]` is empty.
        for shape in [
            &[][..],
            &[1][..],
            &[3][..],
            &[5][..],
            &[7, 3][..],
            &[2, 3, 5, 7][..],
            &[0][..],
        ] {
            let t = wt(shape);
            let mut buf = Vec::new();
            t.encode_into(&mut buf);
            assert_eq!(buf.len(), t.size_bytes(), "size_bytes mismatch for {shape:?}");
            let mut pos = 0;
            let back = WireTensor::decode_from(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len(), "decode must consume the whole frame");
            assert_eq!(back, t);
        }
    }

    #[test]
    fn wire_tensor_rejects_corrupt_frames() {
        let t = wt(&[7, 3]);
        let mut buf = Vec::new();
        t.encode_into(&mut buf);
        // Truncated payload.
        let mut pos = 0;
        assert!(WireTensor::decode_from(&buf[..buf.len() - 1], &mut pos).is_err());
        // Tampered shape: product no longer matches the element count.
        let mut bad = buf.clone();
        bad[4] = 9;
        let mut pos = 0;
        assert!(WireTensor::decode_from(&bad, &mut pos).is_err());
        // Absurd rank.
        let mut pos = 0;
        assert!(WireTensor::decode_from(&99u32.to_le_bytes(), &mut pos).is_err());
    }

    #[test]
    fn conv_work_round_trips_with_and_without_extra() {
        for extra in [None, Some(wt(&[5]))] {
            let msg = Message::ConvWork {
                seq: 7,
                layer: 1,
                dir: 1,
                bucket: 8,
                inputs: wt(&[2, 3, 5, 5]),
                kernels: wt(&[8, 3, 3, 3]),
                extra,
            };
            let (id, buf) = msg.encode();
            assert_eq!(Message::decode(id, &buf).unwrap(), msg);
        }
    }

    #[test]
    fn conv_result_and_control_messages_round_trip() {
        let msgs = [
            Message::ConvResult {
                seq: 3,
                outputs: vec![wt(&[2, 4, 3, 3]), wt(&[4, 3, 5, 5]), wt(&[4])],
                seconds: 0.125,
            },
            Message::Hello { worker_id: 2, version: 1 },
            Message::Calibrate { rounds: 3 },
            Message::CalibrateResult { seconds: 1.5e-3 },
            Message::AllOk,
            Message::TrainOver,
            Message::Error { reason: "boom".into() },
            Message::Ping { nonce: 42 },
            Message::Pong { nonce: 42 },
            Message::Leave { worker_id: 1, reason: "maintenance".into() },
            Message::ShardUpdate { layer: 0, lo: 4, hi: 8, bucket: 4 },
            Message::SpanReport { worker_id: 2, seq: 3, spans: vec![] },
            Message::InferRequest { id: u64::MAX, image: wt(&[3, 32, 32]) },
            Message::InferReply { id: 12, logits: wt(&[10]) },
            Message::Drain,
            Message::GradChunk { seq: 17, param: 2, offset: 64, data: wt(&[33]) },
            Message::GradReduced { seq: 17, param: 2, offset: 64, data: wt(&[33]) },
            Message::SpanReport {
                worker_id: 1,
                seq: 9,
                spans: vec![
                    WireSpan {
                        kind: WireSpan::KIND_SERVE,
                        layer: 1,
                        dir: 0,
                        bucket: 8,
                        start_us: 0,
                        dur_us: 1500,
                    },
                    WireSpan {
                        kind: WireSpan::KIND_CONV,
                        layer: 1,
                        dir: 1,
                        bucket: 8,
                        start_us: 200,
                        dur_us: 1200,
                    },
                ],
            },
        ];
        for msg in msgs {
            let (id, buf) = msg.encode();
            assert_eq!(Message::decode(id, &buf).unwrap(), msg, "{}", msg.tag());
        }
        assert!(Message::decode(0xEE, &[]).is_err());
    }
}
