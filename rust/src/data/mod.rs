//! Data pipeline: synthetic CIFAR-like dataset + real CIFAR-10 binary loader.
//!
//! The paper trains on CIFAR-10 (60 000 32×32 RGB images, 10 classes).  This
//! container has no dataset downloads, so the default source is
//! [`SyntheticCifar`]: a seeded generator whose classes are genuinely
//! learnable (each class has a distinct oriented sinusoidal template; images
//! are template + noise), so the e2e example can demonstrate a falling loss
//! curve and a >> chance accuracy.  If the real CIFAR-10 binary files are
//! present (`data/cifar-10-batches-bin/`), [`CifarBin`] loads them instead —
//! same interface, drop-in.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::tensor::{ITensor, Pcg32, Tensor};

/// One mini-batch: images `[B, C, H, W]` in `[-1, 1]`, labels `[B]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub images: Tensor,
    pub labels: ITensor,
}

impl Batch {
    /// Number of samples in the batch.
    pub fn len(&self) -> usize {
        self.images.shape()[0]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Contiguous sub-batch `[lo, hi)` along the sample axis.  Used by the
    /// replica tier to hand each replica a disjoint slice of the global
    /// batch.
    pub fn slice(&self, lo: usize, hi: usize) -> Result<Batch> {
        let b = self.len();
        ensure!(lo < hi && hi <= b, "batch slice [{lo}, {hi}) out of range for batch {b}");
        let px: usize = self.images.shape()[1..].iter().product();
        let mut shape = self.images.shape().to_vec();
        shape[0] = hi - lo;
        Ok(Batch {
            images: Tensor::new(shape, self.images.data()[lo * px..hi * px].to_vec())?,
            labels: ITensor::new(vec![hi - lo], self.labels.data()[lo..hi].to_vec())?,
        })
    }
}

/// Anything that yields training batches.
pub trait Dataset {
    fn num_classes(&self) -> usize;
    /// Deterministic batch `step` of size `batch` (wraps around the data).
    fn batch(&mut self, batch: usize, step: usize) -> Result<Batch>;
}

// ---------------------------------------------------------------------------
// Synthetic CIFAR
// ---------------------------------------------------------------------------

/// Class-conditioned synthetic 32x32x3 images.
///
/// Class `c` gets a sinusoidal grating with angle `θ_c = cπ/10` and a
/// class-specific phase/frequency, modulated per channel, plus Gaussian
/// pixel noise.  A linear probe cannot trivially solve it (gratings overlap
/// heavily under noise), but a small CNN learns it within a few hundred
/// steps — which is exactly what the e2e driver must demonstrate.
pub struct SyntheticCifar {
    img: usize,
    in_ch: usize,
    classes: usize,
    noise: f32,
    seed: u64,
}

impl SyntheticCifar {
    pub fn new(img: usize, in_ch: usize, classes: usize, seed: u64) -> Self {
        Self { img, in_ch, classes, noise: 0.6, seed }
    }

    pub fn with_noise(mut self, noise: f32) -> Self {
        self.noise = noise;
        self
    }

    fn render(&self, class: usize, rng: &mut Pcg32, out: &mut [f32]) {
        let n = self.img;
        let theta = class as f32 * std::f32::consts::PI / self.classes as f32;
        let freq = 0.35 + 0.06 * (class % 5) as f32;
        let (s, c) = theta.sin_cos();
        let phase = rng.next_f32() * std::f32::consts::TAU;
        for ch in 0..self.in_ch {
            let chmod = 1.0 - 0.25 * ch as f32 / self.in_ch.max(1) as f32;
            for y in 0..n {
                for x in 0..n {
                    let u = c * x as f32 + s * y as f32;
                    let v = (freq * u + phase).sin() * chmod;
                    out[(ch * n + y) * n + x] = (v + self.noise * rng.next_gaussian()).clamp(-3.0, 3.0);
                }
            }
        }
    }
}

impl Dataset for SyntheticCifar {
    fn num_classes(&self) -> usize {
        self.classes
    }

    fn batch(&mut self, batch: usize, step: usize) -> Result<Batch> {
        let px = self.in_ch * self.img * self.img;
        let mut images = vec![0f32; batch * px];
        let mut labels = Vec::with_capacity(batch);
        for i in 0..batch {
            // Stream keyed by (seed, step, i): any batch is reproducible in
            // isolation — needed for the distributed == single-device check.
            let mut rng = Pcg32::seed_stream(self.seed, (step as u64) << 20 | i as u64);
            let class = rng.next_below(self.classes as u32) as usize;
            self.render(class, &mut rng, &mut images[i * px..(i + 1) * px]);
            labels.push(class as i32);
        }
        Ok(Batch {
            images: Tensor::new(vec![batch, self.in_ch, self.img, self.img], images)?,
            labels: ITensor::new(vec![batch], labels)?,
        })
    }
}

// ---------------------------------------------------------------------------
// Real CIFAR-10 (binary format), if available
// ---------------------------------------------------------------------------

/// Loader for the CIFAR-10 binary format: 5 train files of 10 000 records,
/// each record `1 label byte + 3072 pixel bytes` (R, G, B planes).
pub struct CifarBin {
    images: Vec<f32>, // normalized to [-1, 1], NCHW
    labels: Vec<i32>,
    n: usize,
}

impl CifarBin {
    pub const REC: usize = 3073;
    pub const PX: usize = 3072;

    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for i in 1..=5 {
            let path = dir.join(format!("data_batch_{i}.bin"));
            if !path.exists() {
                continue;
            }
            let raw = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
            ensure!(raw.len() % Self::REC == 0, "{path:?} is not a CIFAR-10 binary file");
            for rec in raw.chunks_exact(Self::REC) {
                labels.push(rec[0] as i32);
                images.extend(rec[1..].iter().map(|&b| b as f32 / 127.5 - 1.0));
            }
        }
        ensure!(!labels.is_empty(), "no CIFAR-10 batches found under {dir:?}");
        let n = labels.len();
        Ok(Self { images, labels, n })
    }

    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("data_batch_1.bin").exists()
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

impl Dataset for CifarBin {
    fn num_classes(&self) -> usize {
        10
    }

    fn batch(&mut self, batch: usize, step: usize) -> Result<Batch> {
        let mut images = Vec::with_capacity(batch * Self::PX);
        let mut labels = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (step * batch + i) % self.n;
            images.extend_from_slice(&self.images[idx * Self::PX..(idx + 1) * Self::PX]);
            labels.push(self.labels[idx]);
        }
        Ok(Batch {
            images: Tensor::new(vec![batch, 3, 32, 32], images)?,
            labels: ITensor::new(vec![batch], labels)?,
        })
    }
}

/// Synthetic by default; real CIFAR-10 if its binaries are present under
/// `data/cifar-10-batches-bin` (relative to the repo root).
pub fn default_dataset(img: usize, in_ch: usize, classes: usize, seed: u64) -> Box<dyn Dataset + Send> {
    let dir = Path::new("data/cifar-10-batches-bin");
    if img == 32 && in_ch == 3 && classes == 10 && CifarBin::available(dir) {
        if let Ok(ds) = CifarBin::load_dir(dir) {
            return Box::new(ds);
        }
    }
    Box::new(SyntheticCifar::new(img, in_ch, classes, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_batches_are_deterministic() {
        let mut a = SyntheticCifar::new(32, 3, 10, 7);
        let mut b = SyntheticCifar::new(32, 3, 10, 7);
        let ba = a.batch(8, 3).unwrap();
        let bb = b.batch(8, 3).unwrap();
        assert_eq!(ba.images, bb.images);
        assert_eq!(ba.labels, bb.labels);
        // Different steps differ.
        let bc = a.batch(8, 4).unwrap();
        assert_ne!(ba.images, bc.images);
    }

    #[test]
    fn synthetic_shapes_and_ranges() {
        let mut ds = SyntheticCifar::new(32, 3, 10, 1);
        let b = ds.batch(4, 0).unwrap();
        assert_eq!(b.images.shape(), &[4, 3, 32, 32]);
        assert_eq!(b.labels.shape(), &[4]);
        assert!(b.labels.data().iter().all(|&l| (0..10).contains(&l)));
        assert!(b.images.data().iter().all(|&v| (-3.0..=3.0).contains(&v)));
    }

    #[test]
    fn classes_are_distinguishable_in_expectation() {
        // Mean per-class images must differ clearly even under noise —
        // otherwise the dataset is unlearnable and the e2e demo meaningless.
        let mut ds = SyntheticCifar::new(16, 1, 10, 2).with_noise(0.6);
        let mut means = vec![vec![0f32; 16 * 16]; 10];
        let mut counts = vec![0usize; 10];
        for step in 0..40 {
            let b = ds.batch(16, step).unwrap();
            let px = 16 * 16;
            for i in 0..16 {
                let cls = b.labels.data()[i] as usize;
                counts[cls] += 1;
                for p in 0..px {
                    means[cls][p] += b.images.data()[i * px + p];
                }
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            assert!(c > 10, "class undersampled");
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        // Distinct-class mean images should be far apart relative to noise.
        let d01: f32 = means[0]
            .iter()
            .zip(&means[5])
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(d01 > 2.0, "class templates too close: {d01}");
    }

    #[test]
    fn batch_slice_is_contiguous_and_bounds_checked() {
        let mut ds = SyntheticCifar::new(8, 3, 10, 7);
        let b = ds.batch(6, 0).unwrap();
        assert_eq!(b.len(), 6);
        assert!(!b.is_empty());
        let s = b.slice(2, 5).unwrap();
        assert_eq!(s.images.shape(), &[3, 3, 8, 8]);
        assert_eq!(s.labels.data(), &b.labels.data()[2..5]);
        let px = 3 * 8 * 8;
        assert_eq!(s.images.data(), &b.images.data()[2 * px..5 * px]);
        // Slices must tile the batch exactly: [0,2) ∪ [2,5) ∪ [5,6).
        let a = b.slice(0, 2).unwrap();
        let c = b.slice(5, 6).unwrap();
        assert_eq!(a.len() + s.len() + c.len(), b.len());
        assert!(b.slice(4, 4).is_err());
        assert!(b.slice(0, 7).is_err());
    }

    #[test]
    fn cifar_bin_loader_parses_format() {
        // Forge a tiny valid file with 2 records.
        let dir = std::env::temp_dir().join(format!("convdist_cifar_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut raw = vec![0u8; 2 * CifarBin::REC];
        raw[0] = 3; // label
        raw[1] = 255; // first pixel R
        raw[CifarBin::REC] = 9;
        std::fs::write(dir.join("data_batch_1.bin"), &raw).unwrap();
        let mut ds = CifarBin::load_dir(&dir).unwrap();
        assert_eq!(ds.len(), 2);
        let b = ds.batch(4, 0).unwrap(); // wraps
        assert_eq!(b.labels.data(), &[3, 9, 3, 9]);
        assert!((b.images.data()[0] - 1.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Synthesize one CIFAR-10 binary record: label byte + 3072 pixel bytes
    /// derived deterministically from `label` so round-trips are checkable.
    fn forge_record(label: u8) -> Vec<u8> {
        let mut rec = Vec::with_capacity(CifarBin::REC);
        rec.push(label);
        rec.extend((0..CifarBin::PX).map(|p| (p as u8).wrapping_mul(label.wrapping_add(1))));
        rec
    }

    #[test]
    fn cifar_bin_roundtrips_images_and_labels_across_files() {
        let dir = std::env::temp_dir()
            .join(format!("convdist_cifar_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Two batch files, two records each, distinct labels — the loader
        // must concatenate them in file order.
        let labels: [[u8; 2]; 2] = [[0, 1], [7, 9]];
        for (i, pair) in labels.iter().enumerate() {
            let mut raw = Vec::new();
            for &l in pair {
                raw.extend(forge_record(l));
            }
            std::fs::write(dir.join(format!("data_batch_{}.bin", i + 1)), &raw).unwrap();
        }
        let mut ds = CifarBin::load_dir(&dir).unwrap();
        assert_eq!(ds.len(), 4);
        assert!(!ds.is_empty());
        assert_eq!(ds.num_classes(), 10);
        let b = ds.batch(4, 0).unwrap();
        assert_eq!(b.images.shape(), &[4, 3, 32, 32]);
        assert_eq!(b.labels.data(), &[0, 1, 7, 9]);
        // Pixel round-trip: byte v maps to v/127.5 - 1 in NCHW plane order
        // (the binary layout is already R, G, B planes).
        for (rec_idx, &label) in [0u8, 1, 7, 9].iter().enumerate() {
            let rec = forge_record(label);
            let img = &b.images.data()[rec_idx * CifarBin::PX..(rec_idx + 1) * CifarBin::PX];
            for (p, &v) in img.iter().enumerate() {
                let expect = rec[1 + p] as f32 / 127.5 - 1.0;
                assert!((v - expect).abs() < 1e-6, "record {rec_idx} pixel {p}");
            }
        }
        // Wrap-around indexing is stable over steps.
        let b2 = ds.batch(3, 1).unwrap();
        assert_eq!(b2.labels.data(), &[9, 0, 1]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cifar_bin_rejects_truncated_and_missing_files() {
        let dir = std::env::temp_dir()
            .join(format!("convdist_cifar_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Empty dir: no batches at all.
        assert!(CifarBin::load_dir(&dir).is_err());
        assert!(!CifarBin::available(&dir));
        // A record cut short must be rejected, not silently zero-padded.
        let mut raw = forge_record(5);
        raw.extend_from_slice(&forge_record(6)[..CifarBin::REC - 100]);
        std::fs::write(dir.join("data_batch_1.bin"), &raw).unwrap();
        let err = CifarBin::load_dir(&dir).unwrap_err();
        assert!(err.to_string().contains("not a CIFAR-10 binary"), "{err:#}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
