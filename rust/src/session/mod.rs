//! The unified session API: one builder to compose, run, observe and
//! checkpoint any training run.
//!
//! The paper's experiments are a grid over fleet size, device heterogeneity,
//! bandwidth, batch size and architecture; composing a run used to mean
//! picking the right constructor from a matrix (`spawn_inproc` /
//! `spawn_inproc_planned` / `spawn_inproc_arch` × `DistTrainer::new` /
//! `with_adaptive`) and hand-rolling the training loop.  A [`Session`] is
//! the single composition point:
//!
//! ```no_run
//! use convdist::devices::Throttle;
//! use convdist::session::SessionBuilder;
//!
//! let mut session = SessionBuilder::new()
//!     .arch_preset("deep_cifar")                    // or artifacts / graph file
//!     .workers(&[Throttle::none(), Throttle::new(2.0)]) // in-proc fleet
//!     .steps(50)
//!     .on_event(|ev| eprintln!("{ev:?}"))           // observer hook
//!     .build()?;
//! let report = session.run()?;                      // full loop + eval
//! session.save_checkpoint("run.ckpt")?;             // resumable later
//! session.shutdown()?;
//! # anyhow::Ok(())
//! ```
//!
//! Axes (every combination is valid):
//!
//! * **arch source** — an artifact directory ([`SessionBuilder::artifacts`]),
//!   a named preset ([`SessionBuilder::arch_preset`]), a graph-JSON file
//!   ([`SessionBuilder::arch_graph_file`]) or an explicit
//!   [`ArchSpec`] ([`SessionBuilder::arch_spec`]);
//! * **topology** — an in-proc fleet with [`ThrottlePlan`]s and optional
//!   [`LinkModel`] shaping ([`SessionBuilder::workers`] /
//!   [`SessionBuilder::worker_plans`] / [`SessionBuilder::shaped`]), TCP
//!   endpoints ([`SessionBuilder::tcp`]), or pre-connected raw links
//!   ([`SessionBuilder::links`] — custom worker harnesses in tests);
//! * **scheduling** — static (default) or adaptive
//!   ([`SessionBuilder::adaptive`]);
//! * **trainer knobs** — [`SessionBuilder::trainer`] / `steps` /
//!   `master_throttle` / `dataset`.
//!
//! [`SessionBuilder::from_experiment`] maps a declarative
//! [`ExperimentConfig`] (JSON, including its `arch` field) onto these axes —
//! `convdist run --config exp.json` drives a full session end to end.
//! Checkpointing ([`Session::save_checkpoint`] /
//! [`SessionBuilder::resume_from`]) snapshots parameters, SGD momentum and
//! the step counter so a run can stop and continue exactly where it left
//! off (DESIGN.md §9).

mod checkpoint;

pub use checkpoint::Checkpoint;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::cluster::{spawn_workers_traced, DistTrainer, InprocCluster, StepResult, WorkerSource};
use crate::config::{ArchChoice, ExperimentConfig, ServeConfig, TrainerConfig};
use crate::data::{default_dataset, Batch, Dataset};
use crate::devices::{Throttle, ThrottlePlan};
use crate::metrics::Breakdown;
use crate::net::{Link, LinkModel, TcpLink};
use crate::obs::{live, HealthState, MetricsServer, ObsConfig, Observability};
use crate::replica::{AllReduce, FleetOpts, ReplicaSet, ReplicaSpec};
use crate::runtime::{ArchSpec, Runtime};
use crate::sched::AdaptiveConfig;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// Something observable happened inside the session.  Observers registered
/// with [`SessionBuilder::on_event`] see every event in order — this
/// replaces the hand-rolled logging loop every example used to carry.
#[derive(Clone, Debug)]
pub enum Event {
    /// A training step finished.  `step` counts from the start of training
    /// (it continues across checkpoint resume).
    StepCompleted {
        step: u64,
        loss: f32,
        /// Devices that participated (master included).
        devices: usize,
        breakdown: Breakdown,
        bytes_moved: u64,
    },
    /// The adaptive policy re-sharded the fleet after this step.
    Repartitioned { step: u64 },
    /// A worker died, left or was dropped during this step.
    WorkerLeft {
        step: u64,
        /// Devices still in the fleet (master included).
        devices_left: usize,
    },
    /// A held-out accuracy evaluation completed.
    EvalDone { step: u64, accuracy: f32 },
    /// A checkpoint was written.
    CheckpointSaved { step: u64, path: PathBuf },
    /// A device moved on the health ladder (DESIGN.md §12).  Emitted after
    /// the step (and any `Repartitioned`/`WorkerLeft`) it belongs to.
    HealthChanged {
        step: u64,
        device: usize,
        from: HealthState,
        to: HealthState,
        /// Rate-over-fleet-median ratio that drove the change (0 for Lost).
        ratio: f64,
    },
    /// This step's total time was a high outlier against the rolling
    /// median/MAD window.
    AnomalyFlagged { step: u64, step_ms: f64, median_ms: f64, mad_ms: f64 },
    /// The cross-replica rebalancer adopted new per-replica batch slices
    /// after this step (replica sessions only; implies fleet rebuilds).
    Rebalanced { step: u64, shares: Vec<usize> },
}

/// An event observer.  Boxed `FnMut` so closures can accumulate state.
pub type Observer = Box<dyn FnMut(&Event) + Send>;

// ---------------------------------------------------------------------------
// Builder axes
// ---------------------------------------------------------------------------

/// Where the architecture (and therefore the runtime) comes from.
pub enum ArchSource {
    /// `Runtime::open` over this directory: a `manifest.json` pins the
    /// architecture, otherwise the native default is synthesized.
    Artifacts(PathBuf),
    /// A named [`ArchSpec::preset`] (`default` | `tiny` | `deep_cifar` |
    /// `tiny_deep`), resolved at build time.
    Preset(String),
    /// A standalone graph-JSON file (the `ArchSpec::to_json` schema; the
    /// legacy `k1`/`k2` schema also loads).
    GraphFile(PathBuf),
    /// An explicit, already-built spec.
    Spec(ArchSpec),
}

impl ArchSource {
    /// Resolve to the master's [`Runtime`] plus the source every in-proc
    /// worker opens its *own* runtime from (one runtime per device, like
    /// the paper's one-process-per-slave).  The single resolution site —
    /// the CLI's non-session subcommands reuse it too.
    pub fn resolve(&self) -> Result<(Arc<Runtime>, WorkerSource)> {
        match self {
            ArchSource::Artifacts(dir) => {
                Ok((Runtime::open(dir)?, WorkerSource::Artifacts(dir.clone())))
            }
            ArchSource::Preset(name) => {
                let spec = ArchSpec::preset(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown arch preset {name:?} (try: default, tiny, deep_cifar, tiny_deep)"
                    )
                })?;
                Ok((Runtime::for_arch(spec.clone()), WorkerSource::Arch(spec)))
            }
            ArchSource::GraphFile(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading arch graph {}", path.display()))?;
                let spec = ArchSpec::from_json_str(&text)
                    .with_context(|| format!("parsing arch graph {}", path.display()))?;
                Ok((Runtime::for_arch(spec.clone()), WorkerSource::Arch(spec)))
            }
            ArchSource::Spec(spec) => {
                Ok((Runtime::for_arch(spec.clone()), WorkerSource::Arch(spec.clone())))
            }
        }
    }
}

enum TopologySpec {
    /// Spawn one in-proc worker thread per throttle plan.
    InProc,
    /// Connect to workers listening on these TCP addresses.
    Tcp(Vec<String>),
    /// Use these pre-connected links verbatim.
    Links(Vec<Box<dyn Link>>),
}

// ---------------------------------------------------------------------------
// SessionBuilder
// ---------------------------------------------------------------------------

/// Composes a training run; [`SessionBuilder::build`] calibrates the fleet
/// and returns a ready [`Session`].
pub struct SessionBuilder {
    arch: ArchSource,
    topology: TopologySpec,
    plans: Vec<ThrottlePlan>,
    shape: Option<LinkModel>,
    trainer: TrainerConfig,
    adaptive: AdaptiveConfig,
    master_throttle: Throttle,
    observers: Vec<Observer>,
    dataset: Option<Box<dyn Dataset + Send>>,
    resume: Option<PathBuf>,
    obs: ObsConfig,
    checkpoint_dir: PathBuf,
    replica: ReplicaSpec,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    /// Defaults: the repo's artifact directory (native default arch when no
    /// manifest pins one), a master-only fleet, static scheduling,
    /// `TrainerConfig::default()`, no throttling, no observers.
    pub fn new() -> Self {
        Self {
            arch: ArchSource::Artifacts(crate::artifacts_dir()),
            topology: TopologySpec::InProc,
            plans: Vec::new(),
            shape: None,
            trainer: TrainerConfig::default(),
            adaptive: AdaptiveConfig::disabled(),
            master_throttle: Throttle::none(),
            observers: Vec::new(),
            dataset: None,
            resume: None,
            obs: ObsConfig::default(),
            checkpoint_dir: PathBuf::from("checkpoints"),
            replica: ReplicaSpec::default(),
        }
    }

    /// Map a declarative [`ExperimentConfig`] onto the builder axes: `arch`
    /// (preset name or inline graph), `cluster` (worker count, device
    /// roster -> virtual throttles when `throttle` is set, TCP addresses
    /// when given) and `network` (bandwidth shaping).  Further builder
    /// calls refine the result — the CLI layers its flag overrides on top.
    pub fn from_experiment(cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        // Pre-flight: the full static analyzer (config + graph + plan passes
        // against this config's own arch, roster and bandwidth).  Deny-level
        // findings refuse the build; warnings go to stderr and run anyway.
        let report = crate::analysis::check_experiment(cfg);
        if report.has_deny() {
            anyhow::bail!("config pre-flight failed:\n{}", report.render_human());
        }
        for d in report.diags.iter().filter(|d| d.severity == crate::analysis::Severity::Warn) {
            eprintln!("{d}");
        }
        let mut b = Self::new().trainer(cfg.trainer.clone()).adaptive(cfg.adaptive);
        if let Some(rc) = &cfg.replica {
            b = b.replica_spec(rc.to_spec());
        }
        if let Some(addr) = &cfg.metrics_addr {
            b.obs.metrics_addr = Some(addr.clone());
            b.obs.metrics = true;
        }
        match &cfg.arch {
            Some(ArchChoice::Preset(name)) => b = b.arch(ArchSource::Preset(name.clone())),
            Some(ArchChoice::Graph(json)) => {
                b = b.arch(ArchSource::Spec(
                    ArchSpec::from_json_str(json).context("parsing inline arch graph")?,
                ))
            }
            None => {}
        }
        if !cfg.cluster.worker_addrs.is_empty() {
            // Real sockets carry real timing; `network.shaped` is an in-proc
            // emulation knob and is ignored for TCP (as the CLI always has).
            b = b.tcp(cfg.cluster.worker_addrs.clone());
        } else {
            let profiles = cfg.device_profiles();
            let throttles = if cfg.cluster.throttle {
                // Virtual-time emulation: fastest device pinned at 2 virtual
                // GFLOPS so sleeps dominate the host's real compute.
                Throttle::virtual_cluster(&profiles, 2.0)
            } else {
                vec![Throttle::none(); profiles.len()]
            };
            b = b.master_throttle(throttles[0]).workers(&throttles[1..]);
            if cfg.network.shaped {
                b = b.shaped(LinkModel {
                    bandwidth_bps: cfg.network.bandwidth_mbps * 1e6,
                    latency: Duration::from_secs_f64(cfg.network.latency_ms / 1e3),
                });
            }
        }
        Ok(b)
    }

    // -- arch source ---------------------------------------------------------

    pub fn arch(mut self, source: ArchSource) -> Self {
        self.arch = source;
        self
    }

    /// Resolve the configured arch source without building a fleet — the
    /// `convdist infer` client uses this to shape its requests like the
    /// server it targets.
    pub fn resolve_arch(&self) -> Result<ArchSpec> {
        Ok(self.arch.resolve()?.0.arch().clone())
    }

    pub fn artifacts(self, dir: impl Into<PathBuf>) -> Self {
        self.arch(ArchSource::Artifacts(dir.into()))
    }

    pub fn arch_preset(self, name: impl Into<String>) -> Self {
        self.arch(ArchSource::Preset(name.into()))
    }

    pub fn arch_graph_file(self, path: impl Into<PathBuf>) -> Self {
        self.arch(ArchSource::GraphFile(path.into()))
    }

    pub fn arch_spec(self, spec: ArchSpec) -> Self {
        self.arch(ArchSource::Spec(spec))
    }

    // -- topology ------------------------------------------------------------

    /// In-proc fleet: one worker thread per throttle (fixed-speed plans).
    pub fn workers(self, throttles: &[Throttle]) -> Self {
        self.worker_plans(throttles.iter().map(|&t| ThrottlePlan::fixed(t)).collect())
    }

    /// In-proc fleet with full throttle *plans* — a worker's emulated speed
    /// may change mid-run (`ThrottlePlan::degrade_after`), which is how the
    /// adaptive-scheduler tests make a calibrated fleet go out of balance.
    pub fn worker_plans(mut self, plans: Vec<ThrottlePlan>) -> Self {
        self.topology = TopologySpec::InProc;
        self.plans = plans;
        self
    }

    /// Meter every frame through a bandwidth/latency model (in-proc fleets
    /// only; TCP links carry real network timing already).
    pub fn shaped(mut self, model: LinkModel) -> Self {
        self.shape = Some(model);
        self
    }

    /// Connect to workers listening on TCP addresses (`host:port`).
    pub fn tcp(mut self, addrs: Vec<String>) -> Self {
        self.topology = TopologySpec::Tcp(addrs);
        self
    }

    /// Use pre-connected links verbatim (custom worker harnesses; the links
    /// must speak the worker protocol starting with `Hello`).
    pub fn links(mut self, links: Vec<Box<dyn Link>>) -> Self {
        self.topology = TopologySpec::Links(links);
        self
    }

    // -- scheduling / trainer knobs ------------------------------------------

    /// Adaptive scheduling configuration (`AdaptiveConfig::disabled()` — the
    /// default — is exactly the paper's static path).
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = cfg;
        self
    }

    pub fn trainer(mut self, cfg: TrainerConfig) -> Self {
        self.trainer = cfg;
        self
    }

    /// Steps per [`Session::run`] call (shorthand for mutating `trainer`).
    pub fn steps(mut self, steps: usize) -> Self {
        self.trainer.steps = steps;
        self
    }

    pub fn master_throttle(mut self, t: Throttle) -> Self {
        self.master_throttle = t;
        self
    }

    // -- replica tier --------------------------------------------------------

    /// Train `n` replica fleets data-parallel over the global batch, each an
    /// Eq. 1-partitioned copy of the configured fleet on a disjoint batch
    /// slice, with a synchronous gradient all-reduce every step (DESIGN.md
    /// §14).  `1` (the default) is the classic single-fleet path; `n > 1`
    /// composes with every arch/scheduling knob but requires the in-proc
    /// topology (each replica's runtime is shape-pinned to its slice).
    pub fn replicas(mut self, n: usize) -> Self {
        self.replica.count = n;
        self
    }

    /// Gradient all-reduce strategy for `replicas(n > 1)`.
    pub fn allreduce(mut self, strategy: AllReduce) -> Self {
        self.replica.allreduce = strategy;
        self
    }

    /// Full replica-tier spec (count, strategy, chunking, rebalance knobs).
    pub fn replica_spec(mut self, spec: ReplicaSpec) -> Self {
        self.replica = spec;
        self
    }

    /// Replace the default dataset (synthetic CIFAR seeded from the trainer
    /// seed, or `data/cifar-10-batches-bin` when present).
    pub fn dataset(mut self, ds: Box<dyn Dataset + Send>) -> Self {
        self.dataset = Some(ds);
        self
    }

    // -- observation / resume ------------------------------------------------

    /// Register an event observer (may be called multiple times; observers
    /// fire in registration order).
    pub fn on_event(mut self, f: impl FnMut(&Event) + Send + 'static) -> Self {
        self.observers.push(Box::new(f));
        self
    }

    /// Attach fleet-wide observability (see [`crate::obs`]): spans + a
    /// JSONL run log + a Chrome trace when the config names a directory,
    /// and/or a metrics registry rendered as a table at the end.  Every
    /// [`Event`] is mirrored into the run log; in-proc workers are spawned
    /// with tracing on so their conv spans land in the master's timeline.
    pub fn observe(mut self, cfg: ObsConfig) -> Self {
        self.obs = cfg;
        self
    }

    /// Where `checkpoint_every` auto-checkpoints are written
    /// (`<dir>/step<N>.ckpt`; created on first use).  Default
    /// `checkpoints/`.
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = dir.into();
        self
    }

    /// Restore a [`Checkpoint`] right after the fleet is built: parameters,
    /// momentum and step counter continue where the saved run stopped.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    // -- build ---------------------------------------------------------------

    /// Resolve the arch, assemble the topology, calibrate and Eq.1-partition
    /// the fleet, and (when resuming) restore the checkpoint.
    pub fn build(mut self) -> Result<Session> {
        let (rt, worker_source) = self.arch.resolve()?;
        // Pre-flight the resolved arch.  A spec that came through
        // `ArchSpec::build` already satisfies the hard invariants, but a
        // manifest-pinned arch with hand-edited ladders does not — the graph
        // pass is the last line before workers spawn and memory is committed.
        let report = crate::analysis::check_spec(rt.arch());
        if report.has_deny() {
            anyhow::bail!("arch pre-flight failed:\n{}", report.render_human());
        }
        let (mut trainer, cluster, mut replicas) = if self.replica.count > 1 {
            // Each replica runs a full fleet at its own batch slice; remote
            // workers' runtimes are shape-pinned to the global batch, so the
            // replica tier composes with the in-proc topology only.
            ensure!(
                matches!(self.topology, TopologySpec::InProc),
                "replicas({}) requires the in-proc topology (TCP/custom-link workers are \
                 shape-pinned to the global batch)",
                self.replica.count
            );
            let fleet = FleetOpts {
                plans: self.plans.clone(),
                shape: self.shape,
                master_throttle: self.master_throttle,
                adaptive: self.adaptive,
                trace: self.obs.tracing(),
            };
            let (t, c, set) = ReplicaSet::build(rt.arch(), self.replica, &self.trainer, fleet)?;
            (t, Some(c), Some(set))
        } else {
            let (links, cluster) =
                match std::mem::replace(&mut self.topology, TopologySpec::InProc) {
                    TopologySpec::InProc => {
                        let mut cluster = spawn_workers_traced(
                            worker_source,
                            &self.plans,
                            self.shape,
                            self.obs.tracing(),
                        )?;
                        (cluster.take_links(), Some(cluster))
                    }
                    TopologySpec::Tcp(addrs) => {
                        ensure!(!addrs.is_empty(), "TCP topology needs at least one worker address");
                        // No artificial shaping on real sockets: TCP links carry
                        // real network timing already (`shaped` is an in-proc knob).
                        let mut links: Vec<Box<dyn Link>> = Vec::with_capacity(addrs.len());
                        for addr in &addrs {
                            let link = TcpLink::connect(addr.trim())
                                .with_context(|| format!("connecting to worker {addr}"))?;
                            links.push(Box::new(link));
                        }
                        (links, None)
                    }
                    TopologySpec::Links(links) => (links, None),
                };
            let trainer = DistTrainer::new(
                rt.clone(),
                links,
                &self.trainer,
                self.master_throttle,
                self.adaptive,
            )?;
            (trainer, cluster, None)
        };
        // The obs epoch starts *after* calibration so step 1's spans sit
        // near t=0 of the trace instead of behind the calibration gap.
        let (obs, live) = if self.obs.enabled() {
            let label = rt.arch().label();
            let devices = match &replicas {
                Some(set) => set.total_devices(&trainer),
                None => 1 + trainer.alive_workers(),
            };
            let o = Observability::new(&self.obs, &label, devices, self.trainer.steps)?;
            trainer.attach_obs(o.handle());
            if let Some(set) = replicas.as_mut() {
                set.attach_obs(o.handle());
                Session::snapshot_replica_gauges(&o.handle(), set);
            }
            Session::snapshot_fleet_gauges(&o.handle(), &trainer);
            let live = match &self.obs.metrics_addr {
                Some(addr) => {
                    let h = o.handle();
                    let provider: live::MetricsProvider =
                        Arc::new(move || h.metrics(|m| live::render_prometheus(m)));
                    Some(MetricsServer::start(addr, provider)?)
                }
                None => None,
            };
            (Some(o), live)
        } else {
            (None, None)
        };
        let dataset = match self.dataset.take() {
            Some(ds) => ds,
            None => {
                let a = rt.arch();
                default_dataset(a.img, a.in_ch, a.num_classes, self.trainer.seed)
            }
        };
        let mut session = Session {
            rt,
            trainer,
            cluster,
            replicas,
            cfg: self.trainer,
            observers: self.observers,
            dataset,
            obs,
            live,
            checkpoint_dir: self.checkpoint_dir,
        };
        if let Some(path) = self.resume {
            let ckpt = Checkpoint::load(&path)?;
            session
                .restore(&ckpt)
                .with_context(|| format!("resuming from checkpoint {}", path.display()))?;
        }
        Ok(session)
    }

    /// Build a **forward-only inference session** instead of a trainer: the
    /// same arch/topology/obs axes, but no gradient or optimizer
    /// allocations — parameters come from a `CVDSESS1` checkpoint treated
    /// as a model artifact, and the fleet runs only the distributed conv
    /// shard *forward* path (`convdist serve`, DESIGN.md §13).
    pub fn inference(mut self, ckpt_path: impl Into<PathBuf>) -> Result<InferenceSession> {
        let ckpt_path = ckpt_path.into();
        let (rt, worker_source) = self.arch.resolve()?;
        let report = crate::analysis::check_spec(rt.arch());
        if report.has_deny() {
            anyhow::bail!("arch pre-flight failed:\n{}", report.render_human());
        }
        // Load and validate the model artifact *before* spawning workers so
        // a bad checkpoint fails in milliseconds, not after calibration.
        let ckpt = Checkpoint::load(&ckpt_path)?;
        let params = crate::serve::params_from_checkpoint(
            rt.arch(),
            &ckpt,
            &ckpt_path.display().to_string(),
        )?;
        let (links, cluster) = match std::mem::replace(&mut self.topology, TopologySpec::InProc) {
            TopologySpec::InProc => {
                let mut cluster = spawn_workers_traced(
                    worker_source,
                    &self.plans,
                    self.shape,
                    self.obs.tracing(),
                )?;
                (cluster.take_links(), Some(cluster))
            }
            TopologySpec::Tcp(addrs) => {
                ensure!(!addrs.is_empty(), "TCP topology needs at least one worker address");
                let mut links: Vec<Box<dyn Link>> = Vec::with_capacity(addrs.len());
                for addr in &addrs {
                    let link = TcpLink::connect(addr.trim())
                        .with_context(|| format!("connecting to worker {addr}"))?;
                    links.push(Box::new(link));
                }
                (links, None)
            }
            TopologySpec::Links(links) => (links, None),
        };
        let engine =
            crate::serve::ForwardEngine::new(rt.clone(), links, params, self.trainer.calib_rounds)?;
        let (obs, live) = if self.obs.enabled() {
            let label = rt.arch().label();
            let devices = 1 + engine.worker_count();
            let o = Observability::new(&self.obs, &label, devices, 0)?;
            let live = match &self.obs.metrics_addr {
                Some(addr) => {
                    let h = o.handle();
                    let provider: live::MetricsProvider =
                        Arc::new(move || h.metrics(|m| live::render_prometheus(m)));
                    Some(MetricsServer::start(addr, provider)?)
                }
                None => None,
            };
            (Some(o), live)
        } else {
            (None, None)
        };
        Ok(InferenceSession { rt, engine: Some(engine), cluster, obs, live })
    }
}

// ---------------------------------------------------------------------------
// InferenceSession
// ---------------------------------------------------------------------------

/// A calibrated forward-only fleet (see [`SessionBuilder::inference`]):
/// drive it directly with [`InferenceSession::forward`], or hand it to the
/// dynamic batcher's TCP front-end with [`InferenceSession::serve`].
pub struct InferenceSession {
    rt: Arc<Runtime>,
    engine: Option<crate::serve::ForwardEngine>,
    cluster: Option<InprocCluster>,
    obs: Option<Observability>,
    live: Option<MetricsServer>,
}

impl InferenceSession {
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Distributed forward pass: `images [n, C, H, W]` -> `logits
    /// [n, classes]`; `n` must sit on the arch's `batch_buckets` ladder.
    pub fn forward(&mut self, images: &crate::tensor::Tensor) -> Result<crate::tensor::Tensor> {
        self.engine.as_mut().expect("engine present until serve/shutdown").forward(images)
    }

    /// The bound address of the live metrics endpoint, when one is serving.
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.live.as_ref().map(|s| s.addr())
    }

    /// Start the serve front-end on `addr` and return the running server
    /// (`addr` port 0 picks an ephemeral port).  The engine moves into the
    /// server's dispatch thread; obs/cluster teardown happens in
    /// [`ServingSession::join`].
    pub fn serve(mut self, addr: &str, cfg: ServeConfig) -> Result<ServingSession> {
        let engine = self.engine.take().expect("engine present until serve/shutdown");
        let handle = self.obs.as_ref().map(|o| o.handle());
        let server = crate::serve::ServeServer::start(engine, addr, cfg, handle)?;
        Ok(ServingSession {
            server,
            cluster: self.cluster.take(),
            obs: self.obs.take(),
            live: self.live.take(),
        })
    }

    /// Tell the fleet the session is over and join the in-proc workers.
    pub fn shutdown(mut self) -> Result<()> {
        if let Some(mut srv) = self.live.take() {
            srv.stop();
        }
        if let Some(engine) = self.engine.take() {
            engine.shutdown()?;
        }
        if let Some(c) = self.cluster.take() {
            c.join()?;
        }
        if let Some(o) = self.obs.as_mut() {
            o.finish(0)?;
        }
        Ok(())
    }
}

/// A live `convdist serve` deployment: the TCP front-end plus the fleet and
/// observability it owns.  [`ServingSession::join`] blocks until a client
/// sends `Drain`, then tears everything down in order.
pub struct ServingSession {
    server: crate::serve::ServeServer,
    cluster: Option<InprocCluster>,
    obs: Option<Observability>,
    live: Option<MetricsServer>,
}

impl ServingSession {
    /// The bound serve address (resolves an ephemeral `:0` port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }

    /// The bound address of the live metrics endpoint, when one is serving.
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.live.as_ref().map(|s| s.addr())
    }

    /// Requests answered so far.
    pub fn requests_served(&self) -> u64 {
        self.server.requests_served()
    }

    /// Block until drained: every queued request answered, fleet told
    /// `TrainOver`, in-proc workers joined, obs sinks flushed.  Returns the
    /// number of requests the server answered over its lifetime.
    pub fn join(mut self) -> Result<u64> {
        let (engine, served) = self.server.join()?;
        engine.shutdown()?;
        if let Some(c) = self.cluster.take() {
            c.join()?;
        }
        if let Some(mut srv) = self.live.take() {
            srv.stop();
        }
        if let Some(o) = self.obs.as_mut() {
            o.finish(served)?;
        }
        Ok(served)
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// Outcome of one [`Session::run`] call.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Global step count when the run started (> 0 after a resume).
    pub first_step: u64,
    pub steps_run: usize,
    /// Per-step losses, in order.
    pub losses: Vec<f32>,
    /// Held-out accuracy measured after the last step.
    pub eval_accuracy: f32,
    /// Comm/Conv/Comp totals over the run.
    pub cumulative: Breakdown,
    /// Bytes moved over all links (Eq. 2 ground truth).
    pub bytes_moved: u64,
    /// Lifetime scheduler counters at the end of the run.
    pub repartitions: u64,
    pub departures: u64,
    pub wall: Duration,
}

impl RunReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }
}

/// A composed, calibrated training run.  Drive it coarse
/// ([`Session::run`] — the full loop plus eval) or fine
/// ([`Session::step`] per batch); both emit [`Event`]s.
pub struct Session {
    rt: Arc<Runtime>,
    /// Replica 0's trainer — the primary fleet in a replica session, the
    /// only fleet otherwise (checkpoints and telemetry read from it).
    trainer: DistTrainer,
    cluster: Option<InprocCluster>,
    /// Replicas `1..N` plus the gradient fabric when `replicas(n > 1)`.
    replicas: Option<ReplicaSet>,
    cfg: TrainerConfig,
    observers: Vec<Observer>,
    dataset: Box<dyn Dataset + Send>,
    obs: Option<Observability>,
    /// Live Prometheus endpoint (`ObsConfig::metrics_addr`), stopped by
    /// `finish_obs`/`shutdown` or drop.
    live: Option<MetricsServer>,
    checkpoint_dir: PathBuf,
}

impl Session {
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The underlying trainer: shard tables, probe times, telemetry, params.
    pub fn trainer(&self) -> &DistTrainer {
        &self.trainer
    }

    /// Mutable trainer access (ablations re-partition mid-run, e.g.
    /// `partition_equal`).
    pub fn trainer_mut(&mut self) -> &mut DistTrainer {
        &mut self.trainer
    }

    pub fn config(&self) -> &TrainerConfig {
        &self.cfg
    }

    fn emit(&mut self, ev: Event) {
        // The run log sees every event first, in emission order.
        if let Some(o) = &self.obs {
            o.handle().event(&ev);
        }
        for obs in &mut self.observers {
            obs(&ev);
        }
    }

    /// Refresh the per-device fleet gauges the live endpoint serves:
    /// `health.devN` (state code), `share.devN` (FLOP-weighted kernel
    /// share) and `throughput.devN` (GFLOP/s from the EWMA telemetry).
    fn snapshot_fleet_gauges(h: &crate::obs::ObsHandle, trainer: &DistTrainer) {
        let states = trainer.health_states().to_vec();
        let shares = trainer.device_shares();
        let rates: Vec<Option<f64>> =
            (0..states.len()).map(|d| trainer.telemetry().rate(d)).collect();
        h.metrics(|m| {
            for (d, s) in states.iter().enumerate() {
                m.set_gauge(&format!("health.dev{d}"), s.code() as f64);
            }
            for (d, share) in &shares {
                m.set_gauge(&format!("share.dev{d}"), *share);
            }
            for (d, r) in rates.iter().copied().enumerate() {
                if let Some(r) = r.filter(|r| *r > 0.0) {
                    m.set_gauge(&format!("throughput.dev{d}"), 1.0 / r);
                }
            }
        });
    }

    /// One training step on an explicit batch, with events.  In a replica
    /// session the batch is the *global* batch: it is sliced across the
    /// fleets and the gradients all-reduced before anyone commits.
    pub fn step(&mut self, batch: &Batch) -> Result<StepResult> {
        match self.replicas.take() {
            Some(mut set) => {
                let out = self.replica_step(&mut set, batch);
                self.replicas = Some(set);
                out
            }
            None => self.single_step(batch),
        }
    }

    fn single_step(&mut self, batch: &Batch) -> Result<StepResult> {
        let devices_before = 1 + self.trainer.alive_workers();
        let r = self.trainer.step(batch)?;
        let step = self.trainer.steps_done();
        if let Some(o) = &self.obs {
            let h = o.handle();
            let stats = self.trainer.sched_stats();
            h.metrics(|m| {
                m.absorb_breakdown(&r.breakdown);
                // Keep the live endpoint's scheduler counters current; the
                // end-of-run absorb in `finish_obs` then only re-writes them.
                m.absorb_sched(stats);
                if r.anomaly.is_some() {
                    m.inc("anomalies", 1);
                }
            });
            Self::snapshot_fleet_gauges(&h, &self.trainer);
        }
        self.emit_step_events(&r, step, devices_before);
        Ok(r)
    }

    /// The replica path of [`Session::step`]: hybrid step over all fleets,
    /// then (rarely) adopt a slice-rebalance proposal — the `Rebalanced`
    /// event trails the step it follows, keeping the run log causal.
    fn replica_step(&mut self, set: &mut ReplicaSet, batch: &Batch) -> Result<StepResult> {
        let devices_before = set.total_devices(&self.trainer);
        let (r, proposal) = set.step(&mut self.trainer, batch)?;
        let step = self.trainer.steps_done();
        if let Some(o) = &self.obs {
            let h = o.handle();
            let stats = self.trainer.sched_stats();
            h.metrics(|m| {
                m.absorb_breakdown(&r.breakdown);
                m.absorb_sched(stats);
                if r.anomaly.is_some() {
                    m.inc("anomalies", 1);
                }
            });
            Self::snapshot_fleet_gauges(&h, &self.trainer);
            Self::snapshot_replica_gauges(&h, set);
        }
        self.emit_step_events(&r, step, devices_before);
        if let Some(new) = proposal {
            set.apply_slices(&mut self.trainer, &mut self.cluster, &new)?;
            self.emit(Event::Rebalanced { step, shares: new });
        }
        Ok(r)
    }

    fn emit_step_events(&mut self, r: &StepResult, step: u64, devices_before: usize) {
        self.emit(Event::StepCompleted {
            step,
            loss: r.loss,
            devices: r.devices,
            breakdown: r.breakdown,
            bytes_moved: r.bytes_moved,
        });
        if r.repartitioned {
            self.emit(Event::Repartitioned { step });
        }
        if r.devices < devices_before {
            self.emit(Event::WorkerLeft { step, devices_left: r.devices });
        }
        // Health and anomaly events trail the step (and any membership
        // events) they belong to, keeping the run log causally ordered.
        for t in &r.health {
            self.emit(Event::HealthChanged {
                step,
                device: t.device,
                from: t.from,
                to: t.to,
                ratio: t.ratio,
            });
        }
        if let Some(a) = &r.anomaly {
            self.emit(Event::AnomalyFlagged {
                step,
                step_ms: a.step_ms,
                median_ms: a.median_ms,
                mad_ms: a.mad_ms,
            });
        }
    }

    /// Refresh the per-replica gauges: `share.rN` (batch-slice fraction)
    /// and `throughput.rN` (samples/s from the rebalancer's EWMA).
    fn snapshot_replica_gauges(h: &crate::obs::ObsHandle, set: &ReplicaSet) {
        let slices = set.slices().to_vec();
        let total: usize = slices.iter().sum();
        let rates: Vec<Option<f64>> =
            (0..slices.len()).map(|r| set.telemetry().rate(r)).collect();
        h.metrics(|m| {
            for (r, s) in slices.iter().enumerate() {
                m.set_gauge(&format!("share.r{r}"), *s as f64 / total.max(1) as f64);
            }
            for (r, rate) in rates.iter().copied().enumerate() {
                if let Some(rate) = rate.filter(|v| *v > 0.0) {
                    m.set_gauge(&format!("throughput.r{r}"), 1.0 / rate);
                }
            }
        });
    }

    /// The replica set (replicas `1..N` + fabric), when this is a replica
    /// session.
    pub fn replicas(&self) -> Option<&ReplicaSet> {
        self.replicas.as_ref()
    }

    /// Bytes the gradient all-reduce fabric has moved (0 for single-fleet).
    pub fn allreduce_bytes(&self) -> u64 {
        self.replicas.as_ref().map_or(0, |s| s.allreduce_bytes())
    }

    /// Manually adopt new per-replica batch slices — the same rebuild path
    /// a rebalancer proposal takes (emits [`Event::Rebalanced`]).
    pub fn rebalance(&mut self, shares: &[usize]) -> Result<()> {
        let mut set =
            self.replicas.take().context("rebalance requires a replica session (replicas > 1)")?;
        let out = set.apply_slices(&mut self.trainer, &mut self.cluster, shares);
        if out.is_ok() {
            let step = self.trainer.steps_done();
            self.emit(Event::Rebalanced { step, shares: shares.to_vec() });
        }
        self.replicas = Some(set);
        out
    }

    /// The bound address of the live metrics endpoint, when one is serving
    /// (resolves an ephemeral `:0` port to the real one).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.live.as_ref().map(|s| s.addr())
    }

    /// The full training loop: `trainer.steps` steps from the session
    /// dataset (the cursor is the global step counter, so a resumed session
    /// continues the exact batch sequence), then a held-out eval.
    pub fn run(&mut self) -> Result<RunReport> {
        let t0 = Instant::now();
        let batch_size = self.rt.arch().batch;
        let first_step = self.trainer.steps_done();
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut cumulative = Breakdown::default();
        let mut bytes = 0u64;
        for _ in 0..self.cfg.steps {
            let cursor = self.trainer.steps_done() as usize;
            let batch = self.dataset.batch(batch_size, cursor)?;
            let r = self.step(&batch)?;
            cumulative.add(&r.breakdown);
            bytes += r.bytes_moved;
            losses.push(r.loss);
            // Periodic auto-checkpoint (`checkpoint_every` trainer knob).
            if let Some(every) = self.cfg.checkpoint_every {
                let done = self.trainer.steps_done();
                if every > 0 && done % every as u64 == 0 {
                    std::fs::create_dir_all(&self.checkpoint_dir).with_context(|| {
                        format!("creating checkpoint dir {}", self.checkpoint_dir.display())
                    })?;
                    let path = self.checkpoint_dir.join(format!("step{done}.ckpt"));
                    self.save_checkpoint(path)?;
                }
            }
        }
        let cursor = self.trainer.steps_done() as usize + 1;
        let held_out = self.dataset.batch(batch_size, cursor)?;
        let eval_accuracy = self.eval(&held_out)?;
        let stats = self.trainer.sched_stats();
        let (repartitions, departures) = (stats.repartitions, stats.departures);
        Ok(RunReport {
            first_step,
            steps_run: losses.len(),
            losses,
            eval_accuracy,
            cumulative,
            bytes_moved: bytes,
            repartitions,
            departures,
            wall: t0.elapsed(),
        })
    }

    /// Evaluate accuracy on a batch (emits [`Event::EvalDone`]).  A replica
    /// session slices the batch across fleets (each `eval_full` is
    /// shape-pinned to its slice) and weight-averages the accuracies.
    pub fn eval(&mut self, batch: &Batch) -> Result<f32> {
        let accuracy = match &self.replicas {
            Some(set) => set.eval_accuracy(&self.trainer, batch)?,
            None => self.trainer.eval_accuracy(batch)?,
        };
        let step = self.trainer.steps_done();
        self.emit(Event::EvalDone { step, accuracy });
        Ok(accuracy)
    }

    // -- checkpointing -------------------------------------------------------

    /// Snapshot the complete resume state (params + momentum + step).
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            step: self.trainer.steps_done(),
            arch_label: self.rt.arch().label(),
            params: self.trainer.params.to_named(),
            velocity: self.trainer.optimizer().export_velocity(),
        }
    }

    /// Write a checkpoint to `path` (emits [`Event::CheckpointSaved`]).
    pub fn save_checkpoint(&mut self, path: impl Into<PathBuf>) -> Result<()> {
        let path = path.into();
        self.checkpoint().save(&path)?;
        let step = self.trainer.steps_done();
        self.emit(Event::CheckpointSaved { step, path });
        Ok(())
    }

    /// Restore a snapshot into this session: architecture label and every
    /// tensor shape must match; momentum and the step counter (which is also
    /// the dataset cursor) come along.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let label = self.rt.arch().label();
        ensure!(
            ckpt.arch_label == label,
            "checkpoint is for arch {} but the session runs {label}",
            ckpt.arch_label
        );
        self.trainer.params.load_named(&ckpt.params)?;
        for (name, t) in &ckpt.velocity {
            let p = self.trainer.params.get(name)?;
            ensure!(
                p.shape() == t.shape(),
                "checkpoint velocity {name} shape {:?} != param {:?}",
                t.shape(),
                p.shape()
            );
        }
        self.trainer.optimizer_mut().import_velocity(ckpt.velocity.clone());
        self.trainer.set_steps_done(ckpt.step);
        // Replica sessions: broadcast the restored state so every replica
        // resumes bit-identical to replica 0 (params go over the fabric).
        if let Some(set) = self.replicas.as_mut() {
            set.sync_from(&self.trainer, ckpt.velocity.clone(), ckpt.step)?;
        }
        Ok(())
    }

    /// Flush the observability sinks: absorb the end-of-run scheduler and
    /// link counters into the registry, write the `metrics`/`run_end` run-log
    /// lines and `trace.json`, and return the rendered metrics table (when
    /// metrics are on).  Idempotent; [`Session::shutdown`] calls it too, so
    /// only call this directly to print the table before tearing down.
    pub fn finish_obs(&mut self) -> Result<Option<String>> {
        // Stop serving scrapes before the registry gets its end-of-run
        // absorbs — the endpoint's contract is "live while training".
        if let Some(mut srv) = self.live.take() {
            srv.stop();
        }
        let Some(obs) = self.obs.as_mut() else {
            return Ok(None);
        };
        let h = obs.handle();
        let stats = self.trainer.sched_stats();
        h.metrics(|m| m.absorb_sched(stats));
        for (device, bytes, frames) in self.trainer.link_stats() {
            h.metrics(|m| m.absorb_link(device, bytes, frames));
        }
        obs.finish(self.trainer.steps_done())
    }

    /// Tell every worker training is over and join the in-proc fleet (after
    /// flushing the observability sinks).
    pub fn shutdown(mut self) -> Result<()> {
        let finish = self.finish_obs();
        let Session { trainer, cluster, replicas, .. } = self;
        trainer.shutdown()?;
        if let Some(c) = cluster {
            c.join()?;
        }
        if let Some(set) = replicas {
            set.shutdown()?;
        }
        finish.map(|_| ())
    }
}
