//! Session checkpoints: parameters + SGD momentum + the step counter, in a
//! small self-describing binary format (the offline build has no serde, and
//! JSON would balloon the f32 payload ~3x).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "CVDSESS1" | u32 version | u64 step | str arch_label
//! | section params | section velocity
//! section := u32 count, then per tensor: str name, u32 rank, u64*rank dims,
//!            f32*prod(dims) data
//! str     := u32 byte length + UTF-8 bytes
//! ```
//!
//! A resumed run continues the *optimizer trajectory* exactly: velocity and
//! step counter restore alongside the parameters, and the session's dataset
//! cursor is the restored step, so the batch sequence continues where the
//! interrupted run left off (`rust/tests/session.rs` proves resume ==
//! uninterrupted).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"CVDSESS1";
const VERSION: u32 = 1;

/// A point-in-time snapshot of everything the master mutates during
/// training.  Workers are stateless (they receive kernels every step), so
/// this is the complete resume state.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Training steps completed when the snapshot was taken.
    pub step: u64,
    /// `ArchSpec::label()` of the architecture that produced it — restoring
    /// onto a different graph fails loudly (shapes are re-validated too).
    pub arch_label: String,
    /// Parameters, in manifest order.
    pub params: Vec<(String, Tensor)>,
    /// SGD momentum buffers (params never stepped have no entry).
    pub velocity: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        write_str(&mut out, &self.arch_label);
        write_section(&mut out, &self.params);
        write_section(&mut out, &self.velocity);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader { b: bytes, pos: 0 };
        let magic = r.take(8)?;
        ensure!(magic == MAGIC, "not a convdist checkpoint (bad magic)");
        let version = r.u32()?;
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let step = r.u64()?;
        let arch_label = r.string()?;
        let params = read_section(&mut r)?;
        let velocity = read_section(&mut r)?;
        ensure!(r.pos == bytes.len(), "trailing garbage after checkpoint payload");
        Ok(Self { step, arch_label, params, velocity })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing checkpoint {}", path.display()))
    }
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn write_section(out: &mut Vec<u8>, entries: &[(String, Tensor)]) {
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (name, t) in entries {
        write_str(out, name);
        out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn read_section(r: &mut Reader) -> Result<Vec<(String, Tensor)>> {
    let count = r.u32()? as usize;
    let mut entries = Vec::with_capacity(count);
    for _ in 0..count {
        let name = r.string()?;
        let rank = r.u32()? as usize;
        ensure!(rank <= 8, "tensor {name}: implausible rank {rank}");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let d = r.u64()?;
            ensure!(
                usize::try_from(d).is_ok(),
                "tensor {name}: dimension {d} does not fit this platform"
            );
            shape.push(d as usize);
        }
        // Checked product: a corrupted dim like 2^40 x 2^40 must come back
        // as an error naming the tensor, not an overflow panic.
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("tensor {name}: shape {shape:?} overflows"))?;
        ensure!(
            n.checked_mul(4).map(|b| b <= r.remaining()).unwrap_or(false),
            "tensor {name}: {n} elements exceed the remaining payload"
        );
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        entries.push((name, Tensor::new(shape, data)?));
    }
    Ok(entries)
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated checkpoint at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        ensure!(len <= 4096, "implausible string length {len}");
        Ok(std::str::from_utf8(self.take(len)?)?.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn sample() -> Checkpoint {
        let mut rng = Pcg32::seed(7);
        Checkpoint {
            step: 42,
            arch_label: "4:8".into(),
            params: vec![
                ("conv1.w".into(), Tensor::randn(&[4, 3, 5, 5], &mut rng)),
                ("fc.b".into(), Tensor::zeros(&[10])),
            ],
            velocity: vec![("conv1.w".into(), Tensor::randn(&[4, 3, 5, 5], &mut rng))],
        }
    }

    #[test]
    fn roundtrips_bit_for_bit() {
        let c = sample();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back.step, c.step);
        assert_eq!(back.arch_label, c.arch_label);
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.velocity.len(), 1);
        for (a, b) in c.params.iter().zip(&back.params) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.shape(), b.1.shape());
            assert!(a.1.data().iter().zip(b.1.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn rejects_corruption() {
        let c = sample();
        let mut bytes = c.to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(Checkpoint::from_bytes(&bad).is_err());
        // Truncation anywhere in the payload.
        bytes.truncate(bytes.len() - 3);
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        // Trailing garbage.
        let mut long = c.to_bytes();
        long.push(0);
        assert!(Checkpoint::from_bytes(&long).is_err());
    }

    /// Shared header for hand-assembled corrupt payloads.
    fn header() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION.to_le_bytes());
        b.extend_from_slice(&42u64.to_le_bytes());
        write_str(&mut b, "4:8");
        b
    }

    #[test]
    fn rejects_overflowing_shapes_with_a_clear_error() {
        // A 2^40 x 2^40 tensor's element count overflows usize — the reader
        // must error naming the tensor, not panic on the multiply.
        let mut b = header();
        b.extend_from_slice(&1u32.to_le_bytes()); // params: 1 entry
        write_str(&mut b, "conv1.w");
        b.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        b.extend_from_slice(&(1u64 << 40).to_le_bytes());
        b.extend_from_slice(&(1u64 << 40).to_le_bytes());
        let msg = format!("{:#}", Checkpoint::from_bytes(&b).unwrap_err());
        assert!(msg.contains("conv1.w") && msg.contains("overflows"), "{msg}");
    }

    #[test]
    fn rejects_element_counts_past_the_payload() {
        // A plausible shape whose data the file does not actually contain.
        let mut b = header();
        b.extend_from_slice(&1u32.to_le_bytes());
        write_str(&mut b, "fc.w");
        b.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        b.extend_from_slice(&10_000u64.to_le_bytes());
        b.extend_from_slice(&[0u8; 16]); // 4 floats, not 10k
        let msg = format!("{:#}", Checkpoint::from_bytes(&b).unwrap_err());
        assert!(msg.contains("fc.w") && msg.contains("remaining payload"), "{msg}");
    }

    #[test]
    fn rejects_a_section_count_lie_as_truncation() {
        let mut b = header();
        b.extend_from_slice(&99u32.to_le_bytes()); // 99 params, zero present
        let msg = format!("{:#}", Checkpoint::from_bytes(&b).unwrap_err());
        assert!(msg.contains("truncated checkpoint"), "{msg}");
    }

    #[test]
    fn load_names_the_file_in_errors() {
        let msg =
            format!("{:#}", Checkpoint::load("/definitely/not/here.ckpt").unwrap_err());
        assert!(msg.contains("not/here.ckpt"), "{msg}");
    }
}
