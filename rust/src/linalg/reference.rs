//! The naive triple-loop GEMMs — the pre-engine kernels, kept verbatim as
//! the conformance oracle for the blocked path (`tests/linalg_conformance`)
//! and as the "naive" side of `examples/bench_gemm.rs`.
//!
//! Semantics are identical to the blocked engine up to f32 summation order:
//! row-major operands, accumulate-into-out.  The zero-skip in [`gemm`] and
//! [`gemm_atb`] makes zero-padded kernel buckets nearly free, which the
//! blocked path preserves arithmetically (0 · x contributes exactly 0.0).

/// `out[m,n] += a[m,kd] * b[kd,n]`.  Saxpy inner loop over contiguous rows
/// of `b`/`out` so the autovectorizer gets stride-1 access; zero `a`
/// entries are skipped.
pub fn gemm(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * kd..(i + 1) * kd];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,n] += a[m,kd] * b[n,kd]^T` — both operands read along contiguous
/// rows (dot products), the layout the kernel-gradient contraction wants.
pub fn gemm_abt(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), n * kd);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * kd..(i + 1) * kd];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * kd..(j + 1) * kd];
            let mut acc = 0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o += acc;
        }
    }
}

/// `out[m,n] += a[rows,m]^T * b[rows,n]` (both stored row-major).
pub fn gemm_atb(a: &[f32], b: &[f32], rows: usize, m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..rows {
        let arow = &a[r * m..(r + 1) * m];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}
