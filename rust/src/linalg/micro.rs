//! The register-tiled microkernel and its runtime ISA dispatch.
//!
//! Both kernels consume the packed panel format produced by
//! `super::pack_a`/`super::pack_b`: per k step, one contiguous MR-strip of A
//! and one contiguous NR-strip of B.  They accumulate the full `MR x NR`
//! product tile in registers across the whole KC depth and only then spill
//! it to the caller's tile buffer — the caller adds the valid sub-rectangle
//! into C, so remainder tiles cost nothing extra in the hot loop.

use std::sync::OnceLock;

/// Micro-tile rows — A is packed in strips this wide.
pub const MR: usize = 8;
/// Micro-tile columns — B is packed in strips this wide (one AVX2 f32 lane).
pub const NR: usize = 8;

/// `tile[MR*NR] = sum_k apanel[k*MR + r] * bpanel[k*NR + c]` (overwrites).
pub type MicroKernel = fn(usize, &[f32], &[f32], &mut [f32; MR * NR]);

/// Instruction set selected for the microkernel, detected once at first use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// AVX2 + FMA: one ymm accumulator per micro-row, broadcast-FMA inner
    /// loop (x86-64 only, runtime-detected).
    Avx2Fma,
    /// Portable unrolled scalar kernel — any target, or forced with
    /// `CONVDIST_NO_SIMD=1`.
    Scalar,
}

impl Isa {
    pub fn label(self) -> &'static str {
        match self {
            Isa::Avx2Fma => "avx2+fma",
            Isa::Scalar => "scalar",
        }
    }
}

/// The ISA the engine dispatches to (cached after the first call).
pub fn isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(detect)
}

fn detect() -> Isa {
    if std::env::var_os("CONVDIST_NO_SIMD").is_some_and(|v| v != "0") {
        return Isa::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2Fma;
        }
    }
    Isa::Scalar
}

/// The microkernel for the detected ISA.
pub(super) fn kernel() -> MicroKernel {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => micro_avx2_entry,
        _ => micro_scalar,
    }
}

/// Portable kernel: the 8x8 accumulator block lives in a stack array the
/// optimizer keeps in registers; the inner loop is the same
/// broadcast-multiply-add shape as the SIMD kernel so autovectorization
/// still applies.
fn micro_scalar(kc: usize, apanel: &[f32], bpanel: &[f32], tile: &mut [f32; MR * NR]) {
    debug_assert!(apanel.len() >= kc * MR && bpanel.len() >= kc * NR);
    let mut acc = [0f32; MR * NR];
    for (astep, bstep) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for (r, &av) in astep.iter().enumerate() {
            let row = &mut acc[r * NR..(r + 1) * NR];
            for (o, &bv) in row.iter_mut().zip(bstep) {
                *o += av * bv;
            }
        }
    }
    *tile = acc;
}

/// Safe entry for the AVX2 kernel — [`kernel`] hands this out only after
/// `is_x86_feature_detected!` confirmed avx2+fma at runtime.
#[cfg(target_arch = "x86_64")]
fn micro_avx2_entry(kc: usize, apanel: &[f32], bpanel: &[f32], tile: &mut [f32; MR * NR]) {
    // SAFETY: reachable only through the Isa::Avx2Fma dispatch arm, which
    // requires a positive runtime avx2+fma detection.
    unsafe { micro_avx2(kc, apanel, bpanel, tile) }
}

/// 8x8 FMA kernel: 8 ymm accumulators (one per micro-row), per k step one
/// NR-wide load of B and 8 broadcast-FMAs — the unrolled FMA-friendly inner
/// loop the blocking above feeds from L1/L2-resident packed panels.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn micro_avx2(kc: usize, apanel: &[f32], bpanel: &[f32], tile: &mut [f32; MR * NR]) {
    use std::arch::x86_64::{
        _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    debug_assert!(apanel.len() >= kc * MR && bpanel.len() >= kc * NR);
    let mut acc = [_mm256_setzero_ps(); MR];
    let mut a = apanel.as_ptr();
    let mut b = bpanel.as_ptr();
    for _ in 0..kc {
        let bv = _mm256_loadu_ps(b);
        for (r, accr) in acc.iter_mut().enumerate() {
            *accr = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(r)), bv, *accr);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    for (r, &accr) in acc.iter().enumerate() {
        _mm256_storeu_ps(tile.as_mut_ptr().add(r * NR), accr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both kernels against a direct triple loop over the packed layout.
    fn packed_oracle(kc: usize, ap: &[f32], bp: &[f32]) -> Vec<f32> {
        let mut tile = vec![0f32; MR * NR];
        for k in 0..kc {
            for r in 0..MR {
                for c in 0..NR {
                    tile[r * NR + c] += ap[k * MR + r] * bp[k * NR + c];
                }
            }
        }
        tile
    }

    #[test]
    fn kernels_match_packed_oracle() {
        let mut rng = crate::tensor::Pcg32::seed(41);
        for kc in [1usize, 2, 7, 64] {
            let ap: Vec<f32> = (0..kc * MR).map(|_| rng.next_gaussian()).collect();
            let bp: Vec<f32> = (0..kc * NR).map(|_| rng.next_gaussian()).collect();
            let want = packed_oracle(kc, &ap, &bp);
            let mut tile = [0f32; MR * NR];
            micro_scalar(kc, &ap, &bp, &mut tile);
            for (got, w) in tile.iter().zip(&want) {
                assert!((got - w).abs() < 1e-4, "scalar kernel kc={kc}");
            }
            // The dispatched kernel (AVX2 where available) agrees too.
            let mut tile2 = [0f32; MR * NR];
            kernel()(kc, &ap, &bp, &mut tile2);
            for (got, w) in tile2.iter().zip(&want) {
                assert!((got - w).abs() < 1e-4, "{} kernel kc={kc}", isa().label());
            }
        }
    }

    #[test]
    fn isa_detection_is_stable() {
        assert_eq!(isa(), isa());
        assert!(!isa().label().is_empty());
    }
}
