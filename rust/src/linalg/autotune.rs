//! One-shot startup autotune of the cache-blocking parameters.
//!
//! The right MC/KC/NC depend on the host's cache hierarchy, which the engine
//! cannot know statically (the fleet is heterogeneous by design).  Instead
//! of shipping one guess, the first non-trivial GEMM call times a small
//! probe (~77 MFLOP per run, best of 3, a few ms with SIMD) under each
//! candidate block set and caches the winner in a `OnceLock` for the life
//! of the process —
//! the same shape of one-shot calibration the paper's §4.1.1 probe does
//! across devices, applied inside one device.
//!
//! Override for reproducible runs: `CONVDIST_GEMM_BLOCKS="mc,kc,nc"`.

use std::sync::OnceLock;
use std::time::Instant;

use super::micro::{MR, NR};

/// Cache-blocking parameters: the packed A block is `mc x kc` (sized for
/// L2), the packed B panel is `kc x nc` (streamed, L3-ish), and the
/// microkernel sweeps `kc`-deep strips of both from L1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocks {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Blocks {
    /// Round to friendly values: MC to a multiple of MR, NC to a multiple
    /// of NR, KC at least 4.  Any `>= 1` values are *correct* (the packers
    /// pad remainder panels); this only keeps the autotune candidates and
    /// env overrides on fast shapes.
    pub fn sanitized(self) -> Blocks {
        Blocks {
            mc: self.mc.div_ceil(MR).max(1) * MR,
            kc: self.kc.max(4),
            nc: self.nc.div_ceil(NR).max(1) * NR,
        }
    }
}

/// Candidate grid: small-cache to large-cache block sets.  A-block bytes
/// (`mc*kc*4`) range 8 KiB – 256 KiB, bracketing common L2 sizes; NC trades
/// B-pack reuse against L3 footprint.  Every `mc` is <= the probe's M so
/// the probe actually exercises each candidate's full A block (a candidate
/// taller than the probe would be timed as if clamped and win blind).
const CANDIDATES: [Blocks; 6] = [
    Blocks { mc: 32, kc: 64, nc: 128 },
    Blocks { mc: 64, kc: 128, nc: 256 },
    Blocks { mc: 128, kc: 256, nc: 512 },
    Blocks { mc: 96, kc: 384, nc: 784 },
    Blocks { mc: 64, kc: 256, nc: 784 },
    Blocks { mc: 128, kc: 384, nc: 256 },
];

/// The process-wide block sizes: env override if set, else the autotune
/// probe, computed once and cached.
pub fn blocks() -> Blocks {
    static BLOCKS: OnceLock<Blocks> = OnceLock::new();
    *BLOCKS.get_or_init(|| env_override().unwrap_or_else(autotune).sanitized())
}

fn env_override() -> Option<Blocks> {
    let v = std::env::var("CONVDIST_GEMM_BLOCKS").ok()?;
    let parts: Option<Vec<usize>> = v.split(',').map(|p| p.trim().parse().ok()).collect();
    let parts = parts?;
    if parts.len() != 3 || parts.iter().any(|&p| p == 0) {
        return None;
    }
    Some(Blocks { mc: parts[0], kc: parts[1], nc: parts[2] })
}

/// Time a conv-shaped probe GEMM (tall-ish A, wide B — the im2col product
/// profile) under every candidate; best-of-3 per candidate (the first run
/// doubles as warmup, `min` filters scheduler noise).  M covers the tallest
/// candidate `mc`, K the deepest `kc`, so no candidate is silently clamped.
fn autotune() -> Blocks {
    const M: usize = 128;
    const K: usize = 384;
    const N: usize = 784;
    let mut rng = crate::tensor::Pcg32::seed(0x6e44);
    let a: Vec<f32> = (0..M * K).map(|_| rng.next_f32() - 0.5).collect();
    let b: Vec<f32> = (0..K * N).map(|_| rng.next_f32() - 0.5).collect();
    let mut out = vec![0f32; M * N];
    let mut best_t = f64::MAX;
    let mut best = CANDIDATES[0].sanitized();
    for &cand in &CANDIDATES {
        let cand = cand.sanitized();
        let mut t = f64::MAX;
        for _ in 0..3 {
            let t0 = Instant::now();
            super::gemm_with_blocks(&a, &b, M, K, N, &mut out, cand);
            t = t.min(t0.elapsed().as_secs_f64());
        }
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_rounds_to_microkernel_multiples() {
        let b = Blocks { mc: 1, kc: 1, nc: 9 }.sanitized();
        assert_eq!(b, Blocks { mc: MR, kc: 4, nc: 2 * NR });
        let b = Blocks { mc: 128, kc: 256, nc: 512 }.sanitized();
        assert_eq!(b, Blocks { mc: 128, kc: 256, nc: 512 });
    }

    #[test]
    fn blocks_is_cached_and_legal() {
        let b = blocks();
        assert_eq!(b, blocks());
        assert!(b.mc >= MR && b.mc % MR == 0, "{b:?}");
        assert!(b.nc >= NR && b.nc % NR == 0, "{b:?}");
        assert!(b.kc >= 4, "{b:?}");
    }
}
