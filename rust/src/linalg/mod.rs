//! The native backend's GEMM engine — blocked, packed, SIMD-dispatched.
//!
//! Every convolution (fwd, input-grad, kernel-grad) and the FC layer bottom
//! out in one of three accumulating f32 products, which used to be naive
//! triple loops in `kernels` (now preserved as [`reference`], the
//! conformance oracle).  This module replaces them with a classic
//! GotoBLAS/BLIS structure:
//!
//! * **Blocking** — `NC`-wide column panels of B, `KC`-deep slices, `MC`-row
//!   blocks of A, so the microkernel streams from cache instead of RAM
//!   ([`blocks`] autotunes MC/KC/NC once per process, `OnceLock`-cached;
//!   `CONVDIST_GEMM_BLOCKS="mc,kc,nc"` overrides).
//! * **Packing** — A blocks and B panels are repacked into contiguous
//!   `MR`/`NR`-strips (zero-padded at the edges), which also makes the
//!   transposed variants ([`gemm_abt`], [`gemm_atb`]) free: they differ only
//!   in the strides the packers read through.
//! * **Microkernel** — an 8x8 register tile ([`micro`]): AVX2+FMA where
//!   `is_x86_feature_detected!` says so, a portable unrolled scalar loop
//!   otherwise (`CONVDIST_NO_SIMD=1` forces the fallback).
//! * **Macro-parallelism** — rayon over `MC`-row panels of the output, but
//!   only from non-pool threads: the conv kernels already parallelize over
//!   the batch axis, and their per-image GEMMs must stay serial inside the
//!   pool (no nested blocking joins while thread-local scratch is live).
//!
//! Numerics: for a given (kd, blocks) the f32 summation order of every
//! output element is fixed — independent of row count, column count and
//! thread count, and the naive-fallback cutoff likewise depends only on
//! `kd * n`, never on rows — so within one process (one autotuned block
//! set, shared through the `OnceLock`) kernel-sharded runs reproduce
//! single-device results exactly as the naive loops did.  Across *separate* processes the
//! autotune may pick different KC and therefore a different (tolerance-level)
//! summation order; pin `CONVDIST_GEMM_BLOCKS` on every node when bit-level
//! cross-process reproducibility matters.  Trailing all-zero rows of A are
//! trimmed before blocking (`trailing_nonzero_rows`), so zero-padded
//! kernel buckets stay nearly free and still yield exactly-zero outputs.

use std::cell::RefCell;

use rayon::prelude::*;

mod autotune;
mod micro;
pub mod reference;

pub use autotune::{blocks, Blocks};
pub use micro::{isa, Isa, MR, NR};

/// Below this `kd*n` panel area the packing overhead outweighs the
/// microkernel win and the naive reference loops are used directly.
/// Deliberately independent of the row count `m`: a kernel-sharded slice of
/// a matrix (fewer rows, same `kd` and `n`) must take the same code path as
/// the full matrix, or shard-vs-single results would differ at the ULP
/// level even with pinned blocks.
const SMALL_PANEL: usize = 4 * 1024;

/// Nominal FLOPs of one `m x kd x n` GEMM (multiply + add).
pub fn gemm_flops(m: usize, kd: usize, n: usize) -> f64 {
    2.0 * (m * kd * n) as f64
}

/// Strided read-only view of an operand: element `(i, j)` lives at
/// `data[i * rs + j * cs]`.  The three public entry points differ only in
/// the strides they hand the packers — transposition is free.
#[derive(Clone, Copy)]
struct View<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl View<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.rs + j * self.cs]
    }
}

thread_local! {
    /// Per-thread packed-A scratch: each row-panel job packs its own A
    /// block (B panels are packed once per slice and shared read-only).
    static A_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// How many k-steps ahead the packers prefetch their source stream.  The
/// packers walk strided memory (row stride `rs` per k for B panels, per-row
/// gathers for A blocks); issuing the next strips' loads this far ahead
/// hides the stride-miss latency behind the current strip's copy.
const PACK_PREFETCH: usize = 4;

/// Best-effort prefetch of the cache line holding `p` into all levels.
/// Architecturally a hint: no memory is read or written, so a reference to
/// any in-bounds element is sufficient.  No-op off x86_64.
#[inline(always)]
fn prefetch_read(p: &f32) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: _mm_prefetch has no observable effects and needs no CPU
    // feature beyond baseline x86_64 SSE; `p` is a valid reference.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
            (p as *const f32).cast::<i8>(),
        );
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Rows of the row-major `[m, stride]` matrix `a` up to (excluding) the
/// trailing run of all-zero rows.  Zero-padded kernel buckets put their
/// padding in trailing rows (`Tensor::pad_axis0`), and a zero row
/// contributes exactly 0 to its outputs — trimming keeps padded shards
/// nearly free, the invariant the naive loops' zero-skip provided.  Costs
/// one short scan: it stops at the first non-zero element it meets.
fn trailing_nonzero_rows(a: &[f32], m: usize, stride: usize) -> usize {
    let mut mt = m;
    while mt > 0 && a[(mt - 1) * stride..mt * stride].iter().all(|&v| v == 0.0) {
        mt -= 1;
    }
    mt
}

/// `out[m,n] += a[m,kd] * b[kd,n]` (row-major, accumulating) — drop-in for
/// the former `kernels::gemm_acc`.
pub fn gemm(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    let m = trailing_nonzero_rows(a, m, kd);
    let (a, out) = (&a[..m * kd], &mut out[..m * n]);
    if kd * n <= SMALL_PANEL {
        return reference::gemm(a, b, m, kd, n, out);
    }
    let av = View { data: a, rs: kd, cs: 1 };
    let bv = View { data: b, rs: n, cs: 1 };
    gemm_view(av, bv, m, kd, n, out, blocks(), true);
}

/// `out[m,n] += a[m,kd] * b[n,kd]^T` — the kernel-gradient contraction
/// (drop-in for the former `kernels::gemm_abt_acc`).
pub fn gemm_abt(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), n * kd);
    debug_assert_eq!(out.len(), m * n);
    let m = trailing_nonzero_rows(a, m, kd);
    let (a, out) = (&a[..m * kd], &mut out[..m * n]);
    if kd * n <= SMALL_PANEL {
        return reference::gemm_abt(a, b, m, kd, n, out);
    }
    let av = View { data: a, rs: kd, cs: 1 };
    let bv = View { data: b, rs: 1, cs: kd };
    gemm_view(av, bv, m, kd, n, out, blocks(), true);
}

/// `out[m,n] += a[rows,m]^T * b[rows,n]` (both stored row-major) — drop-in
/// for the former `kernels::gemm_atb_acc`.
pub fn gemm_atb(a: &[f32], b: &[f32], rows: usize, m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    // Trailing zero rows of `a` span the shared dimension here: dropping
    // them drops their (all-zero) contribution to every output.
    let rows = trailing_nonzero_rows(a, rows, m);
    let (a, b) = (&a[..rows * m], &b[..rows * n]);
    // `rows` is the shared dimension here; like above, the path choice must
    // not depend on the output row count `m`.
    if rows * n <= SMALL_PANEL {
        return reference::gemm_atb(a, b, rows, m, n, out);
    }
    let av = View { data: a, rs: 1, cs: m };
    let bv = View { data: b, rs: n, cs: 1 };
    gemm_view(av, bv, m, rows, n, out, blocks(), true);
}

/// [`gemm`] with explicit block sizes, serial, no small-case fallback — the
/// conformance tests force tiny/odd blocks through this to exercise every
/// remainder-tile path, and the autotune probe times candidates with it.
/// Any `mc, kc, nc >= 1` are valid.
pub fn gemm_with_blocks(
    a: &[f32],
    b: &[f32],
    m: usize,
    kd: usize,
    n: usize,
    out: &mut [f32],
    bl: Blocks,
) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), kd * n);
    debug_assert_eq!(out.len(), m * n);
    let av = View { data: a, rs: kd, cs: 1 };
    let bv = View { data: b, rs: n, cs: 1 };
    gemm_view(av, bv, m, kd, n, out, bl, false);
}

/// [`gemm_abt`] with explicit block sizes (see [`gemm_with_blocks`]).
pub fn gemm_abt_with_blocks(
    a: &[f32],
    b: &[f32],
    m: usize,
    kd: usize,
    n: usize,
    out: &mut [f32],
    bl: Blocks,
) {
    debug_assert_eq!(a.len(), m * kd);
    debug_assert_eq!(b.len(), n * kd);
    debug_assert_eq!(out.len(), m * n);
    let av = View { data: a, rs: kd, cs: 1 };
    let bv = View { data: b, rs: 1, cs: kd };
    gemm_view(av, bv, m, kd, n, out, bl, false);
}

/// [`gemm_atb`] with explicit block sizes (see [`gemm_with_blocks`]).
pub fn gemm_atb_with_blocks(
    a: &[f32],
    b: &[f32],
    rows: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
    bl: Blocks,
) {
    debug_assert_eq!(a.len(), rows * m);
    debug_assert_eq!(b.len(), rows * n);
    debug_assert_eq!(out.len(), m * n);
    let av = View { data: a, rs: 1, cs: m };
    let bv = View { data: b, rs: n, cs: 1 };
    gemm_view(av, bv, m, rows, n, out, bl, false);
}

/// The blocked driver: `out[m,n] += A[m,kd] * B[kd,n]` through strided
/// views.  Loop nest (outer to inner): NC column panels of B, KC slices of
/// the shared dimension (B panel packed once per slice), MC row blocks of A
/// (packed per thread, rayon-parallel from non-pool threads), then the
/// NR x MR micro-tile sweep.
#[allow(clippy::too_many_arguments)]
fn gemm_view(
    a: View<'_>,
    b: View<'_>,
    m: usize,
    kd: usize,
    n: usize,
    out: &mut [f32],
    bl: Blocks,
    parallel: bool,
) {
    if m == 0 || n == 0 || kd == 0 {
        return;
    }
    // Nested-parallelism guard: inside a rayon pool thread (the kernels'
    // batch loop) the per-image GEMM runs serial — the pool is already
    // saturated, and a blocking inner join could steal another batch item
    // onto this thread while its scratch borrow is live.
    let parallel = parallel && m > bl.mc && rayon::current_thread_index().is_none();
    let mut bbuf: Vec<f32> = Vec::new();
    let mut jc = 0usize;
    while jc < n {
        let ncb = bl.nc.min(n - jc);
        let mut pc = 0usize;
        while pc < kd {
            let kcb = bl.kc.min(kd - pc);
            let bpack = pack_b(b, pc, kcb, jc, ncb, &mut bbuf);
            let do_panel = |pi: usize, oblock: &mut [f32]| {
                let i0 = pi * bl.mc;
                let mcb = bl.mc.min(m - i0);
                A_SCRATCH.with(|s| {
                    let mut abuf = s.borrow_mut();
                    let apack = pack_a(a, i0, mcb, pc, kcb, &mut abuf);
                    macro_panel(apack, bpack, mcb, kcb, jc, ncb, n, oblock);
                });
            };
            if parallel {
                out.par_chunks_mut(bl.mc * n).enumerate().for_each(|(pi, ob)| do_panel(pi, ob));
            } else {
                for (pi, ob) in out.chunks_mut(bl.mc * n).enumerate() {
                    do_panel(pi, ob);
                }
            }
            pc += kcb;
        }
        jc += ncb;
    }
}

/// Pack the `mcb x kcb` block of A at `(i0, p0)` into MR-row micro-panels:
/// panel `p` stores, k-major, the strip `A[i0 + p*MR + r, p0 + k]`,
/// zero-padded past the last row, so the microkernel reads one contiguous
/// MR-strip per k step.
/// Returns the packed block (`buf[..panels * MR * kcb]`); the scratch vec
/// grows but is never shrunk or redundantly zeroed — every element of the
/// returned slice is written here (values, or explicit zeros in the last
/// panel's pad rows).
fn pack_a<'b>(
    a: View<'_>,
    i0: usize,
    mcb: usize,
    p0: usize,
    kcb: usize,
    buf: &'b mut Vec<f32>,
) -> &'b [f32] {
    let panels = mcb.div_ceil(MR);
    let need = panels * MR * kcb;
    if buf.len() < need {
        buf.resize(need, 0.0);
    }
    let out = &mut buf[..need];
    for (p, dst) in out.chunks_exact_mut(MR * kcb).enumerate() {
        let r0 = p * MR;
        let rows = MR.min(mcb - r0);
        for k in 0..kcb {
            // Prefetch the strip PACK_PREFETCH k-steps ahead (first and
            // last row only, like pack_b — a full per-row sweep would cost
            // more checked address arithmetic than the hint buys back).
            if k + PACK_PREFETCH < kcb {
                let col = (p0 + k + PACK_PREFETCH) * a.cs;
                if let Some(v) = a.data.get((i0 + r0) * a.rs + col) {
                    prefetch_read(v);
                }
                if rows > 1 {
                    if let Some(v) = a.data.get((i0 + r0 + rows - 1) * a.rs + col) {
                        prefetch_read(v);
                    }
                }
            }
            let strip = &mut dst[k * MR..(k + 1) * MR];
            for (r, slot) in strip[..rows].iter_mut().enumerate() {
                *slot = a.at(i0 + r0 + r, p0 + k);
            }
            for slot in &mut strip[rows..] {
                *slot = 0.0;
            }
        }
    }
    out
}

/// Pack the `kcb x ncb` panel of B at `(p0, j0)` into NR-column
/// micro-panels, k-major, zero-padded past the last column.  The common
/// row-major case (`cs == 1`) is a straight `copy_from_slice` per k.
fn pack_b<'b>(
    b: View<'_>,
    p0: usize,
    kcb: usize,
    j0: usize,
    ncb: usize,
    buf: &'b mut Vec<f32>,
) -> &'b [f32] {
    let panels = ncb.div_ceil(NR);
    let need = panels * NR * kcb;
    if buf.len() < need {
        buf.resize(need, 0.0);
    }
    let out = &mut buf[..need];
    for (p, dst) in out.chunks_exact_mut(NR * kcb).enumerate() {
        let c0 = p * NR;
        let cols = NR.min(ncb - c0);
        for k in 0..kcb {
            // Prefetch the strip PACK_PREFETCH k-rows ahead (start and end
            // of the strip — an NR strip spans at most two cache lines in
            // the contiguous case; the strided case gets its first line).
            if k + PACK_PREFETCH < kcb {
                let base = (p0 + k + PACK_PREFETCH) * b.rs + (j0 + c0) * b.cs;
                if let Some(v) = b.data.get(base) {
                    prefetch_read(v);
                }
                if let Some(v) = b.data.get(base + (cols - 1) * b.cs) {
                    prefetch_read(v);
                }
            }
            let strip = &mut dst[k * NR..(k + 1) * NR];
            if b.cs == 1 {
                let src = &b.data[(p0 + k) * b.rs + j0 + c0..][..cols];
                strip[..cols].copy_from_slice(src);
            } else {
                for (c, slot) in strip[..cols].iter_mut().enumerate() {
                    *slot = b.at(p0 + k, j0 + c0 + c);
                }
            }
            for slot in &mut strip[cols..] {
                *slot = 0.0;
            }
        }
    }
    out
}

/// Sweep one packed A block against the packed B panel, accumulating into
/// the output row block (`oblock` holds full `n`-wide rows starting at row
/// `i0` of `out`; this panel touches columns `jc .. jc + ncb`).
#[allow(clippy::too_many_arguments)]
fn macro_panel(
    abuf: &[f32],
    bbuf: &[f32],
    mcb: usize,
    kcb: usize,
    jc: usize,
    ncb: usize,
    n: usize,
    oblock: &mut [f32],
) {
    let kern = micro::kernel();
    let mut tile = [0f32; MR * NR];
    for (pj, bpanel) in bbuf.chunks_exact(NR * kcb).enumerate() {
        let j0 = pj * NR;
        let cols = NR.min(ncb - j0);
        for (pi, apanel) in abuf.chunks_exact(MR * kcb).enumerate() {
            let i0 = pi * MR;
            let rows = MR.min(mcb - i0);
            kern(kcb, apanel, bpanel, &mut tile);
            for r in 0..rows {
                let orow = &mut oblock[(i0 + r) * n + jc + j0..][..cols];
                let trow = &tile[r * NR..r * NR + cols];
                for (o, &t) in orow.iter_mut().zip(trow) {
                    *o += t;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn gemm_matches_reference_above_and_below_small_cutoff() {
        let mut rng = Pcg32::seed(51);
        for &(m, kd, n) in &[(3usize, 4usize, 5usize), (40, 60, 70), (17, 130, 33)] {
            let a = randn(&mut rng, m * kd);
            let b = randn(&mut rng, kd * n);
            let mut got = randn(&mut rng, m * n);
            let mut want = got.clone();
            gemm(&a, &b, m, kd, n, &mut got);
            reference::gemm(&a, &b, m, kd, n, &mut want);
            assert!(max_abs_diff(&got, &want) <= 1e-4, "gemm {m}x{kd}x{n}");
        }
    }

    #[test]
    fn transposed_variants_match_reference() {
        let mut rng = Pcg32::seed(52);
        // kd*n (resp. rows*n) above SMALL_PANEL so the blocked path runs.
        let (m, kd, n) = (33usize, 80usize, 60usize);
        let a = randn(&mut rng, m * kd);
        let bt = randn(&mut rng, n * kd);
        let mut got = vec![0f32; m * n];
        let mut want = vec![0f32; m * n];
        gemm_abt(&a, &bt, m, kd, n, &mut got);
        reference::gemm_abt(&a, &bt, m, kd, n, &mut want);
        assert!(max_abs_diff(&got, &want) <= 1e-4, "gemm_abt");

        let (rows, m2, n2) = (140usize, 26usize, 31usize);
        let at = randn(&mut rng, rows * m2);
        let b = randn(&mut rng, rows * n2);
        let mut got = vec![0f32; m2 * n2];
        let mut want = vec![0f32; m2 * n2];
        gemm_atb(&at, &b, rows, m2, n2, &mut got);
        reference::gemm_atb(&at, &b, rows, m2, n2, &mut want);
        assert!(max_abs_diff(&got, &want) <= 1e-4, "gemm_atb");
    }

    #[test]
    fn odd_blocks_and_remainder_tiles_are_exact() {
        let mut rng = Pcg32::seed(53);
        let (m, kd, n) = (19usize, 23usize, 21usize);
        let a = randn(&mut rng, m * kd);
        let b = randn(&mut rng, kd * n);
        for bl in [
            Blocks { mc: 5, kc: 3, nc: 13 },
            Blocks { mc: 8, kc: 23, nc: 8 },
            Blocks { mc: 19, kc: 1, nc: 21 },
        ] {
            let mut got = vec![0f32; m * n];
            let mut want = vec![0f32; m * n];
            gemm_with_blocks(&a, &b, m, kd, n, &mut got, bl);
            reference::gemm(&a, &b, m, kd, n, &mut want);
            assert!(max_abs_diff(&got, &want) <= 1e-4, "blocks {bl:?}");
        }
    }

    #[test]
    fn zero_rows_of_a_stay_exactly_zero() {
        // Padded kernel buckets rely on 0-rows producing bit-exact zeros.
        let mut rng = Pcg32::seed(54);
        // kd*n above SMALL_PANEL: the blocked path, not the naive fallback.
        let (m, kd, n) = (16usize, 50usize, 96usize);
        let mut a = randn(&mut rng, m * kd);
        for v in &mut a[8 * kd..] {
            *v = 0.0;
        }
        let b = randn(&mut rng, kd * n);
        let mut out = vec![0f32; m * n];
        gemm(&a, &b, m, kd, n, &mut out);
        assert!(out[8 * n..].iter().all(|&v| v == 0.0), "zero rows must stay zero");
        assert!(out[..8 * n].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn accumulates_into_out_instead_of_overwriting() {
        let mut rng = Pcg32::seed(55);
        // kd*n above SMALL_PANEL: exercises blocked-path accumulation.
        let (m, kd, n) = (24usize, 64usize, 80usize);
        let a = randn(&mut rng, m * kd);
        let b = randn(&mut rng, kd * n);
        let mut once = vec![0f32; m * n];
        gemm(&a, &b, m, kd, n, &mut once);
        let mut twice = vec![0f32; m * n];
        gemm(&a, &b, m, kd, n, &mut twice);
        gemm(&a, &b, m, kd, n, &mut twice);
        let scaled: Vec<f32> = once.iter().map(|v| 2.0 * v).collect();
        assert!(max_abs_diff(&twice, &scaled) <= 1e-3);
    }

    #[test]
    fn gemm_flops_counts_multiply_and_add() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }
}
