//! Baselines the paper compares against.
//!
//! * [`SingleDeviceTrainer`] — the reference point for every speedup: the
//!   whole network trained on one device via the fused `grad_full`
//!   executable (served by whichever backend the [`Runtime`] carries).
//!   Also the numeric ground truth the distributed trainer must match
//!   bit-for-bit-ish (same math, different partitioning).
//! * [`DataParallelTrainer`] — §2.2.1: each replica computes full-network
//!   gradients on a batch shard; gradients are averaged and applied once.
//!   This is the TensorFlow/Vishnu-style comparison (Table 1) and exhibits
//!   its failure mode on heterogeneous fleets (the step waits for the
//!   slowest replica).
//! * [`dp_sim_step_time`] — analytic step-time model for the Table 1 anchor.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::config::TrainerConfig;
use crate::data::Batch;
use crate::devices::Throttle;
use crate::metrics::{Breakdown, Phase, PhaseTimer};
use crate::model::{Grads, Params, Sgd};
use crate::runtime::Runtime;
use crate::sim::ArchShape;
use crate::tensor::Value;

/// Run `grad_full_b{batch}` and split the outputs into (loss, grads).
fn run_grad_full(
    rt: &Runtime,
    params: &Params,
    images: Value,
    labels: Value,
    batch: usize,
) -> Result<(f32, Grads)> {
    let name = format!("grad_full_b{batch}");
    let mut args = vec![images, labels];
    args.extend(params.in_order().into_iter().map(Value::F32));
    let outs = rt.execute(&name, &args)?;
    let mut it = outs.into_iter();
    let loss = it.next().unwrap().as_f32()?.item()?;
    let mut grads = Grads::zeros_like(params);
    for name in params.names().to_vec() {
        grads.set(&name, it.next().unwrap().as_f32()?.clone());
    }
    Ok((loss, grads))
}

/// The 1-device reference trainer.
pub struct SingleDeviceTrainer {
    rt: Arc<Runtime>,
    pub params: Params,
    opt: Sgd,
    throttle: Throttle,
}

impl SingleDeviceTrainer {
    pub fn new(rt: Arc<Runtime>, cfg: &TrainerConfig, throttle: Throttle) -> Result<Self> {
        let params = Params::init(rt.arch(), cfg.seed)?;
        Ok(Self { rt, params, opt: Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay), throttle })
    }

    pub fn step(&mut self, batch: &Batch) -> Result<(f32, Breakdown)> {
        let mut timer = PhaseTimer::default();
        let b = batch.labels.len();
        let t0 = std::time::Instant::now();
        let (loss, grads) = run_grad_full(
            &self.rt,
            &self.params,
            Value::F32(batch.images.clone()),
            Value::I32(batch.labels.clone()),
            b,
        )?;
        let padded = self.throttle.pad(t0.elapsed(), self.rt.flops(&format!("grad_full_b{b}")));
        // grad_full fuses conv and non-conv; attribute by the arch's conv
        // FLOP share so breakdowns remain comparable with the cluster's.
        // The share is priced straight off the layer graph's conv FLOPs, so
        // an N-conv ArchSpec needs no two-conv shoehorning.
        let arch = self.rt.arch();
        let share = crate::sim::comp_share_for_train_flops(
            arch.conv_flops_fwd_at(1024) * ArchShape::TRAIN_CONV_FACTOR,
        );
        timer.record(Phase::Conv, padded.mul_f64(1.0 - share));
        timer.record(Phase::Comp, padded.mul_f64(share));
        timer.time(Phase::Comp, || self.opt.step(&mut self.params, &grads))?;
        Ok((loss, timer.breakdown))
    }
}

/// Data-parallel trainer over `replicas` emulated devices.
///
/// The batch is split *evenly* (the paper's §2.2.1 critique: every replica
/// gets the same share regardless of its speed), each shard runs the fused
/// gradient executable, gradients are weighted-averaged, one SGD step is
/// applied.  Replica `i` may be throttled to emulate a heterogeneous fleet;
/// the step time is the max over replicas (synchronous updates).
pub struct DataParallelTrainer {
    rt: Arc<Runtime>,
    pub params: Params,
    opt: Sgd,
    throttles: Vec<Throttle>,
}

impl DataParallelTrainer {
    pub fn new(rt: Arc<Runtime>, cfg: &TrainerConfig, throttles: Vec<Throttle>) -> Result<Self> {
        ensure!(!throttles.is_empty(), "need at least one replica");
        let params = Params::init(rt.arch(), cfg.seed)?;
        Ok(Self { rt, params, opt: Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay), throttles })
    }

    pub fn replicas(&self) -> usize {
        self.throttles.len()
    }

    pub fn step(&mut self, batch: &Batch) -> Result<(f32, Breakdown)> {
        let n = self.throttles.len();
        let b = batch.labels.len();
        ensure!(b % n == 0, "batch {b} not divisible by {n} replicas");
        let shard = b / n;
        ensure!(
            self.rt.arch().batch_buckets.contains(&shard),
            "no grad_full bucket for per-replica batch {shard} (buckets {:?})",
            self.rt.arch().batch_buckets
        );
        let mut timer = PhaseTimer::default();
        let mut acc = Grads::zeros_like(&self.params);
        let mut loss_sum = 0f32;
        let mut slowest = Duration::ZERO;
        for (i, throttle) in self.throttles.clone().into_iter().enumerate() {
            let images = batch.images.slice_axis0(i * shard, (i + 1) * shard)?;
            let labels = batch.labels.slice_axis0(i * shard, (i + 1) * shard)?;
            let t0 = std::time::Instant::now();
            let (loss, grads) = run_grad_full(
                &self.rt,
                &self.params,
                Value::F32(images),
                Value::I32(labels),
                shard,
            )?;
            // Replicas run concurrently on real clusters; we execute them
            // sequentially and report the max (synchronous semantics).
            slowest =
                slowest.max(throttle.pad(t0.elapsed(), self.rt.flops(&format!("grad_full_b{shard}"))));
            // Average of per-shard means: every shard has equal weight.
            acc.axpy(1.0 / n as f32, &grads)?;
            loss_sum += loss / n as f32;
        }
        timer.record(Phase::Conv, slowest);
        timer.time(Phase::Comp, || self.opt.step(&mut self.params, &acc))?;
        Ok((loss_sum, timer.breakdown))
    }
}

/// Analytic data-parallel step time for the Table 1 anchor: `n` identical
/// K20m-class GPUs in one machine, TF's CIFAR-10 CNN.
///
/// `T(n) = compute/(n·g) + ring-sync(params) + fixed overhead` — the fixed
/// overhead (session dispatch + input pipeline, which TF's own comments
/// blame for the flat 3-4 GPU scaling) is calibrated once against the
/// 1-GPU row and held for every n.
pub fn dp_sim_step_time(arch: &ArchShape, n: usize) -> f64 {
    const K20M_GFLOPS: f64 = 100.0; // effective conv throughput (2015 TF)
    const PCIE_GBPS: f64 = 6.0; // gen3 x8 effective
    const OVERHEAD_S: f64 = 0.03; // dispatch + input pipeline per step
    const LAUNCH_S: f64 = 0.004; // per-GPU kernel-launch/queue cost
    // TF cifar10 params ≈ 1.07M plus our FC sizing; conv params negligible.
    let params = (arch.k1 * arch.in_ch + arch.k2 * arch.k1) * arch.kh * arch.kw
        + arch.k2 * arch.p2_out() * arch.p2_out() * 384; // fc stack
    let compute = arch.conv_flops_train() * 1.35 / (n as f64 * K20M_GFLOPS * 1e9);
    let sync = if n == 1 {
        0.0
    } else {
        // Ring all-reduce: 2(n-1)/n of the gradient bytes per device.
        2.0 * (n as f64 - 1.0) / n as f64 * (params * 4) as f64 / (PCIE_GBPS * 1e9 / 8.0)
    };
    compute + sync + OVERHEAD_S + LAUNCH_S * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_big_gain_then_flat() {
        let arch = ArchShape::new(64, 64, 128);
        let t: Vec<f64> = (1..=4).map(|n| dp_sim_step_time(&arch, n)).collect();
        let s2 = t[0] / t[1];
        let s4 = t[0] / t[3];
        assert!(s2 > 1.4, "1→2 GPUs must show a clear win, got {s2}");
        // 3→4 barely improves (paper: "it doesn't seem to be scalable").
        let gain34 = t[2] / t[3];
        assert!(gain34 < 1.15, "3→4 should be nearly flat, got {gain34}");
        assert!(s4 < 4.0, "overheads must keep 4-GPU speedup sublinear, got {s4}");
    }

    #[test]
    fn dp_sim_monotone_nonincreasing() {
        let arch = ArchShape::new(64, 64, 128);
        let mut prev = f64::MAX;
        for n in 1..=4 {
            let t = dp_sim_step_time(&arch, n);
            assert!(t <= prev * 1.02, "step time should not grow much with GPUs");
            prev = t;
        }
    }
}
