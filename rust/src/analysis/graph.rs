//! Graph pass: shape/geometry inference over the layer IR, accumulated as
//! coded diagnostics instead of failing on the first problem.
//!
//! The walk mirrors the invariants [`ArchSpec::build`] enforces — square
//! kernels, valid padding at stride 1, even extents for `maxpool2`, mid ops
//! only after the first conv, an `Fc` head terminated by `SoftmaxXent` —
//! but keeps going after a finding so one `check` run reports everything
//! wrong with a graph.  On top of the hard invariants it lints dead mid
//! segments (G011), odd bucket ladders (G012) and emits a per-layer
//! resource report (G101/G102: params, FLOPs, activation + im2col memory).

use crate::runtime::{ArchSpec, LayerSpec};
use crate::util::json::Json;

use super::diag::Report;

const MIB: f64 = (1u64 << 20) as f64;

/// Analyze an already-built [`ArchSpec`].  Building implies the hard
/// invariants hold, so on specs from [`ArchSpec::build`] this yields only
/// warnings and notes — but a spec whose ladders were mutated after the
/// fact (e.g. by a hand-edited manifest) still gets the ladder lints.
pub fn check_spec(arch: &ArchSpec) -> Report {
    let mut rep = Report::new();
    check_layers(arch.batch, arch.img, arch.in_ch, &arch.layers, &mut rep);
    for (i, cv) in arch.convs.iter().enumerate() {
        lint_ladder(i + 1, cv.k, &cv.buckets, &mut rep);
    }
    resource_report(arch, &mut rep);
    rep
}

/// Analyze a standalone graph document (text form).  A parse failure is
/// itself a diagnostic (G010), not an `Err` — `convdist check` never
/// crashes on its input.
pub fn check_graph_text(text: &str) -> Report {
    match Json::parse(text) {
        Ok(v) => check_graph_json(&v),
        Err(e) => {
            let mut rep = Report::new();
            rep.emit("G010", None, format!("graph is not valid JSON: {e:#}"));
            rep
        }
    }
}

/// Analyze a parsed graph document.  Handles both manifest-config schemas:
/// the layer-graph form (a `"layers"` array, analyzed leniently with
/// per-layer locations) and the legacy two-conv `k1`/`k2` form (delegated
/// to the strict parser, then the built spec is linted).
pub fn check_graph_json(v: &Json) -> Report {
    let mut rep = Report::new();
    if v.opt("layers").is_none() {
        match ArchSpec::from_json(v) {
            Ok(spec) => return check_spec(&spec),
            Err(e) => {
                rep.emit("G010", None, format!("legacy two-conv document rejected: {e:#}"));
                return rep;
            }
        }
    }

    // Geometry keys, each reported independently.
    let key_usize = |rep: &mut Report, key: &str| -> Option<usize> {
        match v.opt(key) {
            None => {
                rep.emit("G010", Some(key.to_string()), format!("missing key {key:?}"));
                None
            }
            Some(x) => match x.as_usize() {
                Ok(n) => Some(n),
                Err(e) => {
                    rep.emit("G010", Some(key.to_string()), format!("{e:#}"));
                    None
                }
            },
        }
    };
    let batch = key_usize(&mut rep, "batch");
    let img = key_usize(&mut rep, "img");
    let in_ch = key_usize(&mut rep, "in_ch");

    // Layers, best effort: a layer that fails to parse is reported and
    // skipped so the structural walk still covers the rest.
    let mut layers: Vec<LayerSpec> = Vec::new();
    match v.get("layers").and_then(|lv| lv.as_arr().map(<[Json]>::to_vec)) {
        Err(e) => rep.emit("G010", Some("layers".into()), format!("{e:#}")),
        Ok(arr) => {
            for (i, item) in arr.iter().enumerate() {
                match parse_layer(item) {
                    Ok(l) => layers.push(l),
                    Err(e) => {
                        rep.emit("G010", Some(format!("layers[{i}]")), format!("{e:#}"));
                    }
                }
            }
        }
    }
    if let (Some(b), Some(im), Some(c)) = (batch, img, in_ch) {
        check_layers(b, im, c, &layers, &mut rep);
    }

    // Ladder override structure, against the conv layers that did parse.
    let conv_ks: Vec<usize> = layers
        .iter()
        .filter_map(|l| if let LayerSpec::Conv { k, .. } = l { Some(*k) } else { None })
        .collect();
    if let Some(bk) = v.opt("buckets") {
        match bk.as_arr() {
            Err(e) => rep.emit("G013", Some("buckets".into()), format!("{e:#}")),
            Ok(lists) => {
                if lists.len() != conv_ks.len() {
                    rep.emit(
                        "G013",
                        Some("buckets".into()),
                        format!("{} ladders for {} conv layers", lists.len(), conv_ks.len()),
                    );
                }
                for (i, (lv, &k)) in lists.iter().zip(&conv_ks).enumerate() {
                    let loc = format!("buckets[{i}]");
                    match lv.as_usize_vec() {
                        Err(e) => rep.emit("G013", Some(loc), format!("{e:#}")),
                        Ok(ladder) => {
                            if ladder.last() != Some(&k) {
                                rep.emit(
                                    "G013",
                                    Some(loc),
                                    format!(
                                        "ladder {ladder:?} must end at k={k} so a single \
                                         surviving device can take the whole layer"
                                    ),
                                );
                            } else {
                                lint_ladder(i + 1, k, &ladder, &mut rep);
                            }
                        }
                    }
                }
            }
        }
    }
    if let Some(bb) = v.opt("batch_buckets") {
        if let Err(e) = bb.as_usize_vec() {
            rep.emit("G010", Some("batch_buckets".into()), format!("{e:#}"));
        }
    }

    // Cross-check: analysis-clean must imply the strict parser accepts the
    // document (probe blocks and anything the walk above does not model).
    if !rep.has_deny() {
        match ArchSpec::from_json(v) {
            Ok(spec) => resource_report(&spec, &mut rep),
            Err(e) => rep.emit("G010", None, format!("{e:#}")),
        }
    }
    rep
}

fn parse_layer(v: &Json) -> anyhow::Result<LayerSpec> {
    let op = v.get("op")?.as_str()?;
    Ok(match op {
        "conv" => LayerSpec::Conv {
            k: v.get("k")?.as_usize()?,
            kh: v.get("kh")?.as_usize()?,
            kw: v.get("kw")?.as_usize()?,
        },
        "lrn" => LayerSpec::Lrn,
        "maxpool2" => LayerSpec::MaxPool2,
        "relu" => LayerSpec::Relu,
        "fc" => LayerSpec::Fc { out: v.get("out")?.as_usize()? },
        "softmax_xent" => LayerSpec::SoftmaxXent,
        other => anyhow::bail!("unknown op {other:?}"),
    })
}

/// The structural + geometric walk.  Geometric checks (G004/G005/G006) stop
/// propagating once the spatial extent is unknowable, but structural checks
/// (ordering, head, loss) continue to the end of the layer list.
fn check_layers(batch: usize, img: usize, in_ch: usize, layers: &[LayerSpec], rep: &mut Report) {
    if batch == 0 || img == 0 || in_ch == 0 {
        rep.emit(
            "G004",
            None,
            format!("degenerate input geometry: batch={batch} img={img} in_ch={in_ch}"),
        );
    }
    let mut geometry_ok = batch > 0 && img > 0 && in_ch > 0;
    let mut hw = img;
    let mut saw_conv = false;
    let mut saw_fc = false;
    let mut saw_loss = false;
    let mut prev: Option<&LayerSpec> = None;
    for (i, l) in layers.iter().enumerate() {
        let loc = || Some(format!("layers[{i}]"));
        if saw_fc && !matches!(l, LayerSpec::SoftmaxXent) {
            rep.emit(
                "G009",
                loc(),
                format!("{l:?} after the Fc head — only SoftmaxXent may follow Fc"),
            );
        }
        match *l {
            LayerSpec::Conv { k, kh, kw } => {
                if k == 0 || kh == 0 || kw == 0 {
                    rep.emit("G004", loc(), format!("degenerate conv: k={k} kh={kh} kw={kw}"));
                    geometry_ok = false;
                } else {
                    if kh != kw {
                        rep.emit(
                            "G003",
                            loc(),
                            format!(
                                "non-square {kh}x{kw} kernel — activations are square, \
                                 so kernels must satisfy kh == kw"
                            ),
                        );
                    }
                    if geometry_ok {
                        if hw >= kh {
                            hw = hw - kh + 1;
                        } else {
                            rep.emit(
                                "G005",
                                loc(),
                                format!(
                                    "{kh}x{kw} conv does not fit a {hw}x{hw} input — valid \
                                     padding at stride 1 needs an extent of at least {kh}"
                                ),
                            );
                            geometry_ok = false;
                        }
                    }
                }
                saw_conv = true;
            }
            LayerSpec::Lrn | LayerSpec::Relu => {
                if !saw_conv {
                    rep.emit(
                        "G002",
                        loc(),
                        format!("{l:?} before the first conv — mid ops attach to a conv layer"),
                    );
                }
                if prev == Some(l) {
                    rep.emit(
                        "G011",
                        loc(),
                        format!(
                            "{l:?} repeated back-to-back — Relu is idempotent and double \
                             LRN is almost surely unintended; the repeat is dead weight"
                        ),
                    );
                }
            }
            LayerSpec::MaxPool2 => {
                if !saw_conv {
                    rep.emit(
                        "G002",
                        loc(),
                        "MaxPool2 before the first conv — mid ops attach to a conv layer",
                    );
                } else if geometry_ok {
                    if hw % 2 == 0 {
                        hw /= 2;
                    } else {
                        rep.emit(
                            "G006",
                            loc(),
                            format!(
                                "maxpool2 needs an even extent, got {hw}x{hw} — the 2x2 \
                                 window at stride 2 cannot tile an odd input"
                            ),
                        );
                        geometry_ok = false;
                    }
                }
            }
            LayerSpec::Fc { out } => {
                if !saw_conv {
                    rep.emit(
                        "G001",
                        loc(),
                        "no conv layer before the Fc head — nothing to distribute",
                    );
                }
                if out == 0 {
                    rep.emit("G004", loc(), "zero-width Fc head");
                }
                saw_fc = true;
            }
            LayerSpec::SoftmaxXent => {
                if !saw_fc {
                    rep.emit("G008", loc(), "SoftmaxXent must directly follow the Fc head");
                } else if saw_loss {
                    rep.emit("G008", loc(), "duplicate SoftmaxXent");
                }
                saw_loss = true;
            }
        }
        prev = Some(l);
    }
    if !saw_fc {
        rep.emit("G007", None, "graph has no Fc head");
    } else if !saw_loss {
        rep.emit("G008", None, "graph must end in SoftmaxXent");
    }
}

/// G012: ladders that the runtime accepts but that waste compile slots or
/// signal a typo — unsorted, duplicate, zero or above-k entries.
fn lint_ladder(layer: usize, k: usize, ladder: &[usize], rep: &mut Report) {
    let loc = format!("conv{layer}.buckets");
    if ladder.iter().any(|&b| b == 0 || b > k) {
        rep.emit(
            "G012",
            Some(loc.clone()),
            format!("ladder {ladder:?} has an entry of 0 or above k={k}"),
        );
    }
    if ladder.windows(2).any(|w| w[0] >= w[1]) {
        rep.emit(
            "G012",
            Some(loc),
            format!(
                "ladder {ladder:?} is not strictly ascending — shard-to-bucket fitting \
                 assumes sorted, duplicate-free ladders"
            ),
        );
    }
}

/// G101/G102: params, forward FLOPs and peak activation + im2col scratch
/// per conv layer, plus whole-network totals (fc head included).
fn resource_report(arch: &ArchSpec, rep: &mut Report) {
    const BYTES: f64 = 4.0;
    let mut total_params: usize = 0;
    let mut total_fwd_flops = 0.0f64;
    for (i, cv) in arch.convs.iter().enumerate() {
        let layer = i + 1;
        let params = cv.k * cv.in_ch * cv.kh * cv.kw + cv.k;
        let flops = arch.conv_layer_flops(layer, cv.k, arch.batch);
        let acts = (arch.batch * cv.in_ch * cv.in_hw * cv.in_hw
            + arch.batch * cv.k * cv.out_hw * cv.out_hw) as f64
            * BYTES;
        let scratch =
            (arch.batch * cv.in_ch * cv.kh * cv.kw * cv.out_hw * cv.out_hw) as f64 * BYTES;
        rep.emit(
            "G101",
            Some(format!("conv{layer}")),
            format!(
                "{} kernels {}x{} over {}x{}x{}: {params} params, {:.2} MFLOP fwd/step, \
                 {:.2} MiB activations + {:.2} MiB im2col scratch at batch {}",
                cv.k,
                cv.kh,
                cv.kw,
                cv.in_ch,
                cv.in_hw,
                cv.in_hw,
                flops / 1e6,
                acts / MIB,
                scratch / MIB,
                arch.batch
            ),
        );
        total_params += params;
        total_fwd_flops += flops;
    }
    let fc_params = arch.fc_in * arch.num_classes + arch.num_classes;
    total_params += fc_params;
    rep.emit(
        "G102",
        None,
        format!(
            "{} conv layers + fc head ({} -> {}): {} params total ({} in the head), \
             {:.2} MFLOP conv fwd per step at batch {}",
            arch.num_convs(),
            arch.fc_in,
            arch.num_classes,
            total_params,
            fc_params,
            total_fwd_flops / 1e6,
            arch.batch
        ),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(rep: &Report) -> Vec<&'static str> {
        rep.diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn presets_are_clean() {
        for name in ["default", "tiny", "deep_cifar", "tiny_deep"] {
            let rep = check_spec(&ArchSpec::preset(name).unwrap());
            assert!(!rep.has_deny(), "{name}: {}", rep.render_human());
            assert!(codes(&rep).contains(&"G102"), "{name} missing resource totals");
        }
    }

    #[test]
    fn walk_reports_everything_not_just_the_first_error() {
        let layers = vec![
            LayerSpec::Relu,                            // G002
            LayerSpec::Conv { k: 4, kh: 5, kw: 3 },     // G003
            LayerSpec::Conv { k: 4, kh: 40, kw: 40 },   // G005
            LayerSpec::Fc { out: 0 },                   // G004
            LayerSpec::Lrn,                             // G009
            LayerSpec::SoftmaxXent,
        ];
        let mut rep = Report::new();
        check_layers(2, 32, 3, &layers, &mut rep);
        for want in ["G002", "G003", "G005", "G004", "G009"] {
            assert!(codes(&rep).contains(&want), "missing {want}: {}", rep.render_human());
        }
    }

    #[test]
    fn dead_mid_segment_is_a_warning_only() {
        let layers = vec![
            LayerSpec::Conv { k: 4, kh: 5, kw: 5 },
            LayerSpec::Relu,
            LayerSpec::Relu, // G011
            LayerSpec::Fc { out: 10 },
            LayerSpec::SoftmaxXent,
        ];
        let mut rep = Report::new();
        check_layers(2, 32, 3, &layers, &mut rep);
        assert!(codes(&rep).contains(&"G011"));
        assert!(!rep.has_deny());
    }

    #[test]
    fn graph_doc_locations_point_at_layers() {
        let rep = check_graph_text(
            r#"{"layers": [{"op": "deconv"}], "batch": 2, "img": 32, "in_ch": 3}"#,
        );
        let d = rep.diags.iter().find(|d| d.code == "G010").unwrap();
        assert_eq!(d.loc.as_deref(), Some("layers[0]"));
        assert!(d.message.contains("deconv"));
    }

    #[test]
    fn ladder_lints() {
        let mut rep = Report::new();
        lint_ladder(1, 8, &[4, 2, 8], &mut rep); // unsorted
        lint_ladder(2, 8, &[4, 12], &mut rep); // entry above k
        assert_eq!(rep.count(super::super::Severity::Warn), 2);
        let mut clean = Report::new();
        lint_ladder(1, 16, &[4, 8, 12, 16], &mut clean);
        assert!(clean.diags.is_empty());
    }
}
