//! The diagnostics engine behind `convdist check`.
//!
//! Every finding carries a **stable code** (`G…` graph pass, `P…` plan pass,
//! `C…` config pass), a severity fixed by the [`REGISTRY`] — not by the call
//! site — an optional source location into the analyzed document
//! (`layers[3]`, `trainer.log_every`, `conv2.buckets`) and a human message.
//! Reports render either human-readable (`error[G005]: … (at layers[0])`)
//! or as JSON-lines for tooling.
//!
//! Codes are append-only: once shipped, a code keeps its meaning and its
//! severity so fixtures, scripts and CI greps stay valid across versions.

use std::fmt;

/// How bad a finding is.  Ordered: `Note < Warn < Deny`.
///
/// * `Deny` — the artifact is unusable; `convdist check` exits non-zero and
///   [`crate::session::SessionBuilder`] refuses to build a session from it.
/// * `Warn` — legal but almost certainly not what was meant (dead layers,
///   comm-bound plans, knobs that can never fire).
/// * `Note` — informational reports (per-layer params/FLOPs/memory).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warn,
    Deny,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warn => "warning",
            Severity::Deny => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// The code registry: `(code, severity, summary)`.  The single source of
/// truth for what each code means — `Report::emit` looks severities up here,
/// DESIGN.md §10 documents the same table, and the `bad_graphs/` fixtures
/// name their expected code in their filename.
pub const REGISTRY: &[(&str, Severity, &str)] = &[
    // -- graph pass ---------------------------------------------------------
    ("G001", Severity::Deny, "no conv layer before the Fc head"),
    ("G002", Severity::Deny, "mid op (lrn/maxpool2/relu) before the first conv"),
    ("G003", Severity::Deny, "non-square conv kernel (activations are square)"),
    ("G004", Severity::Deny, "degenerate geometry (zero batch/img/in_ch/k/kh/kw/fc width)"),
    ("G005", Severity::Deny, "conv kernel larger than its input (valid padding, stride 1)"),
    ("G006", Severity::Deny, "maxpool2 over an odd extent (2x2 window, stride 2)"),
    ("G007", Severity::Deny, "graph has no Fc head"),
    ("G008", Severity::Deny, "SoftmaxXent missing, duplicated, or not directly after Fc"),
    ("G009", Severity::Deny, "layer after the Fc head (only SoftmaxXent may follow)"),
    ("G010", Severity::Deny, "graph JSON malformed (unknown op, missing or ill-typed key)"),
    ("G011", Severity::Warn, "dead mid segment (op repeated back-to-back has no effect)"),
    ("G012", Severity::Warn, "bucket-ladder oddity (unsorted, duplicate, zero or >k entry)"),
    ("G013", Severity::Deny, "bucket-ladder override structurally invalid"),
    ("G101", Severity::Note, "per-layer resource report (params, FLOPs, activation memory)"),
    ("G102", Severity::Note, "whole-network resource totals"),
    // -- plan pass ----------------------------------------------------------
    ("P001", Severity::Warn, "device receives a zero-share shard (idles for the layer)"),
    ("P002", Severity::Deny, "bucket ladder cannot cover a partition the scheduler can reach"),
    ("P003", Severity::Warn, "bucket padding waste above 25% under the Eq.1 plan"),
    ("P004", Severity::Warn, "predicted comm time >= conv compute time at this bandwidth"),
    ("P005", Severity::Warn, "fewer kernels than devices (some devices always idle)"),
    ("P006", Severity::Note, "single-device fleet (nothing to distribute)"),
    ("P007", Severity::Deny, "activation+scratch memory exceeds the device budget (static plan)"),
    ("P008", Severity::Warn, "worst adaptive-reachable bucket exceeds the device memory budget"),
    ("P101", Severity::Note, "plan summary (Eq.1 shares, predicted step composition)"),
    // -- config pass --------------------------------------------------------
    ("C001", Severity::Deny, "unknown config key"),
    ("C002", Severity::Deny, "config value invalid or config JSON malformed"),
    ("C003", Severity::Deny, "worker_addrs count does not match cluster.workers"),
    ("C004", Severity::Warn, "adaptive knob can never fire with these trainer settings"),
    ("C005", Severity::Warn, "in-proc emulation knob (throttle/shaped) ignored over TCP"),
    ("C006", Severity::Note, "log_every exceeds steps (no mid-run step logs)"),
    ("C007", Severity::Warn, "calib_rounds is 0 (clamped to 1 at calibration time)"),
    ("C008", Severity::Deny, "checkpoint_every out of range (0 or >= trainer.steps)"),
    ("C009", Severity::Deny, "serve batcher budget the batch ladder cannot cover"),
    ("C010", Severity::Deny, "degenerate replica setup (zero replicas, ring of one, slice below ladder)"),
];

/// Look a code up in the [`REGISTRY`].
pub fn lookup(code: &str) -> Option<(Severity, &'static str)> {
    REGISTRY.iter().find(|(c, _, _)| *c == code).map(|&(_, sev, summary)| (sev, summary))
}

/// One finding: a registered code, its registry severity, an optional
/// location into the analyzed document, and a message.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub loc: Option<String>,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]: {}", self.severity, self.code, self.message)?;
        if let Some(loc) = &self.loc {
            write!(f, " (at {loc})")?;
        }
        Ok(())
    }
}

/// An ordered collection of findings from one or more passes.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a finding.  The severity comes from the [`REGISTRY`]; an
    /// unregistered code is a bug in the analyzer itself.
    pub fn emit(&mut self, code: &'static str, loc: Option<String>, message: impl Into<String>) {
        let (severity, _) = lookup(code)
            .unwrap_or_else(|| panic!("diagnostic code {code} missing from REGISTRY"));
        self.diags.push(Diagnostic { code, severity, loc, message: message.into() });
    }

    pub fn merge(&mut self, other: Report) {
        self.diags.extend(other.diags);
    }

    pub fn has_deny(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Deny)
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity == severity).count()
    }

    /// `error[G005]: 40x40 conv does not fit … (at layers[0])`, one per line,
    /// deny first, then warnings, then notes (stable within a severity).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for want in [Severity::Deny, Severity::Warn, Severity::Note] {
            for d in self.diags.iter().filter(|d| d.severity == want) {
                out.push_str(&d.to_string());
                out.push('\n');
            }
        }
        out
    }

    /// One JSON object per line: `{"code": …, "severity": …, "loc": …,
    /// "message": …}` — parseable by `crate::util::json` (and anything else).
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str("{\"code\": \"");
            out.push_str(d.code);
            out.push_str("\", \"severity\": \"");
            out.push_str(d.severity.label());
            out.push_str("\", \"loc\": ");
            match &d.loc {
                Some(loc) => {
                    out.push('"');
                    out.push_str(&json_escape(loc));
                    out.push('"');
                }
                None => out.push_str("null"),
            }
            out.push_str(", \"message\": \"");
            out.push_str(&json_escape(&d.message));
            out.push_str("\"}\n");
        }
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_codes_are_unique_and_prefixed() {
        let mut seen = std::collections::BTreeSet::new();
        for (code, _, summary) in REGISTRY {
            assert!(seen.insert(*code), "duplicate code {code}");
            assert!(
                code.starts_with('G') || code.starts_with('P') || code.starts_with('C'),
                "bad code family {code}"
            );
            assert!(!summary.is_empty());
        }
    }

    #[test]
    fn severity_comes_from_registry_not_call_site() {
        let mut rep = Report::new();
        rep.emit("G011", None, "x");
        rep.emit("G005", Some("layers[0]".into()), "y");
        assert_eq!(rep.diags[0].severity, Severity::Warn);
        assert_eq!(rep.diags[1].severity, Severity::Deny);
        assert!(rep.has_deny());
        assert_eq!(rep.count(Severity::Warn), 1);
    }

    #[test]
    fn renderings_are_well_formed() {
        let mut rep = Report::new();
        rep.emit("G101", Some("conv1".into()), "note first in vec");
        rep.emit("C001", Some("trainer.stepz".into()), "unknown key \"stepz\"");
        let human = rep.render_human();
        // Deny renders before the note despite insertion order.
        let deny_at = human.find("error[C001]").unwrap();
        let note_at = human.find("note[G101]").unwrap();
        assert!(deny_at < note_at, "{human}");
        assert!(human.contains("(at trainer.stepz)"));
        for line in rep.render_jsonl().lines() {
            let v = crate::util::json::Json::parse(line).unwrap();
            lookup(v.get("code").unwrap().as_str().unwrap()).unwrap();
            v.get("message").unwrap().as_str().unwrap();
        }
    }
}
