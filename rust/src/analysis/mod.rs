//! Static analysis: pre-flight checks over graphs, partition plans and
//! experiment configs — the engine behind `convdist check`.
//!
//! The paper's speedups only hold when the Eq.1 partition, the compiled
//! bucket ladder and the device fleet are mutually consistent.  Before this
//! module those invariants surfaced at runtime — a panic or a `bail!` at
//! step 0, deep in `cluster::master`.  The analyzer finds them *statically*
//! and reports every problem at once, with stable codes, severities and
//! source locations (see [`diag::REGISTRY`] and DESIGN.md §10):
//!
//! * **graph pass** ([`check_spec`] / [`check_graph_text`], `G…` codes) —
//!   shape/geometry inference over the layer IR with actionable errors,
//!   dead-segment lints and a per-layer params/FLOPs/memory report;
//! * **plan pass** ([`check_plan`], `P…` codes) — Eq.1 feasibility against
//!   a concrete [`crate::devices::DeviceProfile`] roster: ladder coverage
//!   of every partition the adaptive policy can reach, per-device memory
//!   fit, padding waste and comm-vs-compute economics;
//! * **config pass** ([`check_config_text`], `C…` codes) — unknown keys
//!   with precise locations, topology mismatches, knobs that can never
//!   fire given the trainer settings.
//!
//! [`check_experiment`] composes all three the way the session layer does:
//! `SessionBuilder::from_experiment` refuses to build when it reports a
//! deny, and `SessionBuilder::build` re-checks the resolved arch so even
//! hand-assembled sessions are covered.

mod config;
mod diag;
mod graph;
mod plan;

pub use config::{check_config, check_config_text, check_experiment};
pub use diag::{lookup, Diagnostic, Report, Severity, REGISTRY};
pub use graph::{check_graph_json, check_graph_text, check_spec};
pub use plan::{check_plan, PlanCheckOptions};
