//! Config pass: lint `ExperimentConfig` documents and structs.
//!
//! Two entry points: [`check_config_text`] works on raw JSON (so unknown
//! keys get precise `section.key` locations before the strict parser
//! rejects them wholesale) and [`check_config`] lints an already-parsed
//! struct (knobs that can never fire, emulation flags ignored over TCP).
//! [`check_experiment`] is the full pre-flight: config pass, then graph
//! and plan passes against the config's own arch, roster and bandwidth —
//! the same composite gate [`crate::session::SessionBuilder`] runs.

use crate::config::{ArchChoice, ExperimentConfig};
use crate::runtime::ArchSpec;
use crate::util::json::Json;

use super::diag::Report;
use super::graph::check_spec;
use super::plan::{check_plan, PlanCheckOptions};

const ROOT_KEYS: &[&str] =
    &["name", "arch", "trainer", "cluster", "network", "adaptive", "obs", "serve", "replica"];
const TRAINER_KEYS: &[&str] = &[
    "steps",
    "lr",
    "momentum",
    "weight_decay",
    "seed",
    "log_every",
    "calib_rounds",
    "checkpoint_every",
];
const CLUSTER_KEYS: &[&str] = &["workers", "devices", "throttle", "worker_addrs"];
const NETWORK_KEYS: &[&str] = &["bandwidth_mbps", "latency_ms", "shaped"];
const ADAPTIVE_KEYS: &[&str] = &[
    "enabled",
    "alpha",
    "warmup_steps",
    "imbalance_threshold",
    "hysteresis",
    "cooldown_steps",
    "straggler_k",
    "straggler_min_ratio",
    "heartbeat_every",
    "heartbeat_timeout_ms",
    "gather_timeout_ms",
];
const OBS_KEYS: &[&str] = &["metrics_addr"];
const SERVE_KEYS: &[&str] = &["max_delay_ms", "max_batch"];
const REPLICA_KEYS: &[&str] =
    &["count", "allreduce", "chunk_kb", "rebalance_every", "rebalance_threshold"];

fn lint_keys(rep: &mut Report, v: &Json, section: &str, allowed: &[&str]) {
    if let Json::Obj(m) = v {
        for key in m.keys() {
            if !allowed.contains(&key.as_str()) {
                let loc = if section.is_empty() {
                    key.clone()
                } else {
                    format!("{section}.{key}")
                };
                rep.emit(
                    "C001",
                    Some(loc),
                    format!("unknown key {key:?} (allowed: {allowed:?})"),
                );
            }
        }
    }
}

/// Lint a raw experiment-config document, then hand the parsed struct to
/// [`check_experiment`].  Parse/validate failures become C002 diagnostics
/// (or keep the more precise C001/C003 already emitted from the raw doc).
pub fn check_config_text(text: &str) -> Report {
    let mut rep = Report::new();
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            rep.emit("C002", None, format!("config is not valid JSON: {e:#}"));
            return rep;
        }
    };
    lint_keys(&mut rep, &v, "", ROOT_KEYS);
    for (section, allowed) in [
        ("trainer", TRAINER_KEYS),
        ("cluster", CLUSTER_KEYS),
        ("network", NETWORK_KEYS),
        ("adaptive", ADAPTIVE_KEYS),
        ("obs", OBS_KEYS),
        ("serve", SERVE_KEYS),
        ("replica", REPLICA_KEYS),
    ] {
        if let Some(s) = v.opt(section) {
            lint_keys(&mut rep, s, section, allowed);
        }
    }
    // Topology mismatch straight off the raw doc, for a precise code.
    if let Some(c) = v.opt("cluster") {
        if let Some(addrs) = c.opt("worker_addrs").and_then(|x| x.as_arr().ok()) {
            let workers = c
                .opt("workers")
                .and_then(|x| x.as_usize().ok())
                .unwrap_or_else(|| crate::config::ClusterConfig::default().workers);
            if !addrs.is_empty() && addrs.len() != workers {
                rep.emit(
                    "C003",
                    Some("cluster.worker_addrs".into()),
                    format!(
                        "{} worker_addrs for workers={workers} — TCP mode needs exactly \
                         one listen address per worker",
                        addrs.len()
                    ),
                );
            }
        }
    }
    match ExperimentConfig::from_json_str(text) {
        Ok(cfg) => rep.merge(check_experiment(&cfg)),
        Err(e) => {
            let msg = format!("{e:#}");
            // The strict parser stops at the first problem; skip C002 when a
            // raw-doc lint above already coded that exact problem.
            let already = (msg.contains("unknown key")
                && rep.diags.iter().any(|d| d.code == "C001"))
                || (msg.contains("worker_addrs")
                    && rep.diags.iter().any(|d| d.code == "C003"));
            if !already {
                rep.emit("C002", None, msg);
            }
        }
    }
    rep
}

/// Struct-level config lints: everything checkable without the raw JSON.
pub fn check_config(cfg: &ExperimentConfig) -> Report {
    let mut rep = Report::new();
    let steps = cfg.trainer.steps as u64;
    let tcp = !cfg.cluster.worker_addrs.is_empty();
    if tcp && cfg.cluster.worker_addrs.len() != cfg.cluster.workers {
        rep.emit(
            "C003",
            Some("cluster.worker_addrs".into()),
            format!(
                "{} worker_addrs for workers={} — TCP mode needs exactly one listen \
                 address per worker",
                cfg.cluster.worker_addrs.len(),
                cfg.cluster.workers
            ),
        );
    }
    if tcp && (cfg.cluster.throttle || cfg.network.shaped) {
        rep.emit(
            "C005",
            Some("cluster".into()),
            "throttle/shaped are in-proc emulation knobs — over TCP the links carry \
             real device and network timing, so they are ignored",
        );
    }
    if cfg.trainer.log_every as u64 > steps {
        rep.emit(
            "C006",
            Some("trainer.log_every".into()),
            format!(
                "log_every={} exceeds steps={} — only the final report is logged",
                cfg.trainer.log_every, cfg.trainer.steps
            ),
        );
    }
    if cfg.trainer.calib_rounds == 0 {
        rep.emit(
            "C007",
            Some("trainer.calib_rounds".into()),
            "calib_rounds=0 is clamped to 1 at calibration time — say what you mean",
        );
    }
    if let Some(every) = cfg.trainer.checkpoint_every {
        if every == 0 || every as u64 >= steps {
            rep.emit(
                "C008",
                Some("trainer.checkpoint_every".into()),
                format!(
                    "checkpoint_every={every} with steps={steps}: must be in 1..steps \
                     (0 never fires; >= steps only duplicates the final state)"
                ),
            );
        }
    }
    if let Some(s) = &cfg.serve {
        if s.max_batch == 0 {
            rep.emit(
                "C009",
                Some("serve.max_batch".into()),
                "max_batch=0 — the batcher can never form a batch, so no request \
                 is ever answered",
            );
        }
        if s.max_delay_ms > 60_000 {
            rep.emit(
                "C009",
                Some("serve.max_delay_ms".into()),
                format!(
                    "max_delay_ms={} holds requests for over a minute — surely a \
                     units mistake (the budget is milliseconds)",
                    s.max_delay_ms
                ),
            );
        }
    }
    if let Some(r) = &cfg.replica {
        if r.count == 0 {
            rep.emit(
                "C010",
                Some("replica.count".into()),
                "count=0 — a session needs at least one replica (1 means no \
                 replication; >= 2 enables data parallelism)",
            );
        }
        if r.count == 1 && r.allreduce == crate::replica::AllReduce::Ring {
            rep.emit(
                "C010",
                Some("replica.allreduce".into()),
                "allreduce=\"ring\" with count=1 — a ring needs at least two \
                 replicas to pass gradients around",
            );
        }
    }
    let a = &cfg.adaptive;
    if a.enabled {
        if a.warmup_steps >= steps {
            rep.emit(
                "C004",
                Some("adaptive.warmup_steps".into()),
                format!(
                    "warmup_steps={} >= steps={steps}: the policy never leaves warmup, \
                     so no re-partition can ever fire",
                    a.warmup_steps
                ),
            );
        }
        if a.cooldown_steps >= steps {
            rep.emit(
                "C004",
                Some("adaptive.cooldown_steps".into()),
                format!(
                    "cooldown_steps={} >= steps={steps}: at most one re-partition can \
                     ever fire",
                    a.cooldown_steps
                ),
            );
        }
        if a.hysteresis >= a.imbalance_threshold && a.imbalance_threshold > 0.0 {
            rep.emit(
                "C004",
                Some("adaptive.hysteresis".into()),
                format!(
                    "hysteresis={} >= imbalance_threshold={}: the re-arm level clamps \
                     to a gain of 1.0, so under steady imbalance the policy triggers \
                     once and effectively never re-arms",
                    a.hysteresis, a.imbalance_threshold
                ),
            );
        }
        if a.heartbeat_every >= steps && a.heartbeat_every != 0 {
            rep.emit(
                "C004",
                Some("adaptive.heartbeat_every".into()),
                format!(
                    "heartbeat_every={} >= steps={steps}: no heartbeat will ever be \
                     sent, so a hung worker is only detected by gather_timeout",
                    a.heartbeat_every
                ),
            );
        }
    }
    rep
}

/// Full pre-flight over a parsed config: config lints, then the graph and
/// plan passes against the config's own arch, device roster and bandwidth.
pub fn check_experiment(cfg: &ExperimentConfig) -> Report {
    let mut rep = check_config(cfg);
    let arch = match &cfg.arch {
        Some(ArchChoice::Preset(name)) => match ArchSpec::preset(name) {
            Some(a) => Some(a),
            None => {
                rep.emit(
                    "C002",
                    Some("arch".into()),
                    format!("unknown arch preset {name:?}"),
                );
                None
            }
        },
        Some(ArchChoice::Graph(json)) => match ArchSpec::from_json_str(json) {
            Ok(a) => Some(a),
            Err(e) => {
                rep.emit("C002", Some("arch".into()), format!("inline arch graph: {e:#}"));
                None
            }
        },
        // `None` = the artifact directory decides; analyze the native
        // default the runtime synthesizes absent a manifest.
        None => Some(ArchSpec::native_default()),
    };
    if let Some(arch) = arch {
        // The serve batcher pads partial batches up to a rung of the arch's
        // batch ladder; a max_batch above the top rung has no shape to run.
        if let Some(s) = &cfg.serve {
            let top = arch.batch_buckets.last().copied().unwrap_or(arch.batch);
            if s.max_batch > top {
                rep.emit(
                    "C009",
                    Some("serve.max_batch".into()),
                    format!(
                        "max_batch={} exceeds the largest batch rung {top} of arch \
                         {:?} (ladder {:?}) — no padded batch shape can cover it",
                        s.max_batch,
                        arch.label(),
                        arch.batch_buckets
                    ),
                );
            }
        }
        // Each replica trains batch/count samples; a slice of zero (or one
        // smaller than the lowest batch rung) has no executable shape.
        if let Some(r) = &cfg.replica {
            if r.count > 1 {
                let floor = arch.batch / r.count;
                let bottom = arch.batch_buckets.first().copied().unwrap_or(arch.batch);
                if floor == 0 || floor < bottom {
                    rep.emit(
                        "C010",
                        Some("replica.count".into()),
                        format!(
                            "count={} slices the global batch {} down to {floor} \
                             samples per replica, below the smallest batch rung \
                             {bottom} of arch {:?} (ladder {:?})",
                            r.count,
                            arch.batch,
                            arch.label(),
                            arch.batch_buckets
                        ),
                    );
                }
            }
        }
        rep.merge(check_spec(&arch));
        rep.merge(check_plan(
            &arch,
            &cfg.device_profiles(),
            &PlanCheckOptions {
                bandwidth_mbps: cfg.network.bandwidth_mbps,
                adaptive: Some(cfg.adaptive),
            },
        ));
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_keys_get_section_scoped_locations() {
        let rep = check_config_text(r#"{"name": "x", "trainer": {"stepz": 3}}"#);
        let d = rep.diags.iter().find(|d| d.code == "C001").unwrap();
        assert_eq!(d.loc.as_deref(), Some("trainer.stepz"));
        assert!(rep.has_deny());
        // No redundant C002 for the same problem.
        assert!(!rep.diags.iter().any(|d| d.code == "C002"), "{}", rep.render_human());
    }

    #[test]
    fn topology_mismatch_is_c003() {
        let rep = check_config_text(
            r#"{"name": "x", "cluster": {"workers": 2, "worker_addrs": ["127.0.0.1:7901"]}}"#,
        );
        assert!(rep.diags.iter().any(|d| d.code == "C003"), "{}", rep.render_human());
        assert!(!rep.diags.iter().any(|d| d.code == "C002"), "{}", rep.render_human());
    }

    #[test]
    fn dead_adaptive_knobs_warn() {
        let text = r#"{
            "name": "x",
            "trainer": {"steps": 5},
            "adaptive": {"enabled": true, "warmup_steps": 10,
                         "hysteresis": 0.5, "imbalance_threshold": 0.2}
        }"#;
        let rep = check_config_text(text);
        assert!(
            rep.diags.iter().filter(|d| d.code == "C004").count() >= 2,
            "{}",
            rep.render_human()
        );
        assert!(!rep.has_deny(), "{}", rep.render_human());
    }

    #[test]
    fn checkpoint_every_out_of_range_is_c008() {
        // 0 can never fire; >= steps only duplicates the final state.
        for every in [0usize, 4, 9] {
            let text = format!(
                r#"{{"name": "x", "trainer": {{"steps": 4, "checkpoint_every": {every}}}}}"#
            );
            let rep = check_config_text(&text);
            assert!(
                rep.diags.iter().any(|d| d.code == "C008"),
                "every={every}: {}",
                rep.render_human()
            );
            assert!(rep.has_deny());
        }
        let rep = check_config_text(
            r#"{"name": "x", "trainer": {"steps": 4, "checkpoint_every": 2}}"#,
        );
        assert!(!rep.diags.iter().any(|d| d.code == "C008"), "{}", rep.render_human());
    }

    #[test]
    fn serve_budgets_the_ladder_cannot_cover_are_c009() {
        // tiny preset: batch 2, ladder [2] — max_batch 8 has no rung.
        let text = r#"{"name": "x", "arch": "tiny", "serve": {"max_batch": 8}}"#;
        let rep = check_config_text(text);
        let d = rep.diags.iter().find(|d| d.code == "C009").unwrap();
        assert_eq!(d.loc.as_deref(), Some("serve.max_batch"));
        assert!(d.message.contains("largest batch rung"), "{}", d.message);
        assert!(rep.has_deny());
        // Zero batch and an hour-long delay budget are denied arch-free.
        let rep = check_config_text(r#"{"name": "x", "serve": {"max_batch": 0}}"#);
        assert!(rep.diags.iter().any(|d| d.code == "C009"), "{}", rep.render_human());
        let rep =
            check_config_text(r#"{"name": "x", "serve": {"max_delay_ms": 3600000}}"#);
        assert!(rep.diags.iter().any(|d| d.code == "C009"), "{}", rep.render_human());
        // A budget the ladder covers passes clean.
        let rep = check_config_text(
            r#"{"name": "x", "arch": "tiny", "serve": {"max_batch": 2, "max_delay_ms": 5}}"#,
        );
        assert!(!rep.diags.iter().any(|d| d.code == "C009"), "{}", rep.render_human());
        assert!(!rep.has_deny(), "{}", rep.render_human());
        // Typos inside the section stay C001 with a scoped location.
        let rep = check_config_text(r#"{"name": "x", "serve": {"max_bacth": 2}}"#);
        let d = rep.diags.iter().find(|d| d.code == "C001").unwrap();
        assert_eq!(d.loc.as_deref(), Some("serve.max_bacth"));
    }

    #[test]
    fn degenerate_replica_setups_are_c010() {
        // Zero replicas can never train anything.
        let rep = check_config_text(r#"{"name": "x", "replica": {"count": 0}}"#);
        let d = rep.diags.iter().find(|d| d.code == "C010").unwrap();
        assert_eq!(d.loc.as_deref(), Some("replica.count"));
        assert!(rep.has_deny());
        // A ring of one has nobody to pass gradients to.
        let rep = check_config_text(
            r#"{"name": "x", "replica": {"count": 1, "allreduce": "ring"}}"#,
        );
        let d = rep.diags.iter().find(|d| d.code == "C010").unwrap();
        assert_eq!(d.loc.as_deref(), Some("replica.allreduce"));
        // tiny preset: batch 2, ladder [2] — two replicas slice to 1 sample,
        // below the smallest rung.
        let rep = check_config_text(
            r#"{"name": "x", "arch": "tiny", "replica": {"count": 2}}"#,
        );
        let d = rep.diags.iter().find(|d| d.code == "C010").unwrap();
        assert!(d.message.contains("smallest batch rung"), "{}", d.message);
        // The default arch (batch 64, ladder bottom 8) covers 2 replicas fine.
        let rep = check_config_text(
            r#"{"name": "x", "arch": "default", "replica": {"count": 2, "allreduce": "ring"}}"#,
        );
        assert!(!rep.diags.iter().any(|d| d.code == "C010"), "{}", rep.render_human());
        assert!(!rep.has_deny(), "{}", rep.render_human());
        // Typos inside the section stay C001 with a scoped location.
        let rep = check_config_text(r#"{"name": "x", "replica": {"cnt": 2}}"#);
        let d = rep.diags.iter().find(|d| d.code == "C001").unwrap();
        assert_eq!(d.loc.as_deref(), Some("replica.cnt"));
    }

    #[test]
    fn default_experiment_has_no_deny() {
        let rep = check_experiment(&ExperimentConfig::default());
        assert!(!rep.has_deny(), "{}", rep.render_human());
    }

    #[test]
    fn malformed_json_is_c002_not_a_crash() {
        let rep = check_config_text("{\"name\": ");
        assert!(rep.diags.iter().any(|d| d.code == "C002"));
        assert!(rep.has_deny());
    }
}
