//! Plan pass: Eq.1 partition feasibility against a concrete device fleet.
//!
//! Given an [`ArchSpec`] and a [`DeviceProfile`] roster, this pass builds
//! the same static partition the master would (probe times proportional to
//! the catalog GFLOPS, [`partition_network`] over every conv layer) and
//! vets it *before* any worker is spawned:
//!
//! * ladder coverage — every partition the adaptive policy can reach must
//!   fit some bucket, which reduces to "the ladder ends at k" (P002, deny);
//! * memory fit — per-device activation + im2col scratch against a
//!   per-[`DeviceKind`] budget, both for the static plan (P007, deny) and
//!   for the worst adaptive-reachable bucket (P008, warn);
//! * economics — zero-share shards (P001), >25% bucket padding waste
//!   (P003), fewer kernels than devices (P005) and a comm-vs-compute ratio
//!   from the sim cost model at the configured bandwidth (P004).

use crate::devices::{DeviceKind, DeviceProfile};
use crate::runtime::ArchSpec;
use crate::sched::{partition_network, workload_shares, AdaptiveConfig};
use crate::sim::ArchShape;

use super::diag::Report;

const GIB: f64 = (1u64 << 30) as f64;

/// Plan-pass knobs beyond the arch and the fleet.
#[derive(Clone, Debug)]
pub struct PlanCheckOptions {
    /// Master-link bandwidth for the comm-vs-compute warning, in Mbps.
    pub bandwidth_mbps: f64,
    /// Adaptive scheduling config, when known: enables the P008 check over
    /// every bucket a re-partition can reach.
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for PlanCheckOptions {
    fn default() -> Self {
        Self { bandwidth_mbps: crate::sim::EFFECTIVE_BANDWIDTH_MBPS, adaptive: None }
    }
}

/// Activation + scratch budget per device kind, in bytes.  Host RAM for
/// CPUs, VRAM for the paper-era discrete and mobile GPUs — deliberately
/// conservative round numbers; the point is catching plans that are off by
/// orders of magnitude before they OOM a worker at step 0.
fn memory_budget(kind: DeviceKind) -> f64 {
    match kind {
        DeviceKind::Cpu => 8.0 * GIB,
        DeviceKind::Gpu => 2.0 * GIB,
        DeviceKind::MobileGpu => GIB,
    }
}

/// Bytes device-resident for conv `layer` when holding a shard padded to
/// `bucket` kernels: full input slab + padded kernels + padded output +
/// the im2col patch matrix the native backend materializes.
fn layer_device_bytes(arch: &ArchSpec, layer: usize, bucket: usize) -> f64 {
    if bucket == 0 {
        return 0.0;
    }
    let cv = arch.conv(layer);
    let b = arch.batch;
    let inputs = b * cv.in_ch * cv.in_hw * cv.in_hw;
    let kernels = bucket * cv.in_ch * cv.kh * cv.kw;
    let outputs = b * bucket * cv.out_hw * cv.out_hw;
    let im2col = b * cv.in_ch * cv.kh * cv.kw * cv.out_hw * cv.out_hw;
    (inputs + kernels + outputs + im2col) as f64 * 4.0
}

/// Run the plan pass.  Device 0 is the master, like everywhere else.
pub fn check_plan(arch: &ArchSpec, profiles: &[DeviceProfile], opts: &PlanCheckOptions) -> Report {
    let mut rep = Report::new();

    // Ladder coverage is a property of the arch alone: the adaptive policy
    // can concentrate a layer onto any subset of devices, so any shard size
    // in 1..=k is reachable and the ladder must end at k to cover them all
    // (fit_bucket takes the smallest bucket >= n).
    for layer in 1..=arch.num_convs() {
        let k = arch.kernels(layer);
        let buckets = arch.buckets(layer);
        if buckets.iter().copied().max() != Some(k) {
            rep.emit(
                "P002",
                Some(format!("conv{layer}.buckets")),
                format!(
                    "ladder {buckets:?} cannot cover every reachable shard of conv{layer} \
                     (k={k}): a single surviving device takes all {k} kernels, so the \
                     ladder must contain {k}"
                ),
            );
        }
    }

    if profiles.len() <= 1 {
        rep.emit(
            "P006",
            None,
            format!("{}-device fleet — nothing to distribute, Eq.1 is trivial", profiles.len()),
        );
        return rep;
    }

    let probe_flops = arch.probe.flops as f64;
    let times: Vec<f64> = profiles.iter().map(|p| p.exec_time(probe_flops)).collect();
    let shares = match workload_shares(&times) {
        Ok(s) => s,
        Err(e) => {
            rep.emit("P002", None, format!("Eq.1 shares unsolvable for this fleet: {e:#}"));
            return rep;
        }
    };
    let layers: Vec<(usize, &[usize])> =
        (1..=arch.num_convs()).map(|l| (arch.kernels(l), arch.buckets(l))).collect();
    let tables = match partition_network(&layers, &times) {
        Ok(t) => t,
        Err(e) => {
            rep.emit("P002", None, format!("Eq.1 partition infeasible for this fleet: {e:#}"));
            return rep;
        }
    };

    for (li, shards) in tables.iter().enumerate() {
        let layer = li + 1;
        let k = arch.kernels(layer);
        if k < profiles.len() {
            rep.emit(
                "P005",
                Some(format!("conv{layer}")),
                format!(
                    "{k} kernels across {} devices — at least {} device(s) sit idle on \
                     this layer every step",
                    profiles.len(),
                    profiles.len() - k
                ),
            );
        }
        for (d, p) in profiles.iter().enumerate() {
            if !shards.iter().any(|s| s.device == d) {
                rep.emit(
                    "P001",
                    Some(format!("conv{layer}")),
                    format!(
                        "device {d} ({}) gets a zero-share shard — its Eq.1 share of {k} \
                         kernels rounds to zero, so it idles for this layer",
                        p.name
                    ),
                );
            }
        }
        let bucketed: usize = shards.iter().map(|s| s.bucket).sum();
        if bucketed > k {
            let waste = 1.0 - k as f64 / bucketed as f64;
            if waste > 0.25 {
                rep.emit(
                    "P003",
                    Some(format!("conv{layer}")),
                    format!(
                        "bucket padding waste {:.0}%: {k} kernels padded to {bucketed} \
                         bucketed kernels — consider a denser ladder",
                        waste * 100.0
                    ),
                );
            }
        }
    }

    // Memory fit, static plan (deny) and worst adaptive-reachable (warn).
    let adaptive_on = opts.adaptive.is_some_and(|a| a.enabled);
    for (d, prof) in profiles.iter().enumerate() {
        let budget = memory_budget(prof.kind);
        let mut static_peak = 0.0f64;
        let mut reachable_peak = 0.0f64;
        for (li, shards) in tables.iter().enumerate() {
            let layer = li + 1;
            let bucket = shards.iter().find(|s| s.device == d).map_or(0, |s| s.bucket);
            static_peak = static_peak.max(layer_device_bytes(arch, layer, bucket));
            reachable_peak =
                reachable_peak.max(layer_device_bytes(arch, layer, arch.kernels(layer)));
        }
        if static_peak > budget {
            rep.emit(
                "P007",
                None,
                format!(
                    "device {d} ({}, {:?}): static plan needs {:.2} GiB activations + \
                     scratch but the budget is {:.1} GiB",
                    prof.name,
                    prof.kind,
                    static_peak / GIB,
                    budget / GIB
                ),
            );
        } else if adaptive_on && reachable_peak > budget {
            rep.emit(
                "P008",
                None,
                format!(
                    "device {d} ({}): worst adaptive-reachable bucket needs {:.2} GiB \
                     against a {:.1} GiB budget — a re-shard concentrating a full layer \
                     here would not fit",
                    prof.name,
                    reachable_peak / GIB,
                    budget / GIB
                ),
            );
        }
    }

    // Comm vs compute from the sim cost model, generalized to N conv layers
    // (same per-layer volumes as ArchShape::eq2_upload_elements and
    // bwd_upload_elements, summed over arch.convs).
    let n_slaves = profiles.len() - 1;
    let slave_share = 1.0 - shares[0];
    let mut elems = 0.0f64;
    for layer in 1..=arch.num_convs() {
        let cv = arch.conv(layer);
        let inputs = (cv.in_hw * cv.in_hw * cv.in_ch * arch.batch) as f64 * n_slaves as f64;
        let kernels = (cv.kh * cv.kw * cv.k * cv.in_ch) as f64 * slave_share;
        let outputs = (cv.out_hw * cv.out_hw * cv.k * arch.batch) as f64 * slave_share;
        let gy = outputs;
        let kernels_bwd = 2.0 * (cv.kh * cv.kw * cv.k * cv.in_ch) as f64 * slave_share;
        let gx = inputs;
        elems += inputs + kernels + outputs + gy + kernels_bwd + gx;
    }
    let comm_s = elems * 4.0 * 8.0 / (opts.bandwidth_mbps * 1e6);
    let mut comp_s = 0.0f64;
    for (li, shards) in tables.iter().enumerate() {
        let layer = li + 1;
        let mut layer_s = 0.0f64;
        for s in shards {
            let flops =
                arch.conv_layer_flops(layer, s.bucket, arch.batch) * ArchShape::TRAIN_CONV_FACTOR;
            layer_s = layer_s.max(profiles[s.device].exec_time(flops));
        }
        comp_s += layer_s;
    }
    if comp_s > 0.0 && comm_s >= comp_s {
        rep.emit(
            "P004",
            None,
            format!(
                "predicted comm/conv ratio {:.1} at {} Mbps ({:.2} ms comm vs {:.2} ms \
                 conv per step) — the fleet is bandwidth-bound and distribution will \
                 not pay off at this scale",
                comm_s / comp_s,
                opts.bandwidth_mbps,
                comm_s * 1e3,
                comp_s * 1e3
            ),
        );
    }
    let share_str: Vec<String> = shares.iter().map(|s| format!("{s:.2}")).collect();
    rep.emit(
        "P101",
        None,
        format!(
            "{} devices, Eq.1 shares [{}]; predicted per-step conv {:.2} ms, comm {:.2} \
             ms at {} Mbps",
            profiles.len(),
            share_str.join(", "),
            comp_s * 1e3,
            comm_s * 1e3,
            opts.bandwidth_mbps
        ),
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::paper_cpus;

    #[test]
    fn paper_fleet_on_presets_has_no_deny() {
        for name in ["default", "tiny", "deep_cifar", "tiny_deep"] {
            let arch = ArchSpec::preset(name).unwrap();
            let rep = check_plan(&arch, &paper_cpus(), &PlanCheckOptions::default());
            assert!(!rep.has_deny(), "{name}: {}", rep.render_human());
        }
    }

    #[test]
    fn ladder_gap_is_deny() {
        let mut arch = ArchSpec::tiny();
        arch.convs[1].buckets = vec![4]; // k=8 is now unreachable
        let rep = check_plan(&arch, &paper_cpus(), &PlanCheckOptions::default());
        let d = rep.diags.iter().find(|d| d.code == "P002").unwrap();
        assert_eq!(d.loc.as_deref(), Some("conv2.buckets"));
        assert!(rep.has_deny());
    }

    #[test]
    fn single_device_is_a_note() {
        let arch = ArchSpec::tiny();
        let rep = check_plan(&arch, &paper_cpus()[..1], &PlanCheckOptions::default());
        assert!(rep.diags.iter().any(|d| d.code == "P006"));
        assert!(!rep.has_deny());
    }

    #[test]
    fn starved_bandwidth_warns_comm_bound() {
        let arch = ArchSpec::tiny();
        let opts = PlanCheckOptions { bandwidth_mbps: 0.001, adaptive: None };
        let rep = check_plan(&arch, &paper_cpus(), &opts);
        assert!(rep.diags.iter().any(|d| d.code == "P004"), "{}", rep.render_human());
        assert!(!rep.has_deny());
    }

    #[test]
    fn more_devices_than_kernels_warns() {
        let arch = ArchSpec::tiny(); // conv1 has k=4
        let five: Vec<DeviceProfile> =
            (0..5).map(|_| paper_cpus()[0].clone()).collect();
        let rep = check_plan(&arch, &five, &PlanCheckOptions::default());
        assert!(rep.diags.iter().any(|d| d.code == "P005"), "{}", rep.render_human());
    }
}
