//! In-tree substrates that would normally be external crates — the build
//! environment is offline, so: JSON parsing ([`json`]), CLI argument parsing
//! ([`cli`]) and the bench harness ([`bench`]) live here.

pub mod bench;
pub mod cli;
pub mod json;
