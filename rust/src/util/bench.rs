//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warms up, runs timed iterations until a wall budget or iteration cap,
//! reports min/mean/p50/p90 per iteration.  Used by `rust/benches/*` (which
//! are `harness = false` cargo bench targets).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p90: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>6} iters  mean {:>12?}  min {:>12?}  p50 {:>12?}  p90 {:>12?}",
            self.name, self.iters, self.mean, self.min, self.p50, self.p90
        )
    }
}

pub struct Bencher {
    /// Wall-clock budget per benchmark.
    pub budget: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { budget: Duration::from_secs(2), max_iters: 1000, warmup: 2 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self { budget: Duration::from_millis(500), max_iters: 200, warmup: 1 }
    }

    /// Time `f` repeatedly; prints and returns the summary.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let iters = samples.len();
        let mean = samples.iter().sum::<Duration>() / iters as u32;
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean,
            min: samples[0],
            p50: samples[iters / 2],
            p90: samples[(iters * 9 / 10).min(iters - 1)],
        };
        println!("{res}");
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_statistics() {
        let b = Bencher { budget: Duration::from_millis(50), max_iters: 20, warmup: 1 };
        let r = b.run("sleep-1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.iters >= 3);
        assert!(r.min >= Duration::from_millis(1));
        assert!(r.p90 >= r.p50 && r.p50 >= r.min);
        assert!(r.mean >= r.min);
    }
}
