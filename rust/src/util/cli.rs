//! Tiny CLI argument parser: `--flag`, `--key value`, positional subcommand.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: a subcommand plus `--key value` / `--flag` options
/// and any remaining bare tokens as positionals (`convdist report FILE`).
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first bare token is the subcommand; `--key value`
    /// pairs and bare `--flag`s may appear in any order; further bare tokens
    /// collect into `positional` (subcommands that take none reject them).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // A value follows unless the next token is another option or
                // the end (then it's a boolean flag).
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        if out.opts.insert(key.to_string(), v).is_some() {
                            bail!("duplicate option --{key}");
                        }
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.command.is_empty() {
                out.command = tok;
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn get_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}"))?)),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_and_flags() {
        let a = args("train --workers 3 --csv --steps 10");
        assert_eq!(a.command, "train");
        assert_eq!(a.get::<usize>("workers", 0).unwrap(), 3);
        assert_eq!(a.get::<usize>("steps", 0).unwrap(), 10);
        assert!(a.flag("csv"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get::<usize>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["x".into(), "--n".into(), "3".into(), "--n".into(), "4".into()])
            .is_err());
        let a = args("train --steps abc");
        assert!(a.get::<usize>("steps", 0).is_err());
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn positionals_collect_after_the_subcommand() {
        let a = args("report out/run.jsonl");
        assert_eq!(a.command, "report");
        assert_eq!(a.positional, vec!["out/run.jsonl"]);
        // `--key value` consumes its value; it does not become a positional.
        let b = args("report --format human out/run.jsonl extra");
        assert_eq!(b.opt("format"), Some("human"));
        assert_eq!(b.positional, vec!["out/run.jsonl", "extra"]);
        assert!(args("train").positional.is_empty());
    }

    #[test]
    fn trailing_flag() {
        let a = args("figures --csv");
        assert!(a.flag("csv"));
        assert_eq!(a.opt("csv"), None);
    }
}
