//! Minimal JSON parser — enough for `manifest.json` and experiment configs.
//!
//! In-tree because the build environment is offline (no serde).  Supports
//! the full JSON grammar except `\uXXXX` surrogate pairs beyond the BMP;
//! numbers parse as f64 with integer accessors.  Strict: trailing garbage,
//! unterminated literals and bad escapes are errors with byte offsets.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.b.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("expected object while looking up {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        ensure!(n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64, "expected usize, got {n}");
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        ensure!(n >= 0.0 && n.fract() == 0.0, "expected u64, got {n}");
        Ok(n as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    /// `[1, 2, 3]` -> `vec![1usize, 2, 3]`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.pos);
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.pos..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{hex} escape"))?,
                            );
                        }
                        other => bail!("bad escape \\{:?} at {}", other as char, self.pos),
                    }
                }
                _ => {
                    // Consume the full UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c)?;
                    ensure!(start + len <= self.b.len(), "truncated utf-8");
                    out.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while let Some(&c) = self.b.get(self.pos) {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => bail!("invalid utf-8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "version": 1,
            "config": {"k1": 16, "buckets": [4, 8, 12, 16], "probe": {"flops": 2822400}},
            "executables": {
                "conv1_fwd_b4": {"file": "a.hlo.txt",
                                 "args": [["x", [64, 3, 32, 32], "f32"]],
                                 "outs": []}
            }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.get("config").unwrap().get("buckets").unwrap().as_usize_vec().unwrap(),
            vec![4, 8, 12, 16]
        );
        let exe = v.get("executables").unwrap().get("conv1_fwd_b4").unwrap();
        assert_eq!(exe.get("file").unwrap().as_str().unwrap(), "a.hlo.txt");
        let arg0 = &exe.get("args").unwrap().as_arr().unwrap()[0];
        assert_eq!(arg0.as_arr().unwrap()[0].as_str().unwrap(), "x");
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(
            Json::parse(r#""a\n\"bAçä""#).unwrap(),
            Json::Str("a\n\"bAçä".into())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{\"a\":1,}",
            "[1, ]", "\"\\q\"", "nul", "{\"a\":}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_accessor_rejects_fractions_and_negatives() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-3").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
    }

    #[test]
    fn nested_deep_structure() {
        let v = Json::parse(r#"[[[[1]]], {"a": [{"b": 2}]}]"#).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(
            arr[1].get("a").unwrap().as_arr().unwrap()[0].get("b").unwrap().as_usize().unwrap(),
            2
        );
    }
}
