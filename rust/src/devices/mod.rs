//! Device profiles — the paper's heterogeneous testbed, reconstructed.
//!
//! The paper's Tables 2 and 3 list four laptops (Intel i5/i7 CPUs; Radeon
//! 7500M + NVIDIA 840M/940M/GTX 950M GPUs) with "near maximum throughput
//! performances in the range 790–1170 GFLOPS" for the GPUs.  We model every
//! device as a sustained-GFLOPS profile and reproduce heterogeneity on one
//! machine two ways:
//!
//! 1. **Throttle** (real runs): pad each PJRT execution to a virtual
//!    duration (relative multiple or flops/virtual-GFLOPS), so the wire,
//!    the partitioner and the straggler structure behave exactly as if the
//!    device were the modeled one — even on a single-core host.
//! 2. **Analytic profiles** (simulator, Figures 9–13): conv time =
//!    FLOPs / (gflops · utilization), with Gaussian-sampled per-node
//!    variation exactly as the paper's scalability study does.

use std::time::Duration;

use crate::tensor::Pcg32;

/// What kind of silicon a profile models (the paper builds CPU-only and
/// GPU-only clusters — §4.1.1 "Hybrid CPU-CPU and GPU-GPU computing").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    MobileGpu,
}

/// A named device with a sustained conv throughput estimate.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// Sustained GFLOPS on the conv workload (not peak datasheet numbers —
    /// these are effective rates that reproduce the paper's relative
    /// speeds; absolute scale cancels in every speedup).
    pub gflops: f64,
}

impl DeviceProfile {
    pub const fn new(name: &'static str, kind: DeviceKind, gflops: f64) -> Self {
        Self { name, kind, gflops }
    }

    /// Seconds to execute `flops` on this device.
    pub fn exec_time(&self, flops: f64) -> f64 {
        flops / (self.gflops * 1e9)
    }
}

/// Paper Table 2 — the CPU cluster, in introduction order (PC1 = master).
/// Effective conv GFLOPS estimated from core count x clock x SIMD width of
/// each part; only the *ratios* matter for speedups.
pub fn paper_cpus() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile::new("PC1 i5-3210M", DeviceKind::Cpu, 20.0),
        DeviceProfile::new("PC2 i7-4700HQ", DeviceKind::Cpu, 38.0),
        DeviceProfile::new("PC3 i7-5500U", DeviceKind::Cpu, 24.0),
        DeviceProfile::new("PC4 i7-6700HQ", DeviceKind::Cpu, 42.0),
    ]
}

/// Paper Table 3 — the GPU cluster (PC2 = master; PC1's Radeon is excluded
/// because the paper's CUDA path cannot use it).  The paper quotes 790–1170
/// GFLOPS *peak* throughput; the profiles below are effective Matlab-CUDA
/// conv throughput at ~10% of peak, calibrated so the simulated GPU/CPU
/// conv-time ratio reproduces the paper's Fig. 8 breakdown (a Matlab
/// `gpuArray` convn never approaches datasheet FLOPs).
pub fn paper_gpus() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile::new("PC2 GeForce 840M", DeviceKind::Gpu, 79.0),
        DeviceProfile::new("PC3 GeForce 940M", DeviceKind::Gpu, 90.0),
        DeviceProfile::new("PC4 GTX 950M", DeviceKind::Gpu, 117.0),
    ]
}

/// §5.4 "high-end devices" sweep: desktop-class parts, same era.
pub fn highend_cpus() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile::new("HE i7-6950X", DeviceKind::Cpu, 160.0),
        DeviceProfile::new("HE i7-6900K", DeviceKind::Cpu, 140.0),
        DeviceProfile::new("HE E5-2690v4", DeviceKind::Cpu, 150.0),
        DeviceProfile::new("HE i7-6850K", DeviceKind::Cpu, 120.0),
    ]
}

pub fn highend_gpus() -> Vec<DeviceProfile> {
    // Same ~10% effective-of-peak scaling as `paper_gpus`.
    vec![
        DeviceProfile::new("HE GTX 1080", DeviceKind::Gpu, 800.0),
        DeviceProfile::new("HE TITAN X", DeviceKind::Gpu, 1000.0),
        DeviceProfile::new("HE GTX 1070", DeviceKind::Gpu, 600.0),
    ]
}

/// §5.4.1: mobile GPUs are "about 10 times slower" than the desktop GPUs
/// used; master stays a desktop GPU.
pub fn mobile_gpu() -> DeviceProfile {
    DeviceProfile::new("Mobile GPU (Tegra-class)", DeviceKind::MobileGpu, 9.5)
}

/// Sample `n` per-node profiles between the catalog's worst and best, with
/// Gaussian spread — the paper's Figure 9/10 methodology ("assigned random
/// performance values with Gaussian distribution, varying between worst and
/// best case scenario").
pub fn sample_cluster(catalog: &[DeviceProfile], n: usize, rng: &mut Pcg32) -> Vec<DeviceProfile> {
    assert!(!catalog.is_empty());
    let lo = catalog.iter().map(|d| d.gflops).fold(f64::MAX, f64::min);
    let hi = catalog.iter().map(|d| d.gflops).fold(f64::MIN, f64::max);
    let mid = 0.5 * (lo + hi);
    let sigma = (hi - lo) / 4.0; // ±2σ spans the observed range
    (0..n)
        .map(|i| {
            if i < catalog.len() {
                // First nodes are the real measured devices, like the paper
                // growing its own 4-node cluster before extrapolating.
                catalog[i].clone()
            } else {
                let g = (mid + sigma * rng.next_gaussian() as f64).clamp(lo, hi);
                DeviceProfile { name: "sampled", kind: catalog[0].kind, gflops: g }
            }
        })
        .collect()
}

/// Real-execution device emulation: makes the local host *behave like* a
/// slower device by sleep-padding after each compute call.
///
/// Two modes:
/// * `Relative(s)` — pad to `s x` the measured duration.  Simple, but on a
///   single-core host concurrent workers inflate each other's measurements
///   *before* padding, so relative mode cannot demonstrate overlap.
/// * `Virtual { gflops }` — pad to `max(real, flops / gflops)` using the
///   executable's nominal FLOPs from the manifest.  The virtual time is a
///   deterministic function of the workload, exactly like the analytic
///   simulator's device model, so sleeps dominate and genuinely overlap
///   across workers even on one core.  This is the mode the heterogeneity
///   experiments use.
#[derive(Clone, Copy, Debug)]
pub enum Throttle {
    None,
    Relative(f64),
    Virtual { gflops: f64 },
}

impl Throttle {
    pub fn none() -> Self {
        Throttle::None
    }

    pub fn new(slowdown: f64) -> Self {
        assert!(slowdown >= 1.0, "throttle slowdown must be >= 1, got {slowdown}");
        if slowdown == 1.0 {
            Throttle::None
        } else {
            Throttle::Relative(slowdown)
        }
    }

    pub fn virtual_gflops(gflops: f64) -> Self {
        assert!(gflops > 0.0, "virtual gflops must be positive");
        Throttle::Virtual { gflops }
    }

    /// Given the real compute duration and the executable's nominal FLOPs,
    /// sleep the deficit and return the duration the emulated device "took".
    pub fn pad(&self, real: Duration, flops: u64) -> Duration {
        let target = match self {
            Throttle::None => real,
            Throttle::Relative(s) => real.mul_f64(*s),
            Throttle::Virtual { gflops } => {
                let virt = Duration::from_secs_f64(flops as f64 / (gflops * 1e9));
                virt.max(real)
            }
        };
        let pad = target.saturating_sub(real);
        if !pad.is_zero() {
            std::thread::sleep(pad);
        }
        target
    }

    /// Virtual-time throttles mirroring a device roster's *relative* speeds,
    /// with the fastest device pinned at `base_gflops` of virtual throughput
    /// (pick it well below the host's real rate so virtual time dominates).
    pub fn virtual_cluster(profiles: &[DeviceProfile], base_gflops: f64) -> Vec<Throttle> {
        let best = profiles.iter().map(|p| p.gflops).fold(f64::MIN, f64::max);
        profiles
            .iter()
            .map(|p| Throttle::virtual_gflops(base_gflops * p.gflops / best))
            .collect()
    }

    /// Relative throttles for a device set (legacy mode; see enum docs).
    pub fn for_profiles(profiles: &[DeviceProfile]) -> Vec<Throttle> {
        let best = profiles.iter().map(|p| p.gflops).fold(f64::MIN, f64::max);
        profiles.iter().map(|p| Throttle::new(best / p.gflops)).collect()
    }
}

/// A throttle *schedule*: the emulated device speed changes mid-run after a
/// number of throttled conv calls — models thermal throttling, a co-tenant
/// stealing the device, or recovery.  This is what exercises the adaptive
/// scheduler: a fleet calibrated once goes out of balance when a plan
/// switches, and the telemetry/policy loop has to win the time back.
///
/// Bookkeeping note: one distributed training step issues 4 conv calls per
/// participating device (fwd + bwd for each of the two layers), so
/// "degrade after N steps" is `switch_after = 4 * N`.
#[derive(Clone, Copy, Debug)]
pub struct ThrottlePlan {
    pub initial: Throttle,
    /// Conv calls served before `then` takes over.
    pub switch_after: u64,
    /// The throttle in force from call `switch_after` on (`None` = fixed).
    pub then: Option<Throttle>,
}

impl ThrottlePlan {
    /// A constant-speed device (the pre-adaptive behavior).
    pub fn fixed(t: Throttle) -> Self {
        Self { initial: t, switch_after: 0, then: None }
    }

    /// Run at `initial` for `calls` conv calls, then switch to `then`.
    pub fn degrade_after(initial: Throttle, calls: u64, then: Throttle) -> Self {
        Self { initial, switch_after: calls, then: Some(then) }
    }

    /// The throttle in force for the `calls`-th conv call (0-based).
    pub fn current(&self, calls: u64) -> Throttle {
        match self.then {
            Some(t) if calls >= self.switch_after => t,
            _ => self.initial,
        }
    }
}

impl From<Throttle> for ThrottlePlan {
    fn from(t: Throttle) -> Self {
        Self::fixed(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_time_inversely_proportional_to_gflops() {
        let fast = DeviceProfile::new("f", DeviceKind::Cpu, 100.0);
        let slow = DeviceProfile::new("s", DeviceKind::Cpu, 25.0);
        let flops = 1e9;
        assert!((slow.exec_time(flops) / fast.exec_time(flops) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn paper_catalogs_shapes() {
        assert_eq!(paper_cpus().len(), 4);
        assert_eq!(paper_gpus().len(), 3);
        assert!(paper_gpus().iter().all(|d| d.kind == DeviceKind::Gpu));
        // GPU effective range: 10% of the paper's 790–1170 GFLOPS peak.
        for g in paper_gpus() {
            assert!((79.0..=117.0).contains(&g.gflops));
        }
    }

    #[test]
    fn mobile_gpu_is_about_10x_slower() {
        let desktop_mean =
            paper_gpus().iter().map(|d| d.gflops).sum::<f64>() / paper_gpus().len() as f64;
        let ratio = desktop_mean / mobile_gpu().gflops;
        assert!((8.0..=12.0).contains(&ratio), "mobile ratio {ratio}");
    }

    #[test]
    fn sampled_cluster_within_range_and_reproducible() {
        let mut rng = Pcg32::seed(11);
        let c = sample_cluster(&paper_cpus(), 32, &mut rng);
        assert_eq!(c.len(), 32);
        let (lo, hi) = (20.0, 42.0);
        assert!(c.iter().all(|d| (lo..=hi).contains(&d.gflops)));
        // First 4 are the real devices.
        assert_eq!(c[0].name, "PC1 i5-3210M");
        let mut rng2 = Pcg32::seed(11);
        let c2 = sample_cluster(&paper_cpus(), 32, &mut rng2);
        assert_eq!(c[10].gflops, c2[10].gflops);
    }

    #[test]
    fn throttle_relative_pads_to_target() {
        let t = Throttle::new(3.0);
        let real = Duration::from_millis(10);
        let start = std::time::Instant::now();
        let reported = t.pad(real, 0);
        assert!(start.elapsed() >= Duration::from_millis(19));
        assert_eq!(reported, Duration::from_millis(30));
    }

    #[test]
    fn throttle_virtual_is_work_deterministic() {
        // 1 GFLOPS virtual device: 2e7 flops = 20ms regardless of the real
        // measured duration (as long as real <= virtual).
        let t = Throttle::virtual_gflops(1.0);
        let reported = t.pad(Duration::from_millis(2), 20_000_000);
        assert_eq!(reported, Duration::from_millis(20));
        // Real slower than virtual: no sleep, report real.
        let reported = t.pad(Duration::from_millis(50), 20_000_000);
        assert_eq!(reported, Duration::from_millis(50));
        // None mode is a no-op.
        assert_eq!(Throttle::none().pad(Duration::from_millis(3), 1 << 40), Duration::from_millis(3));
    }

    #[test]
    fn throttle_plan_switches_at_the_scheduled_call() {
        let fast = Throttle::virtual_gflops(2.0);
        let slow = Throttle::virtual_gflops(0.25);
        let plan = ThrottlePlan::degrade_after(fast, 12, slow);
        for calls in [0u64, 5, 11] {
            match plan.current(calls) {
                Throttle::Virtual { gflops } => assert_eq!(gflops, 2.0),
                other => panic!("expected fast Virtual, got {other:?}"),
            }
        }
        for calls in [12u64, 13, 1000] {
            match plan.current(calls) {
                Throttle::Virtual { gflops } => assert_eq!(gflops, 0.25),
                other => panic!("expected slow Virtual, got {other:?}"),
            }
        }
        // A fixed plan never switches; `From<Throttle>` builds one.
        let fixed: ThrottlePlan = Throttle::new(3.0).into();
        match fixed.current(u64::MAX) {
            Throttle::Relative(s) => assert_eq!(s, 3.0),
            other => panic!("expected Relative, got {other:?}"),
        }
    }

    #[test]
    fn virtual_cluster_mirrors_profile_ratios() {
        let th = Throttle::virtual_cluster(&paper_cpus(), 2.0);
        assert_eq!(th.len(), 4);
        // PC4 (42 GFLOPS) fastest -> pinned at base 2.0 virtual GFLOPS.
        match th[3] {
            Throttle::Virtual { gflops } => assert!((gflops - 2.0).abs() < 1e-12),
            ref other => panic!("expected Virtual, got {other:?}"),
        }
        // PC1 (20 GFLOPS) -> 2.0 * 20/42.
        match th[0] {
            Throttle::Virtual { gflops } => assert!((gflops - 2.0 * 20.0 / 42.0).abs() < 1e-12),
            ref other => panic!("expected Virtual, got {other:?}"),
        }
    }
}
