//! Algorithm 1 — the master node.
//!
//! Owns the full parameter set, the training loop and the non-convolutional
//! layers; scatters per-layer kernel shards to the slaves (same inputs,
//! different kernels), convolves its own shard meanwhile (Algorithm 1 lines
//! 15-17), gathers and reassembles the feature maps, and runs SGD.  All
//! compute goes through the [`Runtime`] executable contract, so the same
//! loop drives the native CPU backend and (with `--features pjrt`) the
//! AOT-HLO path.
//!
//! Extension beyond the paper: if a worker dies mid-training the master
//! drops it, re-runs the Eq. 1 partition over the survivors and retries the
//! batch — the paper's protocol has no recovery story, but a production
//! coordinator needs one.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::TrainerConfig;
use crate::data::Batch;
use crate::devices::Throttle;
use crate::metrics::{Breakdown, Phase, PhaseTimer};
use crate::model::{Grads, Params, Sgd};
use crate::net::Link;
use crate::proto::{Message, WireTensor};
use crate::runtime::{ConvDir, Manifest, Runtime};
use crate::sched::{partition_layer, Shard};
use crate::tensor::{Tensor, Value};

/// Outcome of one distributed training step.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub loss: f32,
    pub breakdown: Breakdown,
    /// Bytes moved over all links during the step (Eq. 2 ground truth).
    pub bytes_moved: u64,
    /// Devices that participated (master included).
    pub devices: usize,
}

struct WorkerSlot {
    link: Box<dyn Link>,
    alive: bool,
}

/// The master node: Algorithm 1 plus calibration, Eq. 1 partitioning and
/// parameter updates.
pub struct DistTrainer {
    rt: Arc<Runtime>,
    workers: Vec<WorkerSlot>,
    /// Probe seconds per device; index 0 = master, i+1 = worker i.
    probe_times: Vec<f64>,
    shards1: Vec<Shard>,
    shards2: Vec<Shard>,
    pub params: Params,
    opt: Sgd,
    master_throttle: Throttle,
    /// Scatter-round sequence number (stale-reply filtering after retries).
    seq: u32,
}

impl DistTrainer {
    /// Handshake, calibrate (paper §4.1.1) and partition (Eq. 1).
    pub fn new(
        rt: Arc<Runtime>,
        links: Vec<Box<dyn Link>>,
        cfg: &TrainerConfig,
        master_throttle: Throttle,
    ) -> Result<Self> {
        let mut workers: Vec<WorkerSlot> =
            links.into_iter().map(|link| WorkerSlot { link, alive: true }).collect();
        // Hello handshake.
        for (i, w) in workers.iter_mut().enumerate() {
            match w.link.recv()? {
                Message::Hello { version, .. } => {
                    ensure!(version == super::worker::PROTO_VERSION, "worker {i} protocol v{version}");
                }
                other => bail!("worker {i}: expected Hello, got {}", other.tag()),
            }
        }
        let params = Params::init(rt.arch(), cfg.seed)?;
        let mut trainer = Self {
            rt,
            workers,
            probe_times: vec![],
            shards1: vec![],
            shards2: vec![],
            params,
            opt: Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay),
            master_throttle,
            seq: 0,
        };
        trainer.calibrate(cfg.calib_rounds)?;
        trainer.partition()?;
        Ok(trainer)
    }

    /// Run the probe on every device concurrently; fill `probe_times`.
    fn calibrate(&mut self, rounds: u32) -> Result<()> {
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            w.link.send(&Message::Calibrate { rounds })?;
        }
        // Master probes itself while the slaves probe.
        let my_secs = {
            let p = &self.rt.arch().probe;
            let mut rng = crate::tensor::Pcg32::seed_stream(0xCA11B, 0);
            let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
            let w = Tensor::randn(&[p.k, p.in_ch, self.rt.arch().kh, self.rt.arch().kw], &mut rng);
            let b = Tensor::zeros(&[p.k]);
            let args = [Value::F32(x), Value::F32(w), Value::F32(b)];
            let _ = self.rt.execute("probe", &args)?; // absorb compile
            let flops = self.rt.flops("probe");
            let mut best = f64::MAX;
            for _ in 0..rounds.max(1) {
                let (_, real) = self.rt.execute_timed("probe", &args)?;
                best = best.min(self.master_throttle.pad(real, flops).as_secs_f64());
            }
            best
        };
        let mut times = vec![my_secs];
        for (i, w) in self.workers.iter_mut().enumerate() {
            if !w.alive {
                times.push(f64::INFINITY);
                continue;
            }
            match w.link.recv()? {
                Message::CalibrateResult { seconds } => times.push(seconds),
                Message::Error { reason } => bail!("worker {i} calibration failed: {reason}"),
                other => bail!("worker {i}: expected CalibrateResult, got {}", other.tag()),
            }
        }
        self.probe_times = times;
        Ok(())
    }

    /// Eq. 1 partition of both conv layers over the alive devices.
    fn partition(&mut self) -> Result<()> {
        let arch = self.rt.arch().clone();
        // Device ids that are alive: master (0) plus live workers.
        let active: Vec<usize> = std::iter::once(0)
            .chain(self.workers.iter().enumerate().filter(|(_, w)| w.alive).map(|(i, _)| i + 1))
            .collect();
        let times: Vec<f64> = active.iter().map(|&d| self.probe_times[d]).collect();
        let remap = |mut shards: Vec<Shard>| -> Vec<Shard> {
            for s in &mut shards {
                s.device = active[s.device];
            }
            shards
        };
        self.shards1 = remap(partition_layer(arch.k1, &times, &arch.buckets1)?);
        self.shards2 = remap(partition_layer(arch.k2, &times, &arch.buckets2)?);
        Ok(())
    }

    pub fn probe_times(&self) -> &[f64] {
        &self.probe_times
    }

    /// Replace the Eq. 1 partition with a *naive equal split* — the
    /// data-parallel assumption the paper argues against (§4.1.1).  Used by
    /// ablations to measure what Eq. 1 buys on a heterogeneous cluster.
    pub fn partition_equal(&mut self) -> Result<()> {
        let saved = std::mem::take(&mut self.probe_times);
        self.probe_times = vec![1.0; saved.len()];
        let r = self.partition();
        self.probe_times = saved;
        r
    }

    pub fn shards(&self, layer: usize) -> &[Shard] {
        match layer {
            1 => &self.shards1,
            2 => &self.shards2,
            _ => panic!("layer {layer} out of range"),
        }
    }

    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    fn total_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.link.bytes_moved()).sum()
    }

    /// One training step with single-retry recovery: if a worker dies, drop
    /// it, re-partition, and rerun the batch on the survivors.
    pub fn step(&mut self, batch: &Batch) -> Result<StepResult> {
        loop {
            let alive_before = self.alive_workers();
            match self.try_step(batch) {
                Ok(r) => return Ok(r),
                Err(e) => {
                    if self.alive_workers() < alive_before {
                        // A worker died; Eq. 1 re-partition and retry.
                        self.partition()?;
                        continue;
                    }
                    return Err(e);
                }
            }
        }
    }

    fn try_step(&mut self, batch: &Batch) -> Result<StepResult> {
        let bytes0 = self.total_bytes();
        let mut timer = PhaseTimer::default();
        let arch = self.rt.arch().clone();
        ensure!(
            batch.images.shape() == [arch.batch, arch.in_ch, arch.img, arch.img],
            "batch shape {:?} does not match compiled arch",
            batch.images.shape()
        );

        // ---------------- forward ----------------
        let shards1 = self.shards1.clone();
        let shards2 = self.shards2.clone();
        let w1 = self.params.get("w1")?.clone();
        let b1 = self.params.get("b1")?.clone();
        let y1 = self.dist_conv_fwd(1, &batch.images, &w1, &b1, &shards1, &mut timer)?;
        let p1 = self.master_exec1("mid1_fwd", Value::F32(y1.clone()), &mut timer)?;

        let w2 = self.params.get("w2")?.clone();
        let b2 = self.params.get("b2")?.clone();
        let y2 = self.dist_conv_fwd(2, &p1, &w2, &b2, &shards2, &mut timer)?;
        let p2 = self.master_exec1("mid2_fwd", Value::F32(y2.clone()), &mut timer)?;

        // head: loss + gradients wrt (p2, wf, bf)
        let wf = self.params.get("wf")?.clone();
        let bf = self.params.get("bf")?.clone();
        let outs = timer.time(Phase::Comp, || {
            self.rt.execute(
                "head_grad",
                &[
                    Value::F32(p2),
                    Value::F32(wf),
                    Value::F32(bf),
                    Value::I32(batch.labels.clone()),
                ],
            )
        })?;
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().as_f32()?.item()?;
        let gp2 = it.next().unwrap();
        let gwf = it.next().unwrap().as_f32()?.clone();
        let gbf = it.next().unwrap().as_f32()?.clone();

        // ---------------- backward ----------------
        let gy2 = {
            let outs = timer.time(Phase::Comp, || {
                self.rt.execute("mid2_bwd", &[Value::F32(y2), gp2])
            })?;
            outs.into_iter().next().unwrap().as_f32()?.clone()
        };
        let (gp1, gw2, gb2) = self.dist_conv_bwd(2, &p1, &w2, &gy2, &shards2, &mut timer)?;
        let gy1 = {
            let outs = timer.time(Phase::Comp, || {
                self.rt.execute("mid1_bwd", &[Value::F32(y1), Value::F32(gp1)])
            })?;
            outs.into_iter().next().unwrap().as_f32()?.clone()
        };
        // Input-layer gx is discarded (no layer below), but the executable
        // computes it anyway — same cost structure as the paper's convn.
        let (_gx, gw1, gb1) = self.dist_conv_bwd(1, &batch.images, &w1, &gy1, &shards1, &mut timer)?;

        // ---------------- update ----------------
        timer.time(Phase::Comp, || -> Result<()> {
            let mut grads = Grads::zeros_like(&self.params);
            grads.set("w1", gw1);
            grads.set("b1", gb1);
            grads.set("w2", gw2);
            grads.set("b2", gb2);
            grads.set("wf", gwf);
            grads.set("bf", gbf);
            self.opt.step(&mut self.params, &grads)
        })?;

        // Batch acknowledged (Algorithm 1 line 21).
        self.broadcast(&Message::AllOk);

        Ok(StepResult {
            loss,
            breakdown: timer.breakdown,
            bytes_moved: self.total_bytes() - bytes0,
            devices: 1 + self.alive_workers(),
        })
    }

    /// Distributed conv forward: scatter shards, convolve own shard, gather
    /// and reassemble `y[B, K, H', W']`.
    fn dist_conv_fwd(
        &mut self,
        layer: usize,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        shards: &[Shard],
        timer: &mut PhaseTimer,
    ) -> Result<Tensor> {
        let t0 = Instant::now();
        self.seq += 1;
        let seq = self.seq;
        // Scatter to workers (Algorithm 1 lines 8-13): same inputs,
        // different kernels.
        for s in shards.iter().filter(|s| s.device != 0) {
            let wk = w.slice_axis0(s.lo, s.hi)?;
            let bk = b.slice_axis0(s.lo, s.hi)?;
            let msg = Message::ConvWork {
                seq,
                layer: layer as u8,
                dir: 0,
                bucket: s.bucket as u32,
                inputs: WireTensor::from(x),
                kernels: WireTensor::from(&wk),
                extra: Some(WireTensor::from(&bk)),
            };
            self.send_to(s.device - 1, &msg)?;
        }
        // Master's own shard overlaps with the slaves' compute.
        let mut parts: Vec<(usize, Tensor)> = Vec::with_capacity(shards.len());
        let mut slowest = Duration::ZERO;
        if let Some(s) = shards.iter().find(|s| s.device == 0) {
            let (y, secs) = self.local_conv_fwd(layer, s, x, w, b)?;
            slowest = slowest.max(secs);
            parts.push((s.lo, y));
        }
        // Gather (Algorithm 1 lines 19-22).
        for s in shards.iter().filter(|s| s.device != 0) {
            let (mut outputs, seconds) = self.recv_result(s.device - 1, seq)?;
            ensure!(outputs.len() == 1, "fwd ConvResult must carry 1 tensor");
            slowest = slowest.max(Duration::from_secs_f64(seconds));
            parts.push((s.lo, outputs.remove(0).into_tensor()?));
        }
        parts.sort_by_key(|(lo, _)| *lo);
        let tensors: Vec<Tensor> = parts.into_iter().map(|(_, t)| t).collect();
        let y = Tensor::concat_axis1(&tensors)?;
        let wall = t0.elapsed();
        // Paper's attribution: Conv = slowest device; the rest of the phase
        // wall time is transfer = Comm.
        timer.record(Phase::Conv, slowest);
        timer.record(Phase::Comm, wall.saturating_sub(slowest));
        Ok(y)
    }

    /// Distributed conv backward: returns (gx_summed, gw_full, gb_full).
    fn dist_conv_bwd(
        &mut self,
        layer: usize,
        x: &Tensor,
        w: &Tensor,
        gy: &Tensor,
        shards: &[Shard],
        timer: &mut PhaseTimer,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let t0 = Instant::now();
        self.seq += 1;
        let seq = self.seq;
        for s in shards.iter().filter(|s| s.device != 0) {
            let wk = w.slice_axis0(s.lo, s.hi)?;
            let gyk = gy.slice_axis1(s.lo, s.hi)?;
            let msg = Message::ConvWork {
                seq,
                layer: layer as u8,
                dir: 1,
                bucket: s.bucket as u32,
                inputs: WireTensor::from(x),
                kernels: WireTensor::from(&wk),
                extra: Some(WireTensor::from(&gyk)),
            };
            self.send_to(s.device - 1, &msg)?;
        }
        let mut gx = Tensor::zeros(x.shape());
        let mut gw_parts: Vec<(usize, Tensor)> = Vec::new();
        let mut gb_parts: Vec<(usize, Tensor)> = Vec::new();
        let mut slowest = Duration::ZERO;
        if let Some(s) = shards.iter().find(|s| s.device == 0) {
            let (gxp, gw, gb, secs) = self.local_conv_bwd(layer, s, x, w, gy)?;
            slowest = slowest.max(secs);
            gx.add_assign(&gxp)?;
            gw_parts.push((s.lo, gw));
            gb_parts.push((s.lo, gb));
        }
        for s in shards.iter().filter(|s| s.device != 0) {
            let (outputs, seconds) = self.recv_result(s.device - 1, seq)?;
            ensure!(outputs.len() == 3, "bwd ConvResult must carry 3 tensors");
            slowest = slowest.max(Duration::from_secs_f64(seconds));
            let mut it = outputs.into_iter();
            // Partial input-cotangents sum (conv is linear in K).
            gx.add_assign(&it.next().unwrap().into_tensor()?)?;
            gw_parts.push((s.lo, it.next().unwrap().into_tensor()?));
            gb_parts.push((s.lo, it.next().unwrap().into_tensor()?));
        }
        gw_parts.sort_by_key(|(lo, _)| *lo);
        gb_parts.sort_by_key(|(lo, _)| *lo);
        let gw = Tensor::concat_axis0(&gw_parts.into_iter().map(|(_, t)| t).collect::<Vec<_>>())?;
        let gb = Tensor::concat_axis0(&gb_parts.into_iter().map(|(_, t)| t).collect::<Vec<_>>())?;
        let wall = t0.elapsed();
        timer.record(Phase::Conv, slowest);
        timer.record(Phase::Comm, wall.saturating_sub(slowest));
        Ok((gx, gw, gb))
    }

    fn local_conv_fwd(
        &self,
        layer: usize,
        s: &Shard,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<(Tensor, Duration)> {
        let exec = Manifest::conv_exec(layer, ConvDir::Fwd, s.bucket);
        let wk = w.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let bk = b.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let args = [Value::F32(x.clone()), Value::F32(wk), Value::F32(bk)];
        let (outs, real) = self.rt.execute_timed(&exec, &args)?;
        let padded = self.master_throttle.pad(real, self.rt.flops(&exec));
        let y = outs.into_iter().next().unwrap().as_f32()?.slice_axis1(0, s.len())?;
        Ok((y, padded))
    }

    fn local_conv_bwd(
        &self,
        layer: usize,
        s: &Shard,
        x: &Tensor,
        w: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor, Duration)> {
        let exec = Manifest::conv_exec(layer, ConvDir::Bwd, s.bucket);
        let wk = w.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let gyk = super::worker::pad_axis1(&gy.slice_axis1(s.lo, s.hi)?, s.bucket)?;
        let args = [Value::F32(x.clone()), Value::F32(wk), Value::F32(gyk)];
        let (outs, real) = self.rt.execute_timed(&exec, &args)?;
        let padded = self.master_throttle.pad(real, self.rt.flops(&exec));
        let mut it = outs.into_iter();
        let gx = it.next().unwrap().as_f32()?.clone();
        let gw = it.next().unwrap().as_f32()?.slice_axis0(0, s.len())?;
        let gb = it.next().unwrap().as_f32()?.slice_axis0(0, s.len())?;
        Ok((gx, gw, gb, padded))
    }

    /// Run a 1-in/1-out master segment, attributing time to Comp.
    fn master_exec1(&self, name: &str, arg: Value, timer: &mut PhaseTimer) -> Result<Tensor> {
        let outs = timer.time(Phase::Comp, || self.rt.execute(name, &[arg]))?;
        Ok(outs.into_iter().next().unwrap().as_f32()?.clone())
    }

    fn send_to(&mut self, worker: usize, msg: &Message) -> Result<()> {
        let slot = &mut self.workers[worker];
        if !slot.alive {
            bail!("worker {worker} is dead");
        }
        if let Err(e) = slot.link.send(msg) {
            slot.alive = false;
            return Err(anyhow!("worker {worker} died on send: {e:#}"));
        }
        Ok(())
    }

    fn recv_from(&mut self, worker: usize) -> Result<Message> {
        let slot = &mut self.workers[worker];
        if !slot.alive {
            bail!("worker {worker} is dead");
        }
        match slot.link.recv() {
            Ok(m) => Ok(m),
            Err(e) => {
                slot.alive = false;
                Err(anyhow!("worker {worker} died on recv: {e:#}"))
            }
        }
    }

    /// Receive the ConvResult for scatter round `seq` from `worker`,
    /// discarding stale replies left over from an aborted round (a worker
    /// death triggers re-partition + retry; survivors may still flush
    /// results for the old round).
    fn recv_result(&mut self, worker: usize, seq: u32) -> Result<(Vec<WireTensor>, f64)> {
        loop {
            match self.recv_from(worker)? {
                Message::ConvResult { seq: got, outputs, seconds } => {
                    if got == seq {
                        return Ok((outputs, seconds));
                    }
                    ensure!(got < seq, "worker {worker} replied from the future: {got} > {seq}");
                    // Stale reply from an aborted round: drop and re-read.
                }
                Message::Error { reason } => bail!("worker failed: {reason}"),
                other => bail!("expected ConvResult, got {}", other.tag()),
            }
        }
    }

    /// Best-effort broadcast (ignores dead links).
    fn broadcast(&mut self, msg: &Message) {
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            if w.link.send(msg).is_err() {
                w.alive = false;
            }
        }
    }

    /// Evaluate accuracy on a batch with the fused eval executable.
    pub fn eval_accuracy(&self, batch: &Batch) -> Result<f32> {
        let mut args = vec![Value::F32(batch.images.clone())];
        args.extend(self.params.in_order().into_iter().map(Value::F32));
        let outs = self.rt.execute("eval_full", &args)?;
        let logits = outs.into_iter().next().unwrap().as_f32()?.clone();
        let classes = self.rt.arch().num_classes;
        let n = batch.labels.len();
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 == batch.labels.data()[i] {
                correct += 1;
            }
        }
        Ok(correct as f32 / n as f32)
    }

    /// Algorithm 1 lines 27-29: tell every slave training is over.
    pub fn shutdown(mut self) -> Result<()> {
        self.broadcast(&Message::TrainOver);
        Ok(())
    }
}
