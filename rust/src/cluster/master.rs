//! Algorithm 1 — the master node.
//!
//! Owns the full parameter set, the training loop and the non-convolutional
//! layers; scatters per-layer kernel shards to the slaves (same inputs,
//! different kernels), convolves its own shard meanwhile (Algorithm 1 lines
//! 15-17), gathers and reassembles the feature maps, and runs SGD.  All
//! compute goes through the [`Runtime`] executable contract, so the same
//! loop drives the native CPU backend and (with `--features pjrt`) the
//! AOT-HLO path.
//!
//! The forward/backward passes are a *loop over the architecture graph's
//! conv layers* (`1..=arch.num_convs()`): each layer is Eq. 1-partitioned
//! from the same calibration, distributed, gathered, and followed by its
//! master-resident `mid{L}` segment — a 3- or N-conv [`ArchSpec`] trains
//! through the identical code path as the paper's two-conv network.
//!
//! Extensions beyond the paper:
//!
//! * **Failure recovery** — if a worker dies mid-training the master drops
//!   it, re-runs the Eq. 1 partition over the survivors and retries the
//!   batch; the paper's protocol has no recovery story.
//! * **Adaptive scheduling** (opt-in via the `AdaptiveConfig` argument of
//!   [`DistTrainer::new`], surfaced as `SessionBuilder::adaptive`) — the
//!   gather loop feeds per-device EWMA timing telemetry, an
//!   [`AdaptivePolicy`] re-runs Eq. 1 over the *smoothed observed* rates
//!   when the predicted payoff clears a threshold, heartbeats detect silent
//!   workers, a gather deadline drops stragglers, and a `Leave` message
//!   lets a worker depart gracefully — elastic membership (DESIGN.md §5).
//!   With adaptation disabled (`AdaptiveConfig::disabled()`) shard tables
//!   and numerics are identical to the static path.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::TrainerConfig;
use crate::data::Batch;
use crate::devices::Throttle;
use crate::metrics::{Breakdown, Phase, PhaseTimer, SchedStats};
use crate::model::{Grads, Params, Sgd};
use crate::net::Link;
use crate::obs::{
    AnomalyDetector, FleetHealth, HealthConfig, HealthState, HealthTransition, ObsHandle,
    SpanCat, SpanRec, StepAnomaly,
};
use crate::proto::{Message, WireSpan, WireTensor};
use crate::runtime::{ArchSpec, ConvDir, Manifest, Runtime};
use crate::sched::{
    partition_network, utilization, AdaptiveConfig, AdaptivePolicy, Decision, FleetTelemetry,
    LayerPlan, Shard,
};
use crate::tensor::{Tensor, Value};

/// Outcome of one distributed training step.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub loss: f32,
    pub breakdown: Breakdown,
    /// Bytes moved over all links during the step (Eq. 2 ground truth).
    pub bytes_moved: u64,
    /// Devices that participated (master included).
    pub devices: usize,
    /// The adaptive policy re-sharded the fleet after this step.
    pub repartitioned: bool,
    /// Health-state transitions this step triggered (EWMA slowness ladder,
    /// departures), in device order.
    pub health: Vec<HealthTransition>,
    /// Set when this step's total time was a high outlier against the
    /// rolling median/MAD window.
    pub anomaly: Option<StepAnomaly>,
}

/// A step stopped at the gradient: forward + backward ran, the update has
/// not.  Produced by [`DistTrainer::step_grads`] so the replica tier can
/// all-reduce gradients across fleets before [`DistTrainer::step_apply`]
/// commits them; [`DistTrainer::step`] composes the two halves unchanged.
pub struct PendingStep {
    loss: f32,
    grads: Grads,
    timer: PhaseTimer,
    step_t0: u64,
    bytes0: u64,
}

impl PendingStep {
    pub fn loss(&self) -> f32 {
        self.loss
    }

    pub fn grads(&self) -> &Grads {
        &self.grads
    }

    pub fn grads_mut(&mut self) -> &mut Grads {
        &mut self.grads
    }

    /// Attribute all-reduce wall time to the step's Comm phase so the
    /// printed breakdown and the trace keep summing to the step total.
    pub fn record_comm(&mut self, d: Duration) {
        self.timer.record(Phase::Comm, d);
    }
}

struct WorkerSlot {
    link: Box<dyn Link>,
    alive: bool,
}

/// Bucket-independent op key for [`SchedStats::observe_gflops`]: re-shards
/// change bucket sizes, and keying by the full executable name would
/// accumulate dead per-bucket entries over a long elastic run.
fn op_key(layer: usize, dir: ConvDir) -> String {
    let d = match dir {
        ConvDir::Fwd => "fwd",
        ConvDir::Bwd => "bwd",
    };
    format!("conv{layer}_{d}")
}

/// FLOPs of one kernel of conv layer `layer`, forward pass — the layer
/// weight the adaptive policy uses (training factors scale every layer
/// equally and cancel in the gain ratio).
fn flops_per_kernel(arch: &ArchSpec, layer: usize) -> f64 {
    arch.conv_layer_flops(layer, 1, arch.batch)
}

/// The master node: Algorithm 1 plus calibration, Eq. 1 partitioning,
/// parameter updates and (opt-in) the adaptive scheduling loop.
pub struct DistTrainer {
    rt: Arc<Runtime>,
    workers: Vec<WorkerSlot>,
    /// Probe seconds per device; index 0 = master, i+1 = worker i.
    probe_times: Vec<f64>,
    /// Per-conv-layer shard tables; index l-1 = conv layer l.
    shards: Vec<Vec<Shard>>,
    pub params: Params,
    opt: Sgd,
    master_throttle: Throttle,
    /// Scatter-round sequence number (stale-reply filtering after retries).
    seq: u32,
    // ---- adaptive scheduling state (inert when `adaptive.enabled` is off)
    adaptive: AdaptiveConfig,
    policy: AdaptivePolicy,
    telemetry: FleetTelemetry,
    stats: SchedStats,
    steps_done: u64,
    hb_nonce: u32,
    /// Observability sink (spans + metrics); `None` = zero-cost no-op path.
    obs: Option<ObsHandle>,
    /// Per-device health ladder over the same telemetry (DESIGN.md §12).
    health: FleetHealth,
    /// Rolling median/MAD outlier detector over step times.
    anomaly: AnomalyDetector,
}

impl DistTrainer {
    /// Handshake, calibrate (paper §4.1.1) and partition (Eq. 1).
    /// `AdaptiveConfig::disabled()` is the paper's static scheduler exactly;
    /// an enabled config turns on the telemetry/re-partition loop.  (Run
    /// composition normally goes through [`crate::session::SessionBuilder`],
    /// which calls this with the links it assembled.)
    pub fn new(
        rt: Arc<Runtime>,
        links: Vec<Box<dyn Link>>,
        cfg: &TrainerConfig,
        master_throttle: Throttle,
        adaptive: AdaptiveConfig,
    ) -> Result<Self> {
        let mut workers: Vec<WorkerSlot> =
            links.into_iter().map(|link| WorkerSlot { link, alive: true }).collect();
        // Hello handshake.
        for (i, w) in workers.iter_mut().enumerate() {
            match w.link.recv()? {
                Message::Hello { version, .. } => {
                    ensure!(version == super::worker::PROTO_VERSION, "worker {i} protocol v{version}");
                }
                other => bail!("worker {i}: expected Hello, got {}", other.tag()),
            }
        }
        let params = Params::init(rt.arch(), cfg.seed)?;
        let n_devices = workers.len() + 1;
        let mut trainer = Self {
            rt,
            workers,
            probe_times: vec![],
            shards: vec![],
            params,
            opt: Sgd::new(cfg.lr, cfg.momentum, cfg.weight_decay),
            master_throttle,
            seq: 0,
            adaptive,
            policy: AdaptivePolicy::new(adaptive),
            telemetry: FleetTelemetry::new(n_devices, adaptive.alpha),
            stats: SchedStats::default(),
            steps_done: 0,
            hb_nonce: 0,
            obs: None,
            health: FleetHealth::new(n_devices, HealthConfig::default()),
            anomaly: AnomalyDetector::default(),
        };
        trainer.calibrate(cfg.calib_rounds)?;
        // Seed the telemetry from the calibration probe so every device has
        // a rate estimate even before (or without ever) receiving a shard —
        // the probe is the same seconds-over-FLOPs quantity the gather loop
        // measures.
        let probe_flops = trainer.rt.arch().probe.flops as f64;
        for d in 0..n_devices {
            let secs = trainer.probe_times[d];
            trainer.telemetry.record(d, secs, probe_flops);
        }
        trainer.partition()?;
        Ok(trainer)
    }

    /// Run the probe on every device concurrently; fill `probe_times`.
    fn calibrate(&mut self, rounds: u32) -> Result<()> {
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            w.link.send(&Message::Calibrate { rounds })?;
        }
        // Master probes itself while the slaves probe.
        let my_secs = {
            let p = self.rt.arch().probe.clone();
            let mut rng = crate::tensor::Pcg32::seed_stream(0xCA11B, 0);
            let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
            let w = Tensor::randn(&[p.k, p.in_ch, p.kh, p.kw], &mut rng);
            let b = Tensor::zeros(&[p.k]);
            let args = [Value::F32(x), Value::F32(w), Value::F32(b)];
            let _ = self.rt.execute("probe", &args)?; // absorb compile
            let flops = self.rt.flops("probe");
            let mut best = f64::MAX;
            for _ in 0..rounds.max(1) {
                let (_, real) = self.rt.execute_timed("probe", &args)?;
                best = best.min(self.master_throttle.pad(real, flops).as_secs_f64());
            }
            best
        };
        let mut times = vec![my_secs];
        for (i, w) in self.workers.iter_mut().enumerate() {
            if !w.alive {
                times.push(f64::INFINITY);
                continue;
            }
            match w.link.recv()? {
                Message::CalibrateResult { seconds } => times.push(seconds),
                Message::Error { reason } => bail!("worker {i} calibration failed: {reason}"),
                other => bail!("worker {i}: expected CalibrateResult, got {}", other.tag()),
            }
        }
        self.probe_times = times;
        Ok(())
    }

    /// Alive device ids: master (0) plus live workers (i + 1).
    fn active_devices(&self) -> Vec<usize> {
        std::iter::once(0)
            .chain(self.workers.iter().enumerate().filter(|(_, w)| w.alive).map(|(i, _)| i + 1))
            .collect()
    }

    /// Eq. 1 partition of every conv layer over the alive devices, using
    /// the calibration probe times (the paper's static scheduler).
    fn partition(&mut self) -> Result<()> {
        let times = self.probe_times.clone();
        self.partition_with(&times)
    }

    /// Eq. 1 partition over the alive devices with per-device times indexed
    /// by device id (probe seconds or telemetry rates — Eq. 1 is scale
    /// free, only ratios matter).
    fn partition_with(&mut self, times_by_dev: &[f64]) -> Result<()> {
        let arch = self.rt.arch().clone();
        let active = self.active_devices();
        let times: Vec<f64> = active.iter().map(|&d| times_by_dev[d]).collect();
        let layers: Vec<(usize, &[usize])> =
            (1..=arch.num_convs()).map(|l| (arch.kernels(l), arch.buckets(l))).collect();
        let mut tables = partition_network(&layers, &times)?;
        for shards in &mut tables {
            for s in shards.iter_mut() {
                s.device = active[s.device];
            }
        }
        self.shards = tables;
        Ok(())
    }

    pub fn probe_times(&self) -> &[f64] {
        &self.probe_times
    }

    /// Replace the Eq. 1 partition with a *naive equal split* — the
    /// data-parallel assumption the paper argues against (§4.1.1).  Used by
    /// ablations to measure what Eq. 1 buys on a heterogeneous cluster.
    pub fn partition_equal(&mut self) -> Result<()> {
        let n = self.probe_times.len();
        self.partition_with(&vec![1.0; n])
    }

    /// Shard table of conv layer `layer` (1-based).
    pub fn shards(&self, layer: usize) -> &[Shard] {
        assert!(
            (1..=self.shards.len()).contains(&layer),
            "conv layer {layer} out of range 1..={}",
            self.shards.len()
        );
        &self.shards[layer - 1]
    }

    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Adaptive-scheduler counters, utilization and per-op achieved
    /// GFLOP/s (see `metrics`).  The GFLOP/s entries are recorded on every
    /// step, adaptation on or off.
    pub fn sched_stats(&self) -> &SchedStats {
        &self.stats
    }

    /// The per-device EWMA timing telemetry (seconds per GFLOP).
    pub fn telemetry(&self) -> &FleetTelemetry {
        &self.telemetry
    }

    /// Current per-device health ladder (index = device id).
    pub fn health_states(&self) -> &[HealthState] {
        self.health.states()
    }

    /// Kernel share per device, FLOP-weighted across every conv layer:
    /// `(device, fraction of total conv work)`. The live metrics endpoint
    /// renders this as `convdist_share{device=..}`.
    pub fn device_shares(&self) -> Vec<(usize, f64)> {
        let arch = self.rt.arch();
        let n_dev = self.probe_times.len().max(1);
        let mut work = vec![0.0f64; n_dev];
        let mut total = 0.0f64;
        for (li, shards) in self.shards.iter().enumerate() {
            let per_kernel = flops_per_kernel(arch, li + 1);
            for s in shards {
                let w = s.len() as f64 * per_kernel;
                if s.device < n_dev {
                    work[s.device] += w;
                }
                total += w;
            }
        }
        let total = total.max(1e-12);
        work.into_iter().enumerate().map(|(d, w)| (d, w / total)).collect()
    }

    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// Restore the global step counter (session checkpoint resume).  The
    /// counter drives the heartbeat cadence and the dataset cursor of a
    /// resumed run; shard tables are untouched (they come from the fresh
    /// calibration of the resumed fleet).
    pub fn set_steps_done(&mut self, steps: u64) {
        self.steps_done = steps;
    }

    /// The optimizer (momentum state travels in session checkpoints).
    pub fn optimizer(&self) -> &Sgd {
        &self.opt
    }

    pub fn optimizer_mut(&mut self) -> &mut Sgd {
        &mut self.opt
    }

    fn total_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.link.bytes_moved()).sum()
    }

    /// Attach an observability handle: spans for scatter/conv/gather/comp
    /// intervals and the per-step phase attribution.  Set by
    /// `SessionBuilder::observe`; without it every obs call is a no-op.
    pub fn attach_obs(&mut self, obs: ObsHandle) {
        self.obs = Some(obs);
    }

    /// Per-worker link traffic as `(device id, bytes, frames)` — absorbed
    /// into the metrics registry when a run finishes.
    pub fn link_stats(&self) -> Vec<(usize, u64, u64)> {
        self.workers
            .iter()
            .enumerate()
            .map(|(i, w)| (i + 1, w.link.bytes_moved(), w.link.frames_moved()))
            .collect()
    }

    fn obs_tracing(&self) -> bool {
        self.obs.as_ref().is_some_and(|o| o.tracing())
    }

    /// Microseconds on the obs clock (0 when no handle is attached).
    fn obs_now(&self) -> u64 {
        self.obs.as_ref().map_or(0, |o| o.now_us())
    }

    /// Record a span on device `device`'s row, attributed to the step in
    /// flight (`steps_done` advances only after `try_step` returns).
    fn obs_span(&self, name: String, cat: SpanCat, device: u32, layer: u32, ts_us: u64, dur_us: u64) {
        if let Some(o) = &self.obs {
            o.span(SpanRec { name, cat, device, layer, step: self.steps_done + 1, ts_us, dur_us });
        }
    }

    /// Place worker-reported spans on the worker's timeline row.  Clocks are
    /// unsynchronized, so the report is end-anchored at the gather receive:
    /// `offset = now - max(start + dur)` shifts the worker-relative spans so
    /// their latest edge meets the receive instant.  A non-tracing worker
    /// sends no report; its conv span is synthesized from the reported
    /// compute seconds instead.
    fn obs_worker_spans(
        &self,
        device: usize,
        layer: usize,
        dir: ConvDir,
        seconds: f64,
        spans: &[WireSpan],
    ) {
        if !self.obs_tracing() {
            return;
        }
        let now = self.obs_now();
        if spans.is_empty() {
            let dur = (seconds * 1e6) as u64;
            self.obs_span(
                format!("{} dev{device}", op_key(layer, dir)),
                SpanCat::Conv,
                device as u32,
                layer as u32,
                now.saturating_sub(dur),
                dur,
            );
            return;
        }
        let end = spans.iter().map(|s| s.start_us.saturating_add(s.dur_us)).max().unwrap_or(0);
        let offset = now.saturating_sub(end);
        for sp in spans {
            let d = if sp.dir == 0 { ConvDir::Fwd } else { ConvDir::Bwd };
            let (name, cat) = if sp.kind == WireSpan::KIND_SERVE {
                (format!("serve dev{device}"), SpanCat::Comm)
            } else {
                (format!("{} dev{device}", op_key(sp.layer as usize, d)), SpanCat::Conv)
            };
            self.obs_span(name, cat, device as u32, sp.layer as u32, offset + sp.start_us, sp.dur_us);
        }
    }

    /// One training step with recovery and (opt-in) adaptation: if a worker
    /// dies, leaves or times out, drop it, re-absorb its kernel range into
    /// the survivors and rerun the batch; after a successful step, consult
    /// the adaptive policy.
    pub fn step(&mut self, batch: &Batch) -> Result<StepResult> {
        let pending = self.step_grads(batch)?;
        self.step_apply(pending, None)
    }

    /// First half of [`Self::step`]: forward + backward with the same
    /// heartbeat/recovery semantics, stopped at the gradient.  The replica
    /// tier all-reduces the pending gradients across fleets before
    /// committing them with [`Self::step_apply`].
    pub fn step_grads(&mut self, batch: &Batch) -> Result<PendingStep> {
        if self.adaptive.enabled
            && self.adaptive.heartbeat_every > 0
            && self.steps_done > 0
            && self.steps_done % self.adaptive.heartbeat_every == 0
        {
            let dropped = self.heartbeat();
            if dropped > 0 {
                self.stats.departures += dropped;
                self.repartition_surviving()?;
            }
        }
        loop {
            // A worker can also die *outside* try_step_grads — a failed
            // AllOk broadcast or ShardUpdate send marks it dead without
            // going through the retry path.  If the tables still reference a
            // dead device, re-absorb its range before scattering; otherwise
            // send_to would fail every step with no recovery.
            if self.tables_reference_dead() {
                self.repartition_surviving()?;
            }
            let alive_before = self.alive_workers();
            match self.try_step_grads(batch) {
                Ok(p) => return Ok(p),
                Err(e) => {
                    let alive_now = self.alive_workers();
                    if alive_now < alive_before {
                        // A worker left the fleet mid-batch: re-absorb its
                        // kernel range and retry on the survivors.
                        self.stats.departures += (alive_before - alive_now) as u64;
                        self.repartition_surviving()?;
                        continue;
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Second half of [`Self::step`]: apply `grads_override` (the reduced
    /// gradients in replica mode) or the pending gradients, acknowledge the
    /// batch, and finish the per-step bookkeeping exactly as `step` does.
    pub fn step_apply(
        &mut self,
        pending: PendingStep,
        grads_override: Option<&Grads>,
    ) -> Result<StepResult> {
        let PendingStep { loss, grads, mut timer, step_t0, bytes0 } = pending;
        let grads = grads_override.unwrap_or(&grads);

        // ---------------- update ----------------
        let opt_t0 = self.obs_now();
        timer.time(Phase::Comp, || self.opt.step(&mut self.params, grads))?;
        if self.obs_tracing() {
            let now = self.obs_now();
            self.obs_span(
                "sgd_step".to_string(),
                SpanCat::Comp,
                0,
                0,
                opt_t0,
                now.saturating_sub(opt_t0),
            );
        }

        // Batch acknowledged (Algorithm 1 line 21).
        self.broadcast(&Message::AllOk);

        if let Some(o) = &self.obs {
            let step = self.steps_done + 1;
            if o.tracing() {
                let now = o.now_us();
                o.span(SpanRec {
                    name: format!("step {step}"),
                    cat: SpanCat::Step,
                    device: 0,
                    layer: 0,
                    step,
                    ts_us: step_t0,
                    dur_us: now.saturating_sub(step_t0),
                });
                // The Figure-6 attribution row: tiled from the step start
                // with the exact values the printed Breakdown carries, so
                // trace and stdout always agree.
                o.phase_spans(step, step_t0, &timer.breakdown);
            }
            let misuse = timer.misuse();
            if misuse > 0 {
                o.metrics(|m| m.inc("phase_timer_misuse", misuse));
            }
        }

        let mut r = StepResult {
            loss,
            breakdown: timer.breakdown,
            bytes_moved: self.total_bytes() - bytes0,
            devices: 1 + self.alive_workers(),
            repartitioned: false,
            health: Vec::new(),
            anomaly: None,
        };
        self.steps_done += 1;
        if self.adaptive.enabled {
            r.repartitioned = self.consider_repartition()?;
        }
        r.anomaly = self.anomaly.observe(r.breakdown.total().as_secs_f64() * 1e3);
        r.health = self.health.update(&self.active_devices(), &self.telemetry);
        Ok(r)
    }

    /// True when a shard table still names a dead worker (its departure was
    /// detected on a one-way send, outside the step retry loop).
    fn tables_reference_dead(&self) -> bool {
        self.shards
            .iter()
            .flatten()
            .any(|s| s.device != 0 && !self.workers[s.device - 1].alive)
    }

    /// Ping every alive worker and wait for its `Pong`; drop the silent
    /// ones.  Returns how many workers were dropped.
    fn heartbeat(&mut self) -> u64 {
        self.hb_nonce = self.hb_nonce.wrapping_add(1);
        let nonce = self.hb_nonce;
        let timeout = self.adaptive.heartbeat_timeout;
        let mut dropped = 0u64;
        let to_check: Vec<usize> =
            (0..self.workers.len()).filter(|&i| self.workers[i].alive).collect();
        for &i in &to_check {
            if self.workers[i].link.send(&Message::Ping { nonce }).is_err() {
                self.workers[i].alive = false;
                dropped += 1;
            }
        }
        for &i in &to_check {
            if !self.workers[i].alive {
                continue;
            }
            loop {
                match self.workers[i].link.recv_timeout(timeout) {
                    Ok(Some(Message::Pong { nonce: got })) if got == nonce => break,
                    // Stale replies from an aborted round or an older ping.
                    Ok(Some(Message::Pong { .. }))
                    | Ok(Some(Message::ConvResult { .. }))
                    | Ok(Some(Message::SpanReport { .. })) => {
                        continue;
                    }
                    // Silent, departing or confused: drop from the fleet.
                    Ok(Some(_)) | Ok(None) | Err(_) => {
                        self.workers[i].alive = false;
                        dropped += 1;
                        break;
                    }
                }
            }
        }
        dropped
    }

    /// Re-partition over the survivors: the smoothed observed rates when
    /// adaptive telemetry has them, the calibration probe times otherwise.
    fn repartition_surviving(&mut self) -> Result<()> {
        let active = self.active_devices();
        if self.adaptive.enabled {
            if let Some(rates) = self.telemetry.rates_for(&active, 1) {
                let mut by_dev = vec![1.0f64; self.probe_times.len()];
                for (&d, &r) in active.iter().zip(&rates) {
                    by_dev[d] = r;
                }
                self.partition_with(&by_dev)?;
                self.warm_own_shards();
                self.notify_shard_updates();
                return Ok(());
            }
        }
        self.partition()
    }

    /// After a successful step, feed the policy and apply its decision.
    /// Returns whether the fleet was re-sharded.
    fn consider_repartition(&mut self) -> Result<bool> {
        let active = self.active_devices();
        let Some(rates) = self.telemetry.rates_for(&active, 1) else {
            return Ok(false);
        };
        let flagged = self.telemetry.stragglers(
            &active,
            self.adaptive.straggler_k,
            self.adaptive.straggler_min_ratio,
        );
        self.stats.straggler_flags += flagged.len() as u64;

        let arch = self.rt.arch().clone();
        let nconv = arch.num_convs();
        let (decision, util) = {
            let plans: Vec<LayerPlan> = (1..=nconv)
                .map(|l| LayerPlan {
                    k: arch.kernels(l),
                    buckets: arch.buckets(l),
                    current: &self.shards[l - 1],
                    flops_per_kernel: flops_per_kernel(&arch, l),
                })
                .collect();
            let util = utilization(&plans, &active, &rates);
            let decision = self.policy.decide(self.steps_done, &plans, &active, &rates)?;
            (decision, util)
        };
        self.stats.utilization = active.iter().copied().zip(util).collect();
        match decision {
            Decision::Keep => Ok(false),
            Decision::Repartition(tables) => {
                ensure!(
                    tables.len() == nconv,
                    "policy returned {} tables for {nconv} conv layers",
                    tables.len()
                );
                self.shards = tables;
                self.stats.repartitions += 1;
                self.warm_own_shards();
                self.notify_shard_updates();
                Ok(true)
            }
        }
    }

    /// Prepare the master's own bucket executables for the current tables
    /// (best effort — a miss only costs compile time on the next step).
    fn warm_own_shards(&self) {
        for (li, shards) in self.shards.iter().enumerate() {
            if let Some(s) = shards.iter().find(|s| s.device == 0) {
                let fwd = Manifest::conv_exec(li + 1, ConvDir::Fwd, s.bucket);
                let bwd = Manifest::conv_exec(li + 1, ConvDir::Bwd, s.bucket);
                let _ = self.rt.warmup(&[fwd.as_str(), bwd.as_str()]);
            }
        }
    }

    /// Tell every alive worker its new shard of every layer so it can
    /// pre-warm the bucket executables (bucket 0 = idle for that layer).
    fn notify_shard_updates(&mut self) {
        let tables = self.shards.clone();
        for (li, shards) in tables.iter().enumerate() {
            for wi in 0..self.workers.len() {
                if !self.workers[wi].alive {
                    continue;
                }
                let msg = match shards.iter().find(|s| s.device == wi + 1) {
                    Some(s) => Message::ShardUpdate {
                        layer: (li + 1) as u8,
                        lo: s.lo as u32,
                        hi: s.hi as u32,
                        bucket: s.bucket as u32,
                    },
                    None => {
                        Message::ShardUpdate { layer: (li + 1) as u8, lo: 0, hi: 0, bucket: 0 }
                    }
                };
                if self.workers[wi].link.send(&msg).is_err() {
                    self.workers[wi].alive = false;
                }
            }
        }
    }

    fn try_step_grads(&mut self, batch: &Batch) -> Result<PendingStep> {
        let bytes0 = self.total_bytes();
        let step_t0 = self.obs_now();
        let mut timer = PhaseTimer::default();
        let arch = self.rt.arch().clone();
        ensure!(
            batch.images.shape() == [arch.batch, arch.in_ch, arch.img, arch.img],
            "batch shape {:?} does not match compiled arch",
            batch.images.shape()
        );
        let nconv = arch.num_convs();
        let tables = self.shards.clone();

        // ---------------- forward: loop over the conv layers ----------------
        let mut ws = Vec::with_capacity(nconv);
        let mut bs = Vec::with_capacity(nconv);
        for l in 1..=nconv {
            ws.push(self.params.get(&ArchSpec::conv_weight(l))?.clone());
            bs.push(self.params.get(&ArchSpec::conv_bias(l))?.clone());
        }
        // Per-layer activations backward needs: the conv inputs and the
        // (pre-mid) conv outputs.
        let mut xs: Vec<Tensor> = Vec::with_capacity(nconv);
        let mut ys: Vec<Tensor> = Vec::with_capacity(nconv);
        let mut p = batch.images.clone();
        for l in 1..=nconv {
            let y =
                self.dist_conv_fwd(l, &p, &ws[l - 1], &bs[l - 1], &tables[l - 1], &mut timer)?;
            let name = format!("mid{l}_fwd");
            let next = self.master_exec1(&name, Value::F32(y.clone()), &mut timer)?;
            xs.push(std::mem::replace(&mut p, next));
            ys.push(y);
        }

        // head: loss + gradients wrt (p, fc.w, fc.b)
        let wf = self.params.get(ArchSpec::FC_W)?.clone();
        let bf = self.params.get(ArchSpec::FC_B)?.clone();
        let head_t0 = self.obs_now();
        let outs = timer.time(Phase::Comp, || {
            self.rt.execute(
                "head_grad",
                &[
                    Value::F32(p),
                    Value::F32(wf),
                    Value::F32(bf),
                    Value::I32(batch.labels.clone()),
                ],
            )
        })?;
        if self.obs_tracing() {
            let now = self.obs_now();
            self.obs_span(
                "head_grad".to_string(),
                SpanCat::Comp,
                0,
                0,
                head_t0,
                now.saturating_sub(head_t0),
            );
        }
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().as_f32()?.item()?;
        let mut gp = it.next().unwrap();
        let gwf = it.next().unwrap().as_f32()?.clone();
        let gbf = it.next().unwrap().as_f32()?.clone();

        // ---------------- backward: deepest conv first ----------------------
        let mut grads = Grads::zeros_like(&self.params);
        grads.set(ArchSpec::FC_W, gwf);
        grads.set(ArchSpec::FC_B, gbf);
        for l in (1..=nconv).rev() {
            let gy = {
                let name = format!("mid{l}_bwd");
                // Backward consumes the stored conv outputs deepest-first,
                // so each y moves out of `ys` instead of being cloned.
                let y = Value::F32(ys.pop().unwrap());
                let outs = timer.time(Phase::Comp, || self.rt.execute(&name, &[y, gp]))?;
                outs.into_iter().next().unwrap().as_f32()?.clone()
            };
            // The input-layer gx is discarded (no layer below), but the
            // executable computes it anyway — same cost structure as the
            // paper's convn.
            let (gx, gw, gb) =
                self.dist_conv_bwd(l, &xs[l - 1], &ws[l - 1], &gy, &tables[l - 1], &mut timer)?;
            grads.set(&ArchSpec::conv_weight(l), gw);
            grads.set(&ArchSpec::conv_bias(l), gb);
            gp = Value::F32(gx);
        }

        Ok(PendingStep { loss, grads, timer, step_t0, bytes0 })
    }

    /// Distributed conv forward: scatter shards, convolve own shard, gather
    /// and reassemble `y[B, K, H', W']`.
    fn dist_conv_fwd(
        &mut self,
        layer: usize,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        shards: &[Shard],
        timer: &mut PhaseTimer,
    ) -> Result<Tensor> {
        let t0 = Instant::now();
        let obs_t0 = self.obs_now();
        self.seq += 1;
        let seq = self.seq;
        // Scatter to workers (Algorithm 1 lines 8-13): same inputs,
        // different kernels.
        for s in shards.iter().filter(|s| s.device != 0) {
            let wk = w.slice_axis0(s.lo, s.hi)?;
            let bk = b.slice_axis0(s.lo, s.hi)?;
            let msg = Message::ConvWork {
                seq,
                layer: layer as u8,
                dir: 0,
                bucket: s.bucket as u32,
                inputs: WireTensor::from(x),
                kernels: WireTensor::from(&wk),
                extra: Some(WireTensor::from(&bk)),
            };
            self.send_to(s.device - 1, &msg)?;
        }
        if self.obs_tracing() {
            let now = self.obs_now();
            self.obs_span(
                format!("scatter {}", op_key(layer, ConvDir::Fwd)),
                SpanCat::Comm,
                0,
                layer as u32,
                obs_t0,
                now.saturating_sub(obs_t0),
            );
        }
        // Master's own shard overlaps with the slaves' compute.
        let mut parts: Vec<(usize, Tensor)> = Vec::with_capacity(shards.len());
        let mut slowest = Duration::ZERO;
        if let Some(s) = shards.iter().find(|s| s.device == 0) {
            let local_t0 = self.obs_now();
            let (y, secs) = self.local_conv_fwd(layer, s, x, w, b)?;
            let exec = Manifest::conv_exec(layer, ConvDir::Fwd, s.bucket);
            let flops = self.rt.flops(&exec) as f64;
            self.telemetry.record(0, secs.as_secs_f64(), flops);
            self.stats.observe_gflops(&op_key(layer, ConvDir::Fwd), secs.as_secs_f64(), flops);
            if self.obs_tracing() {
                self.obs_span(
                    format!("{} dev0", op_key(layer, ConvDir::Fwd)),
                    SpanCat::Conv,
                    0,
                    layer as u32,
                    local_t0,
                    (secs.as_secs_f64() * 1e6) as u64,
                );
            }
            slowest = slowest.max(secs);
            parts.push((s.lo, y));
        }
        // Gather (Algorithm 1 lines 19-22).
        let gather_t0 = self.obs_now();
        for s in shards.iter().filter(|s| s.device != 0) {
            let (mut outputs, seconds, spans) = self.recv_result(s.device - 1, seq)?;
            ensure!(outputs.len() == 1, "fwd ConvResult must carry 1 tensor");
            let exec = Manifest::conv_exec(layer, ConvDir::Fwd, s.bucket);
            let flops = self.rt.flops(&exec) as f64;
            self.telemetry.record(s.device, seconds, flops);
            self.stats.observe_gflops(&op_key(layer, ConvDir::Fwd), seconds, flops);
            self.obs_worker_spans(s.device, layer, ConvDir::Fwd, seconds, &spans);
            slowest = slowest.max(Duration::from_secs_f64(seconds));
            parts.push((s.lo, outputs.remove(0).into_tensor()?));
        }
        if self.obs_tracing() && shards.iter().any(|s| s.device != 0) {
            let now = self.obs_now();
            self.obs_span(
                format!("gather {}", op_key(layer, ConvDir::Fwd)),
                SpanCat::Comm,
                0,
                layer as u32,
                gather_t0,
                now.saturating_sub(gather_t0),
            );
        }
        parts.sort_by_key(|(lo, _)| *lo);
        let tensors: Vec<Tensor> = parts.into_iter().map(|(_, t)| t).collect();
        let y = Tensor::concat_axis1(&tensors)?;
        let wall = t0.elapsed();
        // Paper's attribution: Conv = slowest device; the rest of the phase
        // wall time is transfer = Comm.
        timer.record(Phase::Conv, slowest);
        timer.record(Phase::Comm, wall.saturating_sub(slowest));
        Ok(y)
    }

    /// Distributed conv backward: returns (gx_summed, gw_full, gb_full).
    fn dist_conv_bwd(
        &mut self,
        layer: usize,
        x: &Tensor,
        w: &Tensor,
        gy: &Tensor,
        shards: &[Shard],
        timer: &mut PhaseTimer,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let t0 = Instant::now();
        let obs_t0 = self.obs_now();
        self.seq += 1;
        let seq = self.seq;
        for s in shards.iter().filter(|s| s.device != 0) {
            let wk = w.slice_axis0(s.lo, s.hi)?;
            let gyk = gy.slice_axis1(s.lo, s.hi)?;
            let msg = Message::ConvWork {
                seq,
                layer: layer as u8,
                dir: 1,
                bucket: s.bucket as u32,
                inputs: WireTensor::from(x),
                kernels: WireTensor::from(&wk),
                extra: Some(WireTensor::from(&gyk)),
            };
            self.send_to(s.device - 1, &msg)?;
        }
        if self.obs_tracing() {
            let now = self.obs_now();
            self.obs_span(
                format!("scatter {}", op_key(layer, ConvDir::Bwd)),
                SpanCat::Comm,
                0,
                layer as u32,
                obs_t0,
                now.saturating_sub(obs_t0),
            );
        }
        let mut gx = Tensor::zeros(x.shape());
        let mut gw_parts: Vec<(usize, Tensor)> = Vec::new();
        let mut gb_parts: Vec<(usize, Tensor)> = Vec::new();
        let mut slowest = Duration::ZERO;
        if let Some(s) = shards.iter().find(|s| s.device == 0) {
            let local_t0 = self.obs_now();
            let (gxp, gw, gb, secs) = self.local_conv_bwd(layer, s, x, w, gy)?;
            let exec = Manifest::conv_exec(layer, ConvDir::Bwd, s.bucket);
            let flops = self.rt.flops(&exec) as f64;
            self.telemetry.record(0, secs.as_secs_f64(), flops);
            self.stats.observe_gflops(&op_key(layer, ConvDir::Bwd), secs.as_secs_f64(), flops);
            if self.obs_tracing() {
                self.obs_span(
                    format!("{} dev0", op_key(layer, ConvDir::Bwd)),
                    SpanCat::Conv,
                    0,
                    layer as u32,
                    local_t0,
                    (secs.as_secs_f64() * 1e6) as u64,
                );
            }
            slowest = slowest.max(secs);
            gx.add_assign(&gxp)?;
            gw_parts.push((s.lo, gw));
            gb_parts.push((s.lo, gb));
        }
        let gather_t0 = self.obs_now();
        for s in shards.iter().filter(|s| s.device != 0) {
            let (outputs, seconds, spans) = self.recv_result(s.device - 1, seq)?;
            ensure!(outputs.len() == 3, "bwd ConvResult must carry 3 tensors");
            let exec = Manifest::conv_exec(layer, ConvDir::Bwd, s.bucket);
            let flops = self.rt.flops(&exec) as f64;
            self.telemetry.record(s.device, seconds, flops);
            self.stats.observe_gflops(&op_key(layer, ConvDir::Bwd), seconds, flops);
            self.obs_worker_spans(s.device, layer, ConvDir::Bwd, seconds, &spans);
            slowest = slowest.max(Duration::from_secs_f64(seconds));
            let mut it = outputs.into_iter();
            // Partial input-cotangents sum (conv is linear in K).
            gx.add_assign(&it.next().unwrap().into_tensor()?)?;
            gw_parts.push((s.lo, it.next().unwrap().into_tensor()?));
            gb_parts.push((s.lo, it.next().unwrap().into_tensor()?));
        }
        if self.obs_tracing() && shards.iter().any(|s| s.device != 0) {
            let now = self.obs_now();
            self.obs_span(
                format!("gather {}", op_key(layer, ConvDir::Bwd)),
                SpanCat::Comm,
                0,
                layer as u32,
                gather_t0,
                now.saturating_sub(gather_t0),
            );
        }
        gw_parts.sort_by_key(|(lo, _)| *lo);
        gb_parts.sort_by_key(|(lo, _)| *lo);
        let gw = Tensor::concat_axis0(&gw_parts.into_iter().map(|(_, t)| t).collect::<Vec<_>>())?;
        let gb = Tensor::concat_axis0(&gb_parts.into_iter().map(|(_, t)| t).collect::<Vec<_>>())?;
        let wall = t0.elapsed();
        timer.record(Phase::Conv, slowest);
        timer.record(Phase::Comm, wall.saturating_sub(slowest));
        Ok((gx, gw, gb))
    }

    fn local_conv_fwd(
        &self,
        layer: usize,
        s: &Shard,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<(Tensor, Duration)> {
        let exec = Manifest::conv_exec(layer, ConvDir::Fwd, s.bucket);
        let wk = w.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let bk = b.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let args = [Value::F32(x.clone()), Value::F32(wk), Value::F32(bk)];
        let (outs, real) = self.rt.execute_timed(&exec, &args)?;
        let padded = self.master_throttle.pad(real, self.rt.flops(&exec));
        let y = outs.into_iter().next().unwrap().as_f32()?.slice_axis1(0, s.len())?;
        Ok((y, padded))
    }

    fn local_conv_bwd(
        &self,
        layer: usize,
        s: &Shard,
        x: &Tensor,
        w: &Tensor,
        gy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor, Duration)> {
        let exec = Manifest::conv_exec(layer, ConvDir::Bwd, s.bucket);
        let wk = w.slice_axis0(s.lo, s.hi)?.pad_axis0(s.bucket)?;
        let gyk = super::worker::pad_axis1(&gy.slice_axis1(s.lo, s.hi)?, s.bucket)?;
        let args = [Value::F32(x.clone()), Value::F32(wk), Value::F32(gyk)];
        let (outs, real) = self.rt.execute_timed(&exec, &args)?;
        let padded = self.master_throttle.pad(real, self.rt.flops(&exec));
        let mut it = outs.into_iter();
        let gx = it.next().unwrap().as_f32()?.clone();
        let gw = it.next().unwrap().as_f32()?.slice_axis0(0, s.len())?;
        let gb = it.next().unwrap().as_f32()?.slice_axis0(0, s.len())?;
        Ok((gx, gw, gb, padded))
    }

    /// Run a 1-in/1-out master segment, attributing time to Comp.
    fn master_exec1(&self, name: &str, arg: Value, timer: &mut PhaseTimer) -> Result<Tensor> {
        let t0 = self.obs_now();
        let outs = timer.time(Phase::Comp, || self.rt.execute(name, &[arg]))?;
        if self.obs_tracing() {
            let now = self.obs_now();
            self.obs_span(
                name.to_string(),
                SpanCat::Comp,
                0,
                0,
                t0,
                now.saturating_sub(t0),
            );
        }
        Ok(outs.into_iter().next().unwrap().as_f32()?.clone())
    }

    fn send_to(&mut self, worker: usize, msg: &Message) -> Result<()> {
        let slot = &mut self.workers[worker];
        if !slot.alive {
            bail!("worker {worker} is dead");
        }
        if let Err(e) = slot.link.send(msg) {
            slot.alive = false;
            return Err(anyhow!("worker {worker} died on send: {e:#}"));
        }
        Ok(())
    }

    fn recv_from(&mut self, worker: usize) -> Result<Message> {
        let slot = &mut self.workers[worker];
        if !slot.alive {
            bail!("worker {worker} is dead");
        }
        match slot.link.recv() {
            Ok(m) => Ok(m),
            Err(e) => {
                slot.alive = false;
                Err(anyhow!("worker {worker} died on recv: {e:#}"))
            }
        }
    }

    /// Receive the ConvResult for scatter round `seq` from `worker`,
    /// discarding stale replies left over from an aborted round (a worker
    /// death triggers re-partition + retry; survivors may still flush
    /// results for the old round).  In adaptive mode a `gather_timeout`
    /// bounds the wait: a worker past the deadline is dropped from the
    /// fleet (elastic membership) and the step retried without it.
    ///
    /// A tracing worker sends a `SpanReport` for the round immediately
    /// before its ConvResult; the spans ride back in the third tuple slot
    /// (empty when the worker does not trace).
    fn recv_result(
        &mut self,
        worker: usize,
        seq: u32,
    ) -> Result<(Vec<WireTensor>, f64, Vec<WireSpan>)> {
        let timeout = if self.adaptive.enabled { self.adaptive.gather_timeout } else { None };
        let mut spans: Vec<WireSpan> = Vec::new();
        loop {
            let msg = match timeout {
                Some(d) => {
                    let slot = &mut self.workers[worker];
                    if !slot.alive {
                        bail!("worker {worker} is dead");
                    }
                    match slot.link.recv_timeout(d) {
                        Ok(Some(m)) => m,
                        Ok(None) => {
                            slot.alive = false;
                            bail!("worker {worker} exceeded the {d:?} gather deadline; dropped");
                        }
                        Err(e) => {
                            slot.alive = false;
                            bail!("worker {worker} died on recv: {e:#}");
                        }
                    }
                }
                None => self.recv_from(worker)?,
            };
            match msg {
                Message::ConvResult { seq: got, outputs, seconds } => {
                    if got == seq {
                        return Ok((outputs, seconds, spans));
                    }
                    ensure!(got < seq, "worker {worker} replied from the future: {got} > {seq}");
                    // Stale reply from an aborted round: drop and re-read.
                }
                Message::SpanReport { seq: got, spans: reported, .. } => {
                    // Stale reports (aborted round) are dropped like stale
                    // ConvResults.
                    if got == seq {
                        spans = reported;
                    }
                }
                Message::Leave { reason, .. } => {
                    self.workers[worker].alive = false;
                    bail!("worker {worker} left the fleet: {reason}");
                }
                Message::Pong { .. } => { /* stale heartbeat reply: ignore */ }
                Message::Error { reason } => bail!("worker failed: {reason}"),
                other => bail!("expected ConvResult, got {}", other.tag()),
            }
        }
    }

    /// Best-effort broadcast (ignores dead links).
    fn broadcast(&mut self, msg: &Message) {
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            if w.link.send(msg).is_err() {
                w.alive = false;
            }
        }
    }

    /// Evaluate accuracy on a batch with the fused eval executable.
    pub fn eval_accuracy(&self, batch: &Batch) -> Result<f32> {
        let mut args = vec![Value::F32(batch.images.clone())];
        args.extend(self.params.in_order().into_iter().map(Value::F32));
        let outs = self.rt.execute("eval_full", &args)?;
        let logits = outs.into_iter().next().unwrap().as_f32()?.clone();
        let classes = self.rt.arch().num_classes;
        let n = batch.labels.len();
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            // total_cmp: a NaN logit (e.g. a diverged run at a huge lr) must
            // not panic the master mid-eval — it just loses the argmax.
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map_or(0, |(i, _)| i);
            if pred as i32 == batch.labels.data()[i] {
                correct += 1;
            }
        }
        Ok(correct as f32 / n as f32)
    }

    /// Algorithm 1 lines 27-29: tell every slave training is over.
    pub fn shutdown(mut self) -> Result<()> {
        self.broadcast(&Message::TrainOver);
        Ok(())
    }
}
