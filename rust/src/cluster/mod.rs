//! The paper's system contribution: master/slave distributed convolution.
//!
//! * [`worker`] — Algorithm 2: receive inputs + a kernel shard, convolve,
//!   send the feature maps back, repeat until `TrainOver`.
//! * [`master`] — Algorithm 1: calibrate, partition by Eq. 1, then per batch
//!   scatter ConvWork / compute own shard / gather, run the non-conv layers
//!   locally, and update parameters.
//! * [`spawn_inproc`] — single-process cluster: workers on threads connected
//!   by in-proc links (optionally bandwidth-shaped and throttled).  The TCP
//!   path (`convdist worker` / `convdist master`) uses the identical code
//!   over real sockets.

mod master;
mod worker;

pub use master::{DistTrainer, StepResult};
pub use worker::{compute_conv_work, worker_loop, WorkerOptions};

use std::path::PathBuf;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::devices::{Throttle, ThrottlePlan};
use crate::net::{inproc_pair, Link, LinkModel, ShapedLink};
use crate::runtime::Runtime;

/// Handles to an in-process worker fleet: the master-side links plus the
/// join handles (joined on `TrainOver` so panics propagate to tests).
pub struct InprocCluster {
    pub links: Vec<Box<dyn Link>>,
    pub handles: Vec<JoinHandle<Result<()>>>,
}

/// Spawn one in-process worker per entry of `throttles`; `throttles[i]`
/// slows worker `i` to emulate a heterogeneous device; `shape` meters every
/// frame through the given bandwidth/latency model.
///
/// Each worker opens its *own* [`Runtime`] over `artifacts` — the paper's
/// slaves are separate machines with their own Matlab processes, and one
/// runtime per device mirrors that (it also keeps per-device executable
/// stats and throttling state independent).
pub fn spawn_inproc(
    artifacts: PathBuf,
    throttles: &[Throttle],
    shape: Option<LinkModel>,
) -> InprocCluster {
    let plans: Vec<ThrottlePlan> = throttles.iter().map(|&t| ThrottlePlan::fixed(t)).collect();
    spawn_inproc_planned(artifacts, &plans, shape)
}

/// [`spawn_inproc`] with full throttle *plans*: a worker's emulated speed
/// may change mid-run (`ThrottlePlan::degrade_after`), which is how the
/// adaptive-scheduler tests and the `--adaptive` example make a calibrated
/// fleet go out of balance on cue.
pub fn spawn_inproc_planned(
    artifacts: PathBuf,
    plans: &[ThrottlePlan],
    shape: Option<LinkModel>,
) -> InprocCluster {
    spawn_inproc_impl(WorkerRuntime::Artifacts(artifacts), plans, shape)
}

/// [`spawn_inproc`] for an explicit (synthesized) architecture: every
/// worker opens a native runtime over its own clone of `arch` instead of an
/// artifact directory.  This is how a preset selected on the master (the
/// CLI's `--arch`, the e2e example's `[arch]` argument) reaches in-process
/// workers — as an argument, not ambient env state.
pub fn spawn_inproc_arch(
    arch: crate::runtime::ArchSpec,
    throttles: &[Throttle],
    shape: Option<LinkModel>,
) -> InprocCluster {
    let plans: Vec<ThrottlePlan> = throttles.iter().map(|&t| ThrottlePlan::fixed(t)).collect();
    spawn_inproc_impl(WorkerRuntime::Arch(arch), &plans, shape)
}

/// How each spawned worker obtains its [`Runtime`].
enum WorkerRuntime {
    /// `Runtime::open` over this directory (manifest-pinned or default).
    Artifacts(PathBuf),
    /// `Runtime::for_arch` over a clone of this architecture.
    Arch(crate::runtime::ArchSpec),
}

impl WorkerRuntime {
    fn open(&self) -> Result<std::sync::Arc<Runtime>> {
        match self {
            WorkerRuntime::Artifacts(dir) => Runtime::open(dir),
            WorkerRuntime::Arch(arch) => Ok(Runtime::for_arch(arch.clone())),
        }
    }
}

fn spawn_inproc_impl(
    source: WorkerRuntime,
    plans: &[ThrottlePlan],
    shape: Option<LinkModel>,
) -> InprocCluster {
    let mut links: Vec<Box<dyn Link>> = Vec::new();
    let mut handles = Vec::new();
    let source = std::sync::Arc::new(source);
    for (i, &plan) in plans.iter().enumerate() {
        let (master_end, worker_end) = inproc_pair();
        let opts = WorkerOptions::with_plan(i as u32 + 1, plan);
        let src = source.clone();
        let handle = std::thread::Builder::new()
            .name(format!("convdist-worker-{}", i + 1))
            .spawn(move || {
                let rt = src.open()?;
                // Shaping is applied on the worker side for its sends;
                // master-side sends are shaped on the master's link.
                match shape {
                    Some(m) => worker_loop(ShapedLink::new(worker_end, m), rt, opts),
                    None => worker_loop(worker_end, rt, opts),
                }
            })
            .expect("spawning worker thread");
        let master_link: Box<dyn Link> = match shape {
            Some(m) => Box::new(ShapedLink::new(master_end, m)),
            None => Box::new(master_end),
        };
        links.push(master_link);
        handles.push(handle);
    }
    InprocCluster { links, handles }
}

impl InprocCluster {
    /// Take ownership of the master-side links (leaves the join handles).
    pub fn take_links(&mut self) -> Vec<Box<dyn Link>> {
        std::mem::take(&mut self.links)
    }

    /// Join all workers, propagating the first error/panic.
    pub fn join(self) -> Result<()> {
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        Ok(())
    }
}
