//! The paper's system contribution: master/slave distributed convolution.
//!
//! * [`worker`] — Algorithm 2: receive inputs + a kernel shard, convolve,
//!   send the feature maps back, repeat until `TrainOver`.
//! * [`master`] — Algorithm 1: calibrate, partition by Eq. 1, then per batch
//!   scatter ConvWork / compute own shard / gather, run the non-conv layers
//!   locally, and update parameters.
//! * [`spawn_workers`] — single-process worker fleet: workers on threads
//!   connected by in-proc links (optionally bandwidth-shaped and throttled).
//!   The TCP path (`convdist worker` / `convdist master`) uses the identical
//!   code over real sockets.
//!
//! Run composition lives one level up, in [`crate::session`]: a
//! [`crate::session::SessionBuilder`] picks the architecture source, the
//! topology and the scheduling mode, then drives this module.  Construct a
//! [`DistTrainer`] directly only when you already hold raw [`Link`]s (custom
//! worker harnesses in tests do).

mod master;
mod worker;

pub use master::{DistTrainer, StepResult};
pub use worker::{compute_conv_work, worker_loop, WorkerOptions, PROTO_VERSION};

use std::path::PathBuf;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::devices::ThrottlePlan;
use crate::net::{inproc_pair, Link, LinkModel, ShapedLink};
use crate::runtime::{ArchSpec, Runtime};

/// How each spawned worker obtains its [`Runtime`].  The paper's slaves are
/// separate machines with their own Matlab processes; one runtime per device
/// mirrors that (and keeps per-device executable stats and throttling state
/// independent).
pub enum WorkerSource {
    /// `Runtime::open` over this directory (manifest-pinned or default).
    Artifacts(PathBuf),
    /// `Runtime::for_arch` over a clone of this architecture — how a preset
    /// or graph-file arch selected on the master reaches in-process workers:
    /// as an argument, not ambient env state.
    Arch(ArchSpec),
}

impl WorkerSource {
    fn open(&self) -> Result<std::sync::Arc<Runtime>> {
        match self {
            WorkerSource::Artifacts(dir) => Runtime::open(dir),
            WorkerSource::Arch(arch) => Ok(Runtime::for_arch(arch.clone())),
        }
    }
}

/// Handles to an in-process worker fleet: the master-side links plus the
/// join handles (joined on `TrainOver` so panics propagate to tests).
pub struct InprocCluster {
    pub links: Vec<Box<dyn Link>>,
    pub handles: Vec<JoinHandle<Result<()>>>,
}

/// Spawn one in-process worker per entry of `plans`; `plans[i]` throttles
/// worker `i` to emulate a heterogeneous device (a worker's emulated speed
/// may change mid-run — `ThrottlePlan::degrade_after`); `shape` meters every
/// frame through the given bandwidth/latency model.
///
/// A failed thread spawn propagates as an error (and the partially spawned
/// fleet is torn down by dropping its master links) instead of panicking.
pub fn spawn_workers(
    source: WorkerSource,
    plans: &[ThrottlePlan],
    shape: Option<LinkModel>,
) -> Result<InprocCluster> {
    spawn_workers_traced(source, plans, shape, false)
}

/// [`spawn_workers`] with worker-side tracing: each worker measures its
/// ConvWork service and ships the spans back (`Message::SpanReport`) for
/// the master's obs timeline.
pub fn spawn_workers_traced(
    source: WorkerSource,
    plans: &[ThrottlePlan],
    shape: Option<LinkModel>,
    trace: bool,
) -> Result<InprocCluster> {
    let mut links: Vec<Box<dyn Link>> = Vec::new();
    let mut handles = Vec::new();
    let source = std::sync::Arc::new(source);
    for (i, &plan) in plans.iter().enumerate() {
        let (master_end, worker_end) = inproc_pair();
        let opts = WorkerOptions::with_plan(i as u32 + 1, plan).traced(trace);
        let src = source.clone();
        let handle = std::thread::Builder::new()
            .name(format!("convdist-worker-{}", i + 1))
            .spawn(move || {
                let rt = src.open()?;
                // Shaping is applied on the worker side for its sends;
                // master-side sends are shaped on the master's link.
                match shape {
                    Some(m) => worker_loop(ShapedLink::new(worker_end, m), rt, opts),
                    None => worker_loop(worker_end, rt, opts),
                }
            })
            .with_context(|| format!("spawning worker thread {}", i + 1))?;
        let master_link: Box<dyn Link> = match shape {
            Some(m) => Box::new(ShapedLink::new(master_end, m)),
            None => Box::new(master_end),
        };
        links.push(master_link);
        handles.push(handle);
    }
    Ok(InprocCluster { links, handles })
}

impl InprocCluster {
    /// Take ownership of the master-side links (leaves the join handles).
    pub fn take_links(&mut self) -> Vec<Box<dyn Link>> {
        std::mem::take(&mut self.links)
    }

    /// Join all workers, propagating the first error/panic.
    pub fn join(self) -> Result<()> {
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(p) => std::panic::resume_unwind(p),
            }
        }
        Ok(())
    }
}
