//! Algorithm 2 — the slave node.
//!
//! ```text
//! connectSocket(server)
//! while trainOver == 0:
//!     inputs  <= readSocket(server)
//!     numMaps <= readSocket(server)
//!     kernels <= readSocket(server)
//!     for maps = 1 to numMaps: output = convn(inputs, maps)
//!     output  => writeSocket(server)
//!     allOk   <= readSocket(server)
//! ```
//!
//! Differences from the paper's Matlab loop: (1) the three reads are one
//! self-describing `ConvWork` frame; (2) backward-pass work arrives on the
//! same loop (`dir = 1`) because the paper distributes "forward and backward
//! propagation included"; (3) the worker reports its pure compute seconds so
//! the master can attribute Conv vs Comm time exactly.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::devices::{Throttle, ThrottlePlan};
use crate::net::Link;
use crate::proto::{Message, WireSpan, WireTensor};
use crate::runtime::{ConvDir, Manifest, Runtime};
use crate::tensor::{Tensor, Value};

#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    pub worker_id: u32,
    /// Emulated device speed over time (see `devices::ThrottlePlan`); a
    /// fixed `Throttle` converts with `.into()` or [`WorkerOptions::new`].
    pub throttle: ThrottlePlan,
    /// Scripted graceful departure: after serving this many ConvWork
    /// frames, announce [`Message::Leave`] and exit — exercises the
    /// master's elastic-membership path in tests and demos.
    pub leave_after: Option<u64>,
    /// Measure each ConvWork service (serve + pure conv spans) and ship the
    /// spans back with [`Message::SpanReport`] right before the matching
    /// `ConvResult` — the master's tracer places them on this worker's
    /// timeline row.  Off by default; a non-tracing master absorbs and
    /// drops the extra frame harmlessly.
    pub trace: bool,
}

impl WorkerOptions {
    pub fn new(worker_id: u32, throttle: Throttle) -> Self {
        Self { worker_id, throttle: ThrottlePlan::fixed(throttle), leave_after: None, trace: false }
    }

    pub fn with_plan(worker_id: u32, plan: ThrottlePlan) -> Self {
        Self { worker_id, throttle: plan, leave_after: None, trace: false }
    }

    pub fn traced(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }
}

pub const PROTO_VERSION: u32 = 1;

/// Run the slave loop until `TrainOver` (or a protocol error).
pub fn worker_loop(mut link: impl Link, rt: Arc<Runtime>, opts: WorkerOptions) -> Result<()> {
    link.send(&Message::Hello { worker_id: opts.worker_id, version: PROTO_VERSION })?;
    // ConvWork frames served so far — drives the throttle plan (mid-run
    // degradation) and the scripted departure.
    let mut served: u64 = 0;
    loop {
        match link.recv()? {
            Message::Calibrate { rounds } => {
                let seconds = run_probe(&rt, &opts, rounds)?;
                link.send(&Message::CalibrateResult { seconds })?;
            }
            Message::ConvWork { seq, layer, dir, bucket, inputs, kernels, extra } => {
                if matches!(opts.leave_after, Some(n) if served >= n) {
                    link.send(&Message::Leave {
                        worker_id: opts.worker_id,
                        reason: "scheduled departure".into(),
                    })?;
                    return Ok(());
                }
                let throttle = opts.throttle.current(served);
                served += 1;
                let t0 = Instant::now();
                let reply = compute_conv_work(
                    &rt, throttle, seq, layer, dir, bucket as usize, inputs, kernels, extra,
                );
                match reply {
                    Ok(msg) => {
                        if opts.trace {
                            if let Message::ConvResult { seconds, .. } = &msg {
                                // Serve span = whole frame handling (real
                                // wall); conv span = reported compute
                                // seconds (virtual under a throttle, so it
                                // may exceed the serve wall — the master
                                // end-anchors both at the gather receive).
                                let serve_us = t0.elapsed().as_micros() as u64;
                                let conv_us = (seconds * 1e6) as u64;
                                link.send(&Message::SpanReport {
                                    worker_id: opts.worker_id,
                                    seq,
                                    spans: vec![
                                        WireSpan {
                                            kind: WireSpan::KIND_SERVE,
                                            layer,
                                            dir,
                                            bucket,
                                            start_us: 0,
                                            dur_us: serve_us,
                                        },
                                        WireSpan {
                                            kind: WireSpan::KIND_CONV,
                                            layer,
                                            dir,
                                            bucket,
                                            start_us: serve_us.saturating_sub(conv_us),
                                            dur_us: conv_us,
                                        },
                                    ],
                                })?;
                            }
                        }
                        link.send(&msg)?
                    }
                    Err(e) => {
                        link.send(&Message::Error { reason: format!("worker {}: {e:#}", opts.worker_id) })?;
                        bail!("worker {} failed conv work: {e:#}", opts.worker_id);
                    }
                }
            }
            Message::Ping { nonce } => link.send(&Message::Pong { nonce })?,
            Message::ShardUpdate { layer, bucket, .. } => {
                // Advisory: pre-warm the executables for the re-partitioned
                // bucket so the next scatter is not billed preparation time
                // (bucket recompiles stay off the hot path).  Best-effort —
                // a bad layer/bucket only loses the prefetch.
                if bucket > 0 && (1..=rt.arch().num_convs()).contains(&(layer as usize)) {
                    let fwd = Manifest::conv_exec(layer as usize, ConvDir::Fwd, bucket as usize);
                    let bwd = Manifest::conv_exec(layer as usize, ConvDir::Bwd, bucket as usize);
                    let _ = rt.warmup(&[fwd.as_str(), bwd.as_str()]);
                }
            }
            Message::AllOk => { /* batch acknowledged (Algorithm 2 line 18) */ }
            Message::TrainOver => return Ok(()),
            Message::Error { reason } => bail!("master reported error: {reason}"),
            other => bail!("unexpected message for worker: {}", other.tag()),
        }
    }
}

/// Paper §4.1.1: run the fixed probe convolution `rounds` times, report the
/// minimum (the steady-state rate — the first call may include executable
/// preparation time, which the warmup absorbs).
fn run_probe(rt: &Runtime, opts: &WorkerOptions, rounds: u32) -> Result<f64> {
    let p = &rt.arch().probe;
    let mut rng = crate::tensor::Pcg32::seed_stream(0xCA11B, opts.worker_id as u64);
    let x = Tensor::randn(&[p.batch, p.in_ch, p.img, p.img], &mut rng);
    let w = Tensor::randn(&[p.k, p.in_ch, p.kh, p.kw], &mut rng);
    let b = Tensor::zeros(&[p.k]);
    let args = [Value::F32(x), Value::F32(w), Value::F32(b)];
    rt.warmup(&["probe"])?;
    let _ = rt.execute("probe", &args)?; // absorb first-call effects
    let flops = rt.flops("probe");
    let throttle = opts.throttle.current(0);
    let mut best = f64::MAX;
    for _ in 0..rounds.max(1) {
        let (_, real) = rt.execute_timed("probe", &args)?;
        let padded = throttle.pad(real, flops);
        best = best.min(padded.as_secs_f64());
    }
    Ok(best)
}

/// Execute one shard of conv work (fwd or bwd) and build the reply.
/// Public so tests and custom worker harnesses can reuse the exact compute
/// path (e.g. the failure-injection worker).
#[allow(clippy::too_many_arguments)]
pub fn compute_conv_work(
    rt: &Runtime,
    throttle: Throttle,
    seq: u32,
    layer: u8,
    dir: u8,
    bucket: usize,
    inputs: WireTensor,
    kernels: WireTensor,
    extra: Option<WireTensor>,
) -> Result<Message> {
    let x = inputs.into_tensor()?;
    let w = kernels.into_tensor()?;
    let shard_len = w.shape()[0];
    // The wire carries the true shard (paper: comm volume scales with the
    // kernel count); padding up to the compiled bucket happens locally.
    let w_pad = w.pad_axis0(bucket)?;
    let dirv = match dir {
        0 => ConvDir::Fwd,
        1 => ConvDir::Bwd,
        d => bail!("bad conv dir {d}"),
    };
    // Serving scatters arrive at whatever batch rung the dynamic batcher
    // picked; those dispatch to the `_n{batch}` forward family.  The training
    // hot path (batch == arch.batch) keeps the exact legacy names.
    let exec = if dirv == ConvDir::Fwd && x.shape()[0] != rt.arch().batch {
        format!("conv{}_fwd_b{}_n{}", layer, bucket, x.shape()[0])
    } else {
        Manifest::conv_exec(layer as usize, dirv, bucket)
    };
    match dirv {
        ConvDir::Fwd => {
            let bias = extra.ok_or_else(|| anyhow::anyhow!("fwd ConvWork missing bias"))?.into_tensor()?;
            let b_pad = bias.pad_axis0(bucket)?;
            let args = [Value::F32(x), Value::F32(w_pad), Value::F32(b_pad)];
            let (outs, real) = rt.execute_timed(&exec, &args)?;
            let padded = throttle.pad(real, rt.flops(&exec));
            let y = outs.into_iter().next().unwrap();
            // Slice the zero-kernel padding back off before it hits the wire.
            let y = y.as_f32()?.slice_axis1(0, shard_len)?;
            Ok(Message::ConvResult {
                seq,
                outputs: vec![WireTensor::from(&y)],
                seconds: padded.as_secs_f64(),
            })
        }
        ConvDir::Bwd => {
            let gy = extra.ok_or_else(|| anyhow::anyhow!("bwd ConvWork missing gy"))?.into_tensor()?;
            // gy slice is [B, shard, H, W]; pad the channel axis to bucket.
            let gy_pad = pad_axis1(&gy, bucket)?;
            let args = [Value::F32(x), Value::F32(w_pad), Value::F32(gy_pad)];
            let (outs, real) = rt.execute_timed(&exec, &args)?;
            let padded = throttle.pad(real, rt.flops(&exec));
            let mut it = outs.into_iter();
            let gx = it.next().unwrap(); // full input cotangent (partial sum)
            let gw = it.next().unwrap().as_f32()?.slice_axis0(0, shard_len)?;
            let gb = it.next().unwrap().as_f32()?.slice_axis0(0, shard_len)?;
            Ok(Message::ConvResult {
                seq,
                outputs: vec![
                    WireTensor::from(gx.as_f32()?),
                    WireTensor::from(&gw),
                    WireTensor::from(&gb),
                ],
                seconds: padded.as_secs_f64(),
            })
        }
    }
}

/// Zero-pad axis 1 (feature-map channels) up to `n`.
pub(crate) fn pad_axis1(t: &Tensor, n: usize) -> Result<Tensor> {
    let shape = t.shape().to_vec();
    anyhow::ensure!(shape.len() >= 2, "pad_axis1 needs rank >= 2");
    if shape[1] == n {
        return Ok(t.clone());
    }
    anyhow::ensure!(n > shape[1], "pad_axis1 target {n} < {}", shape[1]);
    let mut padded_shape = shape.clone();
    padded_shape[1] = n;
    let inner: usize = shape[2..].iter().product();
    let mut out = Tensor::zeros(&padded_shape);
    let (b, k) = (shape[0], shape[1]);
    for bi in 0..b {
        let src = &t.data()[bi * k * inner..(bi + 1) * k * inner];
        let dst_base = bi * n * inner;
        out.data_mut()[dst_base..dst_base + k * inner].copy_from_slice(src);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    #[test]
    fn pad_axis1_roundtrip() {
        let mut rng = Pcg32::seed(3);
        let t = Tensor::randn(&[2, 3, 4, 4], &mut rng);
        let p = pad_axis1(&t, 5).unwrap();
        assert_eq!(p.shape(), &[2, 5, 4, 4]);
        assert_eq!(p.slice_axis1(0, 3).unwrap(), t);
        // Padding region is zero.
        let zeros = p.slice_axis1(3, 5).unwrap();
        assert!(zeros.data().iter().all(|&v| v == 0.0));
        // No-op when already at target.
        assert_eq!(pad_axis1(&t, 3).unwrap(), t);
    }

    #[test]
    fn fwd_work_below_the_training_batch_uses_the_serving_execs() {
        // Serving rungs: batch-4 arch with a [2, 4] ladder, so a batch-2
        // scatter must dispatch to `conv1_fwd_b4_n2` and produce exactly the
        // first two images of the batch-4 result.
        let mut arch = crate::runtime::ArchSpec::tiny();
        arch.batch = 4;
        arch.batch_buckets = vec![2, 4];
        let rt = Runtime::for_arch(arch);
        let mut rng = Pcg32::seed(11);
        let x4 = Tensor::randn(&[4, 3, 32, 32], &mut rng);
        let w = Tensor::randn(&[4, 3, 5, 5], &mut rng);
        let bias = Tensor::randn(&[4], &mut rng);
        let run = |x: &Tensor| {
            let msg = compute_conv_work(
                &rt,
                Throttle::none(),
                1,
                1,
                0,
                4,
                WireTensor::from(x),
                WireTensor::from(&w),
                Some(WireTensor::from(&bias)),
            )
            .unwrap();
            match msg {
                Message::ConvResult { outputs, .. } => {
                    outputs.into_iter().next().unwrap().into_tensor().unwrap()
                }
                other => panic!("unexpected reply {}", other.tag()),
            }
        };
        let y4 = run(&x4);
        let y2 = run(&x4.slice_axis0(0, 2).unwrap());
        assert_eq!(y2.shape()[0], 2);
        assert_eq!(y2, y4.slice_axis0(0, 2).unwrap());
    }
}
