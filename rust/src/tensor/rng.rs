//! PCG32: small, fast, deterministic PRNG — no external dependency so every
//! experiment is reproducible from a single u64 seed across platforms.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, n).
    pub fn next_below(&mut self, n: u32) -> u32 {
        // Lemire's method without the rejection loop is fine here: n is tiny
        // (class counts, device counts) relative to 2^32 so bias < 1e-7.
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-7 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = Pcg32::seed(1);
        let mut b = Pcg32::seed(1);
        assert_eq!(a.next_u32(), b.next_u32());
        let mut c = Pcg32::seed_stream(1, 99);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut rng = Pcg32::seed(3);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.next_f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "uniform mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seed(4);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "gaussian var {var}");
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = Pcg32::seed(5);
        for _ in 0..1000 {
            assert!(rng.next_below(10) < 10);
        }
    }
}
