//! Dense host tensors (f32 / i32) used on every boundary of the system:
//! PJRT literals, wire messages, parameter store and data pipeline.
//!
//! Layout is always row-major (C order) and, for activations/kernels, NCHW /
//! OIHW — the same convention the JAX segments were lowered with.

mod rng;

pub use rng::Pcg32;

use anyhow::{bail, ensure, Result};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    /// Uniform on [-a, a] — used by the Kaiming-style initializer.
    pub fn uniform(shape: &[usize], a: f32, rng: &mut Pcg32) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * a).collect();
        Self { shape: shape.to_vec(), data }
    }

    /// Standard-normal entries (Box–Muller) — synthetic data / probe inputs.
    pub fn randn(shape: &[usize], rng: &mut Pcg32) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.next_gaussian()).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> Result<f32> {
        ensure!(self.data.len() == 1, "item() on tensor of {} elements", self.data.len());
        Ok(self.data[0])
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == self.data.len(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        self.shape = shape;
        Ok(self)
    }

    /// Slice `[lo, hi)` along axis 0 (kernel shards: w[K,C,KH,KW] -> rows).
    pub fn slice_axis0(&self, lo: usize, hi: usize) -> Result<Self> {
        ensure!(!self.shape.is_empty(), "slice_axis0 on scalar");
        ensure!(lo <= hi && hi <= self.shape[0], "slice [{lo},{hi}) out of {}", self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(Self { shape, data: self.data[lo * row..hi * row].to_vec() })
    }

    /// Slice `[lo, hi)` along axis 1 (feature maps: y[B,K,H,W] -> channel range).
    pub fn slice_axis1(&self, lo: usize, hi: usize) -> Result<Self> {
        ensure!(self.shape.len() >= 2, "slice_axis1 needs rank >= 2");
        let (b, k) = (self.shape[0], self.shape[1]);
        ensure!(lo <= hi && hi <= k, "slice [{lo},{hi}) out of {k}");
        let inner: usize = self.shape[2..].iter().product();
        let mut shape = self.shape.clone();
        shape[1] = hi - lo;
        let mut data = Vec::with_capacity(b * (hi - lo) * inner);
        for bi in 0..b {
            let base = bi * k * inner;
            data.extend_from_slice(&self.data[base + lo * inner..base + hi * inner]);
        }
        Ok(Self { shape, data })
    }

    /// Concatenate along axis 1 — reassembling gathered feature-map shards
    /// `y_i[B,K_i,H,W]` into the full `y[B,K,H,W]` (Algorithm 1 line 20:
    /// "the master node reshapes and rearranges them").
    pub fn concat_axis1(parts: &[Tensor]) -> Result<Self> {
        ensure!(!parts.is_empty(), "concat of zero tensors");
        let first = &parts[0];
        ensure!(first.shape.len() >= 2, "concat_axis1 needs rank >= 2");
        let b = first.shape[0];
        let inner: usize = first.shape[2..].iter().product();
        let mut k_total = 0;
        for p in parts {
            ensure!(p.shape.len() == first.shape.len(), "rank mismatch in concat");
            ensure!(p.shape[0] == b, "batch mismatch in concat");
            ensure!(
                p.shape[2..] == first.shape[2..],
                "inner shape mismatch in concat: {:?} vs {:?}",
                p.shape,
                first.shape
            );
            k_total += p.shape[1];
        }
        let mut shape = first.shape.clone();
        shape[1] = k_total;
        let mut data = Vec::with_capacity(b * k_total * inner);
        for bi in 0..b {
            for p in parts {
                let k = p.shape[1];
                let base = bi * k * inner;
                data.extend_from_slice(&p.data[base..base + k * inner]);
            }
        }
        Ok(Self { shape, data })
    }

    /// Concatenate along axis 0 (stacking kernel shards back together).
    pub fn concat_axis0(parts: &[Tensor]) -> Result<Self> {
        ensure!(!parts.is_empty(), "concat of zero tensors");
        let first = &parts[0];
        let mut n_total = 0;
        for p in parts {
            ensure!(
                p.shape[1..] == first.shape[1..],
                "inner shape mismatch in concat: {:?} vs {:?}",
                p.shape,
                first.shape
            );
            n_total += p.shape[0];
        }
        let mut shape = first.shape.clone();
        shape[0] = n_total;
        let mut data = Vec::with_capacity(n_total * first.shape[1..].iter().product::<usize>());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Ok(Self { shape, data })
    }

    /// Zero-pad axis 0 up to `n` rows (bucket rounding of kernel shards).
    pub fn pad_axis0(&self, n: usize) -> Result<Self> {
        ensure!(!self.shape.is_empty(), "pad_axis0 on scalar");
        ensure!(n >= self.shape[0], "pad to {n} smaller than {}", self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        let mut data = self.data.clone();
        data.resize(n * row, 0.0);
        Ok(Self { shape, data })
    }

    /// Elementwise `self += other` (summing partial input-cotangents).
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        ensure!(self.shape == other.shape, "add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    /// Elementwise `self += s * other` (gradient averaging and SGD).
    pub fn axpy(&mut self, s: f32, other: &Tensor) -> Result<()> {
        ensure!(self.shape == other.shape, "axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Max |a - b| — the numeric-equivalence metric used by integration tests.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        ensure!(self.shape == other.shape, "diff shape mismatch {:?} vs {:?}", self.shape, other.shape);
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max))
    }

    pub fn l2norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// A dense row-major i32 tensor (labels).
#[derive(Clone, Debug, PartialEq)]
pub struct ITensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl ITensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Ok(Self { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn slice_axis0(&self, lo: usize, hi: usize) -> Result<Self> {
        ensure!(!self.shape.is_empty(), "slice_axis0 on scalar");
        ensure!(lo <= hi && hi <= self.shape[0], "slice [{lo},{hi}) out of {}", self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(Self { shape, data: self.data[lo * row..hi * row].to_vec() })
    }
}

/// Either tensor type — what an executable argument or wire payload holds.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(ITensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "f32",
            Value::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Value::F32(t) => t.size_bytes(),
            Value::I32(t) => t.len() * 4,
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<ITensor> for Value {
    fn from(t: ITensor) -> Self {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_concat_axis1_roundtrip() {
        let mut rng = Pcg32::seed(7);
        let t = Tensor::randn(&[2, 6, 3, 3], &mut rng);
        let a = t.slice_axis1(0, 2).unwrap();
        let b = t.slice_axis1(2, 5).unwrap();
        let c = t.slice_axis1(5, 6).unwrap();
        let back = Tensor::concat_axis1(&[a, b, c]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn slice_concat_axis0_roundtrip() {
        let mut rng = Pcg32::seed(8);
        let t = Tensor::randn(&[7, 4, 5, 5], &mut rng);
        let a = t.slice_axis0(0, 3).unwrap();
        let b = t.slice_axis0(3, 7).unwrap();
        let back = Tensor::concat_axis0(&[a, b]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn pad_axis0_zero_fills() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let p = t.pad_axis0(4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(&p.data()[4..], &[0.0; 4]);
        // And unpadding recovers the original.
        assert_eq!(p.slice_axis0(0, 2).unwrap(), t);
    }

    #[test]
    fn axpy_and_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let mut b = Tensor::zeros(&[3]);
        b.axpy(2.0, &a).unwrap();
        assert_eq!(b.data(), &[2.0, 4.0, 6.0]);
        assert!((b.max_abs_diff(&a).unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn shape_mismatch_errors() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.clone().add_assign(&b).is_err());
        assert!(Tensor::new(vec![2, 2], vec![0.0; 3]).is_err());
        assert!(a.slice_axis1(1, 3).is_err());
    }

    #[test]
    fn rng_is_deterministic() {
        let mut r1 = Pcg32::seed(42);
        let mut r2 = Pcg32::seed(42);
        let a = Tensor::randn(&[16], &mut r1);
        let b = Tensor::randn(&[16], &mut r2);
        assert_eq!(a, b);
    }
}
