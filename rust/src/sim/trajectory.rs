//! What-if simulation of the adaptive scheduler.
//!
//! Runs the *same* [`AdaptivePolicy`] the live master runs — telemetry fed
//! from the analytic device model instead of wall clocks — over a scripted
//! mid-run degradation, and reports three step-time trajectories:
//!
//! * **static**   — the paper's behavior: the partition computed at
//!   calibration time is kept forever;
//! * **adaptive** — the policy re-shards when the predicted payoff clears
//!   its threshold (telemetry EWMA, hysteresis, cooldown — all live code);
//! * **oracle**   — a fresh Eq. 1 partition from the *true* instantaneous
//!   rates every step: the best any re-partitioner could do.
//!
//! This is how the system predicts the payoff of adaptation before doing it
//! live, how `BENCH_sched.json` is produced in CI, and how the policy's
//! convergence is regression-tested without spending wall-clock sleeps.

use anyhow::{ensure, Result};

use crate::runtime::bucket_ladder;
use crate::sched::{
    partition_layer, predicted_cost, AdaptiveConfig, AdaptivePolicy, Decision, FleetTelemetry,
    LayerPlan, Shard,
};

use super::ArchShape;

/// A scripted degradation scenario.
#[derive(Clone, Debug)]
pub struct TrajectorySpec {
    pub arch: ArchShape,
    /// Device GFLOPS, master first (index 0).
    pub gflops: Vec<f64>,
    /// Which device degrades…
    pub degrade_device: usize,
    /// …at which step…
    pub degrade_at_step: usize,
    /// …dividing its speed by this factor (8.0 = the ISSUE scenario).
    pub degrade_factor: f64,
    pub steps: usize,
    pub policy: AdaptiveConfig,
}

impl TrajectorySpec {
    /// The CI benchmark scenario: an equal 4-device fleet, device 1
    /// degrading 8x a quarter of the way in.
    pub fn ci_default() -> Self {
        Self {
            arch: ArchShape::new(300, 1000, 256),
            gflops: vec![30.0, 30.0, 30.0, 30.0],
            degrade_device: 1,
            degrade_at_step: 10,
            degrade_factor: 8.0,
            steps: 60,
            policy: AdaptiveConfig::default(),
        }
    }
}

/// One simulated step of all three schedulers.
#[derive(Clone, Copy, Debug)]
pub struct TrajectoryPoint {
    pub step: usize,
    pub static_secs: f64,
    pub adaptive_secs: f64,
    pub oracle_secs: f64,
    /// The adaptive policy re-sharded *after* this step.
    pub repartitioned: bool,
}

/// Simulate the scenario; returns one point per step.
pub fn simulate_adaptive(spec: &TrajectorySpec) -> Result<Vec<TrajectoryPoint>> {
    let n = spec.gflops.len();
    ensure!(n >= 2, "need at least 2 devices");
    ensure!(spec.degrade_device < n, "degrade_device out of range");
    ensure!(spec.degrade_factor >= 1.0, "degrade_factor must be >= 1");
    let arch = spec.arch;
    let buckets1 = bucket_ladder(arch.k1);
    let buckets2 = bucket_ladder(arch.k2);
    // Per-kernel training FLOPs of each layer (fwd + both grads).
    let fpk = [
        arch.flops_per_kernel_fwd(1) * ArchShape::TRAIN_CONV_FACTOR,
        arch.flops_per_kernel_fwd(2) * ArchShape::TRAIN_CONV_FACTOR,
    ];

    // True seconds-per-FLOP of every device at a given step.
    let rates_at = |step: usize| -> Vec<f64> {
        spec.gflops
            .iter()
            .enumerate()
            .map(|(i, &g)| {
                let g = if i == spec.degrade_device && step >= spec.degrade_at_step {
                    g / spec.degrade_factor
                } else {
                    g
                };
                1.0 / (g * 1e9)
            })
            .collect()
    };
    let table = |rates: &[f64]| -> Result<[Vec<Shard>; 2]> {
        Ok([
            partition_layer(arch.k1, rates, &buckets1)?,
            partition_layer(arch.k2, rates, &buckets2)?,
        ])
    };
    // Step conv time of a table pair — priced by the SAME model the live
    // policy uses (`sched::predicted_cost`), so the simulated trajectories
    // cannot drift from what the master would actually decide on.
    let cost = |t: &[Vec<Shard>; 2], rates: &[f64]| -> f64 {
        let plans = [
            LayerPlan {
                k: arch.k1,
                buckets: &buckets1,
                current: &t[0],
                flops_per_kernel: fpk[0],
            },
            LayerPlan {
                k: arch.k2,
                buckets: &buckets2,
                current: &t[1],
                flops_per_kernel: fpk[1],
            },
        ];
        predicted_cost(&[t[0].as_slice(), t[1].as_slice()], &plans, rates)
    };

    let r0 = rates_at(0);
    let static_table = table(&r0)?;
    let mut adaptive_table = static_table.clone();
    let mut policy = AdaptivePolicy::new(spec.policy);
    let mut telem = FleetTelemetry::new(n, spec.policy.alpha);
    // Calibration analog: seed every device's rate from the initial probe.
    for (d, &r) in r0.iter().enumerate() {
        telem.record(d, r * 1e9, 1e9);
    }
    let active: Vec<usize> = (0..n).collect();

    let mut out = Vec::with_capacity(spec.steps);
    for step in 0..spec.steps {
        let rates = rates_at(step);
        let static_secs = cost(&static_table, &rates);
        let adaptive_secs = cost(&adaptive_table, &rates);
        let oracle_secs = cost(&table(&rates)?, &rates);
        // The master's gather loop, analytically: every device that ran a
        // shard reports its bucketed seconds over the bucket's FLOPs.
        for (li, shards) in adaptive_table.iter().enumerate() {
            for s in shards {
                let flops = s.bucket as f64 * fpk[li];
                telem.record(s.device, flops * rates[s.device], flops);
            }
        }
        let mut repartitioned = false;
        if let Some(obs) = telem.rates_for(&active, 1) {
            let decision = {
                let plans = [
                    LayerPlan {
                        k: arch.k1,
                        buckets: &buckets1,
                        current: &adaptive_table[0],
                        flops_per_kernel: fpk[0],
                    },
                    LayerPlan {
                        k: arch.k2,
                        buckets: &buckets2,
                        current: &adaptive_table[1],
                        flops_per_kernel: fpk[1],
                    },
                ];
                policy.decide(step as u64, &plans, &active, &obs)?
            };
            if let Decision::Repartition(mut tables) = decision {
                adaptive_table[1] = tables.pop().unwrap();
                adaptive_table[0] = tables.pop().unwrap();
                repartitioned = true;
            }
        }
        out.push(TrajectoryPoint { step, static_secs, adaptive_secs, oracle_secs, repartitioned });
    }
    Ok(out)
}

/// Tail means over the last `k` points: `(static, adaptive, oracle)`.
pub fn tail_means(points: &[TrajectoryPoint], k: usize) -> (f64, f64, f64) {
    let tail = &points[points.len().saturating_sub(k)..];
    let n = tail.len().max(1) as f64;
    (
        tail.iter().map(|p| p.static_secs).sum::<f64>() / n,
        tail.iter().map(|p| p.adaptive_secs).sum::<f64>() / n,
        tail.iter().map(|p| p.oracle_secs).sum::<f64>() / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_degradation_means_no_repartition() {
        let spec = TrajectorySpec {
            degrade_factor: 1.0,
            steps: 20,
            ..TrajectorySpec::ci_default()
        };
        let pts = simulate_adaptive(&spec).unwrap();
        assert_eq!(pts.len(), 20);
        for p in &pts {
            assert!(!p.repartitioned, "step {}: spurious re-shard", p.step);
            assert!((p.adaptive_secs - p.static_secs).abs() < 1e-12);
        }
    }

    #[test]
    fn adaptive_recovers_most_of_oracle_speedup_after_8x_degradation() {
        let spec = TrajectorySpec::ci_default();
        let pts = simulate_adaptive(&spec).unwrap();
        // Before the event all three schedulers agree.
        let p0 = &pts[0];
        assert!((p0.adaptive_secs - p0.oracle_secs).abs() < 1e-12);
        // The re-shard happens within warmup + cooldown of the event.
        let window = spec.policy.warmup_steps + spec.policy.cooldown_steps + 1;
        let when = pts.iter().find(|p| p.repartitioned).expect("policy never re-sharded").step;
        assert!(
            when >= spec.degrade_at_step && (when - spec.degrade_at_step) as u64 <= window,
            "re-shard at {when}, degradation at {}",
            spec.degrade_at_step
        );
        // Steady state: adaptive within 10% of the oracle, static far worse.
        let (s, a, o) = tail_means(&pts, 10);
        assert!(a <= o * 1.10, "adaptive tail {a} vs oracle {o}");
        assert!(s >= a * 1.3, "static tail {s} should trail adaptive {a} by >= 1.3x");
    }

    #[test]
    fn oracle_lower_bounds_both() {
        let pts = simulate_adaptive(&TrajectorySpec::ci_default()).unwrap();
        for p in &pts {
            assert!(p.oracle_secs <= p.static_secs + 1e-12, "step {}", p.step);
            assert!(p.oracle_secs <= p.adaptive_secs + 1e-12, "step {}", p.step);
        }
    }
}
