//! Generators for every table and figure of the paper's evaluation section.
//!
//! Each generator returns a [`Figure`] — headers + rows — that the CLI
//! (`convdist figures`), the criterion benches and EXPERIMENTS.md all share.
//! Where the paper prints a number we also print it (`paper` column), so the
//! reproduction can be judged row by row.

use crate::baselines::dp_sim_step_time;
use crate::devices::{
    highend_cpus, highend_gpus, mobile_gpu, paper_cpus, paper_gpus, sample_cluster, DeviceProfile,
};
use crate::tensor::Pcg32;

use super::{simulate_step, speedup, ArchShape, SimConfig};

/// One reproduced table/figure, ready to render.
#[derive(Clone, Debug)]
pub struct Figure {
    pub id: &'static str,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: String,
}

impl Figure {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        if !self.notes.is_empty() {
            out.push_str(&format!("note: {}\n", self.notes));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

const BATCHES: [usize; 5] = [64, 128, 256, 512, 1024];

fn f2(x: f64) -> String {
    format!("{x:.2}")
}

fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Paper Table 1: TensorFlow multi-GPU CIFAR-10 step times (the paper's
/// data-parallel comparison anchor), reproduced with our data-parallel
/// model on K20m-class devices sharing one machine.
pub fn table1() -> Figure {
    // TF's cifar10 model: 2 conv layers of 64 kernels, batch 128.
    let arch = ArchShape::new(64, 64, 128);
    let paper = ["0.35-0.60", "0.13-0.20", "0.13-0.18", "~0.10"];
    let mut rows = Vec::new();
    let t1 = dp_sim_step_time(&arch, 1);
    for n in 1..=4usize {
        let t = dp_sim_step_time(&arch, n);
        rows.push(vec![
            format!("{n} Tesla K20M"),
            f3(t),
            f2(t1 / t),
            paper[n - 1].to_string(),
        ]);
    }
    Figure {
        id: "table1",
        title: "Data-parallel multi-GPU step time (TensorFlow anchor)".into(),
        headers: vec!["system".into(), "step s/batch".into(), "speedup".into(), "paper s/batch".into()],
        rows,
        notes: "shape to reproduce: large gain 1→2 GPUs, then flattening for 3-4 \
                (paper: 'it doesn't seem to be scalable'); absolute TF times include \
                input-pipeline overheads we do not model"
            .into(),
    }
}

/// Figure 5: CPU-cluster speedup, 4 archs x 5 batch sizes x 1-4 CPUs.
pub fn fig5() -> Figure {
    let mut rows = Vec::new();
    for arch in ArchShape::paper_archs(0) {
        for &batch in &BATCHES {
            let a = ArchShape { batch, ..arch };
            let cfg = SimConfig::paper(a);
            let mut row = vec![a.label(), batch.to_string()];
            for n in 2..=4usize {
                row.push(f2(speedup(&cfg, &paper_cpus()[..n])));
            }
            rows.push(row);
        }
    }
    Figure {
        id: "fig5",
        title: "CPU cluster speedup vs #CPUs (1-4), per arch and batch".into(),
        headers: vec!["arch".into(), "batch".into(), "2 cpus".into(), "3 cpus".into(), "4 cpus".into()],
        rows,
        notes: "paper anchors: smallest net ≈1.3/1.5/>1.5x; largest net up to 3.28x at 4 CPUs"
            .into(),
    }
}

/// Figure 6: elapsed-time breakdown (Comm/Conv/Comp), batch 1024, CPUs 1-4.
pub fn fig6() -> Figure {
    breakdown_figure(
        "fig6",
        "CPU elapsed time per 1024-image batch: Comm/Conv/Comp",
        &paper_cpus(),
        20.0,
        4,
        "paper: comp share of 1-CPU time falls 25%→13% from smallest to largest net; \
         largest net speedups 1.98/2.73/3.28x for 2/3/4 CPUs",
    )
}

/// Figure 7: GPU-cluster speedup, 4 archs x 5 batch sizes x 1-3 GPUs.
pub fn fig7() -> Figure {
    let mut rows = Vec::new();
    for arch in ArchShape::paper_archs(0) {
        for &batch in &BATCHES {
            let a = ArchShape { batch, ..arch };
            let mut cfg = SimConfig::paper(a);
            cfg.master_cpu_gflops = 38.0; // PC2 hosts the GPU master
            let mut row = vec![a.label(), batch.to_string()];
            for n in 2..=3usize {
                row.push(f2(speedup(&cfg, &paper_gpus()[..n])));
            }
            rows.push(row);
        }
    }
    Figure {
        id: "fig7",
        title: "GPU cluster speedup vs #GPUs (1-3), per arch and batch".into(),
        headers: vec!["arch".into(), "batch".into(), "2 gpus".into(), "3 gpus".into()],
        rows,
        notes: "paper reports speedups *decreasing* with net size (2.45x smallest → 2.0x \
                largest at 3 GPUs); under wire-exact Eq. 2 accounting the trend reverses — \
                small nets lose to activation-shipping cost.  Documented deviation \
                (EXPERIMENTS.md §Deviations): the paper's trend requires activation \
                transfer to be free."
            .into(),
    }
}

/// Figure 8: GPU breakdown, batch 1024, GPUs 1-3.
pub fn fig8() -> Figure {
    breakdown_figure(
        "fig8",
        "GPU elapsed time per 1024-image batch: Comm/Conv/Comp",
        &paper_gpus(),
        38.0,
        3,
        "paper: with 3 GPUs communication ≈30% of step time and comm+comp dominate",
    )
}

fn breakdown_figure(
    id: &'static str,
    title: &str,
    devices: &[DeviceProfile],
    master_cpu: f64,
    max_n: usize,
    notes: &str,
) -> Figure {
    let mut rows = Vec::new();
    for arch in ArchShape::paper_archs(1024) {
        let mut cfg = SimConfig::paper(arch);
        cfg.master_cpu_gflops = master_cpu;
        let t1 = simulate_step(&cfg, &devices[..1]).total().as_secs_f64();
        for n in 1..=max_n {
            let b = simulate_step(&cfg, &devices[..n]);
            let (pc, pv, pp) = b.percentages();
            rows.push(vec![
                arch.label(),
                n.to_string(),
                f3(b.comm.as_secs_f64()),
                f3(b.conv.as_secs_f64()),
                f3(b.comp.as_secs_f64()),
                f3(b.total().as_secs_f64()),
                format!("{pc:.0}/{pv:.0}/{pp:.0}"),
                f2(t1 / b.total().as_secs_f64()),
            ]);
        }
    }
    Figure {
        id,
        title: title.into(),
        headers: vec![
            "arch".into(),
            "devices".into(),
            "comm s".into(),
            "conv s".into(),
            "comp s".into(),
            "total s".into(),
            "% c/v/p".into(),
            "speedup".into(),
        ],
        rows,
        notes: notes.into(),
    }
}

/// Table 4: best CPU speedups per arch x device count (max over batches).
pub fn table4() -> Figure {
    let paper: [[f64; 3]; 4] =
        [[1.40, 1.51, 1.56], [1.68, 1.93, 2.10], [1.69, 2.15, 2.33], [1.98, 2.74, 3.28]];
    best_speedup_table("table4", "Best CPU speedups (max over batch sizes)", &paper_cpus(), 20.0, &[2, 3, 4], &paper)
}

/// Table 5: best GPU speedups per arch x device count.
pub fn table5() -> Figure {
    let paper: [[f64; 3]; 4] =
        [[1.96, 2.45, 0.0], [1.89, 2.23, 0.0], [1.78, 2.09, 0.0], [1.66, 2.00, 0.0]];
    best_speedup_table("table5", "Best GPU speedups (max over batch sizes)", &paper_gpus(), 38.0, &[2, 3], &paper)
}

fn best_speedup_table(
    id: &'static str,
    title: &str,
    devices: &[DeviceProfile],
    master_cpu: f64,
    counts: &[usize],
    paper: &[[f64; 3]; 4],
) -> Figure {
    let mut rows = Vec::new();
    for (ai, arch) in ArchShape::paper_archs(0).into_iter().enumerate() {
        let mut row = vec![arch.label()];
        for (ci, &n) in counts.iter().enumerate() {
            let best = BATCHES
                .iter()
                .map(|&batch| {
                    let a = ArchShape { batch, ..arch };
                    let mut cfg = SimConfig::paper(a);
                    cfg.master_cpu_gflops = master_cpu;
                    speedup(&cfg, &devices[..n])
                })
                .fold(0.0, f64::max);
            row.push(f2(best));
            row.push(if paper[ai][ci] > 0.0 { f2(paper[ai][ci]) } else { "-".into() });
        }
        rows.push(row);
    }
    let mut headers = vec!["arch".into()];
    for &n in counts {
        headers.push(format!("{n} dev"));
        headers.push(format!("paper {n}"));
    }
    Figure { id, title: title.into(), headers, rows, notes: String::new() }
}

/// Figure 9: CPU scalability to 32 nodes (smallest net @ 64 and largest @
/// 1024), Gaussian-sampled node speeds — the paper's §5.3.4 simulation.
pub fn fig9() -> Figure {
    let mut rows = Vec::new();
    let cases =
        [(ArchShape::new(50, 500, 64), "small@64"), (ArchShape::new(500, 1500, 1024), "large@1024")];
    for (arch, label) in cases {
        let cfg = SimConfig::paper(arch);
        let mut rng = Pcg32::seed(0xF19);
        let cluster = sample_cluster(&paper_cpus(), 32, &mut rng);
        let t1 = simulate_step(&cfg, &cluster[..1]).total().as_secs_f64();
        for n in [1usize, 2, 4, 8, 16, 24, 32] {
            let b = simulate_step(&cfg, &cluster[..n]);
            rows.push(vec![
                label.to_string(),
                n.to_string(),
                f3(b.comm.as_secs_f64()),
                f3(b.conv.as_secs_f64()),
                f3(b.comp.as_secs_f64()),
                f3(b.total().as_secs_f64()),
                f2(t1 / b.total().as_secs_f64()),
            ]);
        }
    }
    Figure {
        id: "fig9",
        title: "CPU cluster scalability, 1-32 nodes (simulated per §5.3.4)".into(),
        headers: vec![
            "case".into(),
            "nodes".into(),
            "comm s".into(),
            "conv s".into(),
            "comp s".into(),
            "total s".into(),
            "speedup".into(),
        ],
        rows,
        notes: "paper: little benefit past 4 CPUs, speedup stabilizes after ~8 nodes; \
                conv bottleneck with 1 CPU flips to comm+comp with many"
            .into(),
    }
}

/// Figure 10: GPU scalability to 32 nodes, largest net @ 1024.
pub fn fig10() -> Figure {
    let arch = ArchShape::new(500, 1500, 1024);
    let mut cfg = SimConfig::paper(arch);
    cfg.master_cpu_gflops = 38.0;
    let mut rng = Pcg32::seed(0xF10);
    let cluster = sample_cluster(&paper_gpus(), 32, &mut rng);
    let t1 = simulate_step(&cfg, &cluster[..1]).total().as_secs_f64();
    let mut rows = Vec::new();
    for n in [1usize, 2, 4, 8, 16, 24, 32] {
        let b = simulate_step(&cfg, &cluster[..n]);
        rows.push(vec![
            n.to_string(),
            f3(b.comm.as_secs_f64()),
            f3(b.conv.as_secs_f64()),
            f3(b.comp.as_secs_f64()),
            f3(b.total().as_secs_f64()),
            f2(t1 / b.total().as_secs_f64()),
        ]);
    }
    Figure {
        id: "fig10",
        title: "GPU cluster scalability, 1-32 nodes, 500:1500 @ 1024".into(),
        headers: vec![
            "nodes".into(),
            "comm s".into(),
            "conv s".into(),
            "comp s".into(),
            "total s".into(),
            "speedup".into(),
        ],
        rows,
        notes: "paper: speedup virtually stagnates for ≥8 nodes; comm+comp dominate \
                because GPU convs are cheap"
            .into(),
    }
}

/// Figures 11/12: speedup vs (bandwidth, nodes) for low/mid vs high-end
/// device catalogs.
fn bandwidth_sweep(
    id: &'static str,
    title: &str,
    lowmid: Vec<DeviceProfile>,
    highend: Vec<DeviceProfile>,
    master_cpu_low: f64,
    master_cpu_high: f64,
) -> Figure {
    let arch = ArchShape::new(500, 1500, 1024);
    let mut rows = Vec::new();
    for (catalog, label, mc) in
        [(lowmid, "low/mid", master_cpu_low), (highend, "high-end", master_cpu_high)]
    {
        let mut rng = Pcg32::seed(0xF11);
        let cluster = sample_cluster(&catalog, 32, &mut rng);
        for bw in [25.0, 100.0, 250.0, 675.0, 5000.0] {
            let mut cfg = SimConfig::paper(arch);
            cfg.bandwidth_mbps = bw;
            cfg.master_cpu_gflops = mc;
            let mut row = vec![label.to_string(), format!("{bw}")];
            for n in [2usize, 4, 8, 16, 32] {
                row.push(f2(speedup(&cfg, &cluster[..n])));
            }
            rows.push(row);
        }
    }
    Figure {
        id,
        title: title.into(),
        headers: vec![
            "devices".into(),
            "Mbps".into(),
            "n=2".into(),
            "n=4".into(),
            "n=8".into(),
            "n=16".into(),
            "n=32".into(),
        ],
        rows,
        notes: "paper: low-end vs high-end peak speedups are nearly identical — comm and \
                comp are the bottleneck; bandwidth moves the ceiling, device class only \
                moves how few nodes reach it (and slow links can push GPU speedup below 1x)"
            .into(),
    }
}

pub fn fig11() -> Figure {
    bandwidth_sweep(
        "fig11",
        "CPU speedup vs bandwidth and nodes, low/mid vs high-end",
        paper_cpus(),
        highend_cpus(),
        20.0,
        150.0,
    )
}

pub fn fig12() -> Figure {
    bandwidth_sweep(
        "fig12",
        "GPU speedup vs bandwidth and nodes, low/mid vs high-end",
        paper_gpus(),
        highend_gpus(),
        38.0,
        60.0,
    )
}

/// Figure 13: mobile-GPU cluster (desktop master), 32 and 128 nodes.
pub fn fig13() -> Figure {
    let arch = ArchShape::new(500, 1500, 1024);
    let mut rows = Vec::new();
    for max_n in [32usize, 128] {
        let mut cluster = vec![paper_gpus()[0].clone()]; // desktop master (§5.4.1)
        cluster.extend(std::iter::repeat(mobile_gpu()).take(max_n - 1));
        for bw in [25.0, 100.0, 250.0, 675.0, 5000.0] {
            let mut cfg = SimConfig::paper(arch);
            cfg.bandwidth_mbps = bw;
            cfg.master_cpu_gflops = 38.0;
            let mut row = vec![max_n.to_string(), format!("{bw}")];
            for n in [2usize, 8, 32, 128] {
                if n > max_n {
                    row.push("-".into());
                } else {
                    row.push(f2(speedup(&cfg, &cluster[..n])));
                }
            }
            rows.push(row);
        }
    }
    Figure {
        id: "fig13",
        title: "Mobile-GPU cluster speedup (desktop master), 32 and 128 nodes".into(),
        headers: vec![
            "cluster".into(),
            "Mbps".into(),
            "n=2".into(),
            "n=8".into(),
            "n=32".into(),
            "n=128".into(),
        ],
        rows,
        notes: "paper: 32 mobile GPUs cannot match desktop-cluster speedups; 128 can, \
                given bandwidth — mobile parts are ~10x slower but far more numerous"
            .into(),
    }
}

/// §5.3.1/§5.4 anchors: Amdahl ceiling + zero-comm speedup.
pub fn amdahl() -> Figure {
    let mut rows = Vec::new();
    for arch in ArchShape::paper_archs(1024) {
        let share = super::comp_share(&arch);
        let ceiling = 1.0 / share;
        let mut cfg = SimConfig::paper(arch);
        cfg.bandwidth_mbps = 1e9; // communication-free limit
        let mut rng = Pcg32::seed(0xA3DA);
        let cluster = sample_cluster(&paper_cpus(), 64, &mut rng);
        let s = speedup(&cfg, &cluster);
        rows.push(vec![arch.label(), format!("{:.0}%", share * 100.0), f2(ceiling), f2(s)]);
    }
    Figure {
        id: "amdahl",
        title: "Amdahl ceiling vs comm-free 64-node speedup".into(),
        headers: vec!["arch".into(), "comp share".into(), "ceiling".into(), "64-node s".into()],
        rows,
        notes: "paper §5.3.1: largest net comp=13% ⇒ max ≈7.76x; §5.3.4 quotes ≈4.3x \
                for zero comm at moderate node counts"
            .into(),
    }
}

/// All figures in paper order.
pub fn all() -> Vec<Figure> {
    vec![
        table1(),
        fig5(),
        fig6(),
        fig7(),
        fig8(),
        table4(),
        table5(),
        fig9(),
        fig10(),
        fig11(),
        fig12(),
        fig13(),
        amdahl(),
    ]
}

/// Lookup by id.
pub fn generate(id: &str) -> Option<Figure> {
    all().into_iter().find(|f| f.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_generate_nonempty() {
        for f in all() {
            assert!(!f.rows.is_empty(), "{} has no rows", f.id);
            for row in &f.rows {
                assert_eq!(row.len(), f.headers.len(), "{} row width", f.id);
            }
            assert!(f.render().contains(f.id));
            assert!(f.to_csv().lines().count() == f.rows.len() + 1);
        }
    }

    #[test]
    fn table4_monotonic_in_devices_for_largest_net() {
        let t4 = table4();
        // Last row = 500:1500; ours columns are 1,3,5.
        let row = t4.rows.last().unwrap();
        let s2: f64 = row[1].parse().unwrap();
        let s3: f64 = row[3].parse().unwrap();
        let s4: f64 = row[5].parse().unwrap();
        assert!(s2 < s3 && s3 < s4, "CPU speedup must grow with devices: {s2} {s3} {s4}");
        // Headline: within ~35% of the paper's 3.28x.
        assert!((2.1..=4.5).contains(&s4), "4-CPU largest-net speedup {s4}");
    }

    #[test]
    fn table5_gpu_speedups_below_cpu_and_small_net_unprofitable() {
        // DEVIATION (documented in EXPERIMENTS.md): the paper reports GPU
        // speedups *decreasing* with net size (2.45x smallest), which is
        // only possible if shipping activations were free.  Under
        // wire-exact Eq. 2 accounting the small net cannot profit from GPU
        // distribution at all, and the large net profits less on GPUs than
        // on CPUs (that part matches the paper).
        let t5 = table5();
        let small3: f64 = t5.rows[0][3].parse().unwrap(); // 3 GPUs, smallest
        let large3: f64 = t5.rows[3][3].parse().unwrap(); // 3 GPUs, largest
        assert!(small3 < 1.2, "small-net GPU distribution cannot win under Eq.2: {small3}");
        assert!(large3 > 1.0, "large-net GPU distribution must still win: {large3}");
        let t4 = table4();
        let cpu_large4: f64 = t4.rows[3][5].parse().unwrap();
        assert!(cpu_large4 > large3, "Table 4 vs 5: CPUs outspeed GPUs on the largest net");
    }

    #[test]
    fn fig9_saturates() {
        let f = fig9();
        // large@1024 rows: speedup at 32 nodes should be < 2x speedup at 8.
        let rows: Vec<_> = f.rows.iter().filter(|r| r[0] == "large@1024").collect();
        let s8: f64 = rows.iter().find(|r| r[1] == "8").unwrap()[6].parse().unwrap();
        let s32: f64 = rows.iter().find(|r| r[1] == "32").unwrap()[6].parse().unwrap();
        assert!(s32 < s8 * 1.6, "speedup should stabilize after ~8 nodes: {s8} -> {s32}");
        // Wire-exact comm grows ~linearly with node count (inputs are
        // broadcast per slave), so past the optimum the speedup *declines*
        // rather than stagnating as in the paper's coarser model.
        assert!(s32 >= s8 * 0.4, "decline past the optimum should be gradual: {s8} -> {s32}");
    }
}
