//! Analytic performance simulator — the paper's own scalability methodology
//! (§5.3.4), implemented as a first-class system.
//!
//! The paper extrapolates beyond its 4-laptop testbed with a model built
//! from (a) the Eq. 2 upload volume, (b) the measured ~5 Mbps bandwidth and
//! (c) per-device performance values sampled between the worst and best
//! measured devices.  This module is that model:
//!
//! * conv time  — Eq. 1 integer partition via [`crate::sched::apportion`],
//!   the layer finishes with its slowest shard;
//! * comm time  — the wire volume our *actual protocol* moves (Eq. 2 plus
//!   the backward-pass tensors the paper's formula leaves implicit), pushed
//!   through the master's single link;
//! * comp time  — the non-conv layers, which stay on the master.  The comp
//!   share is a property of the authors' Matlab stack (25 % of a 1-CPU step
//!   on the smallest net, 13 % on the largest — Fig. 6); we calibrate a
//!   per-arch ratio to those reported numbers and document it (DESIGN.md §2).
//!
//! Real throttled cluster runs cross-validate the model at small scale
//! (`rust/tests/sim_validation.rs`).

pub mod figures;
pub mod trajectory;

use std::time::Duration;

use crate::devices::DeviceProfile;
use crate::metrics::Breakdown;
use crate::sched::{apportion, workload_shares};

/// Architecture geometry for simulation — independent of compiled artifacts
/// so paper-scale networks (500:1500 @ batch 1024) can be modeled without
/// compiling them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArchShape {
    pub k1: usize,
    pub k2: usize,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub kh: usize,
    pub kw: usize,
}

impl ArchShape {
    pub fn new(k1: usize, k2: usize, batch: usize) -> Self {
        Self { k1, k2, batch, img: 32, in_ch: 3, kh: 5, kw: 5 }
    }

    /// The four architectures of §5.2, smallest to largest.
    pub fn paper_archs(batch: usize) -> [ArchShape; 4] {
        [
            Self::new(50, 500, batch),
            Self::new(150, 800, batch),
            Self::new(300, 1000, batch),
            Self::new(500, 1500, batch),
        ]
    }

    pub fn label(&self) -> String {
        format!("{}:{}", self.k1, self.k2)
    }

    // Spatial chain 32 -> 28 -> 14 -> 10 -> 5 (valid 5x5 conv, /2 pool).
    pub fn c1_out(&self) -> usize {
        self.img - self.kh + 1
    }

    pub fn p1_out(&self) -> usize {
        self.c1_out() / 2
    }

    pub fn c2_out(&self) -> usize {
        self.p1_out() - self.kh + 1
    }

    pub fn p2_out(&self) -> usize {
        self.c2_out() / 2
    }

    /// Geometry of conv layer `l`: (in_ch, in_hw, out_hw, kernels).
    pub fn layer(&self, l: usize) -> (usize, usize, usize, usize) {
        match l {
            1 => (self.in_ch, self.img, self.c1_out(), self.k1),
            2 => (self.k1, self.p1_out(), self.c2_out(), self.k2),
            _ => panic!("layer {l}"),
        }
    }

    /// FLOPs of one *kernel* of conv layer `l`, forward pass
    /// (2·B·OH²·C·KH·KW — one multiply-add per tap per output pixel).
    pub fn flops_per_kernel_fwd(&self, l: usize) -> f64 {
        let (c, _, oh, _) = self.layer(l);
        2.0 * self.batch as f64 * (oh * oh) as f64 * c as f64 * (self.kh * self.kw) as f64
    }

    /// Training multiplies conv cost ~3x: forward + weight-grad + input-grad
    /// are each a convolution of the same volume.
    pub const TRAIN_CONV_FACTOR: f64 = 3.0;

    pub fn conv_flops_fwd(&self) -> f64 {
        (1..=2).map(|l| self.flops_per_kernel_fwd(l) * self.layer(l).3 as f64).sum()
    }

    pub fn conv_flops_train(&self) -> f64 {
        self.conv_flops_fwd() * Self::TRAIN_CONV_FACTOR
    }

    /// Eq. 2, verbatim: elements exchanged for the *forward* distribution of
    /// both conv layers (inputs broadcast + kernels out + maps back).
    /// `n_slaves` is the number of slave nodes and `slave_share` the summed
    /// Eq. 1 share of the slaves (the master's own shard never leaves it).
    pub fn eq2_upload_elements(&self, n_slaves: usize, slave_share: f64) -> f64 {
        let mut total = 0.0;
        for l in 1..=2 {
            let (in_ch, in_hw, out_hw, num_k) = self.layer(l);
            let inputs = (in_hw * in_hw * in_ch * self.batch) as f64 * n_slaves as f64;
            let kernels = (self.kh * self.kw * num_k * in_ch) as f64 * slave_share;
            let outputs = (out_hw * out_hw * num_k * self.batch) as f64 * slave_share;
            total += inputs + kernels + outputs;
        }
        total
    }

    /// Elements the backward pass moves (our protocol, mirrored by
    /// `cluster::master::dist_conv_bwd`): gy slices + kernel resend out;
    /// gx partials + gw + gb back.  Eq. 2 leaves these implicit; the real
    /// wire moves them, so the model counts them.
    pub fn bwd_upload_elements(&self, n_slaves: usize, slave_share: f64) -> f64 {
        let mut total = 0.0;
        for l in 1..=2 {
            let (in_ch, in_hw, out_hw, num_k) = self.layer(l);
            let gy = (out_hw * out_hw * num_k * self.batch) as f64 * slave_share;
            let kernels = 2.0 * (self.kh * self.kw * num_k * in_ch) as f64 * slave_share; // out + gw back
            let gx = (in_hw * in_hw * in_ch * self.batch) as f64 * n_slaves as f64;
            total += gy + kernels + gx;
        }
        total
    }
}

/// Comp-share calibration: fraction of a 1-CPU training step spent on
/// non-conv layers, per §5.3.1 ("going from 25% with the smallest network to
/// 13% when training the largest one").  Interpolated in log(conv FLOPs).
pub fn comp_share(arch: &ArchShape) -> f64 {
    // Anchor at batch 1024, like the paper's four archs.
    let probe = ArchShape { batch: 1024, ..*arch };
    comp_share_for_train_flops(probe.conv_flops_train())
}

/// [`comp_share`] keyed directly by training conv FLOPs at batch 1024 —
/// the graph-agnostic entry point: an N-conv [`crate::runtime::ArchSpec`]
/// prices its comp share from `conv_flops_fwd_at(1024) * TRAIN_CONV_FACTOR`
/// without squeezing into the two-conv [`ArchShape`].
pub fn comp_share_for_train_flops(flops_train_b1024: f64) -> f64 {
    let x = flops_train_b1024.log10();
    let small = ArchShape::new(50, 500, 1024).conv_flops_train().log10();
    let large = ArchShape::new(500, 1500, 1024).conv_flops_train().log10();
    let t = ((x - small) / (large - small)).clamp(0.0, 1.0);
    0.25 + t * (0.13 - 0.25)
}

/// Effective master-link bandwidth used by default, in Mbps.
///
/// **Calibrated, documented deviation from the paper** (see EXPERIMENTS.md
/// §Deviations): the paper quotes ~5 Mbps Wi-Fi, but its own Eq. 2 volumes
/// at 5 Mbps give *hours* per 1024-image batch — two orders of magnitude
/// more than the comm shares it reports (19–30 % on the GPU cluster,
/// Fig. 8).  We keep Eq. 2 honest and instead calibrate the effective
/// bandwidth so the simulated 3-GPU comm share lands on the Fig. 8 anchor.
pub const EFFECTIVE_BANDWIDTH_MBPS: f64 = 675.0;

/// Simulator inputs beyond the device list.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub arch: ArchShape,
    /// Master's single link, bits per second.
    pub bandwidth_mbps: f64,
    /// Wire bytes per element (we ship f32 = 4; the paper shipped f64 = 8).
    pub bytes_per_elem: f64,
    /// Train (fwd+bwd, the paper's experiments) or forward only.
    pub training: bool,
    /// CPU GFLOPS of the master *machine* — comp always runs on a CPU even
    /// in the GPU cluster ("the computation of the remaining layers is
    /// performed on the CPU", §5.3.2).
    pub master_cpu_gflops: f64,
    /// Global throughput scale: set from a measured local probe to anchor
    /// absolute times to this container; 1.0 keeps the catalog's values.
    pub gflops_scale: f64,
}

impl SimConfig {
    pub fn paper(arch: ArchShape) -> Self {
        Self {
            arch,
            bandwidth_mbps: EFFECTIVE_BANDWIDTH_MBPS,
            bytes_per_elem: 4.0,
            training: true,
            master_cpu_gflops: 20.0, // PC1's CPU
            gflops_scale: 1.0,
        }
    }
}

/// Simulate one training step on `devices` (index 0 = master).  Returns the
/// paper's Comm/Conv/Comp breakdown.
pub fn simulate_step(cfg: &SimConfig, devices: &[DeviceProfile]) -> Breakdown {
    assert!(!devices.is_empty());
    let arch = &cfg.arch;
    let conv_factor = if cfg.training { ArchShape::TRAIN_CONV_FACTOR } else { 1.0 };

    // --- Conv: Eq. 1 integer partition, slowest shard wins -----------------
    // Probe time per device is inversely proportional to its GFLOPS.
    let probe_times: Vec<f64> =
        devices.iter().map(|d| 1.0 / (d.gflops * cfg.gflops_scale)).collect();
    let shares = workload_shares(&probe_times).expect("valid probe times");
    let mut conv = 0.0f64;
    let mut slave_share = 0.0f64;
    for l in 1..=2 {
        let k = arch.layer(l).3;
        let counts = apportion(k, &shares).expect("apportion");
        let fpk = arch.flops_per_kernel_fwd(l) * conv_factor;
        let t_layer = counts
            .iter()
            .zip(devices)
            .map(|(&n, d)| n as f64 * fpk / (d.gflops * cfg.gflops_scale * 1e9))
            .fold(0.0, f64::max);
        conv += t_layer;
        // Kernel-weighted share of work that leaves the master.
        slave_share += counts.iter().skip(1).sum::<usize>() as f64 / k as f64 / 2.0;
    }

    // --- Comm: Eq. 2 volume through the master's link ----------------------
    let n_slaves = devices.len() - 1;
    let mut elements = arch.eq2_upload_elements(n_slaves, slave_share);
    if cfg.training {
        elements += arch.bwd_upload_elements(n_slaves, slave_share);
    }
    let comm = if n_slaves == 0 {
        0.0
    } else {
        elements * cfg.bytes_per_elem * 8.0 / (cfg.bandwidth_mbps * 1e6)
    };

    // --- Comp: calibrated non-conv share, always on the master CPU ---------
    let share = comp_share(arch);
    let conv_1dev_cpu =
        arch.conv_flops_fwd() * conv_factor / (cfg.master_cpu_gflops * cfg.gflops_scale * 1e9);
    let comp = conv_1dev_cpu * share / (1.0 - share);

    Breakdown {
        comm: Duration::from_secs_f64(comm),
        conv: Duration::from_secs_f64(conv),
        comp: Duration::from_secs_f64(comp),
    }
}

/// Speedup of an `n`-device cluster over its own master alone — the paper's
/// definition ("speedup is obtained by comparing execution time against a
/// single device of the same type").
pub fn speedup(cfg: &SimConfig, devices: &[DeviceProfile]) -> f64 {
    let t1 = simulate_step(cfg, &devices[..1]).total().as_secs_f64();
    let tn = simulate_step(cfg, devices).total().as_secs_f64();
    t1 / tn
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{paper_cpus, paper_gpus};

    #[test]
    fn conv_flops_match_hand_count() {
        // Smallest net, batch 64, layer 1: 2*64*50*28^2*3*25.
        let a = ArchShape::new(50, 500, 64);
        let l1 = a.flops_per_kernel_fwd(1) * 50.0;
        assert!((l1 - 2.0 * 64.0 * 50.0 * 784.0 * 75.0).abs() < 1.0);
        assert!(a.conv_flops_train() > a.conv_flops_fwd());
    }

    #[test]
    fn comp_share_matches_paper_anchors() {
        assert!((comp_share(&ArchShape::new(50, 500, 1024)) - 0.25).abs() < 1e-9);
        assert!((comp_share(&ArchShape::new(500, 1500, 1024)) - 0.13).abs() < 1e-9);
        let mid = comp_share(&ArchShape::new(300, 1000, 1024));
        assert!((0.13..0.25).contains(&mid));
    }

    #[test]
    fn single_device_has_no_comm() {
        let cfg = SimConfig::paper(ArchShape::new(50, 500, 64));
        let b = simulate_step(&cfg, &paper_cpus()[..1]);
        assert_eq!(b.comm, Duration::ZERO);
        assert!(b.conv > Duration::ZERO);
        assert!(b.comp > Duration::ZERO);
    }

    #[test]
    fn speedup_above_one_for_paper_cpu_cluster() {
        // Fig. 5d headline: 4 CPUs on 500:1500 @ 1024 must land near 3.3x.
        let cfg = SimConfig::paper(ArchShape::new(500, 1500, 1024));
        let s = speedup(&cfg, &paper_cpus());
        assert!(s > 2.0 && s < 5.0, "4-CPU speedup {s}");
    }

    #[test]
    fn more_bandwidth_less_comm() {
        let arch = ArchShape::new(500, 1500, 1024);
        let mut cfg = SimConfig::paper(arch);
        cfg.bandwidth_mbps = 50.0;
        let slow = simulate_step(&cfg, &paper_cpus());
        cfg.bandwidth_mbps = 500.0;
        let fast = simulate_step(&cfg, &paper_cpus());
        assert!(fast.comm < slow.comm);
        assert_eq!(fast.conv, slow.conv);
    }

    #[test]
    fn gpu_cluster_speedup_smaller_than_cpu_on_large_net() {
        // Table 4 vs Table 5: on 500:1500 CPUs reach ~3.3x while GPUs only
        // ~2x — the GPU conv is so fast that comm+comp dominate.
        let arch = ArchShape::new(500, 1500, 1024);
        let mut cfg = SimConfig::paper(arch);
        let s_cpu = speedup(&cfg, &paper_cpus());
        cfg.master_cpu_gflops = 38.0; // PC2 hosts the GPU master
        let s_gpu = speedup(&cfg, &paper_gpus());
        assert!(s_gpu < s_cpu, "gpu {s_gpu} vs cpu {s_cpu}");
    }

    #[test]
    fn amdahl_bound_holds() {
        // §5.3.1: comp = 13% of 1-CPU time on the largest net limits the
        // speedup to ~7.76x no matter how many devices.
        let arch = ArchShape::new(500, 1500, 1024);
        let mut cfg = SimConfig::paper(arch);
        cfg.bandwidth_mbps = 1e6; // free comm
        let many: Vec<_> =
            (0..64).map(|_| crate::devices::paper_cpus()[0].clone()).collect();
        let s = speedup(&cfg, &many);
        assert!(s < 1.0 / 0.13 + 0.2, "speedup {s} violates Amdahl bound");
        assert!(s > 5.0, "64 free-comm devices should approach the bound, got {s}");
    }

    #[test]
    fn eq2_volume_grows_with_slaves_and_kernels() {
        let small = ArchShape::new(50, 500, 64);
        let large = ArchShape::new(500, 1500, 64);
        assert!(
            large.eq2_upload_elements(3, 0.75) > small.eq2_upload_elements(3, 0.75)
        );
        assert!(small.eq2_upload_elements(4, 0.8) > small.eq2_upload_elements(2, 0.8));
    }
}
