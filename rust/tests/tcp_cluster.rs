//! The real deployment shape: workers listening on TCP sockets, master
//! connecting over loopback — Algorithm 1 line 2 verbatim.  Numerics must
//! match the in-proc path (it is the same code over a different Link).
//! The master side composes through `SessionBuilder::tcp`.

mod common;

use std::net::TcpListener;

use convdist::cluster::{worker_loop, WorkerOptions};
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::net::{Link, LinkModel, ShapedLink, TcpLink};
use convdist::runtime::Runtime;
use convdist::session::SessionBuilder;

fn spawn_tcp_worker(
    id: u32,
    slowdown: f64,
) -> (std::net::SocketAddr, std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let rt = Runtime::open(convdist::artifacts_dir())?;
        let link = TcpLink::accept_one(&listener)?;
        worker_loop(link, rt, WorkerOptions::new(id, Throttle::new(slowdown)))
    });
    (addr, handle)
}

#[test]
fn tcp_cluster_trains_and_matches_inproc_losses() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(2);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 21);

    let (addr1, h1) = spawn_tcp_worker(1, 1.0);
    let (addr2, h2) = spawn_tcp_worker(2, 1.0);
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .tcp(vec![addr1.to_string(), addr2.to_string()])
        .build()
        .unwrap();

    // In-proc reference with identical seeds.
    let mut inproc = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::none(); 2])
        .build()
        .unwrap();

    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let a = dist.step(&batch).unwrap();
        let b = inproc.step(&batch).unwrap();
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * a.loss.abs().max(1.0),
            "step {step}: tcp {} vs inproc {}",
            a.loss,
            b.loss
        );
        assert!(a.bytes_moved > 0, "tcp cluster must move bytes");
    }
    let diff = dist.trainer().params.max_abs_diff(&inproc.trainer().params).unwrap();
    assert!(diff < 1e-4, "tcp vs inproc params: {diff}");

    dist.shutdown().unwrap();
    inproc.shutdown().unwrap();
    h1.join().unwrap().unwrap();
    h2.join().unwrap().unwrap();
}

#[test]
fn shaped_link_inflates_comm_share() {
    // With bandwidth shaping on, the measured Comm share of the step must
    // rise — the §5.4 observation that slow links erase the speedup.
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(1);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 22);
    let batch = ds.batch(arch.batch, 1).unwrap();

    // Unshaped.
    let mut t1 = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::none()])
        .build()
        .unwrap();
    let _ = t1.step(&batch).unwrap(); // compile warm-up
    let fast = t1.step(&batch).unwrap();

    // Shaped to ~200 Mbps: the ~14 MiB of per-step traffic costs ~0.6 s.
    let mut t2 = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::none()])
        .shaped(LinkModel::mbps(200.0))
        .build()
        .unwrap();
    let _ = t2.step(&batch).unwrap();
    let slow = t2.step(&batch).unwrap();

    assert!(
        slow.breakdown.comm > fast.breakdown.comm,
        "shaping must increase comm: {:?} vs {:?}",
        slow.breakdown.comm,
        fast.breakdown.comm
    );
    // Losses identical: shaping affects time, never numerics.
    assert!((slow.loss - fast.loss).abs() < 1e-5);

    t1.shutdown().unwrap();
    t2.shutdown().unwrap();
}

#[test]
fn shaped_tcp_roundtrip_bytes_accounted() {
    // ShapedLink over real TCP: bytes_moved on both ends agree with the
    // frame sizes (Eq. 2 accounting is exact, not sampled).
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = std::thread::spawn(move || {
        let mut link = TcpLink::accept_one(&listener).unwrap();
        let msg = link.recv().unwrap();
        link.send(&msg).unwrap();
        link.bytes_moved()
    });
    let mut master = ShapedLink::new(TcpLink::connect(addr).unwrap(), LinkModel::mbps(1000.0));
    let msg = convdist::proto::Message::Calibrate { rounds: 9 };
    master.send(&msg).unwrap();
    let echoed = master.recv().unwrap();
    assert_eq!(echoed, msg);
    let worker_bytes = h.join().unwrap();
    assert_eq!(master.bytes_moved(), worker_bytes);
    assert_eq!(master.bytes_moved() as usize, 2 * convdist::proto::frame_len(&msg));
}
