//! The replica-tier contract (DESIGN.md §14): hybrid data×model parallelism
//! over N fleets must train like one fleet on the same global batch, the
//! master-rooted and ring all-reduce strategies must be bit-identical, and
//! checkpoint/resume must broadcast the restored state to every replica.
//!
//! Determinism setup mirrors tests/session.rs: rayon pinned to one thread
//! (before any pool exists in this binary) so intra-op reduction splits
//! cannot vary, and virtual-time throttles so calibration probes — and
//! therefore Eq. 1 shard tables — are identical across runs.

use std::sync::Once;

use convdist::config::TrainerConfig;
use convdist::devices::Throttle;
use convdist::replica::AllReduce;
use convdist::runtime::ArchSpec;
use convdist::session::SessionBuilder;
use convdist::tensor::Tensor;

static SERIAL_RAYON: Once = Once::new();

/// Pin the global rayon pool to one thread (set before any rayon use in
/// this process, so the pool is built single-threaded).
fn serial_rayon() {
    SERIAL_RAYON.call_once(|| {
        std::env::set_var("RAYON_NUM_THREADS", "1");
    });
}

/// Virtual device speed: slow enough that virtual time dominates real
/// compute (deterministic probes), fast enough to stay in milliseconds.
fn vthrottle() -> Throttle {
    Throttle::virtual_gflops(0.2)
}

fn cfg(steps: usize) -> TrainerConfig {
    TrainerConfig {
        steps,
        lr: 0.05,
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 42,
        log_every: 100,
        calib_rounds: 1,
        checkpoint_every: None,
    }
}

/// A small-but-divisible geometry: 4+8 kernels over a global batch of 8,
/// so 2 replicas slice to 4 samples each and 3 replicas to [3, 3, 2].
fn arch() -> ArchSpec {
    ArchSpec::from_geometry(4, 8, 8)
}

/// One master + one worker per fleet, all virtual-time.
fn builder(steps: usize) -> SessionBuilder {
    SessionBuilder::new()
        .arch_spec(arch())
        .trainer(cfg(steps))
        .master_throttle(vthrottle())
        .workers(&[vthrottle()])
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("convdist-replica-{tag}-{}.ckpt", std::process::id()))
}

#[test]
fn two_replicas_match_a_single_fleet_on_the_same_global_batch() {
    serial_rayon();
    let steps = 4;
    let mut single = builder(steps).build().unwrap();
    let single_report = single.run().unwrap();
    let mut hybrid = builder(steps).replicas(2).build().unwrap();
    let hybrid_report = hybrid.run().unwrap();

    // Same batch sequence, gradients averaged slice-weighted: the loss
    // trajectory and final params agree up to float re-association.
    assert_eq!(single_report.losses.len(), hybrid_report.losses.len());
    for (i, (a, b)) in single_report.losses.iter().zip(&hybrid_report.losses).enumerate() {
        assert!((a - b).abs() < 1e-3, "step {i}: single loss {a} vs hybrid {b}");
    }
    let diff = single.trainer().params.max_abs_diff(&hybrid.trainer().params).unwrap();
    assert!(diff < 5e-3, "single vs hybrid params diverged: max |d| = {diff}");
    // Eval on the same held-out batch: at most two argmax flips of 8.
    let acc_gap = (single_report.eval_accuracy - hybrid_report.eval_accuracy).abs();
    assert!(acc_gap < 0.26, "eval accuracy gap {acc_gap}");

    // Every replica committed the same all-reduced update: bit-identical.
    let set = hybrid.replicas().expect("replica session");
    assert_eq!(set.count(), 2);
    assert_eq!(set.slices(), &[4, 4]);
    for r in 1..set.count() {
        let d = set.trainer(r).params.max_abs_diff(&hybrid.trainer().params).unwrap();
        assert_eq!(d, 0.0, "replica {r} params differ from replica 0");
    }
    assert!(hybrid.allreduce_bytes() > 0, "all-reduce moved no bytes");
    assert_eq!(single.allreduce_bytes(), 0, "single fleet has no fabric");

    single.shutdown().unwrap();
    hybrid.shutdown().unwrap();
}

#[test]
fn master_and_ring_allreduce_train_bit_identically() {
    serial_rayon();
    let steps = 3;
    let run = |strategy: AllReduce| -> (Vec<f32>, Vec<(String, Tensor)>, u64) {
        let mut s = builder(steps).replicas(3).allreduce(strategy).build().unwrap();
        assert_eq!(s.replicas().unwrap().strategy(), strategy);
        let report = s.run().unwrap();
        let params = s.trainer().params.to_named();
        let bytes = s.allreduce_bytes();
        s.shutdown().unwrap();
        (report.losses, params, bytes)
    };
    let (master_losses, master_params, master_bytes) = run(AllReduce::Master);
    let (ring_losses, ring_params, ring_bytes) = run(AllReduce::Ring);

    for (i, (a, b)) in master_losses.iter().zip(&ring_losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {i}: master loss {a} vs ring {b}");
    }
    for ((na, ta), (nb, tb)) in master_params.iter().zip(&ring_params) {
        assert_eq!(na, nb);
        assert!(
            ta.data().iter().zip(tb.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "param {na}: master and ring updates diverged"
        );
    }
    assert!(master_bytes > 0 && ring_bytes > 0);
    assert!(
        ring_bytes <= master_bytes,
        "ring moved {ring_bytes} bytes > master {master_bytes}"
    );
}

#[test]
fn resume_broadcasts_identical_params_to_every_replica() {
    serial_rayon();
    let path = ckpt_path("resume");
    let mut first = builder(2).replicas(2).build().unwrap();
    first.run().unwrap();
    first.save_checkpoint(&path).unwrap();
    first.shutdown().unwrap();

    let mut resumed = builder(2).replicas(2).resume_from(&path).build().unwrap();
    assert_eq!(resumed.trainer().steps_done(), 2);
    let set = resumed.replicas().expect("replica session");
    for r in 1..set.count() {
        let d = set.trainer(r).params.max_abs_diff(&resumed.trainer().params).unwrap();
        assert_eq!(d, 0.0, "replica {r} not bit-identical to replica 0 after resume");
        assert_eq!(set.trainer(r).steps_done(), 2, "replica {r} step counter not restored");
    }
    let report = resumed.run().unwrap();
    assert_eq!(report.first_step, 2);
    assert!(report.final_loss().is_finite());
    resumed.shutdown().unwrap();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn arch_mismatched_checkpoint_is_refused_citing_the_file() {
    serial_rayon();
    let path = ckpt_path("mismatch");
    let mut donor = builder(1).build().unwrap();
    donor.save_checkpoint(&path).unwrap();
    donor.shutdown().unwrap();

    // A different kernel geometry (8:16 vs 4:8) must be refused with an
    // error naming both the offending file and the arch mismatch.
    let err = SessionBuilder::new()
        .arch_spec(ArchSpec::from_geometry(8, 16, 8))
        .trainer(cfg(1))
        .master_throttle(vthrottle())
        .workers(&[vthrottle()])
        .replicas(2)
        .resume_from(&path)
        .build()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("checkpoint is for arch"), "unhelpful error: {msg}");
    assert!(
        msg.contains(&path.display().to_string()),
        "error does not cite the checkpoint file: {msg}"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn manual_rebalance_rebuilds_fleets_and_training_continues() {
    serial_rayon();
    let mut s = builder(2).replicas(2).build().unwrap();
    s.run().unwrap();
    let before = s.trainer().params.to_named();

    s.rebalance(&[5, 3]).unwrap();
    assert_eq!(s.replicas().unwrap().slices(), &[5, 3]);
    // The rebuild carries the trained state over bit-for-bit.
    for ((na, ta), (nb, tb)) in s.trainer().params.to_named().iter().zip(&before) {
        assert_eq!(na, nb);
        assert!(
            ta.data().iter().zip(tb.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "param {na} changed across the rebuild"
        );
    }
    let set = s.replicas().unwrap();
    for r in 1..set.count() {
        let d = set.trainer(r).params.max_abs_diff(&s.trainer().params).unwrap();
        assert_eq!(d, 0.0, "replica {r} diverged across the rebuild");
    }

    let report = s.run().unwrap();
    assert_eq!(report.steps_run, 2);
    assert!(report.final_loss().is_finite());

    // Degenerate share vectors are refused without killing the session.
    assert!(s.rebalance(&[8, 0]).is_err(), "zero slice must be refused");
    assert!(s.rebalance(&[4, 4, 4]).is_err(), "wrong count must be refused");
    assert!(s.rebalance(&[5, 5]).is_err(), "wrong sum must be refused");
    assert_eq!(s.replicas().unwrap().slices(), &[5, 3], "refusals must not change slices");
    s.shutdown().unwrap();

    // A single-fleet session has nothing to rebalance.
    let mut single = builder(1).build().unwrap();
    let err = single.rebalance(&[4, 4]).unwrap_err();
    assert!(format!("{err:#}").contains("replica session"), "{err:#}");
    single.shutdown().unwrap();
}
