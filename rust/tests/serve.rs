//! Tier-1 gate over `convdist serve` (DESIGN.md §13): the forward-only
//! distributed path must compute the *same* logits as the fused
//! single-device eval executable — bit for bit — whether driven directly,
//! through the dynamic batcher over TCP, or as a zero-padded partial batch.
//!
//! Bitwise equality holds because every parallel axis in the serving path
//! is per-image or per-output-channel: kernel shards split GEMM columns
//! (never the K reduction), batch padding adds rows that are sliced away,
//! and concat is exact.  CI additionally pins `RAYON_NUM_THREADS=1`.

mod common;

use std::sync::{Arc, Barrier};

use convdist::config::ServeConfig;
use convdist::devices::Throttle;
use convdist::model::Params;
use convdist::serve::ServeClient;
use convdist::session::{ArchSource, Checkpoint, SessionBuilder};
use convdist::tensor::{Pcg32, Tensor, Value};

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("convdist-serve-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Image `i` of a `[n, C, H, W]` stack as the `[C, H, W]` tensor a client sends.
fn image_row(images: &Tensor, i: usize) -> Tensor {
    let (c, h, w) = (images.shape()[1], images.shape()[2], images.shape()[3]);
    let n = c * h * w;
    Tensor::new(vec![c, h, w], images.data()[i * n..(i + 1) * n].to_vec()).unwrap()
}

fn assert_row_bitwise(got: &Tensor, want: &Tensor, row: usize, label: &str) {
    let ncls = want.shape()[1];
    assert_eq!(got.shape(), [ncls], "{label}: logits shape");
    let want_row = &want.data()[row * ncls..(row + 1) * ncls];
    for (i, (g, w)) in got.data().iter().zip(want_row).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{label}: logit {i}: {g} vs {w}");
    }
}

#[test]
fn serve_logits_match_eval_bit_for_bit_batched_and_padded() {
    // Train a few steps on the tiny preset and snapshot the weights.
    let cfg = common::fast_cfg(3);
    let dir = scratch_dir("equiv");
    let ckpt_path = dir.join("model.ckpt");
    let mut train = SessionBuilder::new()
        .arch(ArchSource::Preset("tiny".into()))
        .trainer(cfg.clone())
        .workers(&[Throttle::none(); 2])
        .build()
        .unwrap();
    train.run().unwrap();
    train.save_checkpoint(&ckpt_path).unwrap();
    let rt = train.runtime().clone();
    train.shutdown().unwrap();

    // Reference: the fused single-device eval path over the same weights.
    let arch = rt.arch().clone();
    let loaded = Checkpoint::load(&ckpt_path).unwrap();
    let params =
        convdist::serve::params_from_checkpoint(&arch, &loaded, "model.ckpt").unwrap();
    let mut rng = Pcg32::seed(123);
    let images = Tensor::randn(&[arch.batch, arch.in_ch, arch.img, arch.img], &mut rng);
    let mut args = vec![Value::F32(images.clone())];
    args.extend(params.in_order().into_iter().map(Value::F32));
    let want = rt
        .execute("eval_full", &args)
        .unwrap()
        .into_iter()
        .next()
        .unwrap()
        .as_f32()
        .unwrap()
        .clone();
    assert_eq!(want.shape(), [arch.batch, arch.num_classes]);

    // Forward-only distributed session over a 2-worker fleet, driven direct.
    let mut infer = SessionBuilder::new()
        .arch(ArchSource::Preset("tiny".into()))
        .trainer(cfg.clone())
        .workers(&[Throttle::none(); 2])
        .inference(&ckpt_path)
        .unwrap();
    let got = infer.forward(&images).unwrap();
    assert_eq!(got.shape(), want.shape());
    for (i, (g, w)) in got.data().iter().zip(want.data()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "direct forward logit {i}");
    }

    // The dynamic batcher over TCP: two concurrent single-image requests
    // (coalesced or not, the logits must match the eval rows)...
    let serving =
        infer.serve("127.0.0.1:0", ServeConfig { max_delay_ms: 50, max_batch: 2 }).unwrap();
    let addr = serving.addr().to_string();
    let barrier = Arc::new(Barrier::new(2));
    let handles: Vec<_> = (0..2)
        .map(|i| {
            let addr = addr.clone();
            let img = image_row(&images, i);
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).unwrap();
                barrier.wait();
                c.classify(&img).unwrap()
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_row_bitwise(&got, &want, i, "batched request");
    }
    // ...then a lone request exercises the zero-padded partial batch
    // (rung 2, one real row).
    let mut c = ServeClient::connect(&addr).unwrap();
    let got = c.classify(&image_row(&images, 1)).unwrap();
    assert_row_bitwise(&got, &want, 1, "padded request");

    // Graceful drain tears the whole stack down.
    c.drain().unwrap();
    let served = serving.join().unwrap();
    assert_eq!(served, 3, "three requests were answered");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_replies_errors_for_bad_requests_and_refuses_bad_checkpoints() {
    let arch = convdist::runtime::ArchSpec::preset("tiny").unwrap();
    let params = Params::init(&arch, 1).unwrap();
    let dir = scratch_dir("errors");
    let ckpt_path = dir.join("model.ckpt");
    Checkpoint {
        step: 0,
        arch_label: arch.label(),
        params: params.to_named(),
        velocity: vec![],
    }
    .save(&ckpt_path)
    .unwrap();

    // A checkpoint for a different architecture is refused up front, with
    // the file and both labels in the message.
    let other = dir.join("other.ckpt");
    Checkpoint {
        step: 0,
        arch_label: "someone-else".into(),
        params: params.to_named(),
        velocity: vec![],
    }
    .save(&other)
    .unwrap();
    let err = SessionBuilder::new()
        .arch(ArchSource::Preset("tiny".into()))
        .trainer(common::fast_cfg(1))
        .workers(&[Throttle::none(); 1])
        .inference(&other)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("someone-else") && msg.contains("other.ckpt"),
        "arch-mismatch error must name the file and labels: {msg}"
    );

    // A live server answers a malformed request with an error and keeps the
    // connection usable.
    let infer = SessionBuilder::new()
        .arch(ArchSource::Preset("tiny".into()))
        .trainer(common::fast_cfg(1))
        .workers(&[Throttle::none(); 1])
        .inference(&ckpt_path)
        .unwrap();
    let serving = infer.serve("127.0.0.1:0", ServeConfig::default()).unwrap();
    let mut c = ServeClient::connect(&serving.addr().to_string()).unwrap();
    let err = c.classify(&Tensor::zeros(&[1, 8, 8])).unwrap_err();
    assert!(
        format!("{err:#}").contains("does not match arch"),
        "shape-mismatch reply: {err:#}"
    );
    let mut rng = Pcg32::seed(5);
    let good = Tensor::randn(&[arch.in_ch, arch.img, arch.img], &mut rng);
    let logits = c.classify(&good).unwrap();
    assert_eq!(logits.shape(), [arch.num_classes]);
    c.drain().unwrap();
    assert_eq!(serving.join().unwrap(), 1);
    std::fs::remove_dir_all(&dir).ok();
}
