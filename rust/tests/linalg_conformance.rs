//! Randomized-shape conformance of the blocked `linalg` engine against the
//! naive `linalg::reference` oracle (the pre-engine kernels, preserved
//! verbatim).  Seeded PCG streams, like the other property suites, so any
//! failure is reproducible by seed.  The `_with_blocks` cases force tiny and
//! odd MC/KC/NC so every remainder-tile path (M, N and K not multiples of
//! the 8x8 microkernel, partial packed panels) is exercised hundreds of
//! times regardless of what the autotune picked on this host.

use convdist::linalg::{self, reference, Blocks};
use convdist::tensor::Pcg32;

const CASES: usize = 200;

fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian()).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn prop_gemm_matches_reference_on_random_shapes() {
    let mut rng = Pcg32::seed(2101);
    for case in 0..CASES {
        let m = 1 + rng.next_below(40) as usize;
        let kd = 1 + rng.next_below(96) as usize;
        let n = 1 + rng.next_below(64) as usize;
        let a = randn(&mut rng, m * kd);
        let b = randn(&mut rng, kd * n);
        // Accumulate into a non-zero out: the engine must add, not assign.
        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm(&a, &b, m, kd, n, &mut got);
        reference::gemm(&a, &b, m, kd, n, &mut want);
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-4, "case {case}: gemm {m}x{kd}x{n} diverged by {d}");
    }
}

#[test]
fn prop_gemm_abt_matches_reference_on_random_shapes() {
    let mut rng = Pcg32::seed(2102);
    for case in 0..CASES {
        let m = 1 + rng.next_below(40) as usize;
        let kd = 1 + rng.next_below(96) as usize;
        let n = 1 + rng.next_below(48) as usize;
        let a = randn(&mut rng, m * kd);
        let bt = randn(&mut rng, n * kd);
        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm_abt(&a, &bt, m, kd, n, &mut got);
        reference::gemm_abt(&a, &bt, m, kd, n, &mut want);
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-4, "case {case}: gemm_abt {m}x{kd}x{n} diverged by {d}");
    }
}

#[test]
fn prop_gemm_atb_matches_reference_on_random_shapes() {
    let mut rng = Pcg32::seed(2103);
    for case in 0..CASES {
        let rows = 1 + rng.next_below(64) as usize;
        let m = 1 + rng.next_below(48) as usize;
        let n = 1 + rng.next_below(48) as usize;
        let a = randn(&mut rng, rows * m);
        let b = randn(&mut rng, rows * n);
        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm_atb(&a, &b, rows, m, n, &mut got);
        reference::gemm_atb(&a, &b, rows, m, n, &mut want);
        let d = max_abs_diff(&got, &want);
        assert!(d <= 1e-4, "case {case}: gemm_atb {rows}x{m}x{n} diverged by {d}");
    }
}

/// Forced odd blocks through the explicit-blocks entry points: bypasses the
/// small-case fallback entirely, so even 1x1x1 problems run the full
/// pack/microkernel machinery with heavy remainder traffic.
#[test]
fn prop_remainder_tiles_under_odd_blocks_all_ops() {
    let mut rng = Pcg32::seed(2104);
    let blocksets = [
        Blocks { mc: 8, kc: 4, nc: 8 },
        Blocks { mc: 5, kc: 3, nc: 13 },
        Blocks { mc: 16, kc: 7, nc: 24 },
        Blocks { mc: 1, kc: 1, nc: 1 },
    ];
    for case in 0..CASES {
        let bl = blocksets[case % blocksets.len()];
        let m = 1 + rng.next_below(33) as usize;
        let kd = 1 + rng.next_below(40) as usize;
        let n = 1 + rng.next_below(33) as usize;
        let a = randn(&mut rng, m * kd);
        let b = randn(&mut rng, kd * n);
        let bt = randn(&mut rng, n * kd);
        let at = randn(&mut rng, kd * m);
        let bn = randn(&mut rng, kd * n);

        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm_with_blocks(&a, &b, m, kd, n, &mut got, bl);
        reference::gemm(&a, &b, m, kd, n, &mut want);
        assert!(
            max_abs_diff(&got, &want) <= 1e-4,
            "case {case}: gemm {m}x{kd}x{n} under {bl:?}"
        );

        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm_abt_with_blocks(&a, &bt, m, kd, n, &mut got, bl);
        reference::gemm_abt(&a, &bt, m, kd, n, &mut want);
        assert!(
            max_abs_diff(&got, &want) <= 1e-4,
            "case {case}: gemm_abt {m}x{kd}x{n} under {bl:?}"
        );

        let mut got = randn(&mut rng, m * n);
        let mut want = got.clone();
        linalg::gemm_atb_with_blocks(&at, &bn, kd, m, n, &mut got, bl);
        reference::gemm_atb(&at, &bn, kd, m, n, &mut want);
        assert!(
            max_abs_diff(&got, &want) <= 1e-4,
            "case {case}: gemm_atb {kd}x{m}x{n} under {bl:?}"
        );
    }
}

/// The microkernel boundary shapes, explicitly: every combination of
/// below/at/above MR/NR and a few K values, against the oracle.
#[test]
fn microkernel_boundary_shapes_are_exact() {
    let mut rng = Pcg32::seed(2105);
    let dims = [1usize, 7, 8, 9, 15, 16, 17];
    for &m in &dims {
        for &n in &dims {
            for &kd in &[1usize, 2, 8, 13] {
                let a = randn(&mut rng, m * kd);
                let b = randn(&mut rng, kd * n);
                let mut got = vec![0f32; m * n];
                let mut want = vec![0f32; m * n];
                linalg::gemm_with_blocks(
                    &a,
                    &b,
                    m,
                    kd,
                    n,
                    &mut got,
                    Blocks { mc: 8, kc: 8, nc: 8 },
                );
                reference::gemm(&a, &b, m, kd, n, &mut want);
                let d = max_abs_diff(&got, &want);
                assert!(d <= 1e-4, "boundary {m}x{kd}x{n} diverged by {d}");
            }
        }
    }
}
