//! The paper's central correctness claim, verified on the real stack:
//! distributing the convolutional layers "diminish[es] the training time
//! without affecting the classification performance" — i.e. the distributed
//! step computes the *same* update as single-device training.
//!
//! Clusters are composed through the session API (`SessionBuilder`); the
//! single-device references stay on the raw baseline trainers.

mod common;

use convdist::baselines::{DataParallelTrainer, SingleDeviceTrainer};
use convdist::data::{Dataset, SyntheticCifar};
use convdist::devices::Throttle;
use convdist::session::SessionBuilder;

#[test]
fn distributed_step_matches_single_device() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(4);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 7);

    // Reference: fused single-device trainer.
    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none()).unwrap();
    let mut single_losses = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let (loss, _) = single.step(&batch).unwrap();
        single_losses.push(loss);
    }

    // Distributed: master + 2 workers, same seed.
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::none(); 2])
        .build()
        .unwrap();
    let mut dist_losses = Vec::new();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let res = dist.step(&batch).unwrap();
        assert_eq!(res.devices, 3);
        dist_losses.push(res.loss);
    }

    // Same losses step for step (segmented vs fused float paths differ only
    // by reduction order).
    for (i, (a, b)) in single_losses.iter().zip(&dist_losses).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * a.abs().max(1.0),
            "step {i}: single {a} vs distributed {b}"
        );
    }
    // And the parameters themselves must agree.
    let diff = dist.trainer().params.max_abs_diff(&single.params).unwrap();
    assert!(diff < 5e-3, "param divergence after {} steps: {diff}", cfg.steps);

    dist.shutdown().unwrap();
}

#[test]
fn distributed_matches_with_heterogeneous_throttles() {
    // Unequal shards (Eq. 1 splits 1x/2x/4x devices) must not change the
    // numerics, only the partition.
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(2);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 9);

    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none()).unwrap();
    let mut dist = SessionBuilder::new()
        .trainer(cfg.clone())
        .workers(&[Throttle::new(2.0), Throttle::new(4.0)])
        .build()
        .unwrap();

    // The throttled workers must have received *smaller* shards.
    let shards = dist.trainer().shards(2);
    let master_shard = shards.iter().find(|s| s.device == 0).map(|s| s.len()).unwrap_or(0);
    let w2_shard = shards.iter().find(|s| s.device == 2).map(|s| s.len()).unwrap_or(0);
    assert!(
        master_shard > w2_shard,
        "Eq.1 must give the 4x-slower device fewer kernels: master {master_shard} vs w2 {w2_shard}"
    );

    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let (sl, _) = single.step(&batch).unwrap();
        let r = dist.step(&batch).unwrap();
        assert!((sl - r.loss).abs() < 1e-3 * sl.abs().max(1.0), "step {step}: {sl} vs {}", r.loss);
    }
    let diff = dist.trainer().params.max_abs_diff(&single.params).unwrap();
    assert!(diff < 5e-3, "param divergence: {diff}");
    dist.shutdown().unwrap();
}

#[test]
fn data_parallel_baseline_trains_and_differs_by_averaging_only() {
    let rt = common::runtime();
    let arch = rt.arch().clone();
    let cfg = common::fast_cfg(3);
    let mut ds = SyntheticCifar::new(arch.img, arch.in_ch, arch.num_classes, 11);

    let mut dp = DataParallelTrainer::new(rt.clone(), &cfg, vec![Throttle::none(); 2]).unwrap();
    let mut single = SingleDeviceTrainer::new(rt.clone(), &cfg, Throttle::none()).unwrap();
    for step in 0..cfg.steps {
        let batch = ds.batch(arch.batch, step).unwrap();
        let (dl, _) = dp.step(&batch).unwrap();
        let (sl, _) = single.step(&batch).unwrap();
        // Mean-of-shard-means == full-batch mean for equal shards, so the
        // loss and gradients agree up to float reassociation.
        assert!((dl - sl).abs() < 1e-3 * sl.abs().max(1.0), "step {step}: dp {dl} vs single {sl}");
    }
    let diff = dp.params.max_abs_diff(&single.params).unwrap();
    assert!(diff < 5e-3, "dp vs single param divergence: {diff}");
}

#[test]
fn training_reduces_loss_and_beats_chance_accuracy() {
    // The e2e learning signal at test scale: 15 steps of distributed
    // training on the synthetic task must cut the loss and beat 10-class
    // chance on a held-out batch — driven entirely by Session::run.
    let cfg = common::fast_cfg(15);
    let mut session = SessionBuilder::new()
        .trainer(cfg)
        .workers(&[Throttle::none(); 2])
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.steps_run, 15);
    let first = report.losses[0];
    let last = report.final_loss();
    assert!(last < first, "loss must fall: {first} -> {last}");
    assert!(
        report.eval_accuracy > 0.15,
        "accuracy {} should beat 10-class chance",
        report.eval_accuracy
    );
    session.shutdown().unwrap();
}
